//! Acceptance suite for the multi-tenant training service: per-tenant
//! trajectories are bitwise-identical to standalone runs at every
//! residency cap and under eviction, the central ledger hard-stops at
//! the budgeted step and never exceeds a declared budget, crash-resume
//! never double-commits epsilon, and checkpoints can never cross
//! tenant namespaces.

use dp_shortcuts::analysis::BudgetSpec;
use dp_shortcuts::coordinator::trainer::{config_fingerprint, resolve_sigma};
use dp_shortcuts::fault::{
    latest_valid, load_checkpoint, tenant_dir, write_checkpoint, CheckpointError,
};
use dp_shortcuts::privacy::AccountantKind;
use dp_shortcuts::serve::scheduler::TenantOutcome;
use dp_shortcuts::serve::{run_serve, BudgetLedger, ServeOptions, Tenant, TenantStatus};
use dp_shortcuts::{Runtime, TrainConfig, TrainReport, Trainer};
use proptest::prelude::*;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Unique scratch directory per call — tests run concurrently.
fn scratch(tag: &str) -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    std::env::temp_dir().join(format!(
        "dpshort_serve_test_{tag}_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ))
}

/// A mixed 3-tenant fleet: different models, clip variants, seeds,
/// accountants, and worker counts, each with a roomy budget.
fn three_tenants(rt: &Runtime, steps: u64) -> Vec<Tenant> {
    let default_model = rt.default_model().expect("manifest has models").to_string();
    let base = TrainConfig {
        model: default_model.clone(),
        dataset_size: 48,
        sampling_rate: 0.25,
        physical_batch: 8,
        steps,
        noise_multiplier: Some(1.0),
        eval_examples: 0,
        ..TrainConfig::default()
    };
    let configs = vec![
        TrainConfig { variant: "masked".into(), seed: 1, ..base.clone() },
        TrainConfig {
            model: "mlp-small".into(),
            variant: "ghost".into(),
            seed: 2,
            accountant: AccountantKind::Pld,
            ..base.clone()
        },
        TrainConfig { variant: "perex".into(), seed: 3, workers: 2, ..base },
    ];
    configs
        .into_iter()
        .enumerate()
        .map(|(i, config)| Tenant {
            name: format!("tenant-{i}"),
            budget: BudgetSpec { epsilon: 100.0, delta: config.delta },
            config,
        })
        .collect()
}

fn standalone_reports(rt: &Runtime, tenants: &[Tenant]) -> Vec<TrainReport> {
    tenants
        .iter()
        .map(|t| Trainer::new(rt, t.config.clone()).unwrap().run().unwrap())
        .collect()
}

/// Bitwise trajectory equality: final params, per-step losses and
/// sampled batches, and the session-priced epsilon.
fn assert_same_trajectory(outcome: &TenantOutcome, standalone: &TrainReport, ctx: &str) {
    let served = outcome.report.as_ref().unwrap_or_else(|| panic!("{ctx}: no report"));
    assert_eq!(served.final_params, standalone.final_params, "{ctx}: params diverged");
    assert_eq!(served.steps.len(), standalone.steps.len(), "{ctx}: step counts");
    for (a, b) in served.steps.iter().zip(&standalone.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{ctx}: loss bits at step {}", a.step);
        assert_eq!(a.logical_batch, b.logical_batch, "{ctx}: sampled batch at step {}", a.step);
    }
    assert_eq!(
        served.epsilon_spent.to_bits(),
        standalone.epsilon_spent.to_bits(),
        "{ctx}: epsilon diverged"
    );
}

#[test]
fn served_tenants_match_standalone_runs_at_every_concurrency() {
    let rt = Runtime::reference();
    let tenants = three_tenants(&rt, 5);
    let standalone = standalone_reports(&rt, &tenants);
    for max_concurrent in [1usize, 2, 3] {
        let root = scratch("parity");
        let opts = ServeOptions {
            max_concurrent,
            memory_budget_bytes: 0.0,
            steps_per_slice: 2,
            ckpt_root: root.clone(),
            max_slices: None,
        };
        let mut ledger = BudgetLedger::new();
        let report = run_serve(&rt, &tenants, &mut ledger, &opts).unwrap();
        assert!(!report.interrupted);
        assert_eq!(report.outcomes.len(), 3);
        for (outcome, solo) in report.outcomes.iter().zip(&standalone) {
            let ctx = format!("{} @ max_concurrent={max_concurrent}", outcome.name);
            assert_eq!(outcome.status, TenantStatus::Completed, "{ctx}");
            assert_eq!(outcome.steps_done, 5, "{ctx}");
            assert_same_trajectory(outcome, solo, &ctx);
            // The ledger's independent pricing agrees with the
            // session's accountant to float tolerance and never
            // exceeds the declared budget.
            assert!(
                (outcome.epsilon_committed - solo.epsilon_spent).abs()
                    <= 1e-6 * solo.epsilon_spent.max(1.0),
                "{ctx}: ledger {} vs session {}",
                outcome.epsilon_committed,
                solo.epsilon_spent
            );
            assert!(outcome.epsilon_committed <= outcome.budget_epsilon, "{ctx}");
        }
        // max_concurrent=1 cannot keep 3 tenants resident: evictions
        // must have happened (and changed nothing, per the asserts
        // above); full residency needs none.
        if max_concurrent == 1 {
            assert!(report.evictions > 0);
        }
        if max_concurrent == 3 {
            assert_eq!(report.evictions, 0);
        }
        let _ = std::fs::remove_dir_all(&root);
    }
}

#[test]
fn eviction_under_memory_pressure_is_bitwise_invisible() {
    let rt = Runtime::reference();
    let tenants = three_tenants(&rt, 4);
    let standalone = standalone_reports(&rt, &tenants);
    // Price the fleet and set a budget that fits only the largest
    // single resident — every tenant switch must evict.
    let max_bytes = tenants
        .iter()
        .map(|t| {
            let meta = rt.model(&t.config.model).unwrap();
            dp_shortcuts::serve::resident_bytes(t, meta.meta())
        })
        .fold(0.0f64, f64::max);
    let root = scratch("memory");
    let opts = ServeOptions {
        max_concurrent: 3,
        memory_budget_bytes: max_bytes * 1.5,
        steps_per_slice: 2,
        ckpt_root: root.clone(),
        max_slices: None,
    };
    let mut ledger = BudgetLedger::new();
    let report = run_serve(&rt, &tenants, &mut ledger, &opts).unwrap();
    assert!(report.evictions > 0, "memory budget {max_bytes:.0}B forced no evictions");
    for (outcome, solo) in report.outcomes.iter().zip(&standalone) {
        assert_same_trajectory(outcome, solo, &format!("{} under eviction", outcome.name));
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn budget_exhaustion_halts_exactly_at_the_affordable_step() {
    let rt = Runtime::reference();
    let mut tenants = three_tenants(&rt, 6);
    tenants.truncate(1);
    let t = &mut tenants[0];
    // No static declaration (admission would refuse the overspend);
    // the ledger's runtime backstop is what this test exercises: a
    // budget worth exactly 3 of the configured 6 steps.
    t.config.declared_epsilon = None;
    let sigma = resolve_sigma(&t.config).unwrap();
    let k_steps = 3u64;
    let affordable_eps = t.config.accountant.epsilon_after(
        t.config.sampling_rate,
        sigma,
        k_steps,
        t.config.delta,
    );
    t.budget = BudgetSpec { epsilon: affordable_eps, delta: t.config.delta };
    let root = scratch("budget");
    let opts = ServeOptions {
        max_concurrent: 1,
        memory_budget_bytes: 0.0,
        steps_per_slice: 2,
        ckpt_root: root.clone(),
        max_slices: None,
    };
    let mut ledger = BudgetLedger::new();
    let report = run_serve(&rt, &tenants, &mut ledger, &opts).unwrap();
    let outcome = &report.outcomes[0];
    assert_eq!(outcome.status, TenantStatus::BudgetExhausted);
    // Hard-stopped the step before the budget would be exceeded: step
    // 4 would overspend, so the tenant halts having committed exactly 3.
    assert_eq!(outcome.steps_done, k_steps);
    assert!(outcome.epsilon_committed <= affordable_eps * (1.0 + 1e-9));
    // The halt is durable: the final checkpoint carries step 3 and the
    // persisted ledger agrees.
    let fp = config_fingerprint(&tenants[0].config, sigma);
    let scan = latest_valid(&tenant_dir(&root, &tenants[0].name), &fp).unwrap();
    assert_eq!(scan.found.unwrap().1.step, k_steps);
    let persisted = BudgetLedger::load(&root).unwrap().unwrap();
    assert_eq!(persisted.committed_steps(&tenants[0].name), k_steps);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn crash_resume_never_double_commits_epsilon() {
    let rt = Runtime::reference();
    let tenants = three_tenants(&rt, 4);

    // Uninterrupted baseline.
    let baseline_root = scratch("crash_base");
    let opts = |root: PathBuf, max_slices: Option<u64>| ServeOptions {
        max_concurrent: 2,
        memory_budget_bytes: 0.0,
        steps_per_slice: 2,
        ckpt_root: root,
        max_slices,
    };
    let mut baseline_ledger = BudgetLedger::new();
    let baseline =
        run_serve(&rt, &tenants, &mut baseline_ledger, &opts(baseline_root.clone(), None))
            .unwrap();

    // Crash after 3 slices (mid-fleet), then resume from the persisted
    // ledger + checkpoints.
    let root = scratch("crash");
    let mut ledger = BudgetLedger::new();
    let first = run_serve(&rt, &tenants, &mut ledger, &opts(root.clone(), Some(3))).unwrap();
    assert!(first.interrupted);
    let committed_at_crash: Vec<(String, u64, f64)> = tenants
        .iter()
        .map(|t| (t.name.clone(), ledger.committed_steps(&t.name), ledger.epsilon(&t.name)))
        .collect();

    // The resume path the CLI takes: reload the snapshot from disk.
    let mut resumed_ledger = BudgetLedger::load(&root).unwrap().expect("persisted ledger");
    for (name, steps, eps) in &committed_at_crash {
        assert_eq!(resumed_ledger.committed_steps(name), *steps, "{name}: snapshot drifted");
        assert_eq!(resumed_ledger.epsilon(name).to_bits(), eps.to_bits(), "{name}");
    }
    let second =
        run_serve(&rt, &tenants, &mut resumed_ledger, &opts(root.clone(), None)).unwrap();
    assert!(!second.interrupted);

    // Epsilon is committed by step position, never re-added: the
    // resumed total equals the uninterrupted total exactly, and the
    // trajectories are bitwise-identical.
    for (outcome, base) in second.outcomes.iter().zip(&baseline.outcomes) {
        assert_eq!(outcome.status, TenantStatus::Completed);
        assert_eq!(outcome.steps_done, base.steps_done);
        assert_eq!(
            outcome.epsilon_committed.to_bits(),
            base.epsilon_committed.to_bits(),
            "{}: crash-resume double-committed epsilon",
            outcome.name
        );
        assert_eq!(
            outcome.report.as_ref().unwrap().final_params,
            base.report.as_ref().unwrap().final_params,
            "{}: crash-resume diverged",
            outcome.name
        );
    }

    // A second reconcile of the same checkpoints (re-running resume
    // with the final ledger) is a no-op on epsilon: commits are
    // idempotent by step.
    for t in &tenants {
        let before = resumed_ledger.epsilon(&t.name);
        let steps = resumed_ledger.committed_steps(&t.name);
        let after = resumed_ledger.commit_to(&t.name, steps).unwrap();
        assert_eq!(before.to_bits(), after.to_bits(), "{}", t.name);
    }
    let _ = std::fs::remove_dir_all(&baseline_root);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn checkpoints_cannot_cross_tenant_namespaces() {
    // Regression for the per-tenant checkpoint store: tenant A's
    // checkpoint must be invisible from B's namespace (path defense)
    // and must refuse to load as B even if handed over directly
    // (fingerprint defense).
    let rt = Runtime::reference();
    let tenants = three_tenants(&rt, 2);
    let (a, b) = (&tenants[0], &tenants[1]);
    let root = scratch("namespace");
    let dir_a = tenant_dir(&root, &a.name);
    let dir_b = tenant_dir(&root, &b.name);
    assert_ne!(dir_a, dir_b);

    let mut session = dp_shortcuts::TrainSession::new(&rt, a.config.clone()).unwrap();
    session.step().unwrap();
    let ckpt = session.checkpoint().unwrap();
    let path_a = write_checkpoint(&dir_a, &ckpt, None).unwrap();

    // Path defense: scanning B's namespace finds nothing.
    let fp_b = config_fingerprint(&b.config, resolve_sigma(&b.config).unwrap());
    let scan = latest_valid(&dir_b, &fp_b).unwrap();
    assert!(scan.found.is_none() && scan.skipped.is_empty());

    // Fingerprint defense: A's file handed to B's loader is a typed
    // rejection, not a silent cross-tenant resume.
    let err = load_checkpoint(&path_a, Some(&fp_b)).unwrap_err();
    assert!(matches!(err, CheckpointError::Fingerprint { .. }), "got {err:?}");

    // Hostile tenant names cannot escape the checkpoint root.
    let evil = tenant_dir(&root, "../../etc/passwd");
    assert!(evil.starts_with(&root), "{}", evil.display());
    let _ = std::fs::remove_dir_all(&root);
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    /// The ledger invariant the service stands on: whatever the
    /// budget, rate, noise, accountant, or commit schedule, committed
    /// epsilon never exceeds the declared budget (beyond float
    /// tolerance), and the hard-stop leaves no affordable step behind.
    #[test]
    fn ledger_never_exceeds_a_declared_budget(
        budget_epsilon in 1e-3f64..20.0,
        q in 0.05f64..0.9,
        sigma in 0.7f64..4.0,
        pld in proptest::bool::ANY,
        slice in 1u64..5,
        seed in proptest::num::u64::ANY,
    ) {
        let accountant = if pld { AccountantKind::Pld } else { AccountantKind::Rdp };
        let config = TrainConfig {
            sampling_rate: q,
            noise_multiplier: Some(sigma),
            steps: 64,
            accountant,
            seed,
            ..TrainConfig::default()
        };
        let tenant = Tenant {
            name: "prop".into(),
            budget: BudgetSpec { epsilon: budget_epsilon, delta: config.delta },
            config,
        };
        let mut ledger = BudgetLedger::new();
        ledger.register(&tenant, sigma).unwrap();
        // Drive the scheduler's commit protocol until the hard stop.
        let mut halted = false;
        for _ in 0..200 {
            let done = ledger.committed_steps("prop");
            let want = slice.min(tenant.config.steps - done);
            if want == 0 { break; }
            let afford = ledger.affordable_steps("prop", want);
            if afford == 0 { halted = true; break; }
            let eps = ledger.commit_to("prop", done + afford).unwrap();
            prop_assert!(eps <= budget_epsilon * (1.0 + 1e-9),
                "committed {eps} over budget {budget_epsilon}");
        }
        let spent = ledger.epsilon("prop");
        prop_assert!(spent <= budget_epsilon * (1.0 + 1e-9));
        if halted {
            // The stop is exact: one more step would overspend.
            let next = ledger.committed_steps("prop") + 1;
            let entry = ledger.entry("prop").unwrap();
            prop_assert!(entry.price(next) > budget_epsilon * (1.0 - 1e-9));
        }
    }
}
