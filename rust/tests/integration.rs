//! Integration tests over the real AOT artifacts (require `make
//! artifacts` to have run; they are skipped with a clear message
//! otherwise so `cargo test` works on a fresh checkout).

use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the pjrt feature — artifacts cannot execute");
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::load("artifacts").expect("loading artifacts"))
}

fn base_config() -> TrainConfig {
    TrainConfig {
        model: "vit-micro".into(),
        variant: "masked".into(),
        dataset_size: 128,
        sampling_rate: 0.25,
        physical_batch: 8,
        steps: 2,
        eval_examples: 0,
        ..Default::default()
    }
}

#[test]
fn manifest_models_have_complete_artifact_sets() {
    let Some(rt) = runtime() else { return };
    for (name, m) in &rt.manifest().models {
        assert!(m.find_apply().is_some(), "{name}: no apply");
        assert!(m.find_eval().is_some(), "{name}: no eval");
        assert!(!m.variants().is_empty(), "{name}: no accum variants");
        assert!(m.n_params > 0);
    }
}

#[test]
fn init_params_load_and_are_finite() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("vit-micro").unwrap();
    let p = m.init_params().unwrap();
    let v = p.to_vec();
    assert_eq!(v.len(), m.n_params());
    assert!(v.iter().all(|x| x.is_finite()));
    // initialization is not degenerate
    let nonzero = v.iter().filter(|x| **x != 0.0).count();
    assert!(nonzero > v.len() / 2);
}

#[test]
fn masked_training_runs_and_accounts() {
    let Some(rt) = runtime() else { return };
    let cfg = base_config();
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(rep.steps.len(), 2);
    assert!(rep.noise_multiplier > 0.0);
    assert!(rep.epsilon_spent > 0.0 && rep.epsilon_spent <= 8.0 + 1e-6);
    for s in &rep.steps {
        assert!(s.loss.is_finite() && s.loss > 0.0);
        // Algorithm 2: computed examples = ceil(|L|/p)*p >= |L|
        assert!(s.computed_examples >= s.logical_batch);
        assert_eq!(s.computed_examples % 8, 0);
    }
    assert!(rep.throughput > 0.0);
    assert!(rep.computed_throughput >= rep.throughput);
}

#[test]
fn masked_mode_compiles_exactly_one_accum_shape() {
    let Some(rt) = runtime() else { return };
    let rep = Trainer::new(&rt, base_config()).unwrap().run().unwrap();
    let accum_compiles = rep
        .compiles
        .iter()
        .filter(|(p, _)| p.contains("_accum"))
        .count();
    assert_eq!(accum_compiles, 1, "masked DP-SGD must never recompile: {:?}", rep.compiles);
}

#[test]
fn naive_mode_recompiles_per_batch_size() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_config();
    cfg.variant = "naive".into();
    cfg.mode = BatchingMode::Variable;
    cfg.dataset_size = 256;
    cfg.sampling_rate = 0.3;
    cfg.steps = 3;
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let accum_compiles = rep
        .compiles
        .iter()
        .filter(|(p, _)| p.contains("_accum"))
        .count();
    // Variable logical batches force several distinct chunk sizes.
    assert!(
        accum_compiles >= 2,
        "naive mode should hit multiple batch-size compilations: {:?}",
        rep.compiles
    );
}

#[test]
fn deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let r1 = Trainer::new(&rt, base_config()).unwrap().run().unwrap();
    let r2 = Trainer::new(&rt, base_config()).unwrap().run().unwrap();
    for (a, b) in r1.steps.iter().zip(&r2.steps) {
        assert_eq!(a.logical_batch, b.logical_batch);
        assert!((a.loss - b.loss).abs() < 1e-6, "{} vs {}", a.loss, b.loss);
    }
}

#[test]
fn different_seeds_differ() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_config();
    cfg.seed = 1;
    let r1 = Trainer::new(&rt, base_config()).unwrap().run().unwrap();
    let r2 = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(
        r1.steps[0].logical_batch != r2.steps[0].logical_batch
            || (r1.steps[0].loss - r2.steps[0].loss).abs() > 1e-9
    );
}

#[test]
fn nonprivate_baseline_runs_without_noise() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_config();
    cfg.variant = "nonprivate".into();
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(rep.noise_multiplier, 0.0);
    assert_eq!(rep.epsilon_spent, 0.0);
}

#[test]
fn ghost_and_bk_agree_with_masked_through_pjrt() {
    // The L2-level equivalence re-checked through the whole AOT+PJRT
    // path: same logical batches => same losses (clipped grads agree).
    let Some(rt) = runtime() else { return };
    let mut losses = Vec::new();
    for variant in ["masked", "ghost", "bk"] {
        let mut cfg = base_config();
        cfg.variant = variant.into();
        cfg.noise_multiplier = Some(0.0); // isolate the clipping path
        let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
        losses.push(rep.steps.iter().map(|s| s.loss).collect::<Vec<_>>());
    }
    for other in &losses[1..] {
        for (a, b) in losses[0].iter().zip(other) {
            assert!((a - b).abs() / a < 1e-3, "{a} vs {b}");
        }
    }
}

#[test]
fn resnet_masked_runs() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_config();
    cfg.model = "rn-micro".into();
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(rep.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn eval_after_training_returns_metrics() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_config();
    cfg.eval_examples = 64;
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let (l, a) = (rep.eval_loss.unwrap(), rep.eval_accuracy.unwrap());
    assert!(l > 0.0 && l.is_finite());
    assert!((0.0..=1.0).contains(&a));
}

#[test]
fn bf16_artifacts_execute() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("vit-micro").unwrap();
    let batches = m.accum_batches("masked", "bf16");
    if batches.is_empty() {
        eprintln!("SKIP: no bf16 artifacts lowered");
        return;
    }
    let mut cfg = base_config();
    cfg.bf16 = true;
    cfg.physical_batch = *batches.last().unwrap();
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(rep.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn ghost_hlo_never_materializes_per_example_grads() {
    // The paper's Section 2.2 memory claim, checked STRUCTURALLY on the
    // real lowered artifacts: per-example variants contain a [B, P]
    // tensor; ghost and BK variants must not.
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest().model("vit-micro").unwrap().clone();
    let p = meta.n_params as u64;
    let b = 16u64;
    let dir = std::path::Path::new("artifacts");
    let stats_of = |variant: &str| {
        let e = meta.find_accum(variant, b as usize, "f32").unwrap();
        dp_shortcuts::runtime::analyze_file(&dir.join(&e.path)).unwrap()
    };
    assert!(
        stats_of("masked").has_tensor(&[b, p]),
        "per-example variant should materialize [B, P]"
    );
    for v in ["ghost", "bk"] {
        assert!(
            !stats_of(v).has_tensor(&[b, p]),
            "{v} must not materialize per-example grads"
        );
    }
    // Non-private never needs it either.
    assert!(!stats_of("nonprivate").has_tensor(&[b, p]));
}

#[test]
fn hlo_footprint_ordering_matches_memory_model() {
    // Largest-tensor ordering across variants mirrors the Table 3
    // max-batch ordering: per-example > ghost/bk/non-private.
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest().model("vit-micro").unwrap().clone();
    let dir = std::path::Path::new("artifacts");
    let largest = |variant: &str| {
        let e = meta.find_accum(variant, 16, "f32").unwrap();
        dp_shortcuts::runtime::analyze_file(&dir.join(&e.path))
            .unwrap()
            .largest_tensor_bytes
    };
    let pe = largest("masked");
    let gh = largest("ghost");
    let np = largest("nonprivate");
    assert!(pe > gh, "per-example {pe} should exceed ghost {gh}");
    assert!(pe > np, "per-example {pe} should exceed non-private {np}");
}

#[test]
fn checkpoint_roundtrip() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("vit-micro").unwrap();
    let p = m.init_params().unwrap();
    let path = std::env::temp_dir().join("dpshort_ckpt_test.bin");
    m.save_params(&p, &path).unwrap();
    let p2 = m.load_params(&path).unwrap();
    assert_eq!(p.to_vec(), p2.to_vec());
    // wrong-size file is rejected cleanly
    std::fs::write(&path, [0u8; 12]).unwrap();
    assert!(m.load_params(&path).is_err());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn missing_batch_size_is_a_clean_error() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("vit-micro").unwrap();
    let msg = match m.prepare_accum("masked", 12_345, "f32") {
        Ok(_) => panic!("expected error for unlowered batch size"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("no accum artifact"), "{msg}");
}
