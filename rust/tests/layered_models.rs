//! Layer-IR acceptance gates (DESIGN.md §9):
//!
//! 1. **Seed-kernel oracle** — the one-dense-layer IR model must
//!    reproduce the seed's hardcoded linear+softmax kernel **bitwise**
//!    (accumulator, loss, per-example norms) across every variant,
//!    batch size, mask pattern, and data — the oracle below is a
//!    direct port of the pre-IR kernel, so `ref-linear` trajectories
//!    are pinned to the seed's.
//! 2. **Ghost vs per-example** — the fused ghost-norm path and the
//!    materializing per-example path (different accumulate code) must
//!    agree bitwise on per-example norms *and* accumulators for every
//!    generated layer stack: layer counts, widths, batch sizes
//!    (including 1), and masks (including all-masked). The `mix`
//!    variant — the executed Bu et al. decision rule — must land on
//!    the same bits too.
//! 3. **Backward correctness** — the multi-layer backward pass is
//!    checked against central-difference gradients of an independent
//!    f64 forward implementation.
//! 4. **Clip-method trajectory invariance + the acceptance run** —
//!    training `mlp-small` under any executed clipping method is
//!    bitwise-identical, and `--model mlp-small --clip-method ghost
//!    --workers 2` style runs finish end-to-end with the same bits as
//!    one worker.

use dp_shortcuts::clipping::clip_method_variant;
use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::models::{Activation, LayerSpec};
use dp_shortcuts::runtime::{
    AccumArgs, Backend, ExecutableMeta, ModelMeta, ReferenceBackend, Runtime, Tensor,
    REFERENCE_MODEL,
};
use dp_shortcuts::util::rng::ChaChaRng;
use proptest::prelude::*;
use std::path::Path;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Deterministic batch (x, y) for a model from a seed.
fn synth_batch(meta: &ModelMeta, batch: usize, data_seed: u64) -> (Vec<f32>, Vec<i32>) {
    let d = meta.image * meta.image * meta.channels;
    let mut rng = ChaChaRng::from_seed_stream(data_seed, 0, b"irstack\0");
    let x: Vec<f32> = (0..batch * d).map(|_| rng.next_normal() as f32).collect();
    let y: Vec<i32> = (0..batch)
        .map(|_| (rng.next_u32() % meta.num_classes as u32) as i32)
        .collect();
    (x, y)
}

/// A custom layered ModelMeta for a generated stack (executables are
/// synthesized on demand — `prepare` decodes specs, it never consults
/// `meta.executables`).
fn stack_meta(image: usize, channels: usize, hidden: &[usize], ncls: usize) -> ModelMeta {
    let d = image * image * channels;
    let mut layers = Vec::new();
    let mut cur = d;
    for &w in hidden {
        layers.push(LayerSpec::dense_relu(cur, w));
        cur = w;
    }
    layers.push(LayerSpec::dense(cur, ncls));
    ModelMeta {
        family: "stack".into(),
        n_params: layers.iter().map(LayerSpec::params).sum(),
        image,
        channels,
        num_classes: ncls,
        clip_norm: 1.0,
        flops_fwd_per_example: 1.0,
        init_params: "stack_init.synthetic".into(),
        executables: Vec::new(),
        layers,
    }
}

fn accum_exe(tag: &str, variant: &str, batch: usize) -> ExecutableMeta {
    ExecutableMeta {
        path: format!("{tag}_accum_{variant}_b{batch}.ref"),
        kind: "accum".into(),
        variant: Some(variant.into()),
        batch: Some(batch),
        dtype: None,
    }
}

// ---------------------------------------------------------------------
// 1. The seed-kernel oracle: a direct port of the pre-IR hardcoded
//    linear+softmax accum kernel (8-lane dot, closed-form norm,
//    sequential example-order accumulate). The layered executor run on
//    the one-dense-layer `ref-linear` must match it bit for bit.
// ---------------------------------------------------------------------

/// The seed's 8-lane unrolled dot with its fixed reduction tree.
fn seed_dot(a: &[f32], b: &[f32]) -> f32 {
    let n8 = a.len() - a.len() % 8;
    let (a8, at) = a.split_at(n8);
    let (b8, bt) = b.split_at(n8);
    let mut lanes = [0.0f32; 8];
    for (ac, bc) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for j in 0..8 {
            lanes[j] += ac[j] * bc[j];
        }
    }
    let mut tail = 0.0f32;
    for (av, bv) in at.iter().zip(bt) {
        tail += av * bv;
    }
    (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
        + tail
}

/// Operands of one oracle call (typed struct, like the real ABI).
#[derive(Clone, Copy)]
struct SeedCall<'a> {
    d: usize,
    ncls: usize,
    clip_norm: f32,
    nonprivate: bool,
    params: &'a [f32],
    x: &'a [f32],
    y: &'a [i32],
    mask: &'a [f32],
}

/// Seed accum kernel: flat params `[W row-major | b]`, per-example
/// dlogits + closed-form norm `||dl||^2 (||x||^2 + 1)`, masked
/// clip-and-accumulate in example order. Mutates `acc`; returns
/// `(loss_sum, sq_norms)`.
fn seed_accum(call: &SeedCall<'_>, acc: &mut [f32]) -> (f32, Vec<f32>) {
    let SeedCall { d, ncls, clip_norm, nonprivate, params, x, y, mask } = *call;
    let b = y.len();
    let (w, rest) = params.split_at(ncls * d);
    let bias = &rest[..ncls];
    let mut dlogits = vec![0.0f32; b * ncls];
    let mut scale = vec![0.0f32; b];
    let mut losses = vec![0.0f32; b];
    let mut sq_norms = vec![0.0f32; b];
    for i in 0..b {
        let xi = &x[i * d..(i + 1) * d];
        let dl = &mut dlogits[i * ncls..(i + 1) * ncls];
        for (cls, slot) in dl.iter_mut().enumerate() {
            *slot = seed_dot(&w[cls * d..(cls + 1) * d], xi) + bias[cls];
        }
        let yi = y[i] as usize;
        let ly = dl[yi];
        let max = dl.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in dl.iter_mut() {
            *v = (*v - max).exp();
            z += *v;
        }
        losses[i] = max + z.ln() - ly;
        for v in dl.iter_mut() {
            *v /= z;
        }
        dl[yi] -= 1.0;
        if nonprivate {
            sq_norms[i] = 0.0;
            scale[i] = mask[i];
        } else {
            let xsq = seed_dot(xi, xi);
            let dlsq = seed_dot(dl, dl);
            let sq = dlsq * (xsq + 1.0);
            sq_norms[i] = sq;
            let norm = sq.max(0.0).sqrt().max(1e-12);
            scale[i] = (clip_norm / norm).min(1.0) * mask[i];
        }
    }
    let mut loss_sum = 0.0f32;
    for (&ls, &m) in losses.iter().zip(mask) {
        loss_sum += m * ls;
    }
    let (w_acc, rest) = acc.split_at_mut(ncls * d);
    let bias_acc = &mut rest[..ncls];
    for i in 0..b {
        let sc = scale[i];
        if sc == 0.0 {
            continue;
        }
        let xi = &x[i * d..(i + 1) * d];
        let dl = &dlogits[i * ncls..(i + 1) * ncls];
        for r in 0..ncls {
            let g = sc * dl[r];
            for (a, &xv) in w_acc[r * d..(r + 1) * d].iter_mut().zip(xi) {
                *a += g * xv;
            }
            bias_acc[r] += g;
        }
    }
    (loss_sum, sq_norms)
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(24))]

    /// The layered executor on the one-dense-layer `ref-linear` IR is
    /// bitwise-identical to the seed's hardcoded kernel, for EVERY
    /// lowered variant (they all agree with each other too), across
    /// batch sizes, masks (including all-masked), and data — the pin
    /// that makes the layer refactor trajectory-preserving.
    #[test]
    fn one_layer_ir_matches_the_seed_kernel_bitwise(
        variant_idx in 0usize..6,
        batch_idx in 0usize..4,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
    ) {
        let variant = ["nonprivate", "masked", "ghost", "bk", "perex", "mix"][variant_idx];
        let batch = [1usize, 2, 8, 16][batch_idx];
        let backend = ReferenceBackend::new(0);
        let meta = ReferenceBackend::manifest(0).models[REFERENCE_MODEL].clone();
        let d = meta.image * meta.image * meta.channels;
        let ncls = meta.num_classes;
        let exe = meta.find_accum(variant, batch, "f32").unwrap().clone();
        let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let acc0 = Tensor::zeros(meta.n_params);
        let out = backend
            .run_accum(&prep, &meta, &params, &acc0, &AccumArgs { x: &x, y: &y, mask: &mask })
            .unwrap();

        let mut oracle_acc = vec![0.0f32; meta.n_params];
        let call = SeedCall {
            d,
            ncls,
            clip_norm: meta.clip_norm as f32,
            nonprivate: variant == "nonprivate",
            params: params.as_slice(),
            x: &x,
            y: &y,
            mask: &mask,
        };
        let (oracle_loss, oracle_norms) = seed_accum(&call, &mut oracle_acc);
        prop_assert_eq!(bits(out.acc.as_slice()), bits(&oracle_acc), "variant {}", variant);
        prop_assert_eq!(out.loss_sum.to_bits(), oracle_loss.to_bits());
        prop_assert_eq!(bits(&out.sq_norms), bits(&oracle_norms));
    }
}

// ---------------------------------------------------------------------
// 2. Ghost vs per-example vs mix over generated layer stacks.
// ---------------------------------------------------------------------

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    /// The executed ghost path (fused, Gram-product norms, no
    /// materialized per-example weight grads) and the executed
    /// per-example path (materializing accumulate) agree **bitwise**
    /// on per-example norms and on the accumulator, for every
    /// generated stack: 0–2 hidden ReLU layers of widths 1..6, batch
    /// sizes including 1, masks including all-masked and all-ones.
    /// The mix variant (per-layer decision rule) matches too.
    #[test]
    fn ghost_and_per_example_agree_on_every_layer_stack(
        image in 1usize..=2,
        channels in 1usize..=3,
        hidden in proptest::collection::vec(1usize..=6, 0..=2),
        ncls in 2usize..=5,
        batch_idx in 0usize..5,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
    ) {
        let batch = [1usize, 2, 3, 5, 8][batch_idx];
        let meta = stack_meta(image, channels, &hidden, ncls);
        let backend = ReferenceBackend::new(3);
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let acc0 = Tensor::zeros(meta.n_params);
        let args = AccumArgs { x: &x, y: &y, mask: &mask };
        let tag = format!("stack_i{image}c{channels}h{hidden:?}n{ncls}");

        let mut outs = Vec::new();
        for variant in ["ghost", "perex", "mix"] {
            let exe = accum_exe(&tag, variant, batch);
            let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
            outs.push(backend.run_accum(&prep, &meta, &params, &acc0, &args).unwrap());
        }
        let ghost = &outs[0];
        for (variant, o) in ["perex", "mix"].iter().zip(&outs[1..]) {
            prop_assert_eq!(
                bits(&ghost.sq_norms),
                bits(&o.sq_norms),
                "{}: per-example norms diverged from ghost on stack {}",
                variant,
                &tag
            );
            prop_assert_eq!(
                bits(ghost.acc.as_slice()),
                bits(o.acc.as_slice()),
                "{}: accumulator diverged from ghost on stack {}",
                variant,
                &tag
            );
            prop_assert_eq!(ghost.loss_sum.to_bits(), o.loss_sum.to_bits());
        }
        // All-masked batches leave the accumulator untouched on every
        // path; norms are still reported per slot.
        if mask.iter().all(|m| *m == 0.0) {
            prop_assert_eq!(bits(ghost.acc.as_slice()), bits(acc0.as_slice()));
        }
        prop_assert_eq!(ghost.sq_norms.len(), batch);
        // Norms are the sum over layers of Gram products: finite and
        // non-negative by construction.
        prop_assert!(ghost.sq_norms.iter().all(|s| s.is_finite() && *s >= 0.0));
    }
}

// ---------------------------------------------------------------------
// 3. Backward correctness: central differences of an independent f64
//    forward.
// ---------------------------------------------------------------------

/// Independent f64 forward over one batch, from the same flat-param
/// layout: returns the summed softmax-xent loss and the smallest
/// hidden |pre-activation| (the gradient check's ReLU-kink guard —
/// `inf` for stacks without hidden layers). One implementation serves
/// both so the kink guard can never drift from the differenced loss.
fn f64_forward(meta: &ModelMeta, params: &[f64], x: &[f32], y: &[i32]) -> (f64, f64) {
    let d = meta.image * meta.image * meta.channels;
    let specs = meta.layer_specs();
    let mut loss = 0.0f64;
    let mut min_preact = f64::INFINITY;
    for (i, &yi) in y.iter().enumerate() {
        let mut a: Vec<f64> = x[i * d..(i + 1) * d].iter().map(|v| *v as f64).collect();
        let mut off = 0usize;
        for (l, spec) in specs.iter().enumerate() {
            let (w, bias) = (
                &params[off..off + spec.d_in * spec.d_out],
                &params[off + spec.d_in * spec.d_out..off + spec.params()],
            );
            off += spec.params();
            let mut z = vec![0.0f64; spec.d_out];
            for (r, zr) in z.iter_mut().enumerate() {
                let mut s = bias[r];
                for (j, &av) in a.iter().enumerate() {
                    s += w[r * spec.d_in + j] * av;
                }
                *zr = s;
            }
            if l + 1 < specs.len() {
                for v in &z {
                    min_preact = min_preact.min(v.abs());
                }
                if spec.activation == Activation::Relu {
                    for v in z.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            a = z;
        }
        let max = a.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = max + a.iter().map(|v| (v - max).exp()).sum::<f64>().ln();
        loss += lse - a[yi as usize];
    }
    (loss, min_preact)
}

#[test]
fn multi_layer_backward_matches_finite_differences() {
    // dense_relu(4, 5) -> dense_relu(5, 4) -> dense(4, 3): small
    // enough to difference every coordinate. The nonprivate variant
    // reports the *unclipped* summed gradient, i.e. exactly
    // d(sum loss)/d(theta).
    let meta = stack_meta(2, 1, &[5, 4], 3);
    let backend = ReferenceBackend::new(0);
    let params = backend.init_params(Path::new("."), &meta).unwrap();
    let p64: Vec<f64> = params.as_slice().iter().map(|v| *v as f64).collect();

    // Pick the first data seed whose batch keeps every hidden
    // pre-activation away from the ReLU kink (h below), so central
    // differences are valid; deterministic, and in practice the first
    // seed qualifies.
    let h = 1e-4f64;
    let batch = 3;
    let (x, y) = (0u64..)
        .map(|s| synth_batch(&meta, batch, s))
        .find(|(x, y)| f64_forward(&meta, &p64, x, y).1 > 100.0 * h)
        .unwrap();

    let exe = accum_exe("gradcheck", "nonprivate", batch);
    let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
    let acc0 = Tensor::zeros(meta.n_params);
    let out = backend
        .run_accum(
            &prep,
            &meta,
            &params,
            &acc0,
            &AccumArgs { x: &x, y: &y, mask: &[1.0; 3] },
        )
        .unwrap();
    let analytic = out.acc.as_slice();

    for j in 0..meta.n_params {
        let mut plus = p64.clone();
        plus[j] += h;
        let mut minus = p64.clone();
        minus[j] -= h;
        let up = f64_forward(&meta, &plus, &x, &y).0;
        let down = f64_forward(&meta, &minus, &x, &y).0;
        let numeric = (up - down) / (2.0 * h);
        let got = analytic[j] as f64;
        let tol = 1e-3 + 2e-2 * numeric.abs().max(got.abs());
        assert!(
            (numeric - got).abs() <= tol,
            "param {j}: analytic {got} vs numeric {numeric} (tol {tol})"
        );
    }
}

// ---------------------------------------------------------------------
// 4. Trajectory invariance across clip methods + the acceptance run.
// ---------------------------------------------------------------------

fn mlp_config(variant: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp-small".into(),
        variant: variant.into(),
        mode: BatchingMode::Masked,
        dataset_size: 48,
        sampling_rate: 0.3,
        physical_batch: 4,
        steps: 3,
        lr: 0.05,
        noise_multiplier: Some(1.1),
        eval_examples: 32,
        workers,
        ..Default::default()
    }
}

#[test]
fn every_clip_method_trains_the_same_trajectory() {
    // The branch choice (fused ghost vs materializing per-example vs
    // the per-layer mix rule) moves memory traffic only: the whole
    // training trajectory — params, losses, epsilon — is
    // bitwise-identical across methods on the multi-layer model.
    let mut reference: Option<dp_shortcuts::TrainReport> = None;
    for method in ["per-example", "ghost", "mix", "bk"] {
        let variant = clip_method_variant(method).unwrap();
        let rt = Runtime::reference();
        let rep = Trainer::new(&rt, mlp_config(variant, 1)).unwrap().run().unwrap();
        if let Some(want) = &reference {
            assert_eq!(
                bits(&rep.final_params),
                bits(&want.final_params),
                "{method} diverged"
            );
            assert_eq!(rep.epsilon_spent.to_bits(), want.epsilon_spent.to_bits());
            for (a, b) in rep.steps.iter().zip(&want.steps) {
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{method}");
            }
        } else {
            reference = Some(rep);
        }
    }
}

#[test]
fn mlp_small_ghost_two_workers_runs_end_to_end() {
    // The acceptance command: `dpshort train --model mlp-small
    // --clip-method ghost --workers 2` — here through the same config
    // the CLI builds, checked bitwise against the 1-worker run.
    let variant = clip_method_variant("ghost").unwrap();
    let solo = {
        let rt = Runtime::reference();
        Trainer::new(&rt, mlp_config(variant, 1)).unwrap().run().unwrap()
    };
    let rt = Runtime::reference();
    let rep = Trainer::new(&rt, mlp_config(variant, 2)).unwrap().run().unwrap();
    assert_eq!(rep.steps.len(), 3);
    assert!(rep.steps.iter().all(|s| s.loss.is_finite()));
    assert!(rep.epsilon_spent > 0.0, "RDP accounting ran");
    assert!(rep.eval_loss.unwrap().is_finite());
    assert_eq!(
        bits(&rep.final_params),
        bits(&solo.final_params),
        "2-worker mlp-small run diverged from 1 worker"
    );
}

#[test]
fn mlp_small_actually_learns() {
    // Non-private SGD on the multi-layer model must drive the loss
    // down — the ReLU backward is doing real work, not just passing
    // the bitwise gates.
    let rt = Runtime::reference();
    let cfg = TrainConfig {
        model: "mlp-small".into(),
        variant: "nonprivate".into(),
        mode: BatchingMode::Masked,
        dataset_size: 96,
        sampling_rate: 0.5,
        physical_batch: 8,
        steps: 12,
        lr: 0.5,
        noise_multiplier: None,
        eval_examples: 0,
        ..Default::default()
    };
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let first = rep.steps.first().unwrap().loss;
    let last = rep.steps.last().unwrap().loss;
    assert!(last < first, "mlp-small loss did not decrease: {first} -> {last}");
}
