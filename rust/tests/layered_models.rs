//! Layer-IR acceptance gates (DESIGN.md §9):
//!
//! 1. **Seed-kernel oracle** — the one-dense-layer IR model must
//!    reproduce the seed's hardcoded linear+softmax kernel **bitwise**
//!    (accumulator, loss, per-example norms) across every variant,
//!    batch size, mask pattern, and data — the oracle below is a
//!    direct port of the pre-IR kernel, so `ref-linear` trajectories
//!    are pinned to the seed's.
//! 2. **Ghost vs per-example** — the fused ghost-norm path and the
//!    materializing per-example path (different accumulate code) must
//!    agree bitwise on per-example norms *and* accumulators for every
//!    generated layer stack: layer counts, widths, batch sizes
//!    (including 1), and masks (including all-masked). The `mix`
//!    variant — the executed Bu et al. decision rule — must land on
//!    the same bits too.
//! 3. **Backward correctness** — the multi-layer backward pass is
//!    checked against central-difference gradients of an independent
//!    f64 forward implementation, for every layer kind: dense chains,
//!    conv2d (including stride > 1 with padding), layernorm, and
//!    single-head attention (DESIGN.md §13).
//! 4. **Clip-method trajectory invariance + the acceptance run** —
//!    training `mlp-small` under any executed clipping method is
//!    bitwise-identical, and `--model mlp-small --clip-method ghost
//!    --workers 2` style runs finish end-to-end with the same bits as
//!    one worker.
//! 5. **Analytic cost cross-check** — the IR's MAC counts and the
//!    clipping time model agree with the closed-form counts of
//!    `python/compile/vit.py` / `resnet.py`.

use dp_shortcuts::clipping::{
    clip_method_variant, mix_ghost_choice, ClippingMethod, LayerChoice, TimeModel,
};
use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::models::{
    bit_resnet, conv_out, vit, Activation, LayerKind, LayerSpec, LinearDims,
};
use dp_shortcuts::runtime::{
    AccumArgs, Backend, ExecutableMeta, LayerPlan, ModelMeta, ReferenceBackend, Runtime, Tensor,
    REFERENCE_MODEL,
};
use dp_shortcuts::util::rng::ChaChaRng;
use proptest::prelude::*;
use std::path::Path;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Deterministic batch (x, y) for a model from a seed.
fn synth_batch(meta: &ModelMeta, batch: usize, data_seed: u64) -> (Vec<f32>, Vec<i32>) {
    let d = meta.image * meta.image * meta.channels;
    let mut rng = ChaChaRng::from_seed_stream(data_seed, 0, b"irstack\0");
    let x: Vec<f32> = (0..batch * d).map(|_| rng.next_normal() as f32).collect();
    let y: Vec<i32> = (0..batch)
        .map(|_| (rng.next_u32() % meta.num_classes as u32) as i32)
        .collect();
    (x, y)
}

/// A custom layered ModelMeta for a generated stack (executables are
/// synthesized on demand — `prepare` decodes specs, it never consults
/// `meta.executables`).
fn stack_meta(image: usize, channels: usize, hidden: &[usize], ncls: usize) -> ModelMeta {
    let d = image * image * channels;
    let mut layers = Vec::new();
    let mut cur = d;
    for &w in hidden {
        layers.push(LayerSpec::dense_relu(cur, w));
        cur = w;
    }
    layers.push(LayerSpec::dense(cur, ncls));
    ModelMeta {
        family: "stack".into(),
        n_params: layers.iter().map(LayerSpec::params).sum(),
        image,
        channels,
        num_classes: ncls,
        clip_norm: 1.0,
        flops_fwd_per_example: 1.0,
        init_params: "stack_init.synthetic".into(),
        executables: Vec::new(),
        layers,
    }
}

/// A ModelMeta over an explicit (possibly non-dense) layer chain —
/// conv2d / layernorm / attention stacks for the kind battery. The
/// first layer must consume the `image * image * channels` input.
fn custom_meta(image: usize, channels: usize, layers: Vec<LayerSpec>, ncls: usize) -> ModelMeta {
    assert_eq!(layers[0].d_in, image * image * channels, "stack input mismatch");
    assert_eq!(layers.last().unwrap().d_out, ncls, "stack head mismatch");
    ModelMeta {
        family: "kinded".into(),
        n_params: layers.iter().map(LayerSpec::params).sum(),
        image,
        channels,
        num_classes: ncls,
        clip_norm: 1.0,
        flops_fwd_per_example: 1.0,
        init_params: "stack_init.synthetic".into(),
        executables: Vec::new(),
        layers,
    }
}

fn accum_exe(tag: &str, variant: &str, batch: usize) -> ExecutableMeta {
    ExecutableMeta {
        path: format!("{tag}_accum_{variant}_b{batch}.ref"),
        kind: "accum".into(),
        variant: Some(variant.into()),
        batch: Some(batch),
        dtype: None,
    }
}

// ---------------------------------------------------------------------
// 1. The seed-kernel oracle: a direct port of the pre-IR hardcoded
//    linear+softmax accum kernel (8-lane dot, closed-form norm,
//    sequential example-order accumulate). The layered executor run on
//    the one-dense-layer `ref-linear` must match it bit for bit.
// ---------------------------------------------------------------------

/// The seed's 8-lane unrolled dot with its fixed reduction tree.
fn seed_dot(a: &[f32], b: &[f32]) -> f32 {
    let n8 = a.len() - a.len() % 8;
    let (a8, at) = a.split_at(n8);
    let (b8, bt) = b.split_at(n8);
    let mut lanes = [0.0f32; 8];
    for (ac, bc) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for j in 0..8 {
            lanes[j] += ac[j] * bc[j];
        }
    }
    let mut tail = 0.0f32;
    for (av, bv) in at.iter().zip(bt) {
        tail += av * bv;
    }
    (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
        + tail
}

/// Operands of one oracle call (typed struct, like the real ABI).
#[derive(Clone, Copy)]
struct SeedCall<'a> {
    d: usize,
    ncls: usize,
    clip_norm: f32,
    nonprivate: bool,
    params: &'a [f32],
    x: &'a [f32],
    y: &'a [i32],
    mask: &'a [f32],
}

/// Seed accum kernel: flat params `[W row-major | b]`, per-example
/// dlogits + closed-form norm `||dl||^2 (||x||^2 + 1)`, masked
/// clip-and-accumulate in example order. Mutates `acc`; returns
/// `(loss_sum, sq_norms)`.
fn seed_accum(call: &SeedCall<'_>, acc: &mut [f32]) -> (f32, Vec<f32>) {
    let SeedCall { d, ncls, clip_norm, nonprivate, params, x, y, mask } = *call;
    let b = y.len();
    let (w, rest) = params.split_at(ncls * d);
    let bias = &rest[..ncls];
    let mut dlogits = vec![0.0f32; b * ncls];
    let mut scale = vec![0.0f32; b];
    let mut losses = vec![0.0f32; b];
    let mut sq_norms = vec![0.0f32; b];
    for i in 0..b {
        let xi = &x[i * d..(i + 1) * d];
        let dl = &mut dlogits[i * ncls..(i + 1) * ncls];
        for (cls, slot) in dl.iter_mut().enumerate() {
            *slot = seed_dot(&w[cls * d..(cls + 1) * d], xi) + bias[cls];
        }
        let yi = y[i] as usize;
        let ly = dl[yi];
        let max = dl.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in dl.iter_mut() {
            *v = (*v - max).exp();
            z += *v;
        }
        losses[i] = max + z.ln() - ly;
        for v in dl.iter_mut() {
            *v /= z;
        }
        dl[yi] -= 1.0;
        if nonprivate {
            sq_norms[i] = 0.0;
            scale[i] = mask[i];
        } else {
            let xsq = seed_dot(xi, xi);
            let dlsq = seed_dot(dl, dl);
            let sq = dlsq * (xsq + 1.0);
            sq_norms[i] = sq;
            let norm = sq.max(0.0).sqrt().max(1e-12);
            scale[i] = (clip_norm / norm).min(1.0) * mask[i];
        }
    }
    let mut loss_sum = 0.0f32;
    for (&ls, &m) in losses.iter().zip(mask) {
        loss_sum += m * ls;
    }
    let (w_acc, rest) = acc.split_at_mut(ncls * d);
    let bias_acc = &mut rest[..ncls];
    for i in 0..b {
        let sc = scale[i];
        if sc == 0.0 {
            continue;
        }
        let xi = &x[i * d..(i + 1) * d];
        let dl = &dlogits[i * ncls..(i + 1) * ncls];
        for r in 0..ncls {
            let g = sc * dl[r];
            for (a, &xv) in w_acc[r * d..(r + 1) * d].iter_mut().zip(xi) {
                *a += g * xv;
            }
            bias_acc[r] += g;
        }
    }
    (loss_sum, sq_norms)
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(24))]

    /// The layered executor on the one-dense-layer `ref-linear` IR is
    /// bitwise-identical to the seed's hardcoded kernel, for EVERY
    /// lowered variant (they all agree with each other too), across
    /// batch sizes, masks (including all-masked), and data — the pin
    /// that makes the layer refactor trajectory-preserving.
    #[test]
    fn one_layer_ir_matches_the_seed_kernel_bitwise(
        variant_idx in 0usize..6,
        batch_idx in 0usize..4,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
    ) {
        let variant = ["nonprivate", "masked", "ghost", "bk", "perex", "mix"][variant_idx];
        let batch = [1usize, 2, 8, 16][batch_idx];
        let backend = ReferenceBackend::new(0);
        let meta = ReferenceBackend::manifest(0).models[REFERENCE_MODEL].clone();
        let d = meta.image * meta.image * meta.channels;
        let ncls = meta.num_classes;
        let exe = meta.find_accum(variant, batch, "f32").unwrap().clone();
        let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let acc0 = Tensor::zeros(meta.n_params);
        let out = backend
            .run_accum(&prep, &meta, &params, &acc0, &AccumArgs { x: &x, y: &y, mask: &mask })
            .unwrap();

        let mut oracle_acc = vec![0.0f32; meta.n_params];
        let call = SeedCall {
            d,
            ncls,
            clip_norm: meta.clip_norm as f32,
            nonprivate: variant == "nonprivate",
            params: params.as_slice(),
            x: &x,
            y: &y,
            mask: &mask,
        };
        let (oracle_loss, oracle_norms) = seed_accum(&call, &mut oracle_acc);
        prop_assert_eq!(bits(out.acc.as_slice()), bits(&oracle_acc), "variant {}", variant);
        prop_assert_eq!(out.loss_sum.to_bits(), oracle_loss.to_bits());
        prop_assert_eq!(bits(&out.sq_norms), bits(&oracle_norms));
    }
}

// ---------------------------------------------------------------------
// 2. Ghost vs per-example vs mix over generated layer stacks.
// ---------------------------------------------------------------------

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    /// The executed ghost path (fused, Gram-product norms, no
    /// materialized per-example weight grads) and the executed
    /// per-example path (materializing accumulate) agree **bitwise**
    /// on per-example norms and on the accumulator, for every
    /// generated stack: 0–2 hidden ReLU layers of widths 1..6, batch
    /// sizes including 1, masks including all-masked and all-ones.
    /// The mix variant (per-layer decision rule) matches too.
    #[test]
    fn ghost_and_per_example_agree_on_every_layer_stack(
        image in 1usize..=2,
        channels in 1usize..=3,
        hidden in proptest::collection::vec(1usize..=6, 0..=2),
        ncls in 2usize..=5,
        batch_idx in 0usize..5,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
    ) {
        let batch = [1usize, 2, 3, 5, 8][batch_idx];
        let meta = stack_meta(image, channels, &hidden, ncls);
        let backend = ReferenceBackend::new(3);
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let acc0 = Tensor::zeros(meta.n_params);
        let args = AccumArgs { x: &x, y: &y, mask: &mask };
        let tag = format!("stack_i{image}c{channels}h{hidden:?}n{ncls}");

        let mut outs = Vec::new();
        for variant in ["ghost", "perex", "mix"] {
            let exe = accum_exe(&tag, variant, batch);
            let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
            outs.push(backend.run_accum(&prep, &meta, &params, &acc0, &args).unwrap());
        }
        let ghost = &outs[0];
        for (variant, o) in ["perex", "mix"].iter().zip(&outs[1..]) {
            prop_assert_eq!(
                bits(&ghost.sq_norms),
                bits(&o.sq_norms),
                "{}: per-example norms diverged from ghost on stack {}",
                variant,
                &tag
            );
            prop_assert_eq!(
                bits(ghost.acc.as_slice()),
                bits(o.acc.as_slice()),
                "{}: accumulator diverged from ghost on stack {}",
                variant,
                &tag
            );
            prop_assert_eq!(ghost.loss_sum.to_bits(), o.loss_sum.to_bits());
        }
        // All-masked batches leave the accumulator untouched on every
        // path; norms are still reported per slot.
        if mask.iter().all(|m| *m == 0.0) {
            prop_assert_eq!(bits(ghost.acc.as_slice()), bits(acc0.as_slice()));
        }
        prop_assert_eq!(ghost.sq_norms.len(), batch);
        // Norms are the sum over layers of Gram products: finite and
        // non-negative by construction.
        prop_assert!(ghost.sq_norms.iter().all(|s| s.is_finite() && *s >= 0.0));
    }
}

/// The heterogeneous stacks for the kind battery: every non-dense kind,
/// alone and composed (conv->dense, conv strided, attention->dense,
/// attention->layernorm->dense, layernorm-first, conv->layernorm).
fn kinded_stacks() -> Vec<ModelMeta> {
    vec![
        custom_meta(
            4,
            2,
            vec![LayerSpec::conv2d(2, 4, 3, 3, 1, 1, Activation::Relu), LayerSpec::dense(48, 3)],
            3,
        ),
        custom_meta(
            4,
            3,
            vec![LayerSpec::conv2d(3, 4, 2, 3, 2, 1, Activation::Relu), LayerSpec::dense(8, 4)],
            4,
        ),
        custom_meta(2, 3, vec![LayerSpec::attention(2, 6, 3), LayerSpec::dense(12, 5)], 5),
        custom_meta(
            4,
            1,
            vec![
                LayerSpec::attention(4, 4, 2),
                LayerSpec::layernorm(16),
                LayerSpec::dense(16, 3),
            ],
            3,
        ),
        custom_meta(
            3,
            2,
            vec![LayerSpec::layernorm(18), LayerSpec::dense_relu(18, 7), LayerSpec::dense(7, 4)],
            4,
        ),
        custom_meta(
            4,
            1,
            vec![
                LayerSpec::conv2d(1, 4, 2, 3, 1, 0, Activation::Relu),
                LayerSpec::layernorm(8),
                LayerSpec::dense(8, 2),
            ],
            2,
        ),
    ]
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    /// The acceptance gate on the non-dense kinds: per-example, ghost,
    /// and mix produce **bitwise-identical** accumulators, losses, and
    /// per-example norms on conv2d / layernorm / attention stacks —
    /// including batch 1, all-masked batches, and 1 / 2 / 4 forced
    /// workers (the ghost Gram-product norms and the materializing
    /// path must agree regardless of how phase 1/2 are sharded).
    #[test]
    fn kinded_stacks_agree_across_variants_and_workers(
        stack_idx in 0usize..6,
        batch_idx in 0usize..4,
        workers_idx in 0usize..3,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
    ) {
        let batch = [1usize, 2, 5, 8][batch_idx];
        let workers = [1usize, 2, 4][workers_idx];
        let meta = kinded_stacks().swap_remove(stack_idx);
        let backend = ReferenceBackend::with_threads(3, workers);
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let acc0 = Tensor::zeros(meta.n_params);
        let args = AccumArgs { x: &x, y: &y, mask: &mask };
        let tag = format!("kinded{stack_idx}");

        let mut outs = Vec::new();
        for variant in ["ghost", "perex", "mix"] {
            let exe = accum_exe(&tag, variant, batch);
            let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
            outs.push(backend.run_accum(&prep, &meta, &params, &acc0, &args).unwrap());
        }
        let ghost = &outs[0];
        for (variant, o) in ["perex", "mix"].iter().zip(&outs[1..]) {
            prop_assert_eq!(
                bits(&ghost.sq_norms),
                bits(&o.sq_norms),
                "{}: norms diverged from ghost on stack {} ({} workers)",
                variant, stack_idx, workers
            );
            prop_assert_eq!(
                bits(ghost.acc.as_slice()),
                bits(o.acc.as_slice()),
                "{}: accumulator diverged from ghost on stack {} ({} workers)",
                variant, stack_idx, workers
            );
            prop_assert_eq!(ghost.loss_sum.to_bits(), o.loss_sum.to_bits());
        }

        // Worker-count invariance: the same ghost call on a forced
        // 1-worker backend lands on the same bits.
        let solo_backend = ReferenceBackend::with_threads(3, 1);
        let exe = accum_exe(&tag, "ghost", batch);
        let prep = solo_backend.prepare(Path::new("."), &meta, &exe).unwrap();
        let solo = solo_backend.run_accum(&prep, &meta, &params, &acc0, &args).unwrap();
        prop_assert_eq!(
            bits(ghost.acc.as_slice()),
            bits(solo.acc.as_slice()),
            "{} workers diverged from 1 on stack {}",
            workers, stack_idx
        );
        prop_assert_eq!(bits(&ghost.sq_norms), bits(&solo.sq_norms));
        prop_assert_eq!(ghost.loss_sum.to_bits(), solo.loss_sum.to_bits());

        if mask.iter().all(|m| *m == 0.0) {
            prop_assert_eq!(bits(ghost.acc.as_slice()), bits(acc0.as_slice()));
        }
        prop_assert_eq!(ghost.sq_norms.len(), batch);
        prop_assert!(ghost.sq_norms.iter().all(|s| s.is_finite() && *s >= 0.0));
    }
}

// ---------------------------------------------------------------------
// 3. Backward correctness: central differences of an independent f64
//    forward.
// ---------------------------------------------------------------------

/// f64 row-major affine map `z_r = b_r + sum_j W[r, j] x_j` — shared by
/// the dense arm and the four attention projections below.
fn f64_affine(w: &[f64], b: &[f64], xs: &[f64]) -> Vec<f64> {
    let d_in = xs.len();
    (0..b.len())
        .map(|r| {
            let mut s = b[r];
            for (j, &v) in xs.iter().enumerate() {
                s += w[r * d_in + j] * v;
            }
            s
        })
        .collect()
}

/// Independent f64 evaluation of one layer's pre-activation from the
/// flat parameter block `p` (same layout the executor decodes:
/// `[W|b]`, `[K|b]`, `[gamma|beta]`, `[Wq|bq|Wk|bk|Wv|bv|Wo|bo]`).
/// Loop order and index math mirror `runtime/reference.rs` so a
/// disagreement in the gradient check can only come from the backward.
fn f64_layer(spec: &LayerSpec, p: &[f64], a: &[f64]) -> Vec<f64> {
    match spec.kind {
        LayerKind::Dense => {
            let (w, bias) = p.split_at(spec.d_in * spec.d_out);
            f64_affine(w, bias, a)
        }
        LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad } => {
            let ho = conv_out(h_in, kh, stride, pad);
            let wo = conv_out(w_in, kw, stride, pad);
            let patch = c_in * kh * kw;
            let (k, bias) = p.split_at(c_out * patch);
            let mut z = vec![0.0f64; c_out * ho * wo];
            for c in 0..c_out {
                let krow = &k[c * patch..(c + 1) * patch];
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut s = bias[c];
                        for cc in 0..c_in {
                            for ky in 0..kh {
                                let iy = oy * stride + ky;
                                if iy < pad || iy - pad >= h_in {
                                    continue;
                                }
                                for kx in 0..kw {
                                    let ix = ox * stride + kx;
                                    if ix < pad || ix - pad >= w_in {
                                        continue;
                                    }
                                    s += krow[cc * kh * kw + ky * kw + kx]
                                        * a[cc * h_in * w_in + (iy - pad) * w_in + (ix - pad)];
                                }
                            }
                        }
                        z[c * ho * wo + oy * wo + ox] = s;
                    }
                }
            }
            z
        }
        LayerKind::LayerNorm => {
            let d = spec.d_out;
            let (gamma, beta) = p.split_at(d);
            let mu = a.iter().sum::<f64>() / d as f64;
            let var = a.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
            let rstd = 1.0 / (var + 1e-6).sqrt();
            (0..d).map(|j| (a[j] - mu) * rstd * gamma[j] + beta[j]).collect()
        }
        LayerKind::Attention { t, d_model, d_head } => {
            let (d, dh) = (d_model, d_head);
            let wlen = dh * d;
            let wq = &p[..wlen];
            let bq = &p[wlen..wlen + dh];
            let wk = &p[wlen + dh..2 * wlen + dh];
            let bk = &p[2 * wlen + dh..2 * (wlen + dh)];
            let wv = &p[2 * (wlen + dh)..3 * wlen + 2 * dh];
            let bv = &p[3 * wlen + 2 * dh..3 * (wlen + dh)];
            let wo = &p[3 * (wlen + dh)..3 * (wlen + dh) + d * dh];
            let bo = &p[3 * (wlen + dh) + d * dh..];
            let inv = 1.0 / (dh as f64).sqrt();
            let q: Vec<Vec<f64>> =
                (0..t).map(|s| f64_affine(wq, bq, &a[s * d..(s + 1) * d])).collect();
            let k: Vec<Vec<f64>> =
                (0..t).map(|s| f64_affine(wk, bk, &a[s * d..(s + 1) * d])).collect();
            let v: Vec<Vec<f64>> =
                (0..t).map(|s| f64_affine(wv, bv, &a[s * d..(s + 1) * d])).collect();
            let mut z = vec![0.0f64; t * d];
            for s in 0..t {
                let mut scores: Vec<f64> = (0..t)
                    .map(|u| q[s].iter().zip(&k[u]).map(|(qv, kv)| qv * kv).sum::<f64>() * inv)
                    .collect();
                let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut zsum = 0.0f64;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max).exp();
                    zsum += *sc;
                }
                for sc in scores.iter_mut() {
                    *sc /= zsum;
                }
                let mut ctx = vec![0.0f64; dh];
                for u in 0..t {
                    for (cv, vv) in ctx.iter_mut().zip(&v[u]) {
                        *cv += scores[u] * vv;
                    }
                }
                z[s * d..(s + 1) * d].copy_from_slice(&f64_affine(wo, bo, &ctx));
            }
            z
        }
    }
}

/// Independent f64 forward over one batch, from the same flat-param
/// layout: returns the summed softmax-xent loss and the smallest
/// ReLU |pre-activation| (the gradient check's kink guard — `inf` for
/// stacks with no ReLU). One implementation serves both so the kink
/// guard can never drift from the differenced loss.
fn f64_forward(meta: &ModelMeta, params: &[f64], x: &[f32], y: &[i32]) -> (f64, f64) {
    let d = meta.image * meta.image * meta.channels;
    let specs = meta.layer_specs();
    let mut loss = 0.0f64;
    let mut min_preact = f64::INFINITY;
    for (i, &yi) in y.iter().enumerate() {
        let mut a: Vec<f64> = x[i * d..(i + 1) * d].iter().map(|v| *v as f64).collect();
        let mut off = 0usize;
        for spec in &specs {
            let mut z = f64_layer(spec, &params[off..off + spec.params()], &a);
            off += spec.params();
            if spec.activation == Activation::Relu {
                for v in &z {
                    min_preact = min_preact.min(v.abs());
                }
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            a = z;
        }
        let max = a.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = max + a.iter().map(|v| (v - max).exp()).sum::<f64>().ln();
        loss += lse - a[yi as usize];
    }
    (loss, min_preact)
}

/// Difference every flat-parameter coordinate of `meta` against the
/// executor's nonprivate accumulator (which reports the *unclipped*
/// summed gradient, i.e. exactly d(sum loss)/d(theta)). Data seeds are
/// searched for a batch that keeps every ReLU pre-activation away from
/// the kink (> 100h), so central differences are valid; deterministic,
/// and in practice the first seed qualifies.
fn grad_check(meta: &ModelMeta, batch: usize, tag: &str) {
    let backend = ReferenceBackend::new(0);
    let params = backend.init_params(Path::new("."), meta).unwrap();
    let p64: Vec<f64> = params.as_slice().iter().map(|v| *v as f64).collect();

    let h = 1e-4f64;
    let (x, y) = (0u64..)
        .map(|s| synth_batch(meta, batch, s))
        .find(|(x, y)| f64_forward(meta, &p64, x, y).1 > 100.0 * h)
        .unwrap();

    let exe = accum_exe(tag, "nonprivate", batch);
    let prep = backend.prepare(Path::new("."), meta, &exe).unwrap();
    let acc0 = Tensor::zeros(meta.n_params);
    let mask = vec![1.0f32; batch];
    let out = backend
        .run_accum(&prep, meta, &params, &acc0, &AccumArgs { x: &x, y: &y, mask: &mask })
        .unwrap();
    let analytic = out.acc.as_slice();

    for j in 0..meta.n_params {
        let mut plus = p64.clone();
        plus[j] += h;
        let mut minus = p64.clone();
        minus[j] -= h;
        let up = f64_forward(meta, &plus, &x, &y).0;
        let down = f64_forward(meta, &minus, &x, &y).0;
        let numeric = (up - down) / (2.0 * h);
        let got = analytic[j] as f64;
        let tol = 1e-3 + 2e-2 * numeric.abs().max(got.abs());
        assert!(
            (numeric - got).abs() <= tol,
            "{tag} param {j}: analytic {got} vs numeric {numeric} (tol {tol})"
        );
    }
}

#[test]
fn multi_layer_backward_matches_finite_differences() {
    // dense_relu(4, 5) -> dense_relu(5, 4) -> dense(4, 3): small
    // enough to difference every coordinate.
    grad_check(&stack_meta(2, 1, &[5, 4], 3), 3, "gradcheck");
}

#[test]
fn conv_backward_matches_finite_differences() {
    // Two ReLU convs — one strided with padding (5x5 -> 3x3), one
    // unpadded (3x3 -> 2x2) — then a dense head: exercises the im2col
    // backward's boundary clipping and stride arithmetic per
    // coordinate (110 parameters).
    let meta = custom_meta(
        5,
        2,
        vec![
            LayerSpec::conv2d(2, 5, 3, 3, 2, 1, Activation::Relu),
            LayerSpec::conv2d(3, 3, 2, 2, 1, 0, Activation::Relu),
            LayerSpec::dense(8, 3),
        ],
        3,
    );
    grad_check(&meta, 3, "convcheck");
}

#[test]
fn layernorm_backward_matches_finite_differences() {
    // LayerNorm sandwiched after a ReLU dense: its backward couples
    // every input through mu/var, the part the tape's (xhat, rstd)
    // extras exist to reconstruct.
    let meta = custom_meta(
        2,
        2,
        vec![LayerSpec::dense_relu(8, 6), LayerSpec::layernorm(6), LayerSpec::dense(6, 3)],
        3,
    );
    grad_check(&meta, 3, "lncheck");
}

#[test]
fn attention_backward_matches_finite_differences() {
    // Single-head attention (3 tokens, d_model 4, d_head 2) ->
    // layernorm -> dense head: differences all four projections
    // through the softmax scores (105 parameters, no ReLU — the kink
    // guard is vacuous).
    let meta = custom_meta(
        2,
        3,
        vec![LayerSpec::attention(3, 4, 2), LayerSpec::layernorm(12), LayerSpec::dense(12, 3)],
        3,
    );
    grad_check(&meta, 3, "attncheck");
}

// ---------------------------------------------------------------------
// 4. Trajectory invariance across clip methods + the acceptance run.
// ---------------------------------------------------------------------

fn mlp_config(variant: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp-small".into(),
        variant: variant.into(),
        mode: BatchingMode::Masked,
        dataset_size: 48,
        sampling_rate: 0.3,
        physical_batch: 4,
        steps: 3,
        lr: 0.05,
        noise_multiplier: Some(1.1),
        eval_examples: 32,
        workers,
        ..Default::default()
    }
}

#[test]
fn every_clip_method_trains_the_same_trajectory() {
    // The branch choice (fused ghost vs materializing per-example vs
    // the per-layer mix rule) moves memory traffic only: the whole
    // training trajectory — params, losses, epsilon — is
    // bitwise-identical across methods on the multi-layer model.
    let mut reference: Option<dp_shortcuts::TrainReport> = None;
    for method in ["per-example", "ghost", "mix", "bk"] {
        let variant = clip_method_variant(method).unwrap();
        let rt = Runtime::reference();
        let rep = Trainer::new(&rt, mlp_config(variant, 1)).unwrap().run().unwrap();
        if let Some(want) = &reference {
            assert_eq!(
                bits(&rep.final_params),
                bits(&want.final_params),
                "{method} diverged"
            );
            assert_eq!(rep.epsilon_spent.to_bits(), want.epsilon_spent.to_bits());
            for (a, b) in rep.steps.iter().zip(&want.steps) {
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{method}");
            }
        } else {
            reference = Some(rep);
        }
    }
}

#[test]
fn mlp_small_ghost_two_workers_runs_end_to_end() {
    // The acceptance command: `dpshort train --model mlp-small
    // --clip-method ghost --workers 2` — here through the same config
    // the CLI builds, checked bitwise against the 1-worker run.
    let variant = clip_method_variant("ghost").unwrap();
    let solo = {
        let rt = Runtime::reference();
        Trainer::new(&rt, mlp_config(variant, 1)).unwrap().run().unwrap()
    };
    let rt = Runtime::reference();
    let rep = Trainer::new(&rt, mlp_config(variant, 2)).unwrap().run().unwrap();
    assert_eq!(rep.steps.len(), 3);
    assert!(rep.steps.iter().all(|s| s.loss.is_finite()));
    assert!(rep.epsilon_spent > 0.0, "RDP accounting ran");
    assert!(rep.eval_loss.unwrap().is_finite());
    assert_eq!(
        bits(&rep.final_params),
        bits(&solo.final_params),
        "2-worker mlp-small run diverged from 1 worker"
    );
}

#[test]
fn mlp_small_actually_learns() {
    // Non-private SGD on the multi-layer model must drive the loss
    // down — the ReLU backward is doing real work, not just passing
    // the bitwise gates.
    let rt = Runtime::reference();
    let cfg = TrainConfig {
        model: "mlp-small".into(),
        variant: "nonprivate".into(),
        mode: BatchingMode::Masked,
        dataset_size: 96,
        sampling_rate: 0.5,
        physical_batch: 8,
        steps: 12,
        lr: 0.5,
        noise_multiplier: None,
        eval_examples: 0,
        ..Default::default()
    };
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let first = rep.steps.first().unwrap().loss;
    let last = rep.steps.last().unwrap().loss;
    assert!(last < first, "mlp-small loss did not decrease: {first} -> {last}");
}

// ---------------------------------------------------------------------
// 5. Analytic cost cross-checks: the layer IR's MAC counts and the
//    clipping time model against python/compile/{vit,resnet}.py.
// ---------------------------------------------------------------------

#[test]
fn layer_ir_macs_match_the_python_analytic_counts() {
    // vit.py flops_per_example counts, per block, 2*MACs of qkv + proj
    // (seq t) plus 2 * (2 t^2 dim) for QK^T + AV. A single-head
    // attention layer with d_head == dim covers exactly those terms:
    // 4 t d^2 (q/k/v/o projections) + 2 t^2 d.
    for (t, dim) in [(17usize, 64usize), (65, 128), (65, 192)] {
        let spec = LayerSpec::attention(t, dim, dim);
        assert_eq!(
            spec.macs(),
            t * dim * (3 * dim) + t * dim * dim + 2 * t * t * dim,
            "attention({t}, {dim}) MACs != vit.py qkv + proj + QK^T + AV"
        );
    }
    // vit.py counts the head at seq 1: plain d_in * d_out.
    assert_eq!(LayerSpec::dense(192, 100).macs(), 192 * 100);

    // resnet.py counts each bottleneck as
    //   2 h^2 (cin*mid + 9 mid^2 + mid*cout)
    // — the three convs in their im2col view. The IR's conv2d MACs
    // reproduce each term (flops = 2 * MACs).
    let (h, cin, cout) = (8usize, 64usize, 256usize);
    let mid = cout / 4;
    let c1 = LayerSpec::conv2d(cin, h, mid, 1, 1, 0, Activation::Relu);
    let c2 = LayerSpec::conv2d(mid, h, mid, 3, 1, 1, Activation::Relu);
    let c3 = LayerSpec::conv2d(mid, h, cout, 1, 1, 0, Activation::None);
    assert_eq!(c1.macs(), h * h * cin * mid);
    assert_eq!(c2.macs(), h * h * 9 * mid * mid);
    assert_eq!(c3.macs(), h * h * mid * cout);
    assert_eq!(
        c1.macs() + c2.macs() + c3.macs(),
        h * h * (cin * mid + 9 * mid * mid + mid * cout),
        "bottleneck MACs != resnet.py per-block term"
    );
    // Downsampling: a stride-2 1x1 conv runs at (h/2)^2 positions.
    assert_eq!(
        LayerSpec::conv2d(cin, h, mid, 1, 2, 0, Activation::None).macs(),
        (h / 2) * (h / 2) * cin * mid
    );

    // The executed ladder agrees end-to-end: LayerPlan's per-example
    // MACs are the spec sum, and the manifest's flops_fwd_per_example
    // is exactly 2 * MACs — for both non-dense ladder models.
    let manifest = ReferenceBackend::manifest(0);
    for name in ["cnn-small", "attn-tiny"] {
        let meta = &manifest.models[name];
        let plan = LayerPlan::build(meta).unwrap();
        let spec_macs: usize = meta.layer_specs().iter().map(LayerSpec::macs).sum();
        assert_eq!(plan.macs_per_example(), spec_macs, "{name}");
        assert_eq!(meta.flops_fwd_per_example, 2.0 * spec_macs as f64, "{name}");
    }
}

#[test]
fn time_model_relative_cost_tracks_the_python_flop_formulas() {
    // The paper-scale ViT-Base: same linear shapes as vit.py's
    // linear_shapes() (qkv / proj / fc1 / fc2 at seq t, head at seq 1)
    // and the same flop formula — linears + depth * 2 * (2 t^2 dim).
    // (vit.py counts the patch embed at seq t; the rust Arch uses the
    // t-1 real patches, so the sum below recomputes over the Arch's
    // own dims.)
    let a = vit("ViT-Base", 12, 768, 4);
    let t = a.tokens;
    assert_eq!(t, 197, "224/16 patches + cls");
    assert_eq!(a.linears.len(), 1 + 12 * 4 + 1);
    let block = &a.linears[1..5];
    let dims: Vec<(usize, usize, usize)> =
        block.iter().map(|l| (l.t, l.d_in, l.d_out)).collect();
    assert_eq!(
        dims,
        vec![
            (197, 768, 3 * 768), // qkv
            (197, 768, 768),     // proj
            (197, 768, 4 * 768), // fc1
            (197, 4 * 768, 768), // fc2
        ]
    );
    let mut flops = 0.0f64;
    for l in &a.linears {
        flops += 2.0 * (l.t * l.d_in * l.d_out) as f64;
    }
    flops += 12.0 * 2.0 * (2 * t * t * 768) as f64; // QK^T + AV
    let rel = (flops - a.fwd_flops_per_example).abs() / flops;
    assert!(rel < 1e-12, "ViT-Base flops drifted from vit.py's formula: {rel}");

    // Paper Section 5.1: on ViTs the mix rule always picks ghost, so
    // the modeled cost degenerates to exactly ghost's.
    let tm = TimeModel::default();
    assert_eq!(
        tm.relative_cost(&a, ClippingMethod::MixGhost).to_bits(),
        tm.relative_cost(&a, ClippingMethod::Ghost).to_bits()
    );

    // BiT-R50x1: the mix rule interpolates per layer, so its modeled
    // cost lies between the pure methods; the per-layer choices flip
    // from per-example (early, huge t = 56^2) to ghost (deep, t = 7^2).
    let r = bit_resnet("BiT-R50x1", &[3, 4, 6, 3], 1);
    let g = tm.relative_cost(&r, ClippingMethod::Ghost);
    let pe = tm.relative_cost(&r, ClippingMethod::PerExample);
    let mix = tm.relative_cost(&r, ClippingMethod::MixGhost);
    assert!(
        mix >= g.min(pe) - 1e-12 && mix <= g.max(pe) + 1e-12,
        "mix cost {mix} outside [{}, {}]",
        g.min(pe),
        g.max(pe)
    );
    assert_eq!(
        mix_ghost_choice(&LinearDims { t: 56 * 56, d_in: 64, d_out: 64 }),
        LayerChoice::PerExample,
        "2 t^2 >> d_in d_out early in the ResNet"
    );
    assert_eq!(
        mix_ghost_choice(&LinearDims { t: 7 * 7, d_in: 2048, d_out: 512 }),
        LayerChoice::Ghost,
        "2 t^2 << d_in d_out at the deepest stage"
    );
}
