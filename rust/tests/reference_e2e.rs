//! End-to-end integration over the pure-Rust reference backend: the
//! whole sampler → batcher → trainer → accountant → report pipeline,
//! fully offline — the regression gate the AOT-artifact tests (see
//! integration.rs) cannot provide on a fresh checkout.

use dp_shortcuts::cluster::parallel::plan_groups;
use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::sampler::{PoissonSampler, Sampler};
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::privacy::RdpAccountant;
use dp_shortcuts::runtime::{Runtime, REFERENCE_MODEL};
use std::collections::HashSet;

fn base_config(variant: &str, mode: BatchingMode) -> TrainConfig {
    TrainConfig {
        model: REFERENCE_MODEL.into(),
        variant: variant.into(),
        mode,
        dataset_size: 96,
        sampling_rate: 0.25,
        physical_batch: 8,
        steps: 3,
        lr: 0.05,
        noise_multiplier: Some(1.1),
        eval_examples: 32,
        ..Default::default()
    }
}

/// The satellite invariants on one report: epsilon matches a fresh
/// accountant, and Algorithm-2 padding only ever adds computation.
fn assert_report_invariants(rep: &dp_shortcuts::TrainReport, cfg: &TrainConfig) {
    assert_eq!(rep.steps.len(), cfg.steps as usize);
    let fresh = RdpAccountant::default().epsilon(
        cfg.sampling_rate,
        rep.noise_multiplier,
        cfg.steps,
        cfg.delta,
    );
    assert!(
        (rep.epsilon_spent - fresh).abs() < 1e-9,
        "epsilon_spent {} != fresh accountant {}",
        rep.epsilon_spent,
        fresh
    );
    for s in &rep.steps {
        assert!(s.loss.is_finite());
        assert!(
            s.computed_examples >= s.logical_batch,
            "step {}: computed {} < logical {}",
            s.step,
            s.computed_examples,
            s.logical_batch
        );
    }
    assert_eq!(
        rep.final_params.len(),
        10 * 16 * 16 * 3 + 10,
        "final params must be the full flat vector"
    );
    assert!(rep.final_params.iter().all(|p| p.is_finite()));
}

#[test]
fn masked_training_runs_end_to_end() {
    let rt = Runtime::reference();
    let cfg = base_config("masked", BatchingMode::Masked);
    let rep = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    assert_report_invariants(&rep, &cfg);
    assert!(rep.epsilon_spent > 0.0);
    for s in &rep.steps {
        assert!(s.loss > 0.0);
        // Algorithm 2: computed examples = ceil(|L|/p)*p, full shapes only.
        assert_eq!(s.computed_examples % cfg.physical_batch, 0);
    }
    assert!(rep.throughput > 0.0);
    assert!(rep.computed_throughput >= rep.throughput);
    let (l, a) = (rep.eval_loss.unwrap(), rep.eval_accuracy.unwrap());
    assert!(l.is_finite() && l > 0.0);
    assert!((0.0..=1.0).contains(&a));
}

#[test]
fn variable_training_runs_end_to_end() {
    let rt = Runtime::reference();
    let cfg = base_config("naive", BatchingMode::Variable);
    let rep = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    assert_report_invariants(&rep, &cfg);
    assert!(rep.epsilon_spent > 0.0);
    assert!(rep.steps.iter().all(|s| s.loss > 0.0));
}

#[test]
fn masked_padding_never_changes_the_update() {
    // Same seed => same logical batches, same per-step noise seeds. The
    // masked run pads every logical batch up to full physical shapes
    // (mask-0 slots); the variable run computes exactly the sampled
    // examples. Padding must be update-neutral: identical parameters.
    let masked = {
        let rt = Runtime::reference();
        let cfg = base_config("masked", BatchingMode::Masked);
        Trainer::new(&rt, cfg).unwrap().run().unwrap()
    };
    let unpadded = {
        let rt = Runtime::reference();
        let cfg = base_config("naive", BatchingMode::Variable);
        Trainer::new(&rt, cfg).unwrap().run().unwrap()
    };
    for (s_m, s_u) in masked.steps.iter().zip(&unpadded.steps) {
        assert_eq!(s_m.logical_batch, s_u.logical_batch, "same sampler stream");
        // Losses agree up to f32 summation grouping (the per-batch
        // loss_sum partials are grouped differently across modes).
        assert!(
            (s_m.loss - s_u.loss).abs() < 1e-4,
            "step {}: masked loss {} vs unpadded {}",
            s_m.step,
            s_m.loss,
            s_u.loss
        );
        assert!(s_m.computed_examples >= s_u.computed_examples);
    }
    assert_eq!(
        masked.final_params, unpadded.final_params,
        "Algorithm-2 padding changed the parameter update"
    );
}

#[test]
fn empty_poisson_batches_still_take_noise_only_steps() {
    // q = 0 makes every logical batch empty — the Algorithm-1 corner
    // where the step still happens with noise only.
    for (variant, mode) in [("masked", BatchingMode::Masked), ("naive", BatchingMode::Variable)] {
        let rt = Runtime::reference();
        let mut cfg = base_config(variant, mode);
        cfg.sampling_rate = 0.0;
        cfg.steps = 2;
        cfg.eval_examples = 0;
        let init = rt.model(REFERENCE_MODEL).unwrap().init_params().unwrap();
        let rep = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
        assert_report_invariants(&rep, &cfg);
        for s in &rep.steps {
            assert_eq!(s.logical_batch, 0);
            assert!(s.physical_batches >= 1, "empty batch must still step");
        }
        assert_ne!(
            rep.final_params,
            init.to_vec(),
            "{variant}: noise-only steps must still perturb the parameters"
        );
    }
}

#[test]
fn masked_mode_compiles_exactly_one_accum_shape() {
    let rt = Runtime::reference();
    let cfg = base_config("masked", BatchingMode::Masked);
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let accum_compiles = rep.compiles.iter().filter(|(p, _)| p.contains("_accum_")).count();
    assert_eq!(
        accum_compiles, 1,
        "masked DP-SGD must never recompile: {:?}",
        rep.compiles
    );
    // A second run on the same runtime hits the cache for everything.
    let cfg = base_config("masked", BatchingMode::Masked);
    let rep2 = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(rep2.compiles.is_empty(), "unexpected recompiles: {:?}", rep2.compiles);
    assert_eq!(rep2.sections.compile, 0.0);
}

#[test]
fn variable_mode_compiles_per_batch_size() {
    let rt = Runtime::reference();
    let mut cfg = base_config("naive", BatchingMode::Variable);
    cfg.dataset_size = 256;
    cfg.sampling_rate = 0.3;
    // Derive the exact chunk sizes the trainer will execute by
    // replaying its own decomposition (one global Poisson draw per
    // step, naive split per accumulation group), so the assertion is
    // structural rather than seed-lucky.
    let available = rt
        .model(REFERENCE_MODEL)
        .unwrap()
        .accum_batches("naive", "f32");
    let sampler = PoissonSampler::new(cfg.dataset_size, cfg.sampling_rate, cfg.seed);
    let mut expected_sizes: HashSet<usize> = HashSet::new();
    for step in 0..cfg.steps {
        for group in plan_groups(
            &sampler.sample(step),
            cfg.physical_batch,
            BatchingMode::Variable,
            &available,
        ) {
            expected_sizes.extend(group.chunks.iter().map(|c| c.indices.len()));
        }
    }
    let physical_batch = cfg.physical_batch;
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let accum_compiles = rep.compiles.iter().filter(|(p, _)| p.contains("_accum_")).count();
    // One compilation per distinct executed chunk size — recompiles are
    // the naive-JAX cost this mode exists to demonstrate.
    assert_eq!(
        accum_compiles,
        expected_sizes.len(),
        "naive mode must compile exactly the executed chunk sizes: {:?}",
        rep.compiles
    );
    // Full groups always run the configured physical batch; it must be
    // among the compiled shapes.
    assert!(expected_sizes.contains(&physical_batch));
}

#[test]
fn deterministic_given_seed_and_seed_sensitive() {
    let run = |seed: u64| {
        let rt = Runtime::reference();
        let mut cfg = base_config("masked", BatchingMode::Masked);
        cfg.seed = seed;
        Trainer::new(&rt, cfg).unwrap().run().unwrap()
    };
    let r1 = run(0);
    let r2 = run(0);
    assert_eq!(r1.final_params, r2.final_params);
    for (a, b) in r1.steps.iter().zip(&r2.steps) {
        assert_eq!(a.logical_batch, b.logical_batch);
        assert_eq!(a.loss, b.loss);
    }
    let r3 = run(1);
    assert_ne!(r1.final_params, r3.final_params);
}

#[test]
fn nonprivate_baseline_runs_without_noise() {
    let rt = Runtime::reference();
    let mut cfg = base_config("nonprivate", BatchingMode::Masked);
    cfg.noise_multiplier = None;
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(rep.noise_multiplier, 0.0);
    assert_eq!(rep.epsilon_spent, 0.0);
    assert!(rep.steps.iter().all(|s| s.loss.is_finite() && s.loss > 0.0));
}

#[test]
fn training_reduces_loss_on_the_synthetic_task() {
    // The reference model must actually learn: non-private SGD over the
    // class-conditional synthetic data drives the loss down.
    let rt = Runtime::reference();
    let mut cfg = base_config("nonprivate", BatchingMode::Masked);
    cfg.noise_multiplier = None;
    cfg.steps = 12;
    cfg.lr = 0.5;
    cfg.eval_examples = 0;
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let first = rep.steps.first().unwrap().loss;
    let last = rep.steps.last().unwrap().loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn eval_coverage_is_reported_exactly() {
    // The eval executable's batch size is fixed at AOT time (32 here),
    // so a request that is not a multiple can only cover the full
    // batches — the report must say exactly how many examples the
    // metrics averaged over instead of silently dropping the tail.
    let run = |eval_examples: u32| {
        let rt = Runtime::reference();
        let mut cfg = base_config("masked", BatchingMode::Masked);
        cfg.steps = 1;
        cfg.eval_examples = eval_examples;
        Trainer::new(&rt, cfg).unwrap().run().unwrap()
    };
    // 70 requested, eval batch 32: exactly 64 covered.
    let rep = run(70);
    assert_eq!(rep.eval_covered, 64);
    assert!(rep.eval_loss.is_some() && rep.eval_accuracy.is_some());
    // Exact multiple: full coverage.
    let rep = run(64);
    assert_eq!(rep.eval_covered, 64);
    // Below one eval batch: nothing can run — no metrics, coverage 0.
    let rep = run(10);
    assert_eq!(rep.eval_covered, 0);
    assert!(rep.eval_loss.is_none() && rep.eval_accuracy.is_none());
    // Eval disabled: coverage 0.
    let rep = run(0);
    assert_eq!(rep.eval_covered, 0);
}

#[test]
fn accum_throughput_meter_lands_in_the_report() {
    let rt = Runtime::reference();
    let cfg = base_config("masked", BatchingMode::Masked);
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(rep.accum_throughput_aggregate > 0.0);
    let s = rep.accum_throughput.expect("accum calls ran");
    assert!(s.median > 0.0 && s.n == rep.accum_samples.len());
    assert!(s.ci_low <= s.median && s.median <= s.ci_high);
    let json = rep.to_json().unwrap();
    assert!(json.contains("\"accum_throughput_aggregate\""));
    assert!(json.contains("\"eval_covered\""));
}

#[test]
fn report_serializes_to_json() {
    let rt = Runtime::reference();
    let mut cfg = base_config("masked", BatchingMode::Masked);
    cfg.steps = 1;
    cfg.eval_examples = 0;
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let json = rep.to_json().unwrap();
    assert!(json.contains("\"epsilon_spent\""));
    assert!(json.contains("\"Masked\""));
    assert!(json.contains("\"final_params\""));
    let parsed: serde_json::Value = serde_json::from_str(&json).unwrap();
    assert_eq!(parsed["steps"].as_array().unwrap().len(), 1);
}

#[test]
fn every_compile_is_attributed_including_eval() {
    // Regression for the eval attribution hole: prepare_eval used to
    // run on *every* eval batch and its compile_seconds were never
    // added to SectionTimes.compile. Now the eval loop prepares once
    // and attributes it like accum/apply, so the compile section must
    // equal the sum of every compilation this run caused.
    let rt = Runtime::reference();
    let cfg = base_config("masked", BatchingMode::Masked);
    assert!(cfg.eval_examples > 0, "test needs the eval path");
    let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(
        rep.compiles.iter().any(|(p, _)| p.contains("_eval_")),
        "eval executable should have compiled: {:?}",
        rep.compiles
    );
    let total: f64 = rep.compiles.iter().map(|(_, s)| s).sum();
    assert!(
        (rep.sections.compile - total).abs() < 1e-9,
        "compile section {} != sum of compiles {total}",
        rep.sections.compile
    );
}

#[test]
fn checkpoint_roundtrip_through_reference_model() {
    let rt = Runtime::reference();
    let m = rt.model(REFERENCE_MODEL).unwrap();
    let p = m.init_params().unwrap();
    let path = std::env::temp_dir().join("dpshort_ref_ckpt_test.bin");
    m.save_params(&p, &path).unwrap();
    let p2 = m.load_params(&path).unwrap();
    assert_eq!(p.to_vec(), p2.to_vec());
    std::fs::write(&path, [0u8; 12]).unwrap();
    assert!(m.load_params(&path).is_err());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn missing_batch_size_is_a_clean_error() {
    let rt = Runtime::reference();
    let m = rt.model(REFERENCE_MODEL).unwrap();
    let msg = match m.prepare_accum("masked", 12_345, "f32") {
        Ok(_) => panic!("expected error for unlowered batch size"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("no accum artifact"), "{msg}");
}
