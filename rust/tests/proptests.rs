//! Property tests over the coordinator invariants (routing, batching,
//! accounting, memory, cluster) using the in-tree randomized property
//! runner (`util::prop` — the offline stand-in for proptest; failing
//! cases print their replay seed).

use dp_shortcuts::clipping::ClippingMethod;
use dp_shortcuts::cluster::{fit_parallel_fraction, ring_allreduce_seconds, ClusterSim, Interconnect};
use dp_shortcuts::coordinator::batcher::{BatchMemoryManager, BatchingMode};
use dp_shortcuts::coordinator::sampler::{PoissonSampler, Sampler};
use dp_shortcuts::memory::MemModel;
use dp_shortcuts::metrics::summary_with_ci;
use dp_shortcuts::models::vit;
use dp_shortcuts::privacy::RdpAccountant;
use dp_shortcuts::util::prop::check;

// ------------------------------------------------------------- sampler

#[test]
fn prop_poisson_indices_valid_and_deterministic() {
    check("poisson indices sorted/unique/in-range + replay-stable", 200, |rng| {
        let n = 1 + rng.gen_range(20_000) as u32;
        let q = rng.next_f64();
        let seed = rng.next_u64();
        let step = rng.next_u64() % 1000;
        let s = PoissonSampler::new(n, q, seed);
        let a = s.sample(step);
        if a != s.sample(step) {
            return Err("not deterministic".into());
        }
        if !a.windows(2).all(|w| w[0] < w[1]) {
            return Err("not sorted-unique".into());
        }
        if a.iter().any(|&i| i >= n) {
            return Err("index out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_poisson_mean_concentration() {
    check("poisson batch size ~ Binomial(n, q)", 60, |rng| {
        let n = 5_000 + rng.gen_range(20_000) as u32;
        let q = 0.05 + 0.9 * rng.next_f64();
        let s = PoissonSampler::new(n, q, rng.next_u64());
        let mean = n as f64 * q;
        let sd = (n as f64 * q * (1.0 - q)).sqrt();
        let b = s.sample(rng.next_u64() % 100).len() as f64;
        if (b - mean).abs() > 6.0 * sd {
            return Err(format!("batch {b} vs mean {mean} (sd {sd})"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------- batcher

#[test]
fn prop_masked_split_partitions_and_pads() {
    check("masked split: full shapes, masks sum to |L|, one boundary", 300, |rng| {
        let p = 1 + rng.gen_range(64);
        let tl = rng.gen_range(1000);
        let logical: Vec<u32> = (0..tl as u32).collect();
        let bmm = BatchMemoryManager::new(p, BatchingMode::Masked);
        let batches = bmm.split(&logical);
        if !batches.iter().all(|b| b.indices.len() == p) {
            return Err("non-uniform physical shape".into());
        }
        let real: usize = batches.iter().map(|b| b.real_count()).sum();
        if real != tl {
            return Err(format!("mask total {real} != |L| {tl}"));
        }
        let boundaries = batches.iter().filter(|b| b.step_boundary).count();
        if boundaries != 1 || !batches.last().unwrap().step_boundary {
            return Err("step boundary not exactly-last".into());
        }
        // Real examples appear in order, exactly once.
        let seq: Vec<u32> = batches
            .iter()
            .flat_map(|b| {
                b.indices
                    .iter()
                    .zip(&b.mask)
                    .filter(|(_, &m)| m > 0.0)
                    .map(|(&i, _)| i)
            })
            .collect();
        if seq != logical {
            return Err("real examples lost or reordered".into());
        }
        Ok(())
    });
}

#[test]
fn prop_naive_split_covers_with_available_sizes() {
    check("naive split: chunk sizes lowered, coverage exact", 300, |rng| {
        let mut sizes = vec![2usize, 4, 8, 16, 32];
        sizes.truncate(1 + rng.gen_range(5));
        let tl = rng.gen_range(500);
        let logical: Vec<u32> = (0..tl as u32).collect();
        let batches = BatchMemoryManager::split_naive(&logical, &sizes);
        for b in &batches {
            if !sizes.contains(&b.indices.len()) {
                return Err(format!("chunk size {} not lowered", b.indices.len()));
            }
        }
        let real: usize = batches.iter().map(|b| b.real_count()).sum();
        if real != tl {
            return Err(format!("coverage {real} != {tl}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------ privacy

#[test]
fn prop_rdp_monotone_in_all_arguments() {
    check("epsilon monotone in q, steps; antitone in sigma, delta", 80, |rng| {
        let acc = RdpAccountant::default();
        let q = 0.01 + 0.8 * rng.next_f64();
        let sigma = 0.5 + 4.0 * rng.next_f64();
        let steps = 1 + rng.gen_range(500) as u64;
        let delta = 1e-7 + 1e-4 * rng.next_f64();
        let e = acc.epsilon(q, sigma, steps, delta);
        if !(acc.epsilon((q * 1.2).min(1.0), sigma, steps, delta) >= e - 1e-9) {
            return Err("not monotone in q".into());
        }
        if !(acc.epsilon(q, sigma * 1.2, steps, delta) <= e + 1e-9) {
            return Err("not antitone in sigma".into());
        }
        if !(acc.epsilon(q, sigma, steps * 2, delta) >= e - 1e-9) {
            return Err("not monotone in steps".into());
        }
        if !(acc.epsilon(q, sigma, steps, delta * 10.0) <= e + 1e-9) {
            return Err("not antitone in delta".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rdp_subsampling_amplifies() {
    check("subsampled RDP <= full-batch RDP", 100, |rng| {
        let alpha = 2 + rng.gen_range(60) as u32;
        let sigma = 0.5 + 4.0 * rng.next_f64();
        let q = rng.next_f64();
        let sub = RdpAccountant::rdp_single(q, sigma, alpha);
        let full = RdpAccountant::rdp_single(1.0, sigma, alpha);
        if sub > full + 1e-12 {
            return Err(format!("q={q}: {sub} > {full}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------- memory

#[test]
fn prop_max_batch_monotone_in_budget_and_antitone_in_size() {
    check("memory planner monotonicity", 100, |rng| {
        let mem = MemModel::default();
        let depth = 2 + rng.gen_range(30);
        let dim = 64 * (1 + rng.gen_range(20));
        let a = vit("a", depth, dim, 4);
        let budget = 8e9 + rng.next_f64() * 72e9;
        for m in ClippingMethod::ALL {
            if !m.supports(a.family) {
                continue;
            }
            let b1 = mem.max_physical_batch(&a, *m, budget);
            let b2 = mem.max_physical_batch(&a, *m, budget * 1.5);
            if b2 < b1 {
                return Err(format!("{m:?}: bigger budget smaller batch"));
            }
        }
        // per-example <= ghost <= non-private at any budget
        let pe = mem.max_physical_batch(&a, ClippingMethod::PerExample, budget);
        let gh = mem.max_physical_batch(&a, ClippingMethod::Ghost, budget);
        let np = mem.max_physical_batch(&a, ClippingMethod::NonPrivate, budget);
        if !(pe <= gh && gh <= np) {
            return Err(format!("ordering violated: {pe} {gh} {np}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------- cluster

#[test]
fn prop_cluster_efficiency_bounded_and_slower_scales_better() {
    check("efficiency in (0,1]; slower compute => >= efficiency", 100, |rng| {
        let thr = 50.0 + rng.next_f64() * 5000.0;
        let params = 1e6 + rng.next_f64() * 1e9;
        let mk = |t: f64| ClusterSim {
            single_worker_throughput: t,
            local_batch: 32,
            grad_bytes: params * 4.0,
            overlap: rng_free_overlap(),
            serial_overhead: 1e-3,
            interconnect: Interconnect::default(),
        };
        fn rng_free_overlap() -> f64 {
            0.5
        }
        let n = 8 + 4 * rng.gen_range(19); // 8..80
        let fast = mk(thr).curve(&[n])[0].efficiency;
        let slow = mk(thr / (1.5 + 3.0 * rng.next_f64())).curve(&[n])[0].efficiency;
        if !(fast > 0.0 && fast <= 1.0 + 1e-12) {
            return Err(format!("efficiency out of range: {fast}"));
        }
        if slow + 1e-9 < fast {
            return Err(format!("slower compute scaled worse: {slow} < {fast}"));
        }
        Ok(())
    });
}

#[test]
fn prop_allreduce_monotone_in_bytes() {
    check("allreduce time monotone in message size", 200, |rng| {
        let ic = Interconnect::default();
        let n = 2 + rng.gen_range(127);
        let s1 = rng.next_f64() * 1e9;
        let s2 = s1 * (1.0 + rng.next_f64());
        if ring_allreduce_seconds(&ic, n, s2) + 1e-15 < ring_allreduce_seconds(&ic, n, s1) {
            return Err("not monotone".into());
        }
        Ok(())
    });
}

#[test]
fn prop_amdahl_fit_recovers_planted_fraction() {
    check("Amdahl fit inverts amdahl_speedup", 100, |rng| {
        let p = 0.8 + 0.1999 * rng.next_f64();
        let pts: Vec<(f64, f64)> = [2.0, 4.0, 8.0, 32.0, 80.0]
            .iter()
            .map(|&n| (n, dp_shortcuts::cluster::amdahl_speedup(p, n)))
            .collect();
        let got = fit_parallel_fraction(&pts);
        if (got - p).abs() > 1e-6 {
            return Err(format!("planted {p}, fit {got}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------- metrics

#[test]
fn prop_bootstrap_ci_brackets_median() {
    check("bootstrap CI contains the sample median", 60, |rng| {
        let n = 5 + rng.gen_range(200);
        let samples: Vec<f64> = (0..n).map(|_| 10.0 + rng.next_normal().abs() * 5.0).collect();
        let s = summary_with_ci(&samples, rng.next_u64());
        if !(s.ci_low <= s.median && s.median <= s.ci_high) {
            return Err(format!("CI [{}, {}] vs median {}", s.ci_low, s.ci_high, s.median));
        }
        Ok(())
    });
}
