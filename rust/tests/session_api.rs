//! Session-API invariants: the bound-buffer [`ExecSession`] path and
//! the step-driven [`TrainSession`] must be bitwise-identical to the
//! legacy entry points and to an uninterrupted [`Trainer::run`] — the
//! acceptance gate of the session redesign. Determinism here is a
//! DP-correctness property, not hygiene: the accumulator and the
//! seeded noise feed the privacy accounting.

use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::{
    per_step_noise_seed, TrainCheckpoint, TrainSession, Trainer,
};
use dp_shortcuts::runtime::{
    AccumArgs, ApplyArgs, Backend, ModelMeta, ReferenceBackend, Runtime, Tensor,
    REFERENCE_MODEL,
};
use dp_shortcuts::util::rng::ChaChaRng;
use proptest::prelude::*;
use std::path::Path;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn reference_meta() -> ModelMeta {
    ReferenceBackend::manifest(0).models[REFERENCE_MODEL].clone()
}

/// Deterministic batch (x, y) for the reference model from a seed.
fn synth_batch(meta: &ModelMeta, batch: usize, data_seed: u64) -> (Vec<f32>, Vec<i32>) {
    let d = meta.image * meta.image * meta.channels;
    let mut rng = ChaChaRng::from_seed_stream(data_seed, 0, b"sessdata");
    let x: Vec<f32> = (0..batch * d).map(|_| rng.next_normal() as f32).collect();
    let y: Vec<i32> = (0..batch)
        .map(|_| (rng.next_u32() % meta.num_classes as u32) as i32)
        .collect();
    (x, y)
}

fn train_config(variant: &str, mode: BatchingMode, seed: u64) -> TrainConfig {
    TrainConfig {
        model: REFERENCE_MODEL.into(),
        variant: variant.into(),
        mode,
        dataset_size: 48,
        sampling_rate: 0.25,
        physical_batch: 4,
        steps: 4,
        lr: 0.05,
        noise_multiplier: Some(1.1),
        eval_examples: 0,
        seed,
        ..Default::default()
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(32))]

    /// A session driven through a multi-call sequence — accum, accum,
    /// apply, zero_acc, accum — is bitwise-identical to the same
    /// sequence through the legacy donating entry points with
    /// host-held buffers, across clipping variants, batch sizes, mask
    /// patterns (including all-masked), data, and noise seeds.
    #[test]
    fn session_sequence_bitwise_matches_legacy(
        variant_idx in 0usize..4,
        batch_idx in 0usize..4,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
        noise_seed in proptest::num::u64::ANY,
    ) {
        let variant = ["nonprivate", "masked", "ghost", "bk"][variant_idx];
        let batch = [1usize, 2, 8, 16][batch_idx];
        let backend = ReferenceBackend::new(0);
        let meta = reference_meta();
        let exe = meta.find_accum(variant, batch, "f32").unwrap().clone();
        let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
        let apply_exe = meta.find_apply().unwrap().clone();
        let apply_prep = backend.prepare(Path::new("."), &meta, &apply_exe).unwrap();
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let args = AccumArgs { x: &x, y: &y, mask: &mask };
        let apply = ApplyArgs { seed: noise_seed, denom: 6.0, lr: 0.1, noise_mult: 1.1 };

        let mut sess = backend
            .open_session(Path::new("."), &meta, params.clone())
            .unwrap();
        // Legacy side: host-held buffers through the donating forms.
        let mut p = params.clone();
        let mut acc = Tensor::zeros(meta.n_params);

        for _ in 0..2 {
            let s = sess.accum(&prep, &args).unwrap();
            let l = backend
                .run_accum_into(&prep, &meta, &p, &mut acc, &args)
                .unwrap();
            prop_assert_eq!(s.loss_sum.to_bits(), l.loss_sum.to_bits());
            prop_assert_eq!(bits(&s.sq_norms), bits(&l.sq_norms));
        }
        sess.apply(&apply_prep, &apply).unwrap();
        backend
            .run_apply_into(&apply_prep, &meta, &mut p, &acc, &apply)
            .unwrap();
        prop_assert_eq!(
            bits(sess.read_params().unwrap().as_slice()),
            bits(p.as_slice())
        );

        // zero_acc resets the bound accumulator to a fresh-step state.
        sess.zero_acc().unwrap();
        acc.fill(0.0);
        let s = sess.accum(&prep, &args).unwrap();
        let l = backend
            .run_accum_into(&prep, &meta, &p, &mut acc, &args)
            .unwrap();
        prop_assert_eq!(s.loss_sum.to_bits(), l.loss_sum.to_bits());

        // And one more apply so the accumulated state is observable in
        // the parameters.
        let apply2 = ApplyArgs { seed: noise_seed ^ 1, ..apply };
        sess.apply(&apply_prep, &apply2).unwrap();
        backend
            .run_apply_into(&apply_prep, &meta, &mut p, &acc, &apply2)
            .unwrap();
        prop_assert_eq!(
            bits(sess.read_params().unwrap().as_slice()),
            bits(p.as_slice())
        );
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(10))]

    /// A TrainSession driven step-by-step — including a checkpoint →
    /// JSON round-trip → drop → resume on a *fresh* runtime at a
    /// mid-run step — finishes bitwise-identical to one uninterrupted
    /// `Trainer::run()`, for both batching modes and across seeds.
    #[test]
    fn stepped_and_resumed_session_matches_uninterrupted_run(
        seed in 0u64..1_000,
        masked in proptest::bool::ANY,
        split_at in 1u64..4,
    ) {
        let (variant, mode) = if masked {
            ("masked", BatchingMode::Masked)
        } else {
            ("naive", BatchingMode::Variable)
        };
        let cfg = train_config(variant, mode, seed);

        let uninterrupted = {
            let rt = Runtime::reference();
            Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap()
        };

        // Step-driven with a save → drop → load → resume round-trip.
        let ckpt_json = {
            let rt = Runtime::reference();
            let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
            for _ in 0..split_at {
                s.step().unwrap();
            }
            s.checkpoint().unwrap().to_json().unwrap()
            // session and runtime dropped here
        };
        let rt2 = Runtime::reference();
        let ckpt = TrainCheckpoint::from_json(&ckpt_json).unwrap();
        let mut resumed = TrainSession::resume(&rt2, cfg.clone(), ckpt).unwrap();
        while !resumed.done() {
            resumed.step().unwrap();
        }
        let rep = resumed.finish().unwrap();

        prop_assert_eq!(
            bits(&rep.final_params),
            bits(&uninterrupted.final_params),
            "resume diverged from the uninterrupted run"
        );
        prop_assert_eq!(rep.steps.len(), uninterrupted.steps.len());
        for (a, b) in rep.steps.iter().zip(&uninterrupted.steps) {
            prop_assert_eq!(a.step, b.step);
            prop_assert_eq!(a.logical_batch, b.logical_batch);
            prop_assert_eq!(a.computed_examples, b.computed_examples);
            prop_assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        }
        // The accountant replay reproduces the composition exactly.
        prop_assert_eq!(
            rep.epsilon_spent.to_bits(),
            uninterrupted.epsilon_spent.to_bits()
        );
    }
}

#[test]
fn step_driven_session_matches_thin_run_wrapper() {
    // Trainer::run is a thin loop over TrainSession — driving the
    // session by hand must land on the identical parameter trajectory
    // and step logs.
    let cfg = train_config("masked", BatchingMode::Masked, 7);
    let rt = Runtime::reference();
    let report = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();

    let rt2 = Runtime::reference();
    let mut session = TrainSession::new(&rt2, cfg.clone()).unwrap();
    let mut last = None;
    while !session.done() {
        last = Some(session.step().unwrap());
    }
    let log = last.unwrap();
    assert_eq!(log.step, cfg.steps - 1);
    let params = session.read_params().unwrap();
    assert_eq!(bits(params.as_slice()), bits(&report.final_params));
    // Spot-check the seed layout is what the backends fold.
    let s = per_step_noise_seed(cfg.seed, 3);
    assert_eq!(s & 0xffff_ffff, 3);
}

#[test]
fn mid_run_eval_does_not_perturb_training() {
    // Eval cadence: running held-out evaluation between steps must not
    // change a single bit of the training trajectory (eval is
    // forward-only on the bound params).
    let mut cfg = train_config("masked", BatchingMode::Masked, 3);
    cfg.eval_examples = 64;

    let plain = {
        let rt = Runtime::reference();
        Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap()
    };
    let rt = Runtime::reference();
    let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
    let mut evals = Vec::new();
    while !s.done() {
        s.step().unwrap();
        evals.push(s.eval().unwrap());
    }
    let rep = s.finish().unwrap();
    assert_eq!(bits(&rep.final_params), bits(&plain.final_params));
    // Every mid-run eval covered the full requested batches, and the
    // final eval matches the uninterrupted run's.
    for (loss, acc, covered) in &evals {
        assert_eq!(*covered, 64);
        assert!(loss.unwrap().is_finite() && acc.unwrap() >= 0.0);
    }
    assert_eq!(rep.eval_loss, plain.eval_loss);
    assert_eq!(rep.eval_accuracy, plain.eval_accuracy);
    assert_eq!(rep.eval_covered, plain.eval_covered);
}

#[test]
fn resume_rejects_corrupt_or_mismatched_checkpoints() {
    let cfg = train_config("masked", BatchingMode::Masked, 0);
    let rt = Runtime::reference();
    let good = {
        let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
        s.step().unwrap();
        s.checkpoint().unwrap()
    };
    // The genuine checkpoint resumes fine.
    assert!(TrainSession::resume(&rt, cfg.clone(), good.clone()).is_ok());
    // Wrong parameter length.
    let mut bad = good.clone();
    bad.params = vec![0.0; 3];
    assert!(TrainSession::resume(&rt, cfg.clone(), bad).is_err());
    // Step counter disagreeing with the logs — a truncated/hand-edited
    // checkpoint must not resume silently.
    let mut truncated = good.clone();
    truncated.steps.clear();
    assert!(TrainSession::resume(&rt, cfg.clone(), truncated).is_err());
    // A config that shapes a different trajectory (different seed →
    // different sampling + noise) must be rejected: replaying the
    // accountant under it would mis-report epsilon.
    let mut other_cfg = cfg.clone();
    other_cfg.seed += 1;
    assert!(TrainSession::resume(&rt, other_cfg, good.clone()).is_err());
    // A checkpoint already past the configured step count is stale.
    let mut short_cfg = cfg.clone();
    short_cfg.steps = 0;
    // (fingerprint does not cover `steps`, so this exercises the
    // step-count guard, not the fingerprint.)
    assert!(TrainSession::resume(&rt, short_cfg, good).is_err());
}

#[test]
fn warm_start_via_write_params_matches_checkpoint_file_roundtrip() {
    // --save-params / --load-params seam: params written through
    // ModelRuntime::save_params and loaded back into a fresh session
    // reproduce the exact trajectory of a continued run.
    let cfg = train_config("masked", BatchingMode::Masked, 11);
    let rt = Runtime::reference();
    let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
    s.step().unwrap();
    let params = s.read_params().unwrap();
    let path = std::env::temp_dir().join("dpshort_session_warm_start.bin");
    s.model().save_params(&params, &path).unwrap();

    let rt2 = Runtime::reference();
    let mut warm = TrainSession::new(&rt2, cfg).unwrap();
    let loaded = warm.model().load_params(&path).unwrap();
    warm.write_params(loaded).unwrap();
    assert_eq!(
        bits(warm.read_params().unwrap().as_slice()),
        bits(params.as_slice())
    );
    let _ = std::fs::remove_file(&path);
}
