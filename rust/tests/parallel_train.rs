//! Data-parallel determinism invariants — the acceptance gate of the
//! multi-session executor (DESIGN.md §8): a `TrainSession` at any
//! worker count must be **bitwise-identical** (parameters, losses,
//! epsilon) to every other worker count and to the plain
//! single-session `Trainer::run`, across batching modes, masks
//! (including empty Poisson batches), seeds, **and models** (the
//! layered-IR `mlp-small` as well as the seed single-layer model —
//! the PR-4 contracts must survive the multi-layer refactor) — and a
//! checkpoint taken at 4 workers must resume at 1 worker (and vice
//! versa) exactly as if the worker count had never changed.

use dp_shortcuts::cluster::parallel::{plan_groups, reduce_fixed_tree, shard_ranges};
use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::{TrainCheckpoint, TrainSession, Trainer};
use dp_shortcuts::runtime::{Runtime, Tensor, REFERENCE_MODEL};
use proptest::prelude::*;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// `model` is one of the CPU ladder's executable models: the PR-4
/// contracts (worker-count invariance, padding neutrality, checkpoint
/// portability) must hold for multi-layer models too, so the proptests
/// sample over both the seed single-layer model and `mlp-small`.
const MODELS: &[&str] = &[REFERENCE_MODEL, "mlp-small"];

fn config(
    model: &str,
    variant: &str,
    mode: BatchingMode,
    seed: u64,
    workers: usize,
) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        variant: variant.into(),
        mode,
        dataset_size: 48,
        sampling_rate: 0.4,
        physical_batch: 4,
        steps: 4,
        lr: 0.05,
        noise_multiplier: Some(1.1),
        eval_examples: 0,
        seed,
        workers,
        ..Default::default()
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(12))]

    /// The headline contract: 1-, 2-, and 4-worker runs land on the
    /// same bits as the legacy single-session `Trainer::run` path, in
    /// both batching modes, across seeds and sampling rates (including
    /// rates that produce empty logical batches).
    #[test]
    fn worker_count_never_changes_the_bits(
        seed in 0u64..1_000,
        masked in proptest::bool::ANY,
        rate_idx in 0usize..3,
        model_idx in 0usize..2,
    ) {
        let (variant, mode) = if masked {
            ("masked", BatchingMode::Masked)
        } else {
            ("naive", BatchingMode::Variable)
        };
        let model = MODELS[model_idx];
        let mut reference: Option<dp_shortcuts::TrainReport> = None;
        for workers in [1usize, 2, 4] {
            let mut cfg = config(model, variant, mode, seed, workers);
            cfg.sampling_rate = [0.0, 0.2, 0.5][rate_idx];
            let rt = Runtime::reference();
            let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
            if let Some(want) = &reference {
                prop_assert_eq!(
                    bits(&rep.final_params),
                    bits(&want.final_params),
                    "workers={} diverged from workers=1 ({variant})",
                    workers
                );
                prop_assert_eq!(rep.steps.len(), want.steps.len());
                for (a, b) in rep.steps.iter().zip(&want.steps) {
                    prop_assert_eq!(a.logical_batch, b.logical_batch);
                    prop_assert_eq!(a.physical_batches, b.physical_batches);
                    prop_assert_eq!(a.computed_examples, b.computed_examples);
                    prop_assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "workers={}", workers);
                }
                prop_assert_eq!(rep.epsilon_spent.to_bits(), want.epsilon_spent.to_bits());
            } else {
                reference = Some(rep);
            }
        }
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(8))]

    /// Checkpoint portability across worker counts: train at 4
    /// workers, checkpoint mid-run, resume at 1 worker (and the
    /// reverse) — both finish bitwise-identical to an uninterrupted
    /// single-worker run. `workers` is deliberately outside the
    /// checkpoint fingerprint.
    #[test]
    fn checkpoint_resumes_across_worker_counts(
        seed in 0u64..1_000,
        masked in proptest::bool::ANY,
        split_at in 1u64..4,
        model_idx in 0usize..2,
    ) {
        let (variant, mode) = if masked {
            ("masked", BatchingMode::Masked)
        } else {
            ("naive", BatchingMode::Variable)
        };
        let model = MODELS[model_idx];
        let uninterrupted = {
            let rt = Runtime::reference();
            let cfg = config(model, variant, mode, seed, 1);
            Trainer::new(&rt, cfg).unwrap().run().unwrap()
        };

        for (train_workers, resume_workers) in [(4usize, 1usize), (1, 4)] {
            let ckpt_json = {
                let rt = Runtime::reference();
                let cfg = config(model, variant, mode, seed, train_workers);
                let mut s = TrainSession::new(&rt, cfg).unwrap();
                for _ in 0..split_at {
                    s.step().unwrap();
                }
                s.checkpoint().unwrap().to_json().unwrap()
            };
            let rt = Runtime::reference();
            let cfg = config(model, variant, mode, seed, resume_workers);
            let ckpt = TrainCheckpoint::from_json(&ckpt_json).unwrap();
            let mut resumed = TrainSession::resume(&rt, cfg, ckpt).unwrap();
            while !resumed.done() {
                resumed.step().unwrap();
            }
            let rep = resumed.finish().unwrap();
            prop_assert_eq!(
                bits(&rep.final_params),
                bits(&uninterrupted.final_params),
                "checkpoint at {} workers did not resume at {} workers",
                train_workers,
                resume_workers
            );
            prop_assert_eq!(
                rep.epsilon_spent.to_bits(),
                uninterrupted.epsilon_spent.to_bits()
            );
        }
    }
}

/// Masked and naive-variable runs stay bitwise-identical under
/// data-parallel execution: the accumulation-group grid — not the
/// executable chunking — defines the reduction, so Algorithm-2 padding
/// neutrality survives at every worker count.
#[test]
fn padding_neutrality_holds_at_every_worker_count() {
    for model in MODELS {
        for workers in [1usize, 2, 4] {
            let masked = {
                let rt = Runtime::reference();
                let cfg = config(model, "masked", BatchingMode::Masked, 7, workers);
                Trainer::new(&rt, cfg).unwrap().run().unwrap()
            };
            let naive = {
                let rt = Runtime::reference();
                let cfg = config(model, "naive", BatchingMode::Variable, 7, workers);
                Trainer::new(&rt, cfg).unwrap().run().unwrap()
            };
            assert_eq!(
                bits(&masked.final_params),
                bits(&naive.final_params),
                "{model} workers={workers}: Algorithm-2 padding changed the update"
            );
        }
    }
}

/// More workers than accumulation groups (and a worker count that does
/// not divide the group count) must be handled — surplus ranks idle,
/// bits unchanged.
#[test]
fn surplus_and_ragged_worker_counts_are_exact() {
    let base = {
        let rt = Runtime::reference();
        Trainer::new(&rt, config("mlp-small", "masked", BatchingMode::Masked, 3, 1))
            .unwrap()
            .run()
            .unwrap()
    };
    for workers in [3usize, 7, 32] {
        let rt = Runtime::reference();
        let cfg = config("mlp-small", "masked", BatchingMode::Masked, 3, workers);
        let rep = Trainer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(bits(&rep.final_params), bits(&base.final_params), "workers={workers}");
    }
}

/// A zero physical batch must fail at session construction with a
/// clear error, not panic inside the first step's group planner (the
/// guard the old BatchMemoryManager constructor used to assert).
#[test]
fn zero_physical_batch_is_a_construction_error() {
    for (variant, mode) in [("masked", BatchingMode::Masked), ("naive", BatchingMode::Variable)] {
        let rt = Runtime::reference();
        let mut cfg = config(REFERENCE_MODEL, variant, mode, 0, 1);
        cfg.physical_batch = 0;
        let err = TrainSession::new(&rt, cfg).err().expect("must not construct");
        assert!(err.to_string().contains("physical batch"), "{err:#}");
    }
}

/// `workers: 0` is floored to one session, not an error (the CLI
/// default path).
#[test]
fn zero_workers_means_one() {
    let rt = Runtime::reference();
    let zero = Trainer::new(&rt, config(REFERENCE_MODEL, "masked", BatchingMode::Masked, 5, 0))
        .unwrap()
        .run()
        .unwrap();
    let one_cfg = config(REFERENCE_MODEL, "masked", BatchingMode::Masked, 5, 1);
    let one = Trainer::new(&Runtime::reference(), one_cfg)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(bits(&zero.final_params), bits(&one.final_params));
}

/// A warm start written through `TrainSession::write_params` reaches
/// every rank: the broadcast keeps multi-worker warm starts identical
/// to single-worker ones.
#[test]
fn warm_start_broadcasts_to_all_ranks() {
    let rt = Runtime::reference();
    let mut donor =
        TrainSession::new(&rt, config("mlp-small", "masked", BatchingMode::Masked, 9, 1)).unwrap();
    donor.step().unwrap();
    let warm = donor.read_params().unwrap();

    let run_from = |workers: usize, params: Tensor| {
        let rt = Runtime::reference();
        let mut s =
            TrainSession::new(&rt, config("mlp-small", "masked", BatchingMode::Masked, 9, workers))
                .unwrap();
        s.write_params(params).unwrap();
        while !s.done() {
            s.step().unwrap();
        }
        s.finish().unwrap()
    };
    let solo = run_from(1, warm.clone());
    let fleet = run_from(4, warm);
    assert_eq!(bits(&solo.final_params), bits(&fleet.final_params));
}

/// Unit-level spot checks of the building blocks exposed through
/// `cluster::parallel` (the proptest-heavy coverage lives in the
/// module's own tests; this pins the public seam).
#[test]
fn parallel_building_blocks_are_exposed_and_deterministic() {
    let ranges = shard_ranges(10, 4);
    assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), 10);
    let groups = plan_groups(&(0..10u32).collect::<Vec<_>>(), 4, BatchingMode::Masked, &[4]);
    assert_eq!(groups.len(), 3);
    let reduced = reduce_fixed_tree(vec![
        Tensor::vec1(&[1.0, 2.0]),
        Tensor::vec1(&[10.0, 20.0]),
        Tensor::vec1(&[100.0, 200.0]),
    ])
    .unwrap();
    assert_eq!(reduced.as_slice(), &[111.0, 222.0]);
}
