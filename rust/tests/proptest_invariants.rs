//! Crate invariants under `proptest` (the real crate — the workspace
//! now carries dev-dependencies). Complements rust/tests/proptests.rs,
//! which exercises the in-tree randomized runner; these cover the
//! regressions fixed alongside the backend refactor.

use dp_shortcuts::coordinator::sampler::{Sampler, ShuffleSampler};
use dp_shortcuts::coordinator::trainer::per_step_noise_seed;
use dp_shortcuts::privacy::RdpAccountant;
use dp_shortcuts::runtime::Tensor;
use proptest::prelude::*;

proptest! {
    /// Within one run the per-step noise seed is injective in `step` —
    /// the property the old i32 folding violated (cross-run uniqueness
    /// is only probabilistic via the 32-bit stream id, so it is not
    /// asserted here). The 32-bit ABI fold must stay injective too.
    #[test]
    fn noise_seeds_injective_within_a_run(seed in proptest::num::u64::ANY, s in 0u64..1_000_000, t in 0u64..1_000_000) {
        prop_assume!(s != t);
        let a = per_step_noise_seed(seed, s);
        let b = per_step_noise_seed(seed, t);
        prop_assert_ne!(a, b);
        let fold = |v: u64| ((v >> 32) ^ (v & 0xffff_ffff)) as u32;
        prop_assert_ne!(fold(a), fold(b));
    }

    /// Every epoch of the shuffle sampler is a permutation of the whole
    /// dataset, including when the batch size does not divide n (the
    /// dropped-tail regression).
    #[test]
    fn shuffle_epochs_cover_every_example(n in 1u32..400, batch in 1u32..64, seed in 0u64..100, epoch in 0u64..3) {
        let batch = batch.min(n);
        let s = ShuffleSampler::new(n, batch, seed);
        let steps_per_epoch = n.div_ceil(batch) as u64;
        let lo = epoch * steps_per_epoch;
        let mut seen: Vec<u32> =
            (lo..lo + steps_per_epoch).flat_map(|t| s.sample(t)).collect();
        seen.sort_unstable();
        prop_assert_eq!(seen, (0..n).collect::<Vec<u32>>());
        prop_assert!(s.expected_batch_size() <= batch as f64 + 1e-12);
    }

    /// Epsilon is always finite and non-negative — the clamped-at-zero
    /// fallback closes the corner where every RDP order's candidate is
    /// negative (the old code reported +infinity there).
    #[test]
    fn epsilon_finite_and_nonnegative(q in 0.0f64..1.0, sigma in 0.5f64..200.0, steps in 1u64..100, delta_exp in 1.0f64..7.0) {
        let delta = 10f64.powf(-delta_exp);
        let acc = RdpAccountant::default();
        let eps = acc.epsilon(q, sigma, steps, delta);
        prop_assert!(eps.is_finite(), "eps = {eps}");
        prop_assert!(eps >= 0.0, "eps = {eps}");
    }

    /// Tensor roundtrips preserve the buffer exactly.
    #[test]
    fn tensor_roundtrip(data in proptest::collection::vec(-1e6f32..1e6, 0..64)) {
        let t = Tensor::vec1(&data);
        prop_assert_eq!(t.len(), data.len());
        prop_assert_eq!(t.to_vec(), data.clone());
        prop_assert_eq!(Tensor::from_vec(data.clone()).into_vec(), data);
    }
}
