//! Crate invariants under `proptest` (the real crate — the workspace
//! now carries dev-dependencies). Complements rust/tests/proptests.rs,
//! which exercises the in-tree randomized runner; these cover the
//! regressions fixed alongside the backend refactor.

use dp_shortcuts::coordinator::sampler::{Sampler, ShuffleSampler};
use dp_shortcuts::coordinator::trainer::per_step_noise_seed;
use dp_shortcuts::privacy::RdpAccountant;
use dp_shortcuts::runtime::{
    AccumArgs, ApplyArgs, Backend, ModelMeta, ReferenceBackend, Tensor, REFERENCE_MODEL,
};
use dp_shortcuts::util::rng::ChaChaRng;
use proptest::prelude::*;
use std::path::Path;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn reference_meta() -> ModelMeta {
    ReferenceBackend::manifest(0).models[REFERENCE_MODEL].clone()
}

/// Deterministic batch (x, y) for the reference model from a seed.
fn synth_batch(meta: &ModelMeta, batch: usize, data_seed: u64) -> (Vec<f32>, Vec<i32>) {
    let d = meta.image * meta.image * meta.channels;
    let mut rng = ChaChaRng::from_seed_stream(data_seed, 0, b"propdata");
    let x: Vec<f32> = (0..batch * d).map(|_| rng.next_normal() as f32).collect();
    let y: Vec<i32> = (0..batch)
        .map(|_| (rng.next_u32() % meta.num_classes as u32) as i32)
        .collect();
    (x, y)
}

/// Non-trivial starting accumulator (mid-logical-batch state).
fn synth_acc(meta: &ModelMeta, acc_seed: u64) -> Tensor {
    let mut rng = ChaChaRng::from_seed_stream(acc_seed, 1, b"propacc\0");
    let mut acc = Tensor::zeros(meta.n_params);
    for v in acc.as_mut_slice().iter_mut() {
        *v = (0.1 * rng.next_normal()) as f32;
    }
    acc
}

proptest! {
    /// Within one run the per-step noise seed is injective in `step` —
    /// the property the old i32 folding violated (cross-run uniqueness
    /// is only probabilistic via the 32-bit stream id, so it is not
    /// asserted here). The 32-bit ABI fold must stay injective too.
    #[test]
    fn noise_seeds_injective_within_a_run(seed in proptest::num::u64::ANY, s in 0u64..1_000_000, t in 0u64..1_000_000) {
        prop_assume!(s != t);
        let a = per_step_noise_seed(seed, s);
        let b = per_step_noise_seed(seed, t);
        prop_assert_ne!(a, b);
        let fold = |v: u64| ((v >> 32) ^ (v & 0xffff_ffff)) as u32;
        prop_assert_ne!(fold(a), fold(b));
    }

    /// Every epoch of the shuffle sampler is a permutation of the whole
    /// dataset, including when the batch size does not divide n (the
    /// dropped-tail regression).
    #[test]
    fn shuffle_epochs_cover_every_example(n in 1u32..400, batch in 1u32..64, seed in 0u64..100, epoch in 0u64..3) {
        let batch = batch.min(n);
        let s = ShuffleSampler::new(n, batch, seed);
        let steps_per_epoch = n.div_ceil(batch) as u64;
        let lo = epoch * steps_per_epoch;
        let mut seen: Vec<u32> =
            (lo..lo + steps_per_epoch).flat_map(|t| s.sample(t)).collect();
        seen.sort_unstable();
        prop_assert_eq!(seen, (0..n).collect::<Vec<u32>>());
        prop_assert!(s.expected_batch_size() <= batch as f64 + 1e-12);
    }

    /// Epsilon is always finite and non-negative — the clamped-at-zero
    /// fallback closes the corner where every RDP order's candidate is
    /// negative (the old code reported +infinity there).
    #[test]
    fn epsilon_finite_and_nonnegative(q in 0.0f64..1.0, sigma in 0.5f64..200.0, steps in 1u64..100, delta_exp in 1.0f64..7.0) {
        let delta = 10f64.powf(-delta_exp);
        let acc = RdpAccountant::default();
        let eps = acc.epsilon(q, sigma, steps, delta);
        prop_assert!(eps.is_finite(), "eps = {eps}");
        prop_assert!(eps >= 0.0, "eps = {eps}");
    }

    /// Tensor roundtrips preserve the buffer exactly.
    #[test]
    fn tensor_roundtrip(data in proptest::collection::vec(-1e6f32..1e6, 0..64)) {
        let t = Tensor::vec1(&data);
        prop_assert_eq!(t.len(), data.len());
        prop_assert_eq!(t.to_vec(), data.clone());
        prop_assert_eq!(Tensor::from_vec(data.clone()).into_vec(), data);
    }
}

// Donation + determinism invariants of the execution ABI, driven
// through the **session API** (`Backend::open_session`) — per the PR-4
// deprecation plan, first-party tests no longer call the legacy
// donating shims (`run_accum_into`/`run_apply_into`); the only
// remaining legacy call sites are `rust/tests/session_api.rs`, whose
// explicit job is the session-vs-legacy equivalence gate. The copying
// forms exercised here are the trait's required primitives, giving an
// independent second path to compare against. Determinism is a
// DP-correctness property, not hygiene: the accumulator and the seeded
// noise feed the privacy accounting, so the session hot path (the
// native in-place kernels) and the copying path must agree *bitwise*,
// and threading must never perturb a single bit.
proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    /// The session accum path is bitwise-identical to the copying
    /// primitive across every clipping variant (the executed
    /// per-example/ghost/mix graphs included), batch size, mask pattern
    /// (including all-masked), data, and accumulator state.
    #[test]
    fn session_accum_bitwise_matches_copying(
        variant_idx in 0usize..6,
        batch_idx in 0usize..5,
        mask_bits in prop_oneof![Just(0u32), Just(u32::MAX), proptest::num::u32::ANY],
        data_seed in proptest::num::u64::ANY,
        acc_seed in proptest::num::u64::ANY,
    ) {
        let variant = ["nonprivate", "masked", "ghost", "bk", "perex", "mix"][variant_idx];
        let batch = [1usize, 2, 4, 8, 16][batch_idx];
        let backend = ReferenceBackend::new(0);
        let meta = reference_meta();
        let exe = meta.find_accum(variant, batch, "f32").unwrap().clone();
        let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let acc0 = synth_acc(&meta, acc_seed);
        let args = AccumArgs { x: &x, y: &y, mask: &mask };

        let copied = backend
            .run_accum(&prep, &meta, &params, &acc0, &args)
            .unwrap();
        // Session side: bind the params, install the mid-logical-batch
        // accumulator through the all-reduce seam, run the bound-buffer
        // accum.
        let mut sess = backend
            .open_session(Path::new("."), &meta, params.clone())
            .unwrap();
        sess.write_acc(acc0.clone()).unwrap();
        let stats = sess.accum(&prep, &args).unwrap();
        let session_acc = sess.read_acc().unwrap();

        prop_assert_eq!(bits(copied.acc.as_slice()), bits(session_acc.as_slice()));
        prop_assert_eq!(copied.loss_sum.to_bits(), stats.loss_sum.to_bits());
        prop_assert_eq!(bits(&copied.sq_norms), bits(&stats.sq_norms));
        // All-masked batches must leave the accumulator untouched.
        if mask.iter().all(|m| *m == 0.0) {
            prop_assert_eq!(bits(session_acc.as_slice()), bits(acc0.as_slice()));
        }
    }

    /// The session apply path is bitwise-identical to the copying
    /// primitive across noise seeds, with and without the Gaussian
    /// path.
    #[test]
    fn session_apply_bitwise_matches_copying(
        noise_seed in proptest::num::u64::ANY,
        acc_seed in proptest::num::u64::ANY,
        noise_on in proptest::bool::ANY,
        denom in 0.5f32..64.0,
        lr in 1e-4f32..0.5,
    ) {
        let backend = ReferenceBackend::new(0);
        let meta = reference_meta();
        let exe = meta.find_apply().unwrap().clone();
        let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
        let params = backend.init_params(Path::new("."), &meta).unwrap();
        let acc = synth_acc(&meta, acc_seed);
        let noise_mult = if noise_on { 1.1 } else { 0.0 };
        let args = ApplyArgs { seed: noise_seed, denom, lr, noise_mult };

        let copied = backend
            .run_apply(&prep, &meta, &params, &acc, &args)
            .unwrap();
        let mut sess = backend
            .open_session(Path::new("."), &meta, params.clone())
            .unwrap();
        sess.write_acc(acc.clone()).unwrap();
        sess.apply(&prep, &args).unwrap();
        prop_assert_eq!(
            bits(copied.as_slice()),
            bits(sess.read_params().unwrap().as_slice())
        );
    }

    /// Threaded session accum is bitwise-reproducible: the
    /// worker-thread count is a wall-clock knob only. Batch 32 sits
    /// above the threading gate, so 1-vs-N genuinely compares
    /// sequential to parallel.
    #[test]
    fn accum_bits_independent_of_thread_count(
        threads in 2usize..5,
        mask_bits in proptest::num::u32::ANY,
        data_seed in proptest::num::u64::ANY,
    ) {
        let batch = 32usize;
        let meta = reference_meta();
        let (x, y) = synth_batch(&meta, batch, data_seed);
        let mask: Vec<f32> = (0..batch)
            .map(|i| if (mask_bits >> (i % 32)) & 1 == 1 { 1.0 } else { 0.0 })
            .collect();
        let run = |nthreads: usize| {
            let backend = ReferenceBackend::with_threads(0, nthreads);
            let exe = meta.find_accum("masked", batch, "f32").unwrap().clone();
            let prep = backend.prepare(Path::new("."), &meta, &exe).unwrap();
            let params = backend.init_params(Path::new("."), &meta).unwrap();
            let args = AccumArgs { x: &x, y: &y, mask: &mask };
            let mut sess = backend
                .open_session(Path::new("."), &meta, params)
                .unwrap();
            let stats = sess.accum(&prep, &args).unwrap();
            (sess.read_acc().unwrap(), stats)
        };
        let (acc_seq, stats_seq) = run(1);
        let (acc_par, stats_par) = run(threads);
        prop_assert_eq!(bits(acc_seq.as_slice()), bits(acc_par.as_slice()));
        prop_assert_eq!(stats_seq.loss_sum.to_bits(), stats_par.loss_sum.to_bits());
        prop_assert_eq!(bits(&stats_seq.sq_norms), bits(&stats_par.sq_norms));
    }
}
