//! Chaos suite for the fault-tolerant training runtime (DESIGN.md §11):
//! for **any** injected fault schedule, a run either recovers to a
//! trajectory bitwise-identical to the fault-free run — parameters,
//! per-step losses, and epsilon — or aborts with a *typed* error.
//! Never a panic across the API boundary, never an epsilon overspend,
//! never a noise stream reused for a different draw (the bit-equality
//! of the recovered trajectory is exactly that property: a retry that
//! redrew the mask or advanced the noise stream could not reproduce
//! the fault-free bits).

use dp_shortcuts::cluster::parallel::WorkerFailure;
use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::{
    config_fingerprint, resolve_sigma, TrainCheckpoint, TrainReport, TrainSession, Trainer,
};
use dp_shortcuts::fault::{
    checkpoint_file_name, faulty_runtime, latest_valid, load_checkpoint, write_checkpoint,
    CheckpointError, FaultPlan,
};
use dp_shortcuts::runtime::{Runtime, REFERENCE_MODEL};
use proptest::prelude::*;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::Arc;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Injected worker panics are *expected* here; silence their default
/// hook output so chaos runs don't spam the test log. Everything else
/// still prints through the previous hook.
fn quiet_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("injected worker panic") {
                prev(info);
            }
        }));
    });
}

/// Small-but-multi-group config: E[L] = 24 over physical batch 4, so
/// every step has ~6 accumulation groups to shard, fail, and re-run.
fn chaos_config(variant: &str, workers: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        model: REFERENCE_MODEL.into(),
        variant: variant.into(),
        mode: BatchingMode::Masked,
        dataset_size: 48,
        sampling_rate: 0.5,
        physical_batch: 4,
        steps: 3,
        lr: 0.05,
        noise_multiplier: Some(1.0),
        eval_examples: 0,
        seed,
        workers,
        ..Default::default()
    }
}

/// The fault-free trajectory every recovered run must reproduce.
/// Runs single-worker: the fixed-tree contract says worker count never
/// moves bits, so this is also the N-worker fault-free trajectory.
fn baseline(cfg: &TrainConfig) -> TrainReport {
    let mut c = cfg.clone();
    c.workers = 1;
    let rt = Runtime::reference();
    Trainer::new(&rt, c).unwrap().run().unwrap()
}

/// Drive a full run over a fault-wrapped runtime.
fn chaos_run(cfg: &TrainConfig, plan: Arc<FaultPlan>) -> anyhow::Result<TrainReport> {
    let rt = Runtime::reference();
    let frt = faulty_runtime(&rt, Arc::clone(&plan));
    let mut s = TrainSession::with_faults(&frt, cfg.clone(), plan)?;
    while !s.done() {
        s.step()?;
    }
    s.finish()
}

fn assert_matches_baseline(rep: &TrainReport, base: &TrainReport) {
    assert_eq!(
        bits(&rep.final_params),
        bits(&base.final_params),
        "recovered run diverged from the fault-free trajectory"
    );
    assert_eq!(rep.steps.len(), base.steps.len());
    for (a, b) in rep.steps.iter().zip(&base.steps) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.logical_batch, b.logical_batch, "step {}", a.step);
        assert_eq!(a.computed_examples, b.computed_examples, "step {}", a.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    assert_eq!(rep.epsilon_spent.to_bits(), base.epsilon_spent.to_bits());
}

/// Fresh scratch dir under the system temp root, cleaned on entry so a
/// crashed previous run can't leak stale files into the assertions.
fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpshort_fault_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------
// Deterministic single-fault scenarios
// ---------------------------------------------------------------------

#[test]
fn worker_panic_degrades_the_pool_and_recovers_bitwise() {
    quiet_injected_panics();
    let cfg = chaos_config("masked", 2, 7);
    let base = baseline(&cfg);
    // Sanity: the step really has multiple groups, so rank 1 owns work.
    assert!(base.steps[1].logical_batch > cfg.physical_batch);

    let plan = Arc::new(FaultPlan::from_spec("panic@s1.r1.c0", cfg.steps, 2).unwrap());
    let rep = chaos_run(&cfg, plan).unwrap();
    assert_matches_baseline(&rep, &base);
    // The pool degraded: rank 1 is gone, the run finished on rank 0.
    assert_eq!(rep.final_workers, 1);
    let actions: Vec<&str> = rep.recovery_events.iter().map(|e| e.action.as_str()).collect();
    assert!(actions.contains(&"rank-lost"), "events: {actions:?}");
    assert!(actions.contains(&"group-recovered"), "events: {actions:?}");
    let lost = rep.recovery_events.iter().find(|e| e.action == "rank-lost").unwrap();
    assert_eq!((lost.step, lost.rank), (1, 1));
}

#[test]
fn rank_zero_panic_promotes_a_peer_bitwise() {
    quiet_injected_panics();
    let cfg = chaos_config("ghost", 2, 11);
    let base = baseline(&cfg);

    // The apply session itself dies; a surviving peer is promoted and
    // must produce exactly the bits rank 0 would have (the broadcast
    // invariant: every session holds identical pre-apply params).
    let plan = Arc::new(FaultPlan::from_spec("panic@s0.r0.c0", cfg.steps, 2).unwrap());
    let rep = chaos_run(&cfg, plan).unwrap();
    assert_matches_baseline(&rep, &base);
    assert_eq!(rep.final_workers, 1);
    let lost = rep.recovery_events.iter().find(|e| e.action == "rank-lost").unwrap();
    assert_eq!((lost.step, lost.rank), (0, 0));
}

#[test]
fn transient_accum_error_is_rerun_without_losing_the_rank() {
    let cfg = chaos_config("masked", 2, 3);
    let base = baseline(&cfg);

    let plan = Arc::new(FaultPlan::from_spec("accum-err@s1.r0.c0", cfg.steps, 2).unwrap());
    let rep = chaos_run(&cfg, plan).unwrap();
    assert_matches_baseline(&rep, &base);
    // An error is transient: the rank survives, nothing degrades.
    assert_eq!(rep.final_workers, 2);
    let actions: Vec<&str> = rep.recovery_events.iter().map(|e| e.action.as_str()).collect();
    assert!(actions.contains(&"group-failed"), "events: {actions:?}");
    assert!(actions.contains(&"group-recovered"), "events: {actions:?}");
    assert!(!actions.contains(&"rank-lost"), "events: {actions:?}");
}

#[test]
fn apply_error_retries_with_the_same_noise_tuple() {
    let cfg = chaos_config("masked", 1, 5);
    let base = baseline(&cfg);

    // The retried apply reuses the identical ApplyArgs — same per-step
    // noise seed — so bit-equality with the baseline proves the noise
    // stream was not advanced by the failure.
    let plan = Arc::new(FaultPlan::from_spec("apply-err@s2", cfg.steps, 1).unwrap());
    let rep = chaos_run(&cfg, plan).unwrap();
    assert_matches_baseline(&rep, &base);
    let retried = rep.recovery_events.iter().find(|e| e.action == "apply-retried").unwrap();
    assert_eq!(retried.step, 2);
}

#[test]
fn slow_worker_is_a_straggler_not_a_failure() {
    let cfg = chaos_config("masked", 2, 9);
    let base = baseline(&cfg);

    let plan = Arc::new(FaultPlan::from_spec("slow@s0.r1.c0.ms30", cfg.steps, 2).unwrap());
    let rep = chaos_run(&cfg, plan).unwrap();
    assert_matches_baseline(&rep, &base);
    // No recovery engaged: a stall moves wall-clock, never bits.
    assert!(rep.recovery_events.is_empty(), "events: {:?}", rep.recovery_events);
    assert_eq!(rep.final_workers, 2);
    // The site actually fired (the test exercised something).
    assert_eq!(plan.fired().len(), 1);
}

#[test]
fn exhausted_retry_budget_is_a_typed_error_and_the_step_is_replayable() {
    let mut cfg = chaos_config("masked", 1, 13);
    cfg.retry.max_attempts = 1; // retries disabled
    let base = baseline(&cfg);

    let plan = Arc::new(FaultPlan::from_spec("accum-err@s0.r0.c0", cfg.steps, 1).unwrap());
    let rt = Runtime::reference();
    let frt = faulty_runtime(&rt, Arc::clone(&plan));
    let mut s = TrainSession::with_faults(&frt, cfg.clone(), Arc::clone(&plan)).unwrap();

    let eps_before = s.epsilon_spent();
    let err = s.step().unwrap_err();
    assert!(
        err.downcast_ref::<WorkerFailure>().is_some(),
        "expected a typed WorkerFailure, got: {err:#}"
    );
    // The failed step committed nothing: epsilon records only after a
    // successful apply, and the step counter did not advance.
    assert_eq!(s.epsilon_spent().to_bits(), eps_before.to_bits());
    assert_eq!(s.step_index(), 0);

    // The fault site is consumed, so driving the session again replays
    // the *same* step — same draw, same noise — and the whole run still
    // lands on the fault-free bits. A failure can delay a step, never
    // change it.
    while !s.done() {
        s.step().unwrap();
    }
    let rep = s.finish().unwrap();
    assert_matches_baseline(&rep, &base);
}

#[test]
fn losing_every_rank_aborts_typed_never_panics() {
    quiet_injected_panics();
    let cfg = chaos_config("masked", 1, 17);
    let plan = Arc::new(FaultPlan::from_spec("panic@s0.r0.c0", cfg.steps, 1).unwrap());

    let outcome = catch_unwind(AssertUnwindSafe(|| chaos_run(&cfg, plan)));
    let err = outcome.expect("the injected panic must not cross the API").unwrap_err();
    assert!(format!("{err:#}").contains("worker ranks lost"), "got: {err:#}");
}

// ---------------------------------------------------------------------
// Crash-consistent checkpoints
// ---------------------------------------------------------------------

/// A sealed checkpoint a few steps into a run, plus its fingerprint.
fn sealed_checkpoint(cfg: &TrainConfig, steps: u64) -> (TrainCheckpoint, String) {
    let rt = Runtime::reference();
    let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
    for _ in 0..steps {
        s.step().unwrap();
    }
    let fp = config_fingerprint(cfg, resolve_sigma(cfg).unwrap());
    (s.checkpoint().unwrap(), fp)
}

#[test]
fn checkpoint_write_is_atomic_and_roundtrips() {
    let cfg = chaos_config("masked", 1, 21);
    let (ckpt, fp) = sealed_checkpoint(&cfg, 2);
    let dir = scratch_dir("roundtrip");

    let path = write_checkpoint(&dir, &ckpt, None).unwrap();
    assert_eq!(path.file_name().unwrap().to_str().unwrap(), checkpoint_file_name(2));
    // The temp-file+rename protocol leaves no .tmp behind.
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
        .collect();
    assert!(leftovers.is_empty(), "leaked temp files: {leftovers:?}");

    let loaded = load_checkpoint(&path, Some(&fp)).unwrap();
    assert_eq!(loaded.step, ckpt.step);
    assert_eq!(bits(&loaded.params), bits(&ckpt.params));
    assert_eq!(loaded.checksum, ckpt.checksum);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn torn_and_bitrotted_checkpoints_load_as_typed_errors() {
    let cfg = chaos_config("masked", 1, 23);
    let (ckpt, fp) = sealed_checkpoint(&cfg, 2);
    let dir = scratch_dir("corrupt");

    // A torn write (simulated crash mid-write) is unparseable JSON.
    let plan = FaultPlan::from_spec("ckpt-truncate@s2", cfg.steps, 1).unwrap();
    let torn = write_checkpoint(&dir, &ckpt, Some(&plan)).unwrap();
    assert!(matches!(
        load_checkpoint(&torn, Some(&fp)),
        Err(CheckpointError::Torn { .. })
    ));

    // Bit rot keeps the JSON parseable; the content checksum objects.
    let plan = FaultPlan::from_spec("ckpt-flip@s2", cfg.steps, 1).unwrap();
    let rotted = write_checkpoint(&dir, &ckpt, Some(&plan)).unwrap();
    assert!(matches!(
        load_checkpoint(&rotted, Some(&fp)),
        Err(CheckpointError::Checksum { .. })
    ));

    // An intact file under the wrong configuration is a fingerprint
    // mismatch, not a resume.
    let good = write_checkpoint(&dir, &ckpt, None).unwrap();
    assert!(matches!(
        load_checkpoint(&good, Some("v5|something-else")),
        Err(CheckpointError::Fingerprint { .. })
    ));
    // And a missing file is a typed I/O rejection.
    assert!(matches!(
        load_checkpoint(&dir.join("ckpt_step99999999.json"), Some(&fp)),
        Err(CheckpointError::Io { .. })
    ));
    // Hand-truncated JSON (no injector involved) is equally torn.
    let hand = dir.join(checkpoint_file_name(7));
    let json = ckpt.to_json().unwrap();
    std::fs::write(&hand, &json[..json.len() / 3]).unwrap();
    assert!(matches!(load_checkpoint(&hand, Some(&fp)), Err(CheckpointError::Torn { .. })));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_latest_skips_damage_down_to_the_newest_valid_file() {
    let cfg = chaos_config("masked", 1, 25);
    let dir = scratch_dir("scan");

    // A missing directory is an empty scan, not an error.
    let empty = latest_valid(&dir, "v5|x").unwrap();
    assert!(empty.found.is_none() && empty.skipped.is_empty());

    // Valid at step 1; corrupted at steps 2 and 3 (the newest files).
    let rt = Runtime::reference();
    let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
    let fp = config_fingerprint(&cfg, resolve_sigma(&cfg).unwrap());
    let plan = FaultPlan::from_spec("ckpt-flip@s2,ckpt-truncate@s3", cfg.steps, 1).unwrap();
    s.step().unwrap();
    write_checkpoint(&dir, &s.checkpoint().unwrap(), None).unwrap();
    s.step().unwrap();
    write_checkpoint(&dir, &s.checkpoint().unwrap(), Some(&plan)).unwrap();
    s.step().unwrap();
    write_checkpoint(&dir, &s.checkpoint().unwrap(), Some(&plan)).unwrap();
    // A .tmp leftover must never be considered a candidate.
    std::fs::write(dir.join("ckpt_step00000009.json.tmp"), "{").unwrap();

    let scan = latest_valid(&dir, &fp).unwrap();
    let (path, found) = scan.found.expect("the step-1 checkpoint is valid");
    assert_eq!(found.step, 1);
    assert_eq!(path.file_name().unwrap().to_str().unwrap(), checkpoint_file_name(1));
    // Both damaged files were tried first (newest-first) and recorded.
    assert_eq!(scan.skipped.len(), 2);
    assert!(matches!(scan.skipped[0].1, CheckpointError::Torn { .. }), "step 3 torn first");
    assert!(matches!(scan.skipped[1].1, CheckpointError::Checksum { .. }));

    // The survivor resumes to the fault-free trajectory.
    let base = baseline(&cfg);
    let rt2 = Runtime::reference();
    let mut resumed = TrainSession::resume(&rt2, cfg.clone(), found).unwrap();
    while !resumed.done() {
        resumed.step().unwrap();
    }
    assert_matches_baseline(&resumed.finish().unwrap(), &base);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mid_run_kill_after_apply_cannot_double_spend_epsilon() {
    let cfg = chaos_config("masked", 1, 27);
    let base = baseline(&cfg);
    let dir = scratch_dir("kill");

    // Checkpoint after step 0, then take step 1 — apply ran and the
    // accountant committed — and "crash" (drop without checkpointing).
    {
        let rt = Runtime::reference();
        let mut s = TrainSession::new(&rt, cfg.clone()).unwrap();
        s.step().unwrap();
        write_checkpoint(&dir, &s.checkpoint().unwrap(), None).unwrap();
        s.step().unwrap();
        assert!(s.epsilon_spent() > 0.0);
        // killed here: step 1's spend dies with the process.
    }

    // Resume replays step 1 with the same draw and the same noise
    // tuple, and the accountant replay prices exactly one composition
    // per completed step — the pre-crash execution of step 1 leaves no
    // trace, so there is no double-spend and no trajectory fork.
    let fp = config_fingerprint(&cfg, resolve_sigma(&cfg).unwrap());
    let scan = latest_valid(&dir, &fp).unwrap();
    let (_, ckpt) = scan.found.expect("the step-0 checkpoint survived the crash");
    assert_eq!(ckpt.step, 1);
    let rt = Runtime::reference();
    let mut resumed = TrainSession::resume(&rt, cfg.clone(), ckpt).unwrap();
    while !resumed.done() {
        resumed.step().unwrap();
    }
    let rep = resumed.finish().unwrap();
    assert_matches_baseline(&rep, &base);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Chaos property: any schedule → bitwise recovery or typed abort
// ---------------------------------------------------------------------

proptest! {
    #![proptest_config(ProptestConfig::with_cases(16))]

    /// For seeded fault schedules across clipping variants and worker
    /// counts: every step call either succeeds or returns a typed
    /// error — never a panic and never an epsilon overspend — and a
    /// run that completes is bitwise-identical to the fault-free one.
    #[test]
    fn any_fault_schedule_recovers_bitwise_or_aborts_typed(
        fault_seed in 0u64..1_000,
        run_seed in 0u64..1_000,
        nsites in 1usize..5,
        workers_idx in 0usize..3,
        variant_idx in 0usize..2,
    ) {
        quiet_injected_panics();
        let workers = [1usize, 2, 4][workers_idx];
        let variant = ["masked", "ghost"][variant_idx];
        let cfg = chaos_config(variant, workers, run_seed);
        let base = baseline(&cfg);

        let plan = Arc::new(FaultPlan::seeded(fault_seed, nsites, cfg.steps, workers));
        let rt = Runtime::reference();
        let frt = faulty_runtime(&rt, Arc::clone(&plan));
        let mut s = TrainSession::with_faults(&frt, cfg.clone(), Arc::clone(&plan)).unwrap();

        let mut aborted = false;
        while !s.done() {
            // Nothing may unwind across the session API, whatever the
            // schedule throws at it.
            let stepped = catch_unwind(AssertUnwindSafe(|| s.step()));
            match stepped {
                Ok(Ok(_)) => {}
                Ok(Err(_)) => { aborted = true; break; }
                Err(_) => prop_assert!(false, "a panic crossed the session API"),
            }
        }
        if aborted {
            // A typed abort spends only what completed steps committed:
            // never more than the full fault-free composition, and the
            // failed step itself committed nothing.
            prop_assert!(s.epsilon_spent() <= base.epsilon_spent);
            prop_assert!(s.step_index() < cfg.steps);
        } else {
            let rep = s.finish().unwrap();
            prop_assert_eq!(bits(&rep.final_params), bits(&base.final_params));
            for (a, b) in rep.steps.iter().zip(&base.steps) {
                prop_assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            }
            prop_assert_eq!(rep.epsilon_spent.to_bits(), base.epsilon_spent.to_bits());
            prop_assert!(rep.final_workers >= 1 && rep.final_workers <= workers.max(1));
        }
        // Whatever fired is a subset of what was planned.
        prop_assert!(plan.fired().len() <= plan.sites().len());
    }
}
