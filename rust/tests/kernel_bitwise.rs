//! Kernel bitwise-equality battery (DESIGN.md §14).
//!
//! The determinism contract says `--kernel` is a wall-clock knob only:
//! the scalar 8-lane fixed-tree path is the specification and every
//! SIMD path must land on the same bits. This suite pins that at three
//! levels:
//!
//! 1. **Dispatch level** — `dot` / `axpy` / `matvec` / `matvec_t` /
//!    `gram_sq` called with `Kernel::Scalar` vs `Kernel::auto()` agree
//!    bitwise on random operands of awkward lengths (remainder tails,
//!    row counts not divisible by the 4-row block).
//! 2. **Trajectory level** — whole `TrainSession` runs on a
//!    scalar-kernel runtime vs an auto-kernel runtime are
//!    bitwise-identical (final params, per-step losses, epsilon) across
//!    all five reference models × clip variants × worker counts ×
//!    seeds × param dtypes.
//! 3. **Checkpoint level** — the executed bf16 storage mode round-trips
//!    exactly through JSON checkpoints (fingerprint generation `v7`),
//!    and a checkpoint taken under one kernel resumes under the other
//!    without moving a bit (the kernel is excluded from the
//!    fingerprint, like `workers`).
//!
//! The cross-ISA CI job re-runs this whole file with
//! `DPSHORT_FORCE_SCALAR=1`: `Kernel::auto()` then resolves to scalar
//! on every host, so the suite degenerates to scalar-vs-scalar and
//! stays green (and meaningful as a regression harness) on machines
//! with no vector unit.

use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::trainer::{config_fingerprint, resolve_sigma};
use dp_shortcuts::runtime::{kernels, Kernel};
use dp_shortcuts::util::rng::ChaChaRng;
use dp_shortcuts::{Runtime, TrainCheckpoint, TrainConfig, TrainSession, Trainer};
use proptest::prelude::*;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn randv(rng: &mut ChaChaRng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal() as f32).collect()
}

// ---------------------------------------------------------------------
// 1. Dispatch-level equality: scalar vs the detected kernel.
// ---------------------------------------------------------------------

proptest! {
    #![proptest_config(ProptestConfig::with_cases(96))]

    /// `dot` and `axpy` dispatch bitwise-equally across lengths that
    /// exercise empty inputs, pure-tail inputs (< 8), exact multiples
    /// of the 8-lane chunk, and long mixed cases.
    #[test]
    fn dot_and_axpy_dispatch_bitwise_equal(
        len in 0usize..200,
        data_seed in proptest::num::u64::ANY,
    ) {
        let auto = Kernel::auto();
        let mut rng = ChaChaRng::from_seed_stream(data_seed, 0, b"kbitwise");
        let a = randv(&mut rng, len);
        let b = randv(&mut rng, len);
        prop_assert_eq!(
            kernels::dot(Kernel::Scalar, &a, &b).to_bits(),
            kernels::dot(auto, &a, &b).to_bits(),
            "dot diverged at len {} on {:?}", len, auto
        );

        let g = rng.next_normal() as f32;
        let mut scalar_row = a.clone();
        let mut auto_row = a.clone();
        kernels::axpy(Kernel::Scalar, &mut scalar_row, &b, g);
        kernels::axpy(auto, &mut auto_row, &b, g);
        prop_assert_eq!(bits(&scalar_row), bits(&auto_row), "axpy diverged at len {}", len);
    }

    /// The cache-blocked forward matvec and the blocked transpose
    /// matvec (fold of axpy rows) agree bitwise with the scalar
    /// row-at-a-time loops — including row counts that leave 1..3
    /// remainder rows after the 4-row blocks.
    #[test]
    fn blocked_matvecs_dispatch_bitwise_equal(
        d_in in 1usize..48,
        d_out in 1usize..24,
        data_seed in proptest::num::u64::ANY,
    ) {
        let auto = Kernel::auto();
        let mut rng = ChaChaRng::from_seed_stream(data_seed, 1, b"kbitwise");
        let w = randv(&mut rng, d_out * d_in);
        let bias = randv(&mut rng, d_out);
        let a = randv(&mut rng, d_in);

        let mut scalar_out = vec![0.0f32; d_out];
        let mut auto_out = vec![0.0f32; d_out];
        kernels::matvec(Kernel::Scalar, &mut scalar_out, &w, &bias, &a);
        kernels::matvec(auto, &mut auto_out, &w, &bias, &a);
        prop_assert_eq!(
            bits(&scalar_out), bits(&auto_out),
            "matvec diverged at {}x{}", d_out, d_in
        );

        let gs = randv(&mut rng, d_out);
        let seed_da = randv(&mut rng, d_in);
        let mut scalar_da = seed_da.clone();
        let mut auto_da = seed_da;
        kernels::matvec_t(Kernel::Scalar, &mut scalar_da, &w, &gs);
        kernels::matvec_t(auto, &mut auto_da, &w, &gs);
        prop_assert_eq!(
            bits(&scalar_da), bits(&auto_da),
            "matvec_t diverged at {}x{}", d_out, d_in
        );
    }

    /// The ghost Gram-norm product — the one kernel whose *outer*
    /// accumulation order is privacy-relevant — dispatches bitwise
    /// equally over token matrices of every small shape.
    #[test]
    fn gram_sq_dispatch_bitwise_equal(
        t in 1usize..6,
        aw in 1usize..24,
        gw in 1usize..12,
        data_seed in proptest::num::u64::ANY,
    ) {
        let auto = Kernel::auto();
        let mut rng = ChaChaRng::from_seed_stream(data_seed, 2, b"kbitwise");
        let a = randv(&mut rng, t * aw);
        let g = randv(&mut rng, t * gw);
        prop_assert_eq!(
            kernels::gram_sq(Kernel::Scalar, &a, aw, &g, gw, t).to_bits(),
            kernels::gram_sq(auto, &a, aw, &g, gw, t).to_bits(),
            "gram_sq diverged at t={} aw={} gw={}", t, aw, gw
        );
    }
}

// ---------------------------------------------------------------------
// 2. Trajectory-level equality: whole training runs, scalar vs auto.
// ---------------------------------------------------------------------

/// Small-but-real config: Poisson sampling over 48 examples, masked
/// Algorithm-2 batching, 3 noisy steps. Any physical batch in the
/// lowered menu works; 4 keeps the chunk planner honest (logical
/// batches straddle several chunks).
fn train_config(model: &str, variant: &str, workers: usize, seed: u64, bf16: bool) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        variant: variant.into(),
        bf16,
        mode: BatchingMode::Masked,
        dataset_size: 48,
        sampling_rate: 0.3,
        physical_batch: 4,
        steps: 3,
        lr: 0.05,
        noise_multiplier: Some(1.1),
        seed,
        eval_examples: 0,
        workers,
        ..Default::default()
    }
}

fn run(rt: &Runtime, cfg: TrainConfig) -> dp_shortcuts::TrainReport {
    Trainer::new(rt, cfg).unwrap().run().unwrap()
}

proptest! {
    // Every case trains two full sessions, so keep the case count low;
    // the grid below still sweeps all five models, the executed clip
    // variants, 1/2/4 workers, both dtypes, and random seeds (which
    // vary the Poisson masks) across runs.
    #![proptest_config(ProptestConfig::with_cases(8))]

    /// A scalar-kernel runtime and an auto-kernel runtime train the
    /// **identical** trajectory: final parameter bits, per-step loss
    /// bits, and the composed epsilon. This is the executed form of the
    /// DESIGN.md §14 contract ("a kernel switch never moves a single
    /// bit") — and the reason `--kernel` may be excluded from the
    /// checkpoint fingerprint.
    #[test]
    fn training_trajectories_are_kernel_invariant(
        model_idx in 0usize..5,
        variant_idx in 0usize..5,
        workers_idx in 0usize..3,
        bf16 in proptest::bool::ANY,
        seed in 0u64..1_000_000,
    ) {
        let model =
            ["ref-linear", "mlp-small", "mlp-wide", "cnn-small", "attn-tiny"][model_idx];
        let variant = ["masked", "ghost", "perex", "mix", "bk"][variant_idx];
        let workers = [1usize, 2, 4][workers_idx];

        let scalar_rt = Runtime::reference_with_options(0, 0, Kernel::Scalar);
        let auto_rt = Runtime::reference_with_options(0, 0, Kernel::auto());
        let want = run(&scalar_rt, train_config(model, variant, workers, seed, bf16));
        let got = run(&auto_rt, train_config(model, variant, workers, seed, bf16));

        prop_assert_eq!(
            bits(&got.final_params), bits(&want.final_params),
            "{}/{} ({} workers, bf16={}) params diverged across kernels",
            model, variant, workers, bf16
        );
        prop_assert_eq!(got.epsilon_spent.to_bits(), want.epsilon_spent.to_bits());
        prop_assert_eq!(got.steps.len(), want.steps.len());
        for (a, b) in got.steps.iter().zip(&want.steps) {
            prop_assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{}/{}", model, variant);
            prop_assert_eq!(a.logical_batch, b.logical_batch);
        }
    }
}

// ---------------------------------------------------------------------
// 3. Checkpoint-level: executed bf16 storage round-trips exactly, and
//    kernels stay out of the fingerprint.
// ---------------------------------------------------------------------

/// bf16 storage with RNE-on-store keeps the low 16 mantissa bits of
/// every stored parameter zero — the property that makes the storage
/// mode *executed* rather than a tag.
fn all_bf16_quantized(params: &[f32]) -> bool {
    params.iter().all(|p| p.to_bits() & 0xffff == 0)
}

#[test]
fn bf16_checkpoint_round_trip_is_exact() {
    let cfg = train_config("mlp-small", "ghost", 1, 11, true);

    // Uninterrupted bf16 run: the oracle trajectory.
    let rt = Runtime::reference_with_options(0, 0, Kernel::Scalar);
    let want = run(&rt, cfg.clone());
    assert!(
        all_bf16_quantized(&want.final_params),
        "bf16 apply must re-quantize parameter storage after every update"
    );

    // Interrupted run: step once, checkpoint through the JSON wire
    // format, resume in a fresh session, finish.
    let mut first = TrainSession::new(&rt, cfg.clone()).unwrap();
    first.step().unwrap();
    let ckpt = first.checkpoint().unwrap();
    assert!(ckpt.fingerprint.starts_with("v7|"), "fingerprint generation: {}", ckpt.fingerprint);
    assert!(
        all_bf16_quantized(&ckpt.params),
        "checkpointed bf16 params must already be quantized (session-quantized init + \
         requantizing apply)"
    );
    let wire = ckpt.to_json().unwrap();
    let restored = TrainCheckpoint::from_json(&wire).unwrap();
    assert!(restored.checksum_ok(), "JSON round-trip broke the crash-consistency seal");
    assert_eq!(bits(&restored.params), bits(&ckpt.params), "params drifted through JSON");

    let mut resumed = TrainSession::resume(&rt, cfg.clone(), restored).unwrap();
    assert_eq!(resumed.step_index(), 1);
    while !resumed.done() {
        resumed.step().unwrap();
    }
    let got = resumed.finish().unwrap();
    assert_eq!(
        bits(&got.final_params),
        bits(&want.final_params),
        "resumed bf16 run diverged from the uninterrupted one"
    );
    assert_eq!(got.epsilon_spent.to_bits(), want.epsilon_spent.to_bits());
    assert_eq!(got.steps.len(), want.steps.len());
    for (a, b) in got.steps.iter().zip(&want.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}

#[test]
fn checkpoints_resume_across_kernels() {
    // A checkpoint taken on a scalar-kernel runtime resumes on an
    // auto-kernel runtime (and lands on the scalar oracle's bits): the
    // kernel is a wall-clock knob, excluded from the fingerprint
    // exactly like `workers`.
    let cfg = train_config("cnn-small", "mix", 1, 23, false);
    let scalar_rt = Runtime::reference_with_options(0, 0, Kernel::Scalar);
    let want = run(&scalar_rt, cfg.clone());

    let mut first = TrainSession::new(&scalar_rt, cfg.clone()).unwrap();
    first.step().unwrap();
    first.step().unwrap();
    let ckpt = first.checkpoint().unwrap();

    let auto_rt = Runtime::reference_with_options(0, 0, Kernel::auto());
    let mut resumed = TrainSession::resume(&auto_rt, cfg.clone(), ckpt).unwrap();
    while !resumed.done() {
        resumed.step().unwrap();
    }
    let got = resumed.finish().unwrap();
    assert_eq!(
        bits(&got.final_params),
        bits(&want.final_params),
        "cross-kernel resume diverged"
    );
    assert_eq!(got.epsilon_spent.to_bits(), want.epsilon_spent.to_bits());
}

#[test]
fn fingerprint_tracks_dtype_but_not_kernel() {
    let base = train_config("mlp-small", "ghost", 1, 5, false);
    let sigma = resolve_sigma(&base).unwrap();
    let fp = config_fingerprint(&base, sigma);
    assert!(fp.starts_with("v7|"), "{fp}");
    assert!(fp.contains("|f32|"), "dtype tag missing: {fp}");

    // bf16 is an executed storage mode: it changes the trajectory, so
    // it MUST change the fingerprint (a v6-style f32 checkpoint must
    // not resume under bf16 or vice versa).
    let mut bf16 = base.clone();
    bf16.bf16 = true;
    let bf16_fp = config_fingerprint(&bf16, sigma);
    assert_ne!(fp, bf16_fp);
    assert!(bf16_fp.contains("|bf16|"), "{bf16_fp}");

    // The kernel selection never moves a bit, so two configs differing
    // only in `kernel` share one fingerprint — checkpoints flow freely
    // between scalar and SIMD hosts.
    let mut scalar = base.clone();
    scalar.kernel = "scalar".into();
    let mut simd = base;
    simd.kernel = "simd".into();
    assert_eq!(config_fingerprint(&scalar, sigma), config_fingerprint(&simd, sigma));
}
