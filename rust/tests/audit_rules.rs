//! Adversarial fixture suite for the static plan audit (DESIGN.md §10).
//!
//! Three layers of assurance, per the auditor's acceptance criteria:
//!
//! 1. **Each rule is trippable, precisely.** Every Deny rule in the
//!    catalog has a fixture built by mutating ONE aspect of the clean
//!    [`test_plan`], and that fixture's report denies on exactly that
//!    rule — no more, no less. Warn rules get the same treatment
//!    without blocking.
//! 2. **Everything we ship audits clean.** A property test sweeps the
//!    reference backend's model ladder × every CLI clip method × both
//!    accountants × worker counts and requires a clean, schema-valid
//!    report each time.
//! 3. **The trainer honors the verdict.** `TrainSession::new` refuses a
//!    denied plan, `--allow-unsound` converts the refusal into a sticky
//!    `unaudited` stamp on checkpoints and the final report, and the
//!    accountant selection (`rdp`/`pld`) is named in the report.
//!
//! The source-lint half of `dpshort lint --source` is covered by the
//! self-hosting test at the bottom: the shipped tree must lint clean
//! under the checked-in `lint-allowlist.txt`, and the allowlist entries
//! must actually be load-bearing.

use std::collections::BTreeSet;
use std::path::Path;

use dp_shortcuts::analysis::{
    audit_hlo, audit_plan, audit_plan_graph, lint_source, parse_allowlist, rule, test_plan,
    BudgetSpec, ClipKind, Graph, NodeKind, NoiseSite, NoiseStage, RunPlan, Severity, StreamUse,
    RULES,
};
use dp_shortcuts::clipping::{LayerChoice, CLI_CLIP_METHODS};
use dp_shortcuts::coordinator::trainer::resolve_sigma;
use dp_shortcuts::models::LayerKind;
use dp_shortcuts::runtime::{hlo_analysis, REFERENCE_MODEL};
use dp_shortcuts::{
    audit_run, AccountantKind, Runtime, SamplerChoice, TrainConfig, TrainSession, Trainer,
};
use proptest::prelude::*;

/// Fixtures that must produce exactly one Deny rule: `(expected rule,
/// the clean plan with one adversarial mutation)`.
fn deny_fixtures() -> Vec<(&'static str, RunPlan)> {
    let mut out = Vec::new();

    // Each layer clipped by its own norm — wrong sensitivity.
    let mut p = test_plan(3);
    p.clip.kind = ClipKind::PerLayer;
    out.push((rule::CLIP_PER_LAYER, p));

    // Clip dropped entirely on a private variant.
    let mut p = test_plan(3);
    p.clip.kind = ClipKind::Unclipped;
    out.push((rule::CLIP_MISSING, p));

    // Claims sigma = 1 but no noise site exists.
    let mut p = test_plan(3);
    p.noise.clear();
    out.push((rule::NOISE_MISSING, p));

    // Noise present but at 2x the calibrated sigma * C.
    let mut p = test_plan(3);
    p.noise[0].scale = 2.0;
    out.push((rule::NOISE_SCALE, p));

    // Noise added twice (per-site injection doubles the variance).
    let mut p = test_plan(3);
    let scale = p.sigma * p.clip.norm;
    p.noise.push(NoiseSite { stage: NoiseStage::PostAggregation, scale });
    out.push((rule::NOISE_DOUBLE, p));

    // Noise injected into a group partial before the reduction.
    let mut p = test_plan(3);
    p.noise[0].stage = NoiseStage::PreAggregation;
    out.push((rule::NOISE_PRE_AGGREGATION, p));

    // Two consumers constructing the same ChaCha (seed, stream, label).
    let mut p = test_plan(3);
    p.streams = vec![
        StreamUse::new("noise.derive", 7, 0, b"noisesd\0"),
        StreamUse::new("sampler.poisson", 7, 0, b"noisesd\0"),
    ];
    out.push((rule::STREAM_COLLISION, p));

    // A 2^39-byte draw against the old 32-bit counter's 2^38 capacity.
    let mut p = test_plan(3);
    p.rng_counter_bits = 32;
    p.n_params = 1usize << 35;
    out.push((rule::STREAM_EXHAUSTION, p));

    // Shuffle sampling priced with a Poisson accountant — the
    // "shortcut epsilon" of arXiv 2403.17673 / 2411.04205.
    let mut p = test_plan(3);
    p.sampler.choice = SamplerChoice::Shuffle;
    p.sampler.poisson_rate = None;
    out.push((rule::SHORTCUT_EPSILON, p));

    // Each rank drawing its own subsample.
    let mut p = test_plan(3);
    p.sampler.per_rank = true;
    out.push((rule::SAMPLER_PER_RANK, p));

    // Reduction order depends on the worker schedule.
    let mut p = test_plan(3);
    p.reduction.worker_dependent = true;
    out.push((rule::REDUCE_SCHEDULE, p));

    // Step retry re-samples the Poisson mask — the retry analogue of
    // the shortcut epsilon (DESIGN.md §11).
    let mut p = test_plan(3);
    p.retry.resample_on_retry = true;
    out.push((rule::RETRY_FRESH_DRAW, p));

    // Step retry advances the noise stream instead of replaying it.
    let mut p = test_plan(3);
    p.retry.fresh_noise_on_retry = true;
    out.push((rule::RETRY_FRESH_DRAW, p));

    // A no-materialization variant materializing [B, P] grads.
    let mut p = test_plan(3);
    p.choices = vec![LayerChoice::PerExample; 3];
    out.push((rule::MATERIALIZED_PER_EXAMPLE, p));

    // The kind-aware form: a ghost-contract variant materializing ONE
    // conv layer's per-example weight-gradient block (the shape the
    // mix dispatcher legitimately picks under `variant = "mix"`, but
    // a contract violation under "ghost").
    let mut p = test_plan(3);
    p.variant = "ghost".into();
    p.layer_kinds[0] =
        LayerKind::Conv2d { c_in: 3, h_in: 8, w_in: 8, c_out: 4, kh: 3, kw: 3, stride: 1, pad: 1 };
    p.choices[0] = LayerChoice::PerExample;
    out.push((rule::MATERIALIZED_PER_EXAMPLE, p));

    // A declared (epsilon, delta) budget smaller than what the
    // configured steps spend under the RDP accountant — the serve
    // admission contract (a tenant must be refused at submission,
    // never hard-stopped mid-run for a statically-knowable overspend).
    let mut p = test_plan(3);
    p.budget = Some(BudgetSpec { epsilon: 1e-3, delta: 1e-5 });
    out.push((rule::BUDGET_OVERSPEND, p));

    // Same overspend priced under the PLD accountant: the rule must
    // judge the plan's own accountant, not assume RDP.
    let mut p = test_plan(3);
    p.accountant = AccountantKind::Pld;
    p.budget = Some(BudgetSpec { epsilon: 1e-3, delta: 1e-5 });
    out.push((rule::BUDGET_OVERSPEND, p));

    out
}

/// Fixtures that must surface exactly one Warn rule and stay runnable.
fn warn_fixtures() -> Vec<(&'static str, RunPlan)> {
    let mut out = Vec::new();

    // The nonprivate baseline: unclipped by design, flagged once.
    let mut p = test_plan(3);
    p.private = false;
    p.variant = "nonprivate".into();
    p.clip.kind = ClipKind::Unclipped;
    p.noise.clear();
    p.sigma = 0.0;
    out.push((rule::CLIP_NONPRIVATE, p));

    // Private mechanics run with sigma = 0 (bench-only, eps infinite).
    let mut p = test_plan(2);
    p.sigma = 0.0;
    p.noise.clear();
    out.push((rule::NOISE_ZERO_SIGMA, p));

    // Same 2^39-byte draw, but with the widened 64-bit counter: fine
    // now, silently corrupt before the widening — surfaced as a Warn.
    let mut p = test_plan(2);
    p.n_params = 1usize << 35;
    out.push((rule::STREAM_LEGACY_EXHAUSTION, p));

    // An executable dtype the memory model would price at 4 bytes.
    let mut p = test_plan(2);
    p.dtypes.push("fp8".into());
    out.push((rule::DTYPE_UNKNOWN, p));

    // Reference kernels on an ISA the bitwise battery has not pinned:
    // wall-clock knob, so surfaced without blocking.
    let mut p = test_plan(2);
    p.kernel_isa = "avx512".into();
    out.push((rule::KERNEL_UNVERIFIED_ISA, p));

    out
}

#[test]
fn the_clean_fixture_plan_audits_clean() {
    let report = audit_plan(&test_plan(3));
    report.validate().unwrap();
    assert_eq!(report.counts(), (0, 0, 0), "diags: {:#?}", report.diagnostics);
}

#[test]
fn each_deny_fixture_trips_exactly_its_rule() {
    for (expected, plan) in deny_fixtures() {
        let report = audit_plan(&plan);
        report.validate().unwrap();
        assert_eq!(
            report.deny_rules(),
            vec![expected],
            "fixture for {expected} denied on the wrong rule set: {:#?}",
            report.diagnostics
        );
    }
}

#[test]
fn each_warn_fixture_surfaces_without_blocking() {
    for (expected, plan) in warn_fixtures() {
        let report = audit_plan(&plan);
        report.validate().unwrap();
        assert!(report.is_clean(), "warn fixture for {expected} must not deny");
        let warns: Vec<&str> = report
            .diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Warn)
            .map(|d| d.rule)
            .collect();
        assert_eq!(warns, vec![expected], "diags: {:#?}", report.diagnostics);
    }
}

#[test]
fn the_fixture_suite_covers_the_whole_rule_catalog() {
    let mut tripped: BTreeSet<&'static str> = BTreeSet::new();
    for (_, plan) in deny_fixtures().iter().chain(warn_fixtures().iter()) {
        for diag in audit_plan(plan).diagnostics {
            tripped.insert(diag.rule);
        }
    }
    for info in RULES {
        assert!(tripped.contains(info.id), "rule {} has no fixture tripping it", info.id);
    }
}

#[test]
fn a_schedule_dependent_reduce_node_is_caught_on_the_graph() {
    // Mutate the lowered graph directly (a "miscompiled step" shape the
    // plan-level facts would not show) and audit through the graph
    // entry point.
    let plan = test_plan(2);
    let mut g = Graph::lower(&plan);
    for n in &mut g.nodes {
        if let NodeKind::Reduce { fixed_tree } = n {
            *fixed_tree = false;
        }
    }
    let report = audit_plan_graph(&plan, &g);
    report.validate().unwrap();
    assert_eq!(report.deny_rules(), vec![rule::REDUCE_SCHEDULE]);
}

#[test]
fn cutting_one_attention_gram_group_from_the_global_norm_is_caught() {
    // An attention layer folds four Gram products (Wq/Wk/Wv/Wo) into
    // the global norm. Drop ONE group's edge into NormTotal: the
    // layer-level taint cover stays complete (the other three groups
    // still insert the layer), so only the structural completeness
    // check can see that the clip norm under-counts this layer.
    let mut plan = test_plan(3);
    plan.layer_kinds[1] = LayerKind::Attention { t: 4, d_model: 12, d_head: 6 };
    let clean = Graph::lower(&plan);
    assert!(audit_plan_graph(&plan, &clean).is_clean());

    let mut g = clean;
    let groups: Vec<usize> = (0..g.nodes.len())
        .filter(|&i| matches!(g.nodes[i], NodeKind::GramNorm { layer: 1, .. }))
        .collect();
    assert_eq!(groups.len(), 4, "attention must lower one Gram node per parameter group");
    let total = g.nodes.iter().position(|k| matches!(k, NodeKind::NormTotal)).unwrap();
    let cut = groups[2];
    let before = g.edges.len();
    g.edges.retain(|&(f, t)| !(f == cut && t == total));
    assert_eq!(g.edges.len(), before - 1, "exactly one edge removed");

    let report = audit_plan_graph(&plan, &g);
    report.validate().unwrap();
    assert_eq!(report.deny_rules(), vec![rule::CLIP_PER_LAYER]);
    let diag = report
        .diagnostics
        .iter()
        .find(|d| d.rule == rule::CLIP_PER_LAYER)
        .unwrap();
    assert_eq!(diag.location, "layer[1].gram[2]", "{}", diag.message);
    assert!(diag.message.contains("attention"), "{}", diag.message);
}

#[test]
fn the_materialization_diagnostic_names_the_layer_kind() {
    let mut plan = test_plan(2);
    plan.variant = "ghost".into();
    plan.layer_kinds[0] =
        LayerKind::Conv2d { c_in: 3, h_in: 8, w_in: 8, c_out: 4, kh: 3, kw: 3, stride: 1, pad: 1 };
    plan.choices[0] = LayerChoice::PerExample;
    let report = audit_plan(&plan);
    report.validate().unwrap();
    let diag = report
        .diagnostics
        .iter()
        .find(|d| d.rule == rule::MATERIALIZED_PER_EXAMPLE)
        .unwrap();
    assert!(diag.message.contains("conv2d"), "{}", diag.message);
}

#[test]
fn audit_json_is_schema_valid_and_machine_readable() {
    let mut plan = test_plan(2);
    plan.sampler.choice = SamplerChoice::Shuffle;
    plan.sampler.poisson_rate = None;
    let report = audit_plan(&plan);
    report.validate().unwrap();
    let v: serde_json::Value = serde_json::from_str(&report.to_json().unwrap()).unwrap();
    assert_eq!(v["schema_version"], 1);
    assert_eq!(v["sampler"], "shuffle");
    assert_eq!(v["accountant"], "rdp");
    let diag = &v["diagnostics"][0];
    assert_eq!(diag["rule"], rule::SHORTCUT_EPSILON);
    assert_eq!(diag["severity"], "deny");
    assert!(diag["location"].as_str().is_some_and(|s| !s.is_empty()));
    assert!(diag["message"].as_str().is_some_and(|s| !s.is_empty()));
}

#[test]
fn hlo_pass_flags_materialization_and_unknown_dtypes() {
    let text = "ENTRY step {\n  \
         grads = f32[8,59]{1,0} dot(a, b)\n  \
         oddball = q3[4,4]{1,0} add(x, y)\n  \
         ROOT out = f32[59]{0} reduce(grads)\n}\n";
    let stats = hlo_analysis::analyze(text);
    // Under a no-materialization contract the [B, P] = [8, 59] tensor
    // is a violation; the unknown dtype is flagged either way.
    let ghost: BTreeSet<&str> = audit_hlo(&stats, 8, 59, "ghost").iter().map(|d| d.rule).collect();
    assert!(ghost.contains(rule::MATERIALIZED_PER_EXAMPLE));
    assert!(ghost.contains(rule::DTYPE_UNKNOWN));
    // The materializing per-example branch is allowed to hold it.
    let perex = audit_hlo(&stats, 8, 59, "perex");
    assert!(!perex.is_empty());
    assert!(perex.iter().all(|d| d.rule == rule::DTYPE_UNKNOWN), "{perex:#?}");
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    // Every shipped model x CLI clip method x accountant x worker count
    // lowers to a plan the auditor accepts with a schema-valid report.
    #[test]
    fn shipped_ladder_configs_audit_clean(
        model_idx in 0usize..64,
        method_idx in 0usize..CLI_CLIP_METHODS.len(),
        pld in any::<bool>(),
        workers in prop_oneof![Just(1usize), Just(2usize), Just(4usize)],
    ) {
        let rt = Runtime::reference();
        let models: Vec<String> = rt.manifest().models.keys().cloned().collect();
        let model = models[model_idx % models.len()].clone();
        let (_, variant) = CLI_CLIP_METHODS[method_idx];
        let cfg = TrainConfig {
            model: model.clone(),
            variant: variant.to_string(),
            noise_multiplier: Some(1.0),
            accountant: if pld { AccountantKind::Pld } else { AccountantKind::Rdp },
            workers,
            ..TrainConfig::default()
        };
        let sigma = resolve_sigma(&cfg).unwrap();
        let mr = rt.model(&model).unwrap();
        let report = audit_run(mr.meta(), rt.manifest().seed, &cfg, sigma).unwrap();
        report.validate().unwrap();
        prop_assert!(
            report.is_clean(),
            "{model}/{variant} should audit clean: {:#?}",
            report.diagnostics
        );
    }
}

/// Small fast private run on the reference backend for the e2e tests.
fn e2e_config() -> TrainConfig {
    TrainConfig {
        model: REFERENCE_MODEL.into(),
        dataset_size: 48,
        sampling_rate: 0.25,
        physical_batch: 8,
        steps: 2,
        noise_multiplier: Some(1.0),
        eval_examples: 0,
        ..TrainConfig::default()
    }
}

#[test]
fn shuffle_config_denies_for_both_accountants_via_audit_run() {
    let rt = Runtime::reference();
    for accountant in [AccountantKind::Rdp, AccountantKind::Pld] {
        let cfg = TrainConfig {
            sampler: SamplerChoice::Shuffle,
            accountant,
            ..e2e_config()
        };
        let sigma = resolve_sigma(&cfg).unwrap();
        let mr = rt.model(REFERENCE_MODEL).unwrap();
        let report = audit_run(mr.meta(), rt.manifest().seed, &cfg, sigma).unwrap();
        assert_eq!(report.deny_rules(), vec![rule::SHORTCUT_EPSILON]);
        assert_eq!(report.accountant, accountant.as_str());
    }
}

#[test]
fn session_refuses_a_denied_plan_and_allow_unsound_stamps_it() {
    let rt = Runtime::reference();
    let cfg = TrainConfig { sampler: SamplerChoice::Shuffle, ..e2e_config() };

    // Fail-fast: construction is refused, naming the rule and the
    // opt-out, before any example is touched.
    let err = match TrainSession::new(&rt, cfg.clone()) {
        Ok(_) => panic!("a denied plan must not construct"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains(rule::SHORTCUT_EPSILON), "{err}");
    assert!(err.contains("--allow-unsound"), "{err}");

    // Opt out: the run executes but carries the unaudited stamp.
    let mut session =
        TrainSession::new(&rt, TrainConfig { allow_unsound: true, ..cfg.clone() }).unwrap();
    assert!(session.unaudited());
    session.step().unwrap();
    let ckpt = session.checkpoint().unwrap();
    assert!(ckpt.unaudited, "checkpoints from an unaudited session must carry the stamp");

    // Resuming re-audits: without the opt-out the Deny fires again.
    let second = session.checkpoint().unwrap();
    assert!(TrainSession::resume(&rt, cfg.clone(), second).is_err());

    // With it, the stamp survives into the final report.
    let mut resumed =
        TrainSession::resume(&rt, TrainConfig { allow_unsound: true, ..cfg }, ckpt).unwrap();
    resumed.step().unwrap();
    let rep = resumed.finish().unwrap();
    assert!(rep.unaudited);
    assert_eq!(rep.accountant, "rdp");
}

#[test]
fn the_unaudited_stamp_is_sticky_across_resume() {
    // Even if the resumed segment itself audits clean, a checkpoint
    // from an unaudited segment keeps the whole run unaudited.
    let rt = Runtime::reference();
    let mut session = TrainSession::new(&rt, e2e_config()).unwrap();
    assert!(!session.unaudited());
    session.step().unwrap();
    let mut ckpt = session.checkpoint().unwrap();
    assert!(!ckpt.unaudited);
    ckpt.unaudited = true; // as if an earlier segment ran --allow-unsound
    ckpt.seal(); // the stamp is covered by the content checksum
    let resumed = TrainSession::resume(&rt, e2e_config(), ckpt).unwrap();
    assert!(resumed.unaudited());
}

#[test]
fn a_clean_run_is_audited_and_names_its_accountant() {
    let rt = Runtime::reference();
    let rep = Trainer::new(&rt, e2e_config()).unwrap().run().unwrap();
    assert!(!rep.unaudited);
    assert_eq!(rep.accountant, "rdp");
    assert!(rep.epsilon_spent.is_finite() && rep.epsilon_spent > 0.0);
}

#[test]
fn the_pld_accountant_prices_the_run_end_to_end() {
    let rt = Runtime::reference();
    let rep = Trainer::new(&rt, TrainConfig { accountant: AccountantKind::Pld, ..e2e_config() })
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.accountant, "pld");
    assert!(rep.epsilon_spent.is_finite() && rep.epsilon_spent > 0.0);
}

#[test]
fn the_sampler_is_part_of_the_checkpoint_fingerprint() {
    // A checkpoint taken under Poisson sampling must not resume under
    // shuffle: the batch sequence (and thus the accounting replay)
    // would silently diverge.
    let rt = Runtime::reference();
    let mut session = TrainSession::new(&rt, e2e_config()).unwrap();
    session.step().unwrap();
    let ckpt = session.checkpoint().unwrap();
    let swapped = TrainConfig {
        sampler: SamplerChoice::Shuffle,
        allow_unsound: true,
        ..e2e_config()
    };
    let err = match TrainSession::resume(&rt, swapped, ckpt) {
        Ok(_) => panic!("a fingerprint-mismatched checkpoint must not resume"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("different configuration"), "{err}");
}

#[test]
fn shipped_tree_lints_clean_under_the_checked_in_allowlist() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let allow_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint-allowlist.txt");
    let allow = parse_allowlist(&std::fs::read_to_string(&allow_path).unwrap());
    assert!(!allow.is_empty(), "the allowlist should document the known test-only hits");

    let report = lint_source(&root, &allow).unwrap();
    assert!(report.findings.is_empty(), "lint findings: {:#?}", report.findings);
    assert!(report.files_scanned > 20, "scanned only {} files", report.files_scanned);
    assert!(report.allowed >= 1, "the checked-in allowlist entries are dead");

    // Without the allowlist the suppressed hits resurface — proving the
    // pass is live, not vacuously green.
    let bare = lint_source(&root, &[]).unwrap();
    assert!(!bare.findings.is_empty());
    assert!(
        bare.findings.iter().all(|f| f.rule == "lint.float-accum"),
        "unexpected lint findings: {:#?}",
        bare.findings
    );
}
