//! Machine-readable throughput harness: the engine behind
//! `dpshort bench` and `benches/bench_throughput.rs`.
//!
//! Runs the steady-state accum/apply sweep over the active backend's
//! manifest (the paper's Figures 1/2/4/6 estimator: medians with seeded
//! bootstrap 95% CIs), measures data-parallel training throughput per
//! (model, clip method, worker count) — the measured side of the
//! paper's Figure 7 scaling study, across the executable clipping
//! methods — and emits `BENCH_throughput.json`, so every PR records
//! the measured perf trajectory instead of printing text that
//! evaporates. The schema (version 3, DESIGN.md §6):
//!
//! ```json
//! {
//!   "schema_version": 3,
//!   "backend": "reference",
//!   "seed": 0,
//!   "quick": true,
//!   "models": ["mlp-small", "ref-linear"],
//!   "clip_methods": ["per-example", "ghost"],
//!   "sections": {"sampling": .., "data": .., "accum": .., "apply": .., "compile": ..},
//!   "entries": [
//!     {"kind": "accum", "model": "ref-linear", "variant": "masked",
//!      "batch": 64, "repeats": 30, "unit": "examples_per_sec",
//!      "median": 1.0e5, "ci_low": .., "ci_high": .., "n": 30,
//!      "secs_total": ..},
//!     {"kind": "apply", "model": "ref-linear", "variant": null,
//!      "batch": null, "repeats": 30, "unit": "calls_per_sec", ...}
//!   ],
//!   "workers": [
//!     {"workers": 1, "model": "ref-linear", "clip_method": "ghost",
//!      "steps": 4, "throughput": 1.0e5, "unit": "examples_per_sec",
//!      "secs_total": ..},
//!     {"workers": 2, ...}, {"workers": 4, ...}
//!   ]
//! }
//! ```
//!
//! `workers` rows are keyed by `(model, clip_method, workers)`: each
//! times the *wall clock* of a short fixed-shape training run of that
//! model under that clipping method at that worker count, over the
//! data-parallel executor (DESIGN.md §8) — identical logical work per
//! row, since the trajectory is bitwise worker-count- *and*
//! clip-method-invariant — so the ratios are directly measured scaling
//! curves that `examples/scaling_study.rs` overlays against the
//! `cluster::simulator` Amdahl predictions. `models` / `clip_methods`
//! echo the run configuration; [`BenchReport::validate`] — the schema
//! gate CI runs against the emitted file (`dpshort bench --check`) —
//! rejects a v3 file whose rows name a model or clip method absent
//! from that configuration (unknown keys used to pass `--check`
//! silently).
//!
//! Schema v4 adds the `dpshort bench --serve` synthetic-load sweep:
//! `serve` rows keyed by `(tenants, max_concurrent)` with the
//! multi-tenant scheduler's aggregate examples/sec and per-slice
//! p50/p95/p99 latency, plus the `serve_tenants` run-config echo the
//! validator holds every row's `tenant_names` to.
//!
//! Schema v5 adds the kernel / param-dtype bench axes: accum and apply
//! rows carry `kernel` ("scalar" | "simd") and `param_dtype` ("f32" |
//! "bf16") tags referencing the run-config echoes (`kernels` /
//! `param_dtypes`), so one file holds the scalar-vs-SIMD and
//! f32-vs-bf16 measured comparisons side by side (DESIGN.md §14). Both
//! axes are wall-clock-only for the kernel (bitwise-identical results
//! by construction) and trajectory-changing for the dtype (bf16
//! storage, f32 compute).
//!
//! Version 1 (no `workers`), version 2 (worker curve without
//! `clip_method` keys), version 3 (no `serve` rows), and version 4 (no
//! kernel/dtype axes) files remain valid.

use crate::coordinator::batcher::BatchingMode;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::trainer::{SectionTimes, TrainSession, Trainer};
use crate::metrics::summary_with_ci;
use crate::runtime::{Kernel, Runtime};
use crate::serve::{admit, run_serve, BudgetLedger, JobSpec, JobsFile, ServeOptions};
use anyhow::{anyhow, Context, Result};
use serde::{Deserialize, Serialize};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Version stamp of the `BENCH_throughput.json` schema this build
/// emits. v2 added the per-worker-count `workers` scaling entries; v3
/// keys those rows by `(model, clip_method, workers)` and echoes the
/// run config (`models` / `clip_methods`) so `--check` can reject rows
/// naming unknown keys; v4 adds the multi-tenant `serve` load-sweep
/// rows keyed by `(tenants, max_concurrent)` and their `serve_tenants`
/// echo; v5 adds the kernel / param-dtype axes — accum/apply rows may
/// carry `kernel` and `param_dtype` tags referencing the `kernels` /
/// `param_dtypes` run-config echoes. [`BenchReport::validate`] still
/// accepts v1/v2/v3/v4 files (which predate the fields).
pub const SCHEMA_VERSION: u32 = 5;

/// Oldest schema version [`BenchReport::validate`] accepts.
pub const MIN_SCHEMA_VERSION: u32 = 1;

/// Default output file name (repo-root convention; empty until a sweep
/// has run on a machine).
pub const DEFAULT_OUT: &str = "BENCH_throughput.json";

/// Batch sizes the `--quick` sweep keeps per (model, variant) — the
/// smoke-test subset; the full sweep runs the whole lowered ladder.
const QUICK_BATCHES: [usize; 2] = [16, 64];

/// One measured configuration.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct BenchEntry {
    /// "accum" | "apply".
    pub kind: String,
    pub model: String,
    /// Clipping variant (accum entries; `null` for apply).
    pub variant: Option<String>,
    /// Physical batch size (accum entries; `null` for apply).
    pub batch: Option<usize>,
    /// Requested timed repeats.
    pub repeats: usize,
    /// "examples_per_sec" (accum) | "calls_per_sec" (apply).
    pub unit: String,
    /// Median of the per-call samples.
    pub median: f64,
    /// Bootstrap 95% CI (seeded, 1000 resamples).
    pub ci_low: f64,
    pub ci_high: f64,
    /// Timed samples behind the median.
    pub n: usize,
    /// Total timed seconds this entry consumed.
    pub secs_total: f64,
    /// Kernel axis of this row (schema v5): "scalar" | "simd", one of
    /// the report's `kernels` echo. Empty in pre-v5 files (and in v5
    /// files whose run had no kernel axis, e.g. PJRT sweeps).
    #[serde(default, skip_serializing_if = "String::is_empty")]
    pub kernel: String,
    /// Parameter-storage dtype axis of this row (schema v5): "f32" |
    /// "bf16", one of the report's `param_dtypes` echo. Empty in pre-v5
    /// files and axis-less v5 runs.
    #[serde(default, skip_serializing_if = "String::is_empty")]
    pub param_dtype: String,
}

/// One point of the measured data-parallel scaling curve (schema v2):
/// wall-clock training throughput of a short masked run at a given
/// worker count. The run's *results* are bitwise-identical across
/// entries (the §8 determinism contract), so only the wall clock moves.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct WorkerEntry {
    /// Data-parallel worker sessions of this run.
    pub workers: usize,
    /// Model the run trained.
    pub model: String,
    /// Clipping method of this run (schema v3; one of the report's
    /// `clip_methods`). Empty in v1/v2 files, which predate the key.
    #[serde(default, skip_serializing_if = "String::is_empty")]
    pub clip_method: String,
    /// Optimizer steps timed.
    pub steps: u64,
    /// Real (sampled) examples per wall-clock second over the step
    /// loop, compile excluded.
    pub throughput: f64,
    /// Always "examples_per_sec".
    pub unit: String,
    /// Wall-clock seconds of the timed step loop.
    pub secs_total: f64,
}

/// One point of the multi-tenant synthetic-load sweep (schema v4):
/// a full `serve` run of `tenants` jobs at one `max_concurrent`
/// residency cap. Rows are keyed by `(tenants, max_concurrent)`; the
/// per-tenant *results* are bitwise-identical across rows (cooperative
/// scheduling moves wall clock and memory only), so the rows measure
/// pure scheduling overhead: aggregate throughput and the per-slice
/// latency tail.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct ServeEntry {
    /// Tenants of this run (the row key's first half).
    pub tenants: usize,
    /// Residency cap of this run (the row key's second half).
    pub max_concurrent: usize,
    /// Names of the tenants this row served — each must appear in the
    /// report's `serve_tenants` run-config echo.
    pub tenant_names: Vec<String>,
    /// Optimizer steps each tenant ran.
    pub steps_per_tenant: u64,
    /// Scheduler slices the run completed.
    pub slices: u64,
    /// Sessions evicted to checkpoint under residency pressure.
    pub evictions: usize,
    /// Aggregate real examples per wall-clock second over all slices.
    pub throughput: f64,
    /// Nearest-rank per-slice latency quantiles, in seconds.
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    /// Always "examples_per_sec".
    pub unit: String,
    /// Total wall-clock seconds across the run's slices.
    pub secs_total: f64,
}

/// The full document written to `BENCH_throughput.json`.
#[derive(Debug, Serialize, Deserialize)]
pub struct BenchReport {
    /// Schema version of this document (see [`SCHEMA_VERSION`]).
    pub schema_version: u32,
    /// Active backend name ("reference" | "pjrt").
    pub backend: String,
    /// Seed driving data, bootstrap resampling, and the sections run.
    pub seed: u64,
    /// Whether the `--quick` smoke subset produced this report.
    pub quick: bool,
    /// Run config echo (schema v3): the models this sweep covered.
    /// Every entry/worker row must name one of them — the validator's
    /// defense against rows citing models the run never measured.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub models: Vec<String>,
    /// Run config echo (schema v3): the clip methods of the worker
    /// scaling sweep. Every worker row's `clip_method` must be one.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub clip_methods: Vec<String>,
    /// Run config echo (schema v5): the kernel axes this sweep
    /// measured ("scalar" / "simd"). Non-empty iff the entries carry
    /// `kernel` tags.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub kernels: Vec<String>,
    /// Run config echo (schema v5): the parameter-storage dtypes this
    /// sweep measured ("f32" / "bf16"). Non-empty iff the entries
    /// carry `param_dtype` tags.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub param_dtypes: Vec<String>,
    /// Per-section wall-clock of a short masked training run on the
    /// first swept model (the Table-2 analogue for this checkout).
    pub sections: Option<SectionTimes>,
    /// Measured accum/apply configurations.
    pub entries: Vec<BenchEntry>,
    /// Measured data-parallel scaling curve (schema v2; absent in v1
    /// files and when the worker sweep is skipped).
    #[serde(default, skip_serializing_if = "Option::is_none")]
    pub workers: Option<Vec<WorkerEntry>>,
    /// Run config echo (schema v4): the tenants of the serve sweep.
    /// Every serve row's `tenant_names` must be a subset — the
    /// validator's defense against rows citing tenants the run never
    /// configured.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub serve_tenants: Vec<String>,
    /// Multi-tenant synthetic-load sweep (schema v4), one row per
    /// `(tenants, max_concurrent)`; empty when `--serve` was not run.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub serve: Vec<ServeEntry>,
}

impl BenchReport {
    pub fn to_json(&self) -> Result<String> {
        serde_json::to_string_pretty(self).context("serializing bench report")
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let report: Self = serde_json::from_str(text).context("parsing bench report")?;
        Ok(report)
    }

    /// Write to `path` (pretty JSON + trailing newline).
    pub fn write(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json()?;
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
    }

    /// Load and schema-check an emitted file — the CI smoke gate.
    pub fn check_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let report = Self::from_json(&text)?;
        report.validate()?;
        Ok(report)
    }

    /// Schema invariants beyond what deserialization enforces. Accepts
    /// every version in `MIN_SCHEMA_VERSION..=SCHEMA_VERSION`: v1 files
    /// (written before the worker scaling sweep) must not carry a
    /// `workers` field; v2 files may; v3 files must also echo the run
    /// config (`models` / `clip_methods`) and every row must reference
    /// it — a row naming a model or clip method the run never measured
    /// is rejected instead of passing `--check` silently. v4 files may
    /// carry `serve` load-sweep rows, keyed uniquely by
    /// `(tenants, max_concurrent)` and naming only tenants echoed in
    /// `serve_tenants`. v5 files may carry the kernel / param-dtype
    /// axes: entry `kernel`/`param_dtype` tags and their `kernels` /
    /// `param_dtypes` echoes must be present together and agree.
    pub fn validate(&self) -> Result<()> {
        if self.schema_version < MIN_SCHEMA_VERSION || self.schema_version > SCHEMA_VERSION {
            return Err(anyhow!(
                "schema_version {} outside supported {MIN_SCHEMA_VERSION}..={SCHEMA_VERSION}",
                self.schema_version
            ));
        }
        if self.schema_version < 2 && self.workers.is_some() {
            return Err(anyhow!("v1 reports cannot carry a `workers` scaling curve"));
        }
        if self.backend.is_empty() {
            return Err(anyhow!("backend must be non-empty"));
        }
        let v3 = self.schema_version >= 3;
        if v3 {
            if self.models.is_empty() {
                return Err(anyhow!("v3 reports must echo the swept `models`"));
            }
            for m in &self.clip_methods {
                if !crate::clipping::is_clip_method(m) {
                    return Err(anyhow!("clip_methods names unknown method {m:?}"));
                }
            }
        } else if !self.models.is_empty() || !self.clip_methods.is_empty() {
            return Err(anyhow!(
                "pre-v3 reports cannot carry `models`/`clip_methods` config echoes"
            ));
        }
        if self.schema_version < 4 && (!self.serve.is_empty() || !self.serve_tenants.is_empty()) {
            return Err(anyhow!(
                "pre-v4 reports cannot carry `serve` rows or the `serve_tenants` echo"
            ));
        }
        let v5 = self.schema_version >= 5;
        if !v5 && (!self.kernels.is_empty() || !self.param_dtypes.is_empty()) {
            return Err(anyhow!(
                "pre-v5 reports cannot carry `kernels`/`param_dtypes` config echoes"
            ));
        }
        for k in &self.kernels {
            if k != "scalar" && k != "simd" {
                return Err(anyhow!("kernels echo names unknown axis {k:?}"));
            }
        }
        for d in &self.param_dtypes {
            if d != "f32" && d != "bf16" {
                return Err(anyhow!("param_dtypes echo names unknown dtype {d:?}"));
            }
        }
        if !self.serve.is_empty() && self.serve_tenants.is_empty() {
            return Err(anyhow!("serve rows need the `serve_tenants` run-config echo"));
        }
        for (i, s) in self.serve.iter().enumerate() {
            let ctx = |msg: &str| {
                anyhow!(
                    "serve row {i} (tenants={}, max_concurrent={}): {msg}",
                    s.tenants,
                    s.max_concurrent
                )
            };
            if s.tenants == 0 || s.max_concurrent == 0 {
                return Err(ctx("tenants and max_concurrent must be positive"));
            }
            if s.tenant_names.len() != s.tenants {
                return Err(ctx("tenant_names must list exactly `tenants` names"));
            }
            for name in &s.tenant_names {
                if !self.serve_tenants.contains(name) {
                    return Err(ctx("row names a tenant absent from the run config"));
                }
            }
            if s.unit != "examples_per_sec" {
                return Err(ctx("unit must be examples_per_sec"));
            }
            if !(s.throughput.is_finite() && s.throughput > 0.0) {
                return Err(ctx("throughput must be finite and positive"));
            }
            let lats = [s.p50_latency, s.p95_latency, s.p99_latency];
            if lats.iter().any(|l| !(l.is_finite() && *l > 0.0)) {
                return Err(ctx("latency quantiles must be finite and positive"));
            }
            if !(s.p50_latency <= s.p95_latency && s.p95_latency <= s.p99_latency) {
                return Err(ctx("latency quantiles must be non-decreasing p50<=p95<=p99"));
            }
            if s.steps_per_tenant == 0 || s.slices == 0 {
                return Err(ctx("steps_per_tenant and slices must be positive"));
            }
            if !(s.secs_total.is_finite() && s.secs_total >= 0.0) {
                return Err(ctx("secs_total must be finite and non-negative"));
            }
        }
        // Serve rows are keyed by (tenants, max_concurrent) and must be
        // unique — one run pretending to be several is malformed.
        let mut serve_keys: Vec<(usize, usize)> =
            self.serve.iter().map(|s| (s.tenants, s.max_concurrent)).collect();
        serve_keys.sort_unstable();
        serve_keys.dedup();
        if serve_keys.len() != self.serve.len() {
            return Err(anyhow!("serve sweep repeats a (tenants, max_concurrent) row"));
        }
        if let Some(workers) = &self.workers {
            if workers.is_empty() {
                return Err(anyhow!("workers scaling curve must be absent, not empty"));
            }
            if v3 && self.clip_methods.is_empty() {
                return Err(anyhow!(
                    "v3 reports with a worker curve must echo the swept `clip_methods`"
                ));
            }
            for (i, w) in workers.iter().enumerate() {
                let ctx = |msg: &str| anyhow!("workers entry {i} (n={}): {msg}", w.workers);
                if w.workers == 0 {
                    return Err(ctx("worker count must be positive"));
                }
                if w.unit != "examples_per_sec" {
                    return Err(ctx("unit must be examples_per_sec"));
                }
                if !(w.throughput.is_finite() && w.throughput > 0.0) {
                    return Err(ctx("throughput must be finite and positive"));
                }
                if !(w.secs_total.is_finite() && w.secs_total >= 0.0) {
                    return Err(ctx("secs_total must be finite and non-negative"));
                }
                if w.steps == 0 || w.model.is_empty() {
                    return Err(ctx("steps must be positive and model non-empty"));
                }
                if v3 {
                    if !self.models.contains(&w.model) {
                        return Err(ctx("row names a model absent from the run config"));
                    }
                    if !self.clip_methods.contains(&w.clip_method) {
                        return Err(ctx("row names a clip_method absent from the run config"));
                    }
                } else if !w.clip_method.is_empty() {
                    return Err(ctx("pre-v3 rows cannot carry a clip_method"));
                }
            }
            // One measurement pretending to be several: rows are keyed
            // by (model, clip_method, workers) and must be unique.
            let mut keys: Vec<(&str, &str, usize)> = workers
                .iter()
                .map(|w| (w.model.as_str(), w.clip_method.as_str(), w.workers))
                .collect();
            keys.sort_unstable();
            keys.dedup();
            if keys.len() != workers.len() {
                return Err(anyhow!(
                    "workers scaling curve repeats a (model, clip_method, workers) row"
                ));
            }
        }
        // A serve-only report (bench --serve) legitimately carries no
        // accum/apply entries; anything else must measure something.
        if self.entries.is_empty() && self.serve.is_empty() {
            return Err(anyhow!("bench report has no entries"));
        }
        for (i, e) in self.entries.iter().enumerate() {
            let ctx = |msg: &str| anyhow!("entry {i} ({}/{:?}): {msg}", e.model, e.variant);
            match e.kind.as_str() {
                "accum" => {
                    if e.variant.is_none() || e.batch.is_none() {
                        return Err(ctx("accum entries need variant and batch"));
                    }
                    if e.unit != "examples_per_sec" {
                        return Err(ctx("accum unit must be examples_per_sec"));
                    }
                }
                "apply" => {
                    if e.unit != "calls_per_sec" {
                        return Err(ctx("apply unit must be calls_per_sec"));
                    }
                }
                _ => return Err(ctx("kind must be accum|apply")),
            }
            if e.n == 0 || e.n > e.repeats {
                return Err(ctx("sample count n must be in 1..=repeats"));
            }
            if !(e.median.is_finite() && e.median > 0.0) {
                return Err(ctx("median must be finite and positive"));
            }
            if !(e.ci_low.is_finite() && e.ci_high.is_finite()) {
                return Err(ctx("CI bounds must be finite"));
            }
            if e.ci_low > e.median || e.median > e.ci_high {
                return Err(ctx("CI must bracket the median"));
            }
            if !(e.secs_total.is_finite() && e.secs_total >= 0.0) {
                return Err(ctx("secs_total must be finite and non-negative"));
            }
            if self.schema_version >= 3 && !self.models.contains(&e.model) {
                return Err(ctx("entry names a model absent from the run config"));
            }
            if !v5 && (!e.kernel.is_empty() || !e.param_dtype.is_empty()) {
                return Err(ctx("pre-v5 entries cannot carry kernel/param_dtype tags"));
            }
            if self.kernels.is_empty() != e.kernel.is_empty() {
                return Err(ctx("entry kernel tags and the `kernels` echo must appear together"));
            }
            if !e.kernel.is_empty() && !self.kernels.contains(&e.kernel) {
                return Err(ctx("entry names a kernel absent from the run config"));
            }
            if self.param_dtypes.is_empty() != e.param_dtype.is_empty() {
                return Err(ctx(
                    "entry param_dtype tags and the `param_dtypes` echo must appear together",
                ));
            }
            if !e.param_dtype.is_empty() && !self.param_dtypes.contains(&e.param_dtype) {
                return Err(ctx("entry names a param_dtype absent from the run config"));
            }
        }
        Ok(())
    }

    /// The accum entry for (model, variant, batch), if swept. With a
    /// v5 multi-axis sweep this returns the first combo's row; use
    /// [`Self::accum_entry_axis`] to pin a (kernel, dtype) point.
    pub fn accum_entry(&self, model: &str, variant: &str, batch: usize) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| {
            e.kind == "accum"
                && e.model == model
                && e.variant.as_deref() == Some(variant)
                && e.batch == Some(batch)
        })
    }

    /// The accum entry at one (kernel, param_dtype) axis point (schema
    /// v5), if swept.
    pub fn accum_entry_axis(
        &self,
        model: &str,
        variant: &str,
        batch: usize,
        kernel: &str,
        param_dtype: &str,
    ) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| {
            e.kind == "accum"
                && e.model == model
                && e.variant.as_deref() == Some(variant)
                && e.batch == Some(batch)
                && e.kernel == kernel
                && e.param_dtype == param_dtype
        })
    }
}

/// What to sweep. `None` filters mean "everything the manifest lowers".
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Restrict to one model (default: every manifest model).
    pub model: Option<String>,
    /// Restrict to one clipping variant.
    pub variant: Option<String>,
    /// Restrict to one physical batch size.
    pub batch: Option<usize>,
    /// Timed repeats per configuration.
    pub repeats: usize,
    /// Smoke mode: restrict batches to the quick subset (16 / 64).
    pub quick: bool,
    /// Seed for data, bootstrap, and the sections run.
    pub seed: u64,
    /// Also time a short training run for the per-section breakdown.
    pub with_sections: bool,
    /// Worker counts for the data-parallel scaling sweep (schema v3
    /// `workers`); empty skips it (the report then omits the field).
    pub worker_counts: Vec<usize>,
    /// Clip methods for the scaling sweep (CLI names, see
    /// [`crate::clipping::CLI_CLIP_METHODS`]); the curve gets one row
    /// per (model, clip method, worker count).
    pub clip_methods: Vec<String>,
    /// Kernel axis (`bench --kernels`): selections out of
    /// "scalar" | "simd" | "auto", one accum/apply series per resolved
    /// axis. Reference backend only; empty means `["auto"]`.
    pub kernels: Vec<String>,
    /// Parameter-storage dtype axis (`bench --param-dtypes`):
    /// selections out of "f32" | "bf16", one accum/apply series each.
    /// Reference backend only; empty means `["f32"]`.
    pub param_dtypes: Vec<String>,
    /// Worker-thread count for the per-kernel reference runtimes the
    /// axis sweep rebuilds (`0` = auto; the `--threads` knob).
    pub threads: usize,
}

impl SweepOptions {
    /// Defaults: full ladder at 30 repeats, or the quick smoke subset
    /// at 5; data-parallel scaling measured at 1/2/4 workers under
    /// per-example and ghost clipping; auto kernel, f32 storage.
    pub fn new(quick: bool) -> Self {
        Self {
            model: None,
            variant: None,
            batch: None,
            repeats: if quick { 5 } else { 30 },
            quick,
            seed: 0,
            with_sections: true,
            worker_counts: vec![1, 2, 4],
            clip_methods: vec!["per-example".into(), "ghost".into()],
            kernels: vec!["auto".into()],
            param_dtypes: vec!["f32".into()],
            threads: 0,
        }
    }
}

/// Run the accum/apply sweep and assemble the validated report.
pub fn run_sweep(rt: &Runtime, opts: &SweepOptions) -> Result<BenchReport> {
    // Reject malformed worker counts / clip methods before minutes of
    // sweep work run only to be discarded by the scaling pass at the
    // end.
    if opts.worker_counts.contains(&0) {
        return Err(anyhow!("--workers counts must be positive"));
    }
    for m in &opts.clip_methods {
        if crate::clipping::clip_method_variant(m).is_none() {
            return Err(anyhow!("--clip-methods names unknown method {m:?}"));
        }
    }
    if !opts.worker_counts.is_empty() && opts.clip_methods.is_empty() {
        return Err(anyhow!("the worker scaling sweep needs at least one clip method"));
    }
    let models: Vec<String> = rt
        .manifest()
        .models
        .keys()
        .filter(|m| opts.model.as_deref().is_none_or(|want| want == m.as_str()))
        .cloned()
        .collect();
    if models.is_empty() {
        return Err(anyhow!(
            "no models match {:?} (manifest has {:?})",
            opts.model,
            rt.manifest().models.keys().collect::<Vec<_>>()
        ));
    }
    // Resolve the schema-v5 kernel / param-dtype axes up front.
    let kernel_names: Vec<String> = if opts.kernels.is_empty() {
        vec!["auto".into()]
    } else {
        opts.kernels.clone()
    };
    let mut kernel_axes: Vec<(String, Kernel)> = Vec::new();
    for name in &kernel_names {
        let k = Kernel::parse(name).ok_or_else(|| {
            anyhow!("--kernels names unknown kernel {name:?} (scalar | simd | auto)")
        })?;
        // Dedup by *resolved* axis: on a host without SIMD support,
        // "simd"/"auto" fall back to scalar and would duplicate rows.
        if !kernel_axes.iter().any(|(a, _)| a == k.axis()) {
            kernel_axes.push((k.axis().to_string(), k));
        }
    }
    let requested_dtypes: Vec<String> = if opts.param_dtypes.is_empty() {
        vec!["f32".into()]
    } else {
        opts.param_dtypes.clone()
    };
    let mut dtypes: Vec<String> = Vec::new();
    for d in &requested_dtypes {
        if d != "f32" && d != "bf16" {
            return Err(anyhow!("--param-dtypes names unknown dtype {d:?} (f32 | bf16)"));
        }
        if !dtypes.contains(d) {
            dtypes.push(d.clone());
        }
    }
    // The kernel is a reference-backend construction knob; PJRT owns
    // its own kernels, so the axes only apply there with the defaults.
    let reference = rt.backend_name() == "reference";
    if !reference && (kernel_names != ["auto"] || dtypes != ["f32"]) {
        return Err(anyhow!(
            "--kernels/--param-dtypes axes apply to the reference backend only"
        ));
    }

    let mut entries = Vec::new();
    let mut sections = None;
    let mut worker_rows: Vec<WorkerEntry> = Vec::new();
    let mut first_combo = true;
    for (axis, kern) in &kernel_axes {
        // Rebuild the reference runtime per kernel axis (same manifest
        // seed, so the same models and the same init bits — the kernel
        // moves wall-clock only).
        let owned;
        let krt: &Runtime = if reference {
            owned = Runtime::reference_with_options(rt.manifest().seed, opts.threads, *kern);
            &owned
        } else {
            rt
        };
        for dtype in &dtypes {
            let bf16 = dtype == "bf16";
            // Rows are tagged (and the echoes emitted) only when the
            // reference backend executes the axes; PJRT sweeps stay
            // axis-less.
            let (ktag, dtag) = if reference {
                (axis.as_str(), dtype.as_str())
            } else {
                ("", "")
            };
            for model in &models {
                let meta = krt.manifest().model(model)?.clone();
                for variant in meta.variants() {
                    if let Some(want) = &opts.variant {
                        if *want != variant {
                            continue;
                        }
                    } else if variant == "naive" {
                        // "naive" shares the masked accum kernel and only
                        // differs in Variable-mode chunking; skip unless
                        // asked.
                        continue;
                    }
                    let mut batches = meta.accum_batches(&variant, dtype);
                    if let Some(want) = opts.batch {
                        batches.retain(|b| *b == want);
                    } else if opts.quick {
                        let full = batches.clone();
                        batches.retain(|b| QUICK_BATCHES.contains(b));
                        if batches.is_empty() {
                            // Ladder without the canonical rungs: keep the
                            // largest.
                            batches = full.last().copied().into_iter().collect();
                        }
                    }
                    for b in batches {
                        let cfg = TrainConfig {
                            model: model.clone(),
                            variant: variant.clone(),
                            physical_batch: b,
                            seed: opts.seed,
                            bf16,
                            kernel: axis.clone(),
                            ..Default::default()
                        };
                        let trainer = Trainer::new(krt, cfg)?;
                        let samples = trainer.bench_accum(&variant, b, opts.repeats)?;
                        entries.push(entry_from(
                            "accum",
                            model,
                            Some(variant.clone()),
                            Some(b),
                            opts,
                            &samples,
                            (ktag, dtag),
                        ));
                    }
                }
                let cfg = TrainConfig {
                    model: model.clone(),
                    seed: opts.seed,
                    bf16,
                    kernel: axis.clone(),
                    ..Default::default()
                };
                let trainer = Trainer::new(krt, cfg)?;
                let samples = trainer.bench_apply(opts.repeats)?;
                entries.push(entry_from("apply", model, None, None, opts, &samples, (ktag, dtag)));

                if first_combo && opts.with_sections && sections.is_none() {
                    sections = Some(train_sections(krt, model, opts)?);
                }
            }
            // The worker scaling curve (and the sections run) measure a
            // single point of the axis grid — the first combo — so axis
            // sweeps do not multiply the slowest rows.
            if first_combo {
                for model in &models {
                    for method in &opts.clip_methods {
                        worker_rows.extend(worker_scaling(krt, model, method, opts)?);
                    }
                }
            }
            first_combo = false;
        }
    }
    // An explicit filter that matched nothing is an error, not a report
    // quietly missing the requested measurement (the apply entries keep
    // `entries` non-empty, so validate() alone cannot catch this).
    if let Some(want) = &opts.variant {
        if !entries
            .iter()
            .any(|e| e.kind == "accum" && e.variant.as_deref() == Some(want.as_str()))
        {
            return Err(anyhow!("--variant {want} matches no lowered accum executable"));
        }
    }
    if let Some(want) = opts.batch {
        if !entries.iter().any(|e| e.kind == "accum" && e.batch == Some(want)) {
            return Err(anyhow!("--batch {want} matches no lowered accum executable"));
        }
    }
    let workers = if opts.worker_counts.is_empty() {
        None
    } else {
        // An unmeasurable curve (no fixed-shape variants lowered,
        // degenerate clock) omits the field rather than emitting an
        // invalid empty list.
        (!worker_rows.is_empty()).then_some(worker_rows)
    };
    let report = BenchReport {
        schema_version: SCHEMA_VERSION,
        backend: rt.backend_name().to_string(),
        seed: opts.seed,
        quick: opts.quick,
        models,
        clip_methods: opts.clip_methods.clone(),
        kernels: if reference {
            kernel_axes.iter().map(|(a, _)| a.clone()).collect()
        } else {
            Vec::new()
        },
        param_dtypes: if reference { dtypes } else { Vec::new() },
        sections,
        entries,
        workers,
        serve_tenants: Vec::new(),
        serve: Vec::new(),
    };
    report.validate()?;
    Ok(report)
}

/// What the multi-tenant synthetic-load sweep runs (`bench --serve`).
#[derive(Debug, Clone)]
pub struct ServeSweepOptions {
    /// Synthetic tenants per run.
    pub tenants: usize,
    /// `max_concurrent` residency caps to sweep — one serve row each.
    pub concurrency: Vec<usize>,
    /// Optimizer steps per tenant.
    pub steps: u64,
    /// Steps per scheduler slice.
    pub steps_per_slice: u64,
    /// Seed offsetting each tenant's dataset draw.
    pub seed: u64,
    /// Scratch root for checkpoint namespaces + ledger snapshots; each
    /// concurrency level uses its own subdirectory.
    pub ckpt_root: PathBuf,
    /// `--memory-budget-bytes` applied to every run (0 = unlimited).
    pub memory_budget_bytes: f64,
}

impl ServeSweepOptions {
    /// Defaults: 3 tenants for 4 steps in 2-step slices, swept at
    /// residency caps 1, 2, and `tenants` (the quick subset halves the
    /// steps).
    pub fn new(quick: bool, ckpt_root: PathBuf) -> Self {
        Self {
            tenants: 3,
            concurrency: vec![1, 2, 3],
            steps: if quick { 2 } else { 4 },
            steps_per_slice: if quick { 1 } else { 2 },
            seed: 0,
            ckpt_root,
            memory_budget_bytes: 0.0,
        }
    }
}

/// The synthetic manifest the load sweep admits: `tenants` jobs over
/// the default model, cycling clip methods and accountants, each with
/// its own dataset seed and a budget roomy enough that the sweep
/// measures scheduling, not hard-stops.
pub fn synthetic_jobs(tenants: usize, steps: u64, seed: u64) -> JobsFile {
    const METHODS: [&str; 3] = ["masked", "per-example", "ghost"];
    let tenants = (0..tenants)
        .map(|i| JobSpec {
            name: format!("tenant-{i:02}"),
            model: None,
            clip_method: METHODS[i % METHODS.len()].into(),
            dataset_size: Some(96),
            seed: Some(seed.wrapping_add(i as u64)),
            sampling_rate: Some(0.25),
            physical_batch: Some(8),
            steps,
            lr: None,
            noise_multiplier: Some(1.0),
            budget_epsilon: 50.0,
            budget_delta: None,
            sampler: None,
            accountant: Some(if i % 2 == 0 { "rdp" } else { "pld" }.into()),
            workers: None,
        })
        .collect();
    JobsFile { tenants }
}

/// Run the multi-tenant synthetic-load sweep: admit the synthetic
/// manifest once, then serve it from scratch at every requested
/// `max_concurrent`, producing one schema-v4 `serve` row per level.
pub fn run_serve_sweep(rt: &Runtime, opts: &ServeSweepOptions) -> Result<BenchReport> {
    if opts.tenants == 0 {
        return Err(anyhow!("--tenants must be positive"));
    }
    let mut levels = opts.concurrency.clone();
    levels.sort_unstable();
    levels.dedup();
    if levels.is_empty() || levels.contains(&0) {
        return Err(anyhow!("--max-concurrent levels must be a non-empty positive list"));
    }
    let jobs = synthetic_jobs(opts.tenants, opts.steps, opts.seed);
    let (admitted, rejected) = admit(rt, &jobs)?;
    if !rejected.is_empty() {
        return Err(anyhow!(
            "synthetic load manifest was partially rejected at admission: {rejected:?}"
        ));
    }
    let tenant_names: Vec<String> = admitted.iter().map(|t| t.name.clone()).collect();
    let mut models: Vec<String> = admitted.iter().map(|t| t.config.model.clone()).collect();
    models.sort_unstable();
    models.dedup();
    // The clip_methods echo keeps its v3 meaning (CLI names only);
    // tenants using a raw variant ("masked") are echoed via
    // serve_tenants instead.
    let mut clip_methods: Vec<String> = jobs
        .tenants
        .iter()
        .map(|j| j.clip_method.clone())
        .filter(|m| crate::clipping::is_clip_method(m))
        .collect();
    clip_methods.sort_unstable();
    clip_methods.dedup();
    let mut serve_rows = Vec::with_capacity(levels.len());
    for &mc in &levels {
        let serve_opts = ServeOptions {
            max_concurrent: mc,
            memory_budget_bytes: opts.memory_budget_bytes,
            steps_per_slice: opts.steps_per_slice,
            ckpt_root: opts.ckpt_root.join(format!("mc{mc}")),
            max_slices: None,
        };
        let mut ledger = BudgetLedger::new();
        let run = run_serve(rt, &admitted, &mut ledger, &serve_opts)?;
        let latency = run
            .slice_latency
            .ok_or_else(|| anyhow!("serve run at max_concurrent={mc} completed no slices"))?;
        serve_rows.push(ServeEntry {
            tenants: opts.tenants,
            max_concurrent: mc,
            tenant_names: tenant_names.clone(),
            steps_per_tenant: opts.steps,
            slices: run.slices.len() as u64,
            evictions: run.evictions,
            throughput: run.aggregate_examples_per_sec,
            p50_latency: latency.p50,
            p95_latency: latency.p95,
            p99_latency: latency.p99,
            unit: "examples_per_sec".into(),
            secs_total: run.slices.iter().map(|s| s.secs).sum(),
        });
    }
    let report = BenchReport {
        schema_version: SCHEMA_VERSION,
        backend: rt.backend_name().to_string(),
        seed: opts.seed,
        quick: false,
        models,
        clip_methods,
        kernels: Vec::new(),
        param_dtypes: Vec::new(),
        sections: None,
        entries: Vec::new(),
        workers: None,
        serve_tenants: tenant_names,
        serve: serve_rows,
    };
    report.validate()?;
    Ok(report)
}

/// Measured data-parallel scaling for one (model, clip method): a
/// short fixed-shape training run per worker count, identical logical
/// work (same seed → same sampled batches, and the §8 contract makes
/// the results bitwise-identical), timed over the step loop's wall
/// clock. Session construction — and with it every compile — happens
/// outside the timed region, the same discount the steady-state sweep
/// applies. Returns no rows when the model does not lower the method's
/// variant (e.g. artifact catalogs without the `perex`/`mix` graphs).
fn worker_scaling(
    rt: &Runtime,
    model: &str,
    clip_method: &str,
    opts: &SweepOptions,
) -> Result<Vec<WorkerEntry>> {
    let variant = crate::clipping::clip_method_variant(clip_method)
        .ok_or_else(|| anyhow!("unknown clip method {clip_method:?}"))?;
    let meta = rt.manifest().model(model)?.clone();
    let batches = meta.accum_batches(variant, "f32");
    let batch = batches
        .iter()
        .copied()
        .filter(|b| *b <= 16)
        .max()
        .or_else(|| batches.first().copied());
    let Some(batch) = batch else {
        // Variant not lowered for this model: skip the series, the
        // report simply carries no rows for it.
        return Ok(Vec::new());
    };
    let mut counts = opts.worker_counts.clone();
    counts.sort_unstable();
    counts.dedup();
    let mut out = Vec::with_capacity(counts.len());
    for &workers in &counts {
        let cfg = TrainConfig {
            model: model.to_string(),
            variant: variant.into(),
            mode: BatchingMode::Masked,
            physical_batch: batch,
            dataset_size: 512,
            sampling_rate: 0.5,
            steps: if opts.quick { 2 } else { 4 },
            noise_multiplier: Some(1.0),
            eval_examples: 0,
            seed: opts.seed,
            workers,
            ..Default::default()
        };
        let steps = cfg.steps;
        let mut session = TrainSession::new(rt, cfg)?;
        let t = Instant::now();
        while !session.done() {
            session.step()?;
        }
        let secs_total = t.elapsed().as_secs_f64();
        let report = session.finish()?;
        let real: f64 = report.steps.iter().map(|s| s.logical_batch as f64).sum();
        if secs_total <= 0.0 {
            continue; // clock too coarse to time this run
        }
        out.push(WorkerEntry {
            workers,
            model: model.to_string(),
            clip_method: clip_method.to_string(),
            steps,
            throughput: real / secs_total,
            unit: "examples_per_sec".into(),
            secs_total,
        });
    }
    Ok(out)
}

/// `axes` is the `(kernel, param_dtype)` tag pair — `("", "")` on
/// axis-less (PJRT) sweeps.
fn entry_from(
    kind: &str,
    model: &str,
    variant: Option<String>,
    batch: Option<usize>,
    opts: &SweepOptions,
    samples: &[f64],
    axes: (&str, &str),
) -> BenchEntry {
    let s = summary_with_ci(samples, opts.seed);
    // Samples are rates; invert (scaled by the per-call example count)
    // to recover the timed seconds.
    let per_call = batch.unwrap_or(1) as f64;
    let secs_total: f64 = samples.iter().filter(|r| **r > 0.0).map(|r| per_call / r).sum();
    BenchEntry {
        kind: kind.to_string(),
        model: model.to_string(),
        unit: if kind == "accum" { "examples_per_sec" } else { "calls_per_sec" }.to_string(),
        variant,
        batch,
        repeats: opts.repeats,
        median: s.median,
        ci_low: s.ci_low,
        ci_high: s.ci_high,
        n: s.n,
        secs_total,
        kernel: axes.0.to_string(),
        param_dtype: axes.1.to_string(),
    }
}

/// Short masked training run for the per-section breakdown (Table 2).
fn train_sections(rt: &Runtime, model: &str, opts: &SweepOptions) -> Result<SectionTimes> {
    let meta = rt.manifest().model(model)?.clone();
    let variants = meta.variants();
    let variant = if variants.iter().any(|v| v == "masked") {
        "masked".to_string()
    } else {
        variants
            .first()
            .cloned()
            .ok_or_else(|| anyhow!("model {model} lowers no accum variants"))?
    };
    let batches = meta.accum_batches(&variant, "f32");
    let batch = batches
        .iter()
        .copied()
        .filter(|b| *b <= 16)
        .max()
        .or_else(|| batches.first().copied())
        .ok_or_else(|| anyhow!("model {model} lowers no {variant} batches"))?;
    let cfg = TrainConfig {
        model: model.to_string(),
        variant: variant.clone(),
        mode: if variant == "naive" { BatchingMode::Variable } else { BatchingMode::Masked },
        physical_batch: batch,
        dataset_size: 256,
        sampling_rate: 0.25,
        steps: if opts.quick { 2 } else { 4 },
        noise_multiplier: Some(1.0),
        eval_examples: 0,
        seed: opts.seed,
        ..Default::default()
    };
    Ok(Trainer::new(rt, cfg)?.run()?.sections)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_report() -> BenchReport {
        let rt = Runtime::reference();
        let mut opts = SweepOptions::new(true);
        opts.repeats = 3;
        opts.variant = Some("masked".to_string());
        opts.batch = Some(16);
        opts.worker_counts = vec![1, 2];
        run_sweep(&rt, &opts).unwrap()
    }

    /// Downgrade a v5 report to the pre-v5 shape: no kernel/dtype
    /// echoes, no entry tags.
    fn strip_axes(report: &mut BenchReport) {
        report.kernels.clear();
        report.param_dtypes.clear();
        for e in &mut report.entries {
            e.kernel.clear();
            e.param_dtype.clear();
        }
    }

    #[test]
    fn sweep_emits_valid_schema_and_roundtrips() {
        let report = quick_report();
        report.validate().unwrap();
        assert_eq!(report.schema_version, SCHEMA_VERSION);
        assert_eq!(report.backend, "reference");
        assert!(report.accum_entry("ref-linear", "masked", 16).is_some());
        assert!(report.accum_entry("mlp-small", "masked", 16).is_some());
        assert!(report.entries.iter().any(|e| e.kind == "apply"));
        let sections = report.sections.expect("sections run");
        assert!(sections.accum > 0.0);
        // The run-config echo covers the whole CPU ladder + methods.
        assert!(report.models.contains(&"ref-linear".to_string()));
        assert!(report.models.contains(&"mlp-small".to_string()));
        assert_eq!(report.clip_methods, vec!["per-example", "ghost"]);
        // The v3 worker scaling curve: one row per
        // (model, clip_method, workers) — at least two models × two
        // clip methods (the acceptance gate), each series over the
        // requested counts.
        let workers = report.workers.as_ref().expect("worker scaling curve");
        let mut series: Vec<(&str, &str)> = workers
            .iter()
            .map(|w| (w.model.as_str(), w.clip_method.as_str()))
            .collect();
        series.sort_unstable();
        series.dedup();
        assert!(series.len() >= 4, "series: {series:?}");
        assert!(series.contains(&("mlp-small", "ghost")));
        assert!(series.contains(&("ref-linear", "per-example")));
        for (model, method) in series {
            let counts: Vec<usize> = workers
                .iter()
                .filter(|w| w.model == model && w.clip_method == method)
                .map(|w| w.workers)
                .collect();
            assert_eq!(counts, vec![1, 2], "{model}/{method}");
        }
        assert!(workers.iter().all(|w| w.throughput > 0.0 && w.unit == "examples_per_sec"));
        // JSON roundtrip preserves the schema.
        let text = report.to_json().unwrap();
        let parsed = BenchReport::from_json(&text).unwrap();
        parsed.validate().unwrap();
        assert_eq!(parsed.entries.len(), report.entries.len());
        assert_eq!(parsed.workers.unwrap().len(), report.workers.as_ref().unwrap().len());
    }

    #[test]
    fn v1_reports_without_workers_field_still_validate() {
        // A file emitted by the schema-v1 harness: no `workers` key, no
        // config echoes. --check must keep accepting it.
        let mut report = quick_report();
        report.schema_version = 1;
        report.workers = None;
        report.models = Vec::new();
        report.clip_methods = Vec::new();
        strip_axes(&mut report);
        report.validate().unwrap();
        let text = report.to_json().unwrap();
        assert!(!text.contains("\"workers\""), "v1 serialization must omit the field");
        assert!(!text.contains("\"models\""), "v1 serialization must omit the echo");
        let parsed = BenchReport::from_json(&text).unwrap();
        parsed.validate().unwrap();
        // ...but a v1 report *carrying* a scaling curve is malformed.
        let mut bad = quick_report();
        bad.schema_version = 1;
        bad.models = Vec::new();
        bad.clip_methods = Vec::new();
        strip_axes(&mut bad);
        assert!(bad.workers.is_some());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn v2_reports_with_unkeyed_worker_rows_still_validate() {
        // A file emitted by the schema-v2 harness: worker rows without
        // clip_method keys, no config echoes.
        let mut report = quick_report();
        report.schema_version = 2;
        report.models = Vec::new();
        report.clip_methods = Vec::new();
        strip_axes(&mut report);
        let rows = report.workers.as_mut().unwrap();
        // v2 had one series; keep one model's per-example rows.
        rows.retain(|w| w.model == "ref-linear" && w.clip_method == "per-example");
        for w in rows.iter_mut() {
            w.clip_method = String::new();
        }
        report.validate().unwrap();
        let text = report.to_json().unwrap();
        assert!(!text.contains("\"clip_method\""), "v2 rows carry no clip_method");
        BenchReport::from_json(&text).unwrap().validate().unwrap();
        // A v2 row *carrying* a clip_method is malformed...
        let mut bad = BenchReport::from_json(&text).unwrap();
        bad.workers.as_mut().unwrap()[0].clip_method = "ghost".into();
        assert!(bad.validate().is_err());
        // ...as is a v2 report carrying the v3 config echoes.
        let mut bad = BenchReport::from_json(&text).unwrap();
        bad.models = vec!["ref-linear".into()];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn v3_rejects_rows_naming_unknown_models_or_clip_methods() {
        // Regression (schema-v3 gate): rows citing a clip_method or
        // model absent from the run config used to pass --check
        // silently.
        let mut report = quick_report();
        report.workers.as_mut().unwrap()[0].clip_method = "mystery".into();
        let err = report.validate().unwrap_err().to_string();
        assert!(err.contains("clip_method"), "{err}");

        let mut report = quick_report();
        report.workers.as_mut().unwrap()[0].model = "ghost-net".into();
        let err = report.validate().unwrap_err().to_string();
        assert!(err.contains("model"), "{err}");

        // A clip method the sweep ran but the echo dropped.
        let mut report = quick_report();
        report.clip_methods = vec!["per-example".into()];
        assert!(report.validate().is_err());

        // An accum entry citing an unswept model.
        let mut report = quick_report();
        report.entries[0].model = "vit-galaxy".into();
        assert!(report.validate().is_err());

        // The echo itself naming a non-method.
        let mut report = quick_report();
        report.clip_methods.push("masked".into());
        assert!(report.validate().is_err(), "variant names are not clip methods");

        // And an empty echo on a v3 report.
        let mut report = quick_report();
        report.models = Vec::new();
        assert!(report.validate().is_err());
    }

    #[test]
    fn v5_entries_carry_kernel_and_param_dtype_axes() {
        let report = quick_report();
        assert_eq!(report.schema_version, 5);
        // The default sweep resolves "auto" to the detected axis.
        assert_eq!(report.kernels, vec![Kernel::auto().axis().to_string()]);
        assert_eq!(report.param_dtypes, vec!["f32".to_string()]);
        for e in &report.entries {
            assert_eq!(e.kernel, report.kernels[0], "{}/{:?}", e.model, e.variant);
            assert_eq!(e.param_dtype, "f32");
        }
        // The tags survive the JSON roundtrip.
        let text = report.to_json().unwrap();
        let parsed = BenchReport::from_json(&text).unwrap();
        parsed.validate().unwrap();
        assert_eq!(parsed.entries[0].kernel, report.entries[0].kernel);
    }

    #[test]
    fn kernel_axis_sweep_measures_every_requested_combo() {
        let rt = Runtime::reference();
        let mut opts = SweepOptions::new(true);
        opts.repeats = 2;
        opts.with_sections = false;
        opts.worker_counts = Vec::new();
        opts.model = Some("ref-linear".into());
        opts.variant = Some("masked".into());
        opts.batch = Some(16);
        opts.kernels = vec!["scalar".into(), "simd".into()];
        opts.param_dtypes = vec!["f32".into(), "bf16".into()];
        let report = run_sweep(&rt, &opts).unwrap();
        report.validate().unwrap();
        assert_eq!(report.param_dtypes, vec!["f32".to_string(), "bf16".to_string()]);
        // Hosts without SIMD support dedup the kernel axis to scalar
        // alone; SIMD-capable hosts measure both.
        assert!(report.kernels.contains(&"scalar".to_string()));
        for kernel in &report.kernels {
            for dtype in ["f32", "bf16"] {
                let e = report
                    .accum_entry_axis("ref-linear", "masked", 16, kernel, dtype)
                    .unwrap_or_else(|| panic!("missing {kernel}/{dtype} row"));
                assert!(e.median > 0.0);
            }
        }
    }

    #[test]
    fn v5_rejects_axis_tag_and_echo_mismatches() {
        // Pre-v5 files cannot carry the axes.
        let mut report = quick_report();
        report.schema_version = 4;
        assert!(report.validate().is_err());
        // An entry tag naming a kernel the run config never echoed.
        let mut report = quick_report();
        let other = if report.kernels[0] == "simd" { "scalar" } else { "simd" };
        report.entries[0].kernel = other.into();
        assert!(report.validate().is_err());
        // ...or a dtype it never echoed.
        let mut report = quick_report();
        report.entries[0].param_dtype = "bf16".into();
        assert!(report.validate().is_err());
        // Tag and echo must appear together.
        let mut report = quick_report();
        report.entries[0].kernel.clear();
        assert!(report.validate().is_err());
        let mut report = quick_report();
        report.entries[0].param_dtype.clear();
        assert!(report.validate().is_err());
        // The echoes only admit the known axis names.
        let mut report = quick_report();
        report.kernels.push("avx512".into());
        assert!(report.validate().is_err());
        let mut report = quick_report();
        report.param_dtypes.push("fp8".into());
        assert!(report.validate().is_err());
    }

    #[test]
    fn unknown_kernel_and_dtype_axes_are_rejected_before_the_sweep() {
        let rt = Runtime::reference();
        let mut opts = SweepOptions::new(true);
        opts.repeats = 2;
        opts.with_sections = false;
        opts.kernels = vec!["avx512".into()];
        assert!(run_sweep(&rt, &opts).is_err());
        let mut opts = SweepOptions::new(true);
        opts.repeats = 2;
        opts.with_sections = false;
        opts.param_dtypes = vec!["fp8".into()];
        assert!(run_sweep(&rt, &opts).is_err());
    }

    #[test]
    fn worker_curve_schema_violations_are_rejected() {
        let broken = |f: fn(&mut WorkerEntry)| {
            let mut report = quick_report();
            f(&mut report.workers.as_mut().unwrap()[0]);
            report.validate()
        };
        assert!(broken(|w| w.workers = 0).is_err());
        assert!(broken(|w| w.throughput = f64::NAN).is_err());
        assert!(broken(|w| w.throughput = -1.0).is_err());
        assert!(broken(|w| w.unit = "calls_per_sec".into()).is_err());
        assert!(broken(|w| w.steps = 0).is_err());
        // Duplicate (model, clip_method, workers) rows are one
        // measurement pretending to be a curve.
        let mut report = quick_report();
        let dup = report.workers.as_ref().unwrap()[0].clone();
        report.workers.as_mut().unwrap().push(dup);
        assert!(report.validate().is_err());
        // Empty curve must be expressed as an absent field.
        let mut report = quick_report();
        report.workers = Some(Vec::new());
        assert!(report.validate().is_err());
    }

    /// A small serve sweep in a per-call scratch dir (tests run
    /// concurrently; a shared dir would race).
    fn serve_report() -> BenchReport {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let rt = Runtime::reference();
        let root = std::env::temp_dir().join(format!(
            "dpshort_serve_sweep_test_{}_{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&root);
        let mut opts = ServeSweepOptions::new(true, root.clone());
        opts.tenants = 2;
        opts.concurrency = vec![1, 2];
        let report = run_serve_sweep(&rt, &opts).unwrap();
        let _ = std::fs::remove_dir_all(&root);
        report
    }

    #[test]
    fn serve_sweep_emits_v4_rows_keyed_by_concurrency() {
        let report = serve_report();
        report.validate().unwrap();
        assert_eq!(report.schema_version, SCHEMA_VERSION);
        // Serve-only reports legitimately carry no accum/apply entries.
        assert!(report.entries.is_empty());
        assert_eq!(report.serve_tenants, vec!["tenant-00", "tenant-01"]);
        let keys: Vec<(usize, usize)> =
            report.serve.iter().map(|s| (s.tenants, s.max_concurrent)).collect();
        assert_eq!(keys, vec![(2, 1), (2, 2)]);
        for row in &report.serve {
            assert_eq!(row.tenant_names, report.serve_tenants);
            assert!(row.throughput > 0.0 && row.unit == "examples_per_sec");
            assert!(row.p50_latency <= row.p95_latency && row.p95_latency <= row.p99_latency);
            // 2 tenants x 2 steps in 1-step slices: 4 slices per run.
            assert_eq!(row.slices, 4);
        }
        // A residency cap of 1 with 2 interleaved tenants forces
        // checkpoint evictions; a cap of 2 keeps both resident.
        assert!(report.serve[0].evictions > 0, "{:?}", report.serve[0]);
        assert_eq!(report.serve[1].evictions, 0, "{:?}", report.serve[1]);
        let text = report.to_json().unwrap();
        BenchReport::from_json(&text).unwrap().validate().unwrap();
    }

    #[test]
    fn v4_rejects_serve_rows_naming_unknown_tenants() {
        // The acceptance gate: --check must reject v4 rows naming
        // tenants absent from the run config.
        let mut report = serve_report();
        report.serve[0].tenant_names[0] = "stranger".into();
        let err = report.validate().unwrap_err().to_string();
        assert!(err.contains("tenant"), "{err}");

        // Pre-v4 files cannot carry serve rows or the echo.
        let mut report = serve_report();
        report.schema_version = 3;
        assert!(report.validate().is_err());

        // Serve rows without the serve_tenants echo are malformed...
        let mut report = serve_report();
        report.serve_tenants.clear();
        assert!(report.validate().is_err());

        // ...as are duplicate (tenants, max_concurrent) keys...
        let mut report = serve_report();
        let dup = report.serve[0].clone();
        report.serve.push(dup);
        assert!(report.validate().is_err());

        // ...and a disordered latency tail.
        let mut report = serve_report();
        report.serve[0].p95_latency = report.serve[0].p99_latency * 2.0 + 1.0;
        assert!(report.validate().is_err());
    }

    #[test]
    fn unknown_clip_methods_are_rejected_before_the_sweep() {
        let rt = Runtime::reference();
        let mut opts = SweepOptions::new(true);
        opts.repeats = 2;
        opts.with_sections = false;
        opts.clip_methods = vec!["bogus".into()];
        assert!(run_sweep(&rt, &opts).is_err());
    }

    #[test]
    fn check_file_roundtrip_and_rejects_garbage() {
        let report = quick_report();
        let path = std::env::temp_dir().join("dpshort_bench_schema_test.json");
        report.write(&path).unwrap();
        let loaded = BenchReport::check_file(&path).unwrap();
        assert_eq!(loaded.backend, "reference");
        std::fs::write(&path, "{\"schema_version\": 1}").unwrap();
        assert!(BenchReport::check_file(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unmatched_explicit_filters_are_errors() {
        let rt = Runtime::reference();
        let mut opts = SweepOptions::new(true);
        opts.repeats = 2;
        opts.with_sections = false;
        opts.batch = Some(12_345);
        assert!(run_sweep(&rt, &opts).is_err(), "unlowered --batch must not pass silently");
        let mut opts = SweepOptions::new(true);
        opts.repeats = 2;
        opts.with_sections = false;
        opts.variant = Some("mystery".to_string());
        assert!(run_sweep(&rt, &opts).is_err(), "unknown --variant must not pass silently");
    }

    #[test]
    fn validate_catches_schema_violations() {
        let mut report = quick_report();
        report.entries[0].median = f64::NAN;
        assert!(report.validate().is_err());
        let mut report = quick_report();
        report.entries[0].kind = "mystery".into();
        assert!(report.validate().is_err());
        let mut report = quick_report();
        report.schema_version = 99;
        assert!(report.validate().is_err());
        let mut report = quick_report();
        report.entries.clear();
        assert!(report.validate().is_err());
    }
}
