//! Regeneration of every table and figure in the paper's evaluation
//! (the experiment index lives in DESIGN.md §5).
//!
//! Two kinds of numbers appear side by side, always labelled:
//!
//! * **measured** — wall-clock throughput of the real AOT executables on
//!   this testbed (CPU PJRT). Absolute values differ from A100s, but the
//!   paper's claims are about *relative* throughput (private vs
//!   non-private, method vs method), which transfers.
//! * **modeled**  — paper-scale predictions from the analytic substrates
//!   (memory planner, TimeModel, Tf32 roofline, cluster simulator),
//!   calibrated only against the paper's Table 2/3 constants.

use crate::clipping::{ghost_fraction, ClippingMethod, TimeModel};
use crate::cluster::{fit_parallel_fraction, ClusterSim, Interconnect};
use crate::coordinator::config::TrainConfig;
use crate::coordinator::trainer::Trainer;
use crate::memory::{MemModel, A100_BYTES, V100_BYTES};
use crate::metrics::summary_with_ci;
use crate::models::{paper_ladder, Family};
use crate::precision::Tf32Model;
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};

/// Dispatch a report id.
pub fn run(rt: &Runtime, what: &str, quick: bool) -> Result<()> {
    let all = what == "all";
    let mut hit = false;
    if all || what == "table1" {
        print_table1();
        hit = true;
    }
    if all || what == "fig1" || what == "fig2" {
        print_relative_throughput(rt, quick)?;
        hit = true;
    }
    if all || what == "fig3" || what == "table3" {
        print_max_batch_table(A100_BYTES);
        hit = true;
    }
    if all || what == "table2" {
        print_table2(rt)?;
        hit = true;
    }
    if all || what == "fig4" {
        print_fig4(rt, quick)?;
        hit = true;
    }
    if all || what == "fig5" {
        print_fig5(rt, quick)?;
        hit = true;
    }
    if all || what == "fig6" || what == "figA1" {
        print_fig6(rt, quick)?;
        hit = true;
    }
    if all || what == "figA2" {
        print_figa2(rt)?;
        hit = true;
    }
    if all || what == "fig7" || what == "figA4" || what == "figA5" {
        print_scaling_study(rt, default_model(rt)?, &[1, 2, 4, 8, 16, 32, 64, 80])?;
        hit = true;
    }
    if all || what == "figA3" {
        print_figa3(rt, quick)?;
        hit = true;
    }
    if !hit {
        return Err(anyhow!("unknown report id {what:?}"));
    }
    Ok(())
}

fn default_model(rt: &Runtime) -> Result<&str> {
    // One policy for every entry point: Runtime::default_model prefers
    // vit-micro (the artifact ladder's canonical rung), else the
    // backend's first model (the reference backend's linear model).
    rt.default_model()
        .ok_or_else(|| anyhow!("manifest has no models; run `make artifacts`"))
}

fn bench_median(rt: &Runtime, model: &str, variant: &str, batch: usize, repeats: usize) -> Result<f64> {
    let mut cfg = TrainConfig { model: model.into(), variant: variant.into(), ..Default::default() };
    cfg.physical_batch = batch;
    let t = Trainer::new(rt, cfg)?;
    let samples = t.bench_accum(variant, batch, repeats)?;
    Ok(summary_with_ci(&samples, 0).median)
}

/// Largest common lowered batch for a set of variants.
fn common_batch(rt: &Runtime, model: &str, variants: &[&str]) -> Result<usize> {
    let m = rt.manifest().model(model)?;
    let mut common: Option<Vec<usize>> = None;
    for v in variants {
        let b = m.accum_batches(v, "f32");
        common = Some(match common {
            None => b,
            Some(c) => c.into_iter().filter(|x| b.contains(x)).collect(),
        });
    }
    common
        .and_then(|c| c.last().copied())
        .ok_or_else(|| anyhow!("no common batch size for {variants:?} on {model}"))
}

/// Table 1: parameter counts of the paper-scale ladder.
pub fn print_table1() {
    println!("\n== Table 1 — model ladder parameters (paper scale, modeled) ==");
    println!("{:<12} {:>10}", "model", "params(M)");
    for a in paper_ladder() {
        println!("{:<12} {:>10.1}", a.name, a.params_m());
    }
}

/// Figures 1 & 2: relative throughput of DP-SGD variants vs non-private,
/// measured on the executable ladder.
pub fn print_relative_throughput(rt: &Runtime, quick: bool) -> Result<()> {
    let repeats = if quick { 3 } else { 8 };
    println!("\n== Fig 1 / Fig 2 — relative throughput vs non-private (measured) ==");
    println!(
        "{:<12} {:<12} {:>6} {:>12} {:>10}",
        "model", "variant", "B", "ex/s", "rel"
    );
    let names: Vec<String> = rt.manifest().models.keys().cloned().collect();
    for name in names {
        let m = rt.manifest().model(&name)?;
        let variants = m.variants();
        let mut vrefs: Vec<&str> = variants.iter().map(|s| s.as_str()).collect();
        vrefs.retain(|v| *v != "naive"); // naive == masked graph; skip dup
        let b = common_batch(rt, &name, &vrefs)?;
        let base = bench_median(rt, &name, "nonprivate", b, repeats)?;
        for v in &vrefs {
            let thr = if *v == "nonprivate" {
                base
            } else {
                bench_median(rt, &name, v, b, repeats)?
            };
            println!(
                "{:<12} {:<12} {:>6} {:>12.1} {:>10.2}",
                name,
                v,
                b,
                thr,
                thr / base
            );
        }
    }
    println!("(paper: Opacus per-example is x2.6-3.2 slower for ViTs, x4-8 for ResNets;");
    println!(" masked JAX ~x1.2 slower; ghost/BK roughly halve the gap)");
    Ok(())
}

/// Table 3 / Figure 3: analytic max physical batch at paper scale.
pub fn print_max_batch_table(budget_bytes: f64) {
    let m = MemModel::default();
    println!(
        "\n== Table 3 / Fig 3 — max physical batch (modeled, budget {:.0} GB) ==",
        budget_bytes / 1e9
    );
    let methods = [
        ClippingMethod::NonPrivate,
        ClippingMethod::PerExample,
        ClippingMethod::Ghost,
        ClippingMethod::BkGhost,
        ClippingMethod::MaskedJax,
    ];
    print!("{:<12}", "model");
    for meth in methods {
        print!(" {:>12}", meth.variant());
    }
    println!();
    for a in paper_ladder() {
        print!("{:<12}", a.name);
        for meth in methods {
            if !meth.supports(a.family) {
                print!(" {:>12}", "n/a");
            } else {
                print!(" {:>12}", m.max_physical_batch(&a, meth, budget_bytes));
            }
        }
        println!();
    }
    // The paper's Table 3 row (ViT-Base) on both GPUs:
    let vb = paper_ladder().into_iter().find(|a| a.name == "ViT-Base").unwrap();
    println!("ViT-Base @V100 32GB vs paper (216/28/203/189):");
    for (meth, paper) in [
        (ClippingMethod::NonPrivate, 216),
        (ClippingMethod::PerExample, 28),
        (ClippingMethod::Ghost, 203),
        (ClippingMethod::BkGhost, 189),
    ] {
        println!(
            "  {:<24} modeled {:>4}  paper {:>4}",
            meth.label(),
            m.max_physical_batch(&vb, meth, V100_BYTES),
            paper
        );
    }
}

/// Table 2: per-section timing breakdown, non-private vs per-example.
pub fn print_table2(rt: &Runtime) -> Result<()> {
    let model = default_model(rt)?;
    println!("\n== Table 2 — per-section wall-clock (measured, model {model}) ==");
    let mut rows = Vec::new();
    for variant in ["nonprivate", "masked"] {
        let cfg = TrainConfig {
            model: model.into(),
            variant: variant.into(),
            dataset_size: 512,
            sampling_rate: 0.25,
            physical_batch: 16,
            steps: 3,
            eval_examples: 0,
            ..Default::default()
        };
        let rep = Trainer::new(rt, cfg)?.run()?;
        rows.push((variant, rep));
    }
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12}",
        "section", "non-private", "per-example", "ratio", "paper-ratio"
    );
    let np = &rows[0].1.sections;
    let pe = &rows[1].1.sections;
    let paper = [("accum (f+b+c)", (101.53 + 681.48 + 26.76) / (81.14 + 163.85)), ("apply (step)", 99.65 / 38.17)];
    for ((label, paper_ratio), (a, b)) in paper.iter().zip([(np.accum, pe.accum), (np.apply, pe.apply)]) {
        println!(
            "{:<14} {:>11.3}s {:>11.3}s {:>12.2} {:>12.2}",
            label,
            a,
            b,
            b / a.max(1e-12),
            paper_ratio
        );
    }
    println!("(paper Table 2 is per-batch ms on A100; ratios are the transferable part)");
    Ok(())
}

/// Figure 4: throughput per clipping method on two "GPUs" — measured CPU
/// numbers + modeled V100/A100 predictions from the TimeModel.
pub fn print_fig4(rt: &Runtime, quick: bool) -> Result<()> {
    let model = default_model(rt)?;
    let repeats = if quick { 3 } else { 8 };
    println!("\n== Fig 4 — throughput per clipping method (ViT; measured + modeled) ==");
    let variants = ["nonprivate", "masked", "ghost", "bk"];
    let b = common_batch(rt, model, &variants)?;
    println!("{:<12} {:>12} {:>16}", "variant", "measured", "modeled A100 rel");
    let tm = TimeModel::default();
    let vb = paper_ladder().into_iter().find(|a| a.name == "ViT-Base").unwrap();
    for (v, meth) in [
        ("nonprivate", ClippingMethod::NonPrivate),
        ("masked", ClippingMethod::PerExample),
        ("ghost", ClippingMethod::Ghost),
        ("bk", ClippingMethod::BkGhost),
    ] {
        let thr = bench_median(rt, model, v, b, repeats)?;
        println!(
            "{:<12} {:>10.1}/s {:>16.2}",
            v,
            thr,
            1.0 / tm.relative_cost(&vb, meth)
        );
    }
    println!("(paper: BK > ghost > per-example; A100 ~x1.3 V100 across methods)");
    Ok(())
}

/// Figure 5: TF32/FP32 throughput ratio — measured bf16 substitute plus
/// paper-scale roofline model.
pub fn print_fig5(rt: &Runtime, quick: bool) -> Result<()> {
    let repeats = if quick { 3 } else { 8 };
    println!("\n== Fig 5 — lower-precision speedup (bf16 measured; TF32 modeled) ==");
    println!("measured bf16/f32 throughput ratio:");
    let names: Vec<String> = rt.manifest().models.keys().cloned().collect();
    for name in &names {
        let m = rt.manifest().model(name)?;
        for variant in ["nonprivate", "masked"] {
            let b16 = m.accum_batches(variant, "bf16");
            let Some(&b) = b16.last() else { continue };
            if !m.accum_batches(variant, "f32").contains(&b) {
                continue;
            }
            let f32_thr = bench_median(rt, name, variant, b, repeats)?;
            let cfg = TrainConfig {
                model: name.clone(),
                variant: variant.into(),
                bf16: true,
                physical_batch: b,
                ..Default::default()
            };
            let t = Trainer::new(rt, cfg)?;
            let samples = t.bench_accum(variant, b, repeats)?;
            let bf16_thr = summary_with_ci(&samples, 0).median;
            println!(
                "  {:<12} {:<12} B={:<4} ratio {:.3}",
                name,
                variant,
                b,
                bf16_thr / f32_thr
            );
        }
    }
    println!("modeled TF32/FP32 ratio at paper scale (A100 tensor cores):");
    let tf = Tf32Model::default();
    println!("{:<12} {:>12} {:>12}", "model", "non-private", "private");
    for a in &paper_ladder()[..5] {
        println!(
            "{:<12} {:>12.3} {:>12.3}",
            a.name,
            tf.throughput_ratio(a, ClippingMethod::NonPrivate),
            tf.throughput_ratio(a, ClippingMethod::PerExample)
        );
    }
    println!("(paper: non-private grows with size; private peaks at Base then declines)");
    Ok(())
}

/// Figure 6 (+ A.1): throughput vs physical batch size, bootstrap CIs.
pub fn print_fig6(rt: &Runtime, quick: bool) -> Result<()> {
    let model = default_model(rt)?;
    let repeats = if quick { 3 } else { 10 };
    println!("\n== Fig 6 / Fig A.1 — throughput vs physical batch (measured, {model}) ==");
    let m = rt.manifest().model(model)?;
    println!(
        "{:<12} {:>5} {:>12} {:>22} {:>8}",
        "variant", "B", "median ex/s", "95% CI", "% of max"
    );
    for variant in m.variants() {
        if variant == "naive" {
            continue; // identical graph to masked; Fig A.2 covers its compile cost
        }
        let batches = m.accum_batches(&variant, "f32");
        let mut results = Vec::new();
        for &b in &batches {
            let mut cfg = TrainConfig { model: model.into(), variant: variant.clone(), ..Default::default() };
            cfg.physical_batch = b;
            let t = Trainer::new(rt, cfg)?;
            let samples = t.bench_accum(&variant, b, repeats)?;
            results.push((b, summary_with_ci(&samples, 0)));
        }
        let max = results
            .iter()
            .map(|(_, s)| s.median)
            .fold(0.0f64, f64::max)
            .max(1e-12);
        for (b, s) in results {
            println!(
                "{:<12} {:>5} {:>12.1} {:>10.1} -{:>9.1} {:>7.1}%",
                variant,
                b,
                s.median,
                s.ci_low,
                s.ci_high,
                100.0 * s.median / max
            );
        }
    }
    Ok(())
}

/// Figure A.2: compile time vs physical batch size (the naive-JAX
/// recompilation cost, realized as PJRT compilations).
pub fn print_figa2(rt: &Runtime) -> Result<()> {
    let model = default_model(rt)?;
    println!("\n== Fig A.2 — compile time vs batch size (measured PJRT, {model}) ==");
    let m = rt.manifest().model(model)?;
    let mrt = rt.model(model)?;
    let variant = if m.accum_batches("naive", "f32").is_empty() { "masked" } else { "naive" };
    for b in m.accum_batches(variant, "f32") {
        mrt.prepare_accum(variant, b, "f32")?; // compiles on first use
    }
    for r in rt.compile_records() {
        if r.path.contains(&format!("_{variant}_")) {
            println!("  {:<44} {:>8.2}s", r.path, r.seconds);
        }
    }
    println!("(the masked variant compiles exactly one accum shape instead)");
    Ok(())
}

/// Figures 7 / A.4 / A.5: scaling study via the cluster simulator fed
/// with measured single-worker throughputs.
pub fn print_scaling_study(rt: &Runtime, model: &str, gpus: &[usize]) -> Result<()> {
    println!("\n== Fig 7 / A.4 / A.5 — multi-GPU scaling (simulated from measured rates) ==");
    let b = common_batch(rt, model, &["nonprivate", "masked"])?;
    let np_thr = bench_median(rt, model, "nonprivate", b, 5)?;
    let pe_thr = bench_median(rt, model, "masked", b, 5)?;
    println!(
        "single-worker measured: non-private {np_thr:.1} ex/s, private {pe_thr:.1} ex/s (B={b})"
    );
    // Calibration: one free parameter — the gradient volume — is set so
    // the NON-PRIVATE curve reproduces the paper's 53.3% of ideal at 80
    // GPUs (its testbed's comm/compute balance). The PRIVATE curve is
    // then a pure prediction driven by the measured private/non-private
    // compute ratio; the paper's mechanism (slower compute => less
    // exposed communication => better scaling) must emerge on its own.
    let serial = 1.0e-3;
    let make_sim = |thr: f64, grad_bytes: f64| ClusterSim {
        single_worker_throughput: thr,
        local_batch: b,
        grad_bytes,
        overlap: 0.5,
        serial_overhead: serial,
        interconnect: Interconnect::default(),
    };
    let target_np_eff = 0.533;
    let (mut lo, mut hi) = (1e3_f64, 1e13_f64);
    for _ in 0..100 {
        let mid = (lo * hi).sqrt();
        let eff = make_sim(np_thr, mid).curve(&[80])[0].efficiency;
        if eff > target_np_eff {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let grad_bytes = (lo * hi).sqrt();
    println!(
        "calibrated gradient volume: {:.1} MB (non-private pinned to {:.1}% @80)",
        grad_bytes / 1e6,
        100.0 * target_np_eff
    );
    let mut curves = Vec::new();
    for (label, thr) in [("non-private", np_thr), ("private (Opacus-style)", pe_thr)] {
        let sim = make_sim(thr, grad_bytes);
        let curve = sim.curve(gpus);
        println!("{label}:");
        println!("  {:>5} {:>14} {:>14} {:>8}", "gpus", "ex/s", "ideal", "eff");
        for p in &curve {
            println!(
                "  {:>5} {:>14.0} {:>14.0} {:>7.1}%",
                p.gpus,
                p.throughput,
                p.ideal,
                100.0 * p.efficiency
            );
        }
        let pts: Vec<(f64, f64)> = curve
            .iter()
            .filter(|p| p.gpus > 1)
            .map(|p| (p.gpus as f64, p.throughput / (curve[0].throughput)))
            .collect();
        let frac = fit_parallel_fraction(&pts);
        println!("  Amdahl parallel fraction: {:.2}% (paper: private 99.5%, non-private 98.9%)", frac * 100.0);
        curves.push((label, curve));
    }
    let last = curves[0].1.last().unwrap().gpus;
    let e_np = curves[0].1.last().unwrap().efficiency;
    let e_p = curves[1].1.last().unwrap().efficiency;
    println!(
        "at {last} GPUs: private {:.1}% vs non-private {:.1}% of ideal (paper: 69.2% vs 53.3%)",
        100.0 * e_p,
        100.0 * e_np
    );
    Ok(())
}

/// Figure A.3: lower precision combined with distributed training —
/// the bf16-measured single-worker rates drive the cluster simulator.
pub fn print_figa3(rt: &Runtime, quick: bool) -> Result<()> {
    let repeats = if quick { 3 } else { 6 };
    println!("\n== Fig A.3 — lower precision x distributed (measured bf16 + simulator) ==");
    let model = default_model(rt)?;
    let meta = rt.manifest().model(model)?.clone();
    let Some(&b) = meta.accum_batches("masked", "bf16").last() else {
        println!("  (no bf16 artifacts lowered for {model}; skipping)");
        return Ok(());
    };
    if !meta.accum_batches("masked", "f32").contains(&b) {
        println!("  (no matching f32 batch; skipping)");
        return Ok(());
    }
    let mut rates = Vec::new();
    for bf16 in [false, true] {
        let cfg = TrainConfig {
            model: model.into(),
            variant: "masked".into(),
            bf16,
            physical_batch: b,
            ..Default::default()
        };
        let t = Trainer::new(rt, cfg)?;
        let samples = t.bench_accum("masked", b, repeats)?;
        rates.push(summary_with_ci(&samples, 0).median);
    }
    println!(
        "single worker: f32 {:.1} ex/s, bf16 {:.1} ex/s (ratio {:.3})",
        rates[0],
        rates[1],
        rates[1] / rates[0]
    );
    println!("{:>5} {:>14} {:>14}", "gpus", "f32 ex/s", "bf16 ex/s");
    for n in [1usize, 4, 8, 16, 24] {
        let mk = |thr: f64| ClusterSim {
            single_worker_throughput: thr,
            local_batch: b,
            grad_bytes: meta.n_params as f64 * 4.0,
            overlap: 0.5,
            serial_overhead: 1.0e-3,
            interconnect: Interconnect::default(),
        };
        println!(
            "{:>5} {:>14.0} {:>14.0}",
            n,
            mk(rates[0]).throughput(n),
            mk(rates[1]).throughput(n)
        );
    }
    println!("(paper A.3: the TF32 advantage persists under scaling until");
    println!(" communication dominates; bf16 is the CPU-testbed substitute)");
    Ok(())
}

/// Mix-ghost decision summary (Section 5.1 discussion).
pub fn print_mix_ghost_summary() {
    println!("\n== Mix-ghost per-layer decisions (modeled, paper scale) ==");
    for a in paper_ladder() {
        let f = ghost_fraction(&a);
        let note = match a.family {
            Family::ViT => "always ghost (paper: mix never helps ViT)",
            Family::BiTResNet => "split (paper: ~half per-example, half ghost)",
        };
        println!("  {:<12} ghost for {:>5.1}% of layers — {}", a.name, 100.0 * f, note);
    }
}
