//! One admitted tenant of the multi-tenant training service: its
//! training configuration, its declared `(epsilon, delta)` budget, and
//! the analytic memory price the scheduler's eviction policy charges
//! it while resident.

use crate::analysis::BudgetSpec;
use crate::clipping::ClippingMethod;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::trainer::{config_fingerprint, resolve_sigma};
use crate::memory::MemModel;
use crate::models::{Arch, Family};
use crate::runtime::ModelMeta;
use anyhow::Result;

/// An admitted job: everything the scheduler and the ledger need.
///
/// The budget is carried alongside (not only inside) the config: the
/// config's `declared_epsilon` drives the *static* `budget.overspend`
/// admission audit, while `budget` is what the runtime ledger enforces
/// — the defense-in-depth backstop for spend the static price cannot
/// see (e.g. a tenant resumed with epsilon already committed).
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Unique tenant name (also the checkpoint-namespace key).
    pub name: String,
    /// The run this tenant wants to execute.
    pub config: TrainConfig,
    /// The `(epsilon, delta)` budget the ledger holds it to.
    pub budget: BudgetSpec,
}

impl Tenant {
    /// Resolved noise multiplier of this tenant's run.
    pub fn sigma(&self) -> Result<f64> {
        resolve_sigma(&self.config)
    }

    /// The checkpoint fingerprint its sessions write and its resumes
    /// demand — the content-level cross-tenant defense (the namespace
    /// directory is the path-level one).
    pub fn fingerprint(&self) -> Result<String> {
        Ok(config_fingerprint(&self.config, self.sigma()?))
    }
}

/// The [`ClippingMethod`] whose executable variant is `variant` — the
/// bridge from a tenant's config to the memory model's per-method
/// branch. Variants shared by several Table-A1 methods (`mix`) resolve
/// to the first, which prices identically.
pub fn method_for_variant(variant: &str) -> ClippingMethod {
    ClippingMethod::ALL
        .iter()
        .copied()
        .find(|m| m.variant() == variant)
        .unwrap_or(ClippingMethod::MaskedJax)
}

/// Lift an executable model's layer IR into the analytic [`Arch`] the
/// memory model prices: one `LinearDims` per dense layer (sequence
/// length 1 — the CPU ladder has no token axis) and a forward tape of
/// each layer's input + pre-activation output.
pub fn arch_of(name: &str, meta: &ModelMeta) -> Arch {
    let linears = meta.layers.iter().map(|l| l.linear_dims()).collect();
    let act_floats_per_example = meta.layers.iter().map(|l| l.d_in + l.d_out).sum();
    Arch {
        name: name.to_string(),
        family: Family::ViT,
        linears,
        other_params: 0,
        act_floats_per_example,
        fwd_flops_per_example: meta.flops_fwd_per_example,
        tokens: 1,
    }
}

/// Bytes a resident session of this tenant holds at its physical batch
/// size, per [`MemModel::peak_bytes`] — the quantity the scheduler sums
/// against `--memory-budget-bytes`.
pub fn resident_bytes(tenant: &Tenant, meta: &ModelMeta) -> f64 {
    let arch = arch_of(&tenant.config.model, meta);
    let method = method_for_variant(&tenant.config.variant);
    MemModel::default().peak_bytes(&arch, method, tenant.config.physical_batch.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_cli_variant_resolves_to_a_priced_method() {
        for (_, variant) in crate::clipping::CLI_CLIP_METHODS {
            let m = method_for_variant(variant);
            assert_eq!(m.variant(), *variant);
        }
        // Unknown variants price conservatively as masked, not panic.
        assert_eq!(method_for_variant("mystery"), ClippingMethod::MaskedJax);
    }

    #[test]
    fn arch_bridge_preserves_layer_dims() {
        use crate::models::LayerSpec;
        let layers = vec![LayerSpec::dense_relu(12, 5), LayerSpec::dense(5, 3)];
        let meta = ModelMeta {
            family: "test".into(),
            n_params: layers.iter().map(LayerSpec::params).sum(),
            image: 2,
            channels: 3,
            num_classes: 3,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "t.bin".into(),
            executables: Vec::new(),
            layers,
        };
        let arch = arch_of("t", &meta);
        assert_eq!(arch.params(), meta.n_params);
        assert_eq!(arch.linears.len(), 2);
        assert_eq!(arch.act_floats_per_example, 12 + 5 + 5 + 3);
        // Footprint is positive and grows with the batch for every method.
        let mm = MemModel::default();
        for m in ClippingMethod::ALL {
            assert!(mm.peak_bytes(&arch, *m, 2) > mm.peak_bytes(&arch, *m, 1) - 1.0);
        }
    }
}
