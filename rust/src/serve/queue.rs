//! The admission queue: parse a job manifest and admit each job
//! through the static plan auditor *at submission* — a Deny plan is a
//! rejection with named rules, never a mid-run surprise.
//!
//! Manifest format (`dpshort serve --jobs FILE.json`):
//!
//! ```json
//! {
//!   "tenants": [
//!     { "name": "acme",
//!       "model": "mlp-small",
//!       "clip_method": "ghost",
//!       "dataset_size": 256, "seed": 7,
//!       "sampling_rate": 0.25, "physical_batch": 8,
//!       "steps": 4, "noise_multiplier": 1.0,
//!       "budget_epsilon": 8.0, "budget_delta": 2.04e-5 }
//!   ]
//! }
//! ```
//!
//! Every field except `name`, `steps`, and `budget_epsilon` has a
//! default; `sampler`/`accountant` accept the CLI names. The declared
//! budget is wired into the config (`declared_epsilon`), so admission
//! runs the full rule catalog *including* `budget.overspend`: a job
//! whose configured steps would already overspend its own budget is
//! refused before it runs a single step.

use super::tenant::Tenant;
use crate::analysis::BudgetSpec;
use crate::clipping::clip_method_variant;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::sampler::SamplerChoice;
use crate::coordinator::trainer::resolve_sigma;
use crate::privacy::AccountantKind;
use crate::runtime::Runtime;
use anyhow::{anyhow, Context, Result};
use serde::Deserialize;
use std::collections::BTreeSet;
use std::path::Path;

/// One job in the manifest. Serde-deserialized; unknown fields are
/// rejected so a typo'd budget key cannot silently admit an
/// unconstrained job.
#[derive(Debug, Clone, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct JobSpec {
    /// Unique tenant name (checkpoint namespace + ledger account key).
    pub name: String,
    /// Model name; the runtime's default model when omitted.
    #[serde(default)]
    pub model: Option<String>,
    /// CLI clip-method name (`nonprivate|per-example|ghost|bk|mix`) or
    /// an executable accum variant (`masked`, the Algorithm-2 default).
    #[serde(default = "default_clip_method")]
    pub clip_method: String,
    /// Per-tenant dataset size N.
    #[serde(default)]
    pub dataset_size: Option<u32>,
    /// Per-tenant dataset/experiment seed.
    #[serde(default)]
    pub seed: Option<u64>,
    /// Poisson sampling rate q.
    #[serde(default)]
    pub sampling_rate: Option<f64>,
    /// Physical batch size.
    #[serde(default)]
    pub physical_batch: Option<usize>,
    /// Optimizer steps the tenant wants.
    pub steps: u64,
    /// Learning rate.
    #[serde(default)]
    pub lr: Option<f64>,
    /// Noise multiplier sigma; calibrated from the budget when omitted.
    #[serde(default)]
    pub noise_multiplier: Option<f64>,
    /// Declared epsilon budget (the ledger cap).
    pub budget_epsilon: f64,
    /// Delta the budget is quoted at; the trainer default when omitted.
    #[serde(default)]
    pub budget_delta: Option<f64>,
    /// Sampler name (`poisson|shuffle`).
    #[serde(default)]
    pub sampler: Option<String>,
    /// Accountant name (`rdp|pld`).
    #[serde(default)]
    pub accountant: Option<String>,
    /// Data-parallel workers for this tenant's sessions.
    #[serde(default)]
    pub workers: Option<usize>,
}

fn default_clip_method() -> String {
    "masked".into()
}

/// The manifest file: a list of tenants.
#[derive(Debug, Clone, Deserialize)]
#[serde(deny_unknown_fields)]
pub struct JobsFile {
    /// Submitted jobs, in manifest order (also the scheduling order).
    pub tenants: Vec<JobSpec>,
}

/// A job the auditor (or manifest validation) refused at submission.
#[derive(Debug, Clone, serde::Serialize)]
pub struct Rejection {
    /// Tenant name of the refused job.
    pub name: String,
    /// Human-readable refusal.
    pub reason: String,
    /// Deny rules that fired, when the auditor did the refusing.
    pub rules: Vec<String>,
}

impl JobSpec {
    /// Lower this job into the [`TrainConfig`] its sessions run. The
    /// declared budget becomes both the config's `declared_epsilon`
    /// (static admission audit) and the calibration target when no
    /// explicit sigma is given.
    pub fn to_config(&self, rt: &Runtime) -> Result<TrainConfig> {
        let defaults = TrainConfig::default();
        let model = match &self.model {
            Some(m) => m.clone(),
            None => rt
                .default_model()
                .ok_or_else(|| {
                    anyhow!("job {:?}: no model given and the manifest has none", self.name)
                })?
                .to_string(),
        };
        // Accept either the CLI clip-method names or a raw executable
        // variant ("masked" has no CLI alias — it's the config default).
        let variant = clip_method_variant(&self.clip_method)
            .or_else(|| {
                crate::clipping::ClippingMethod::ALL
                    .iter()
                    .map(|m| m.variant())
                    .find(|v| *v == self.clip_method)
            })
            .ok_or_else(|| {
                anyhow!("job {:?}: unknown clip method {:?}", self.name, self.clip_method)
            })?
            .to_string();
        let sampler = match &self.sampler {
            Some(s) => SamplerChoice::parse(s)
                .ok_or_else(|| anyhow!("job {:?}: unknown sampler {s:?}", self.name))?,
            None => defaults.sampler,
        };
        let accountant = match &self.accountant {
            Some(a) => AccountantKind::parse(a)
                .ok_or_else(|| anyhow!("job {:?}: unknown accountant {a:?}", self.name))?,
            None => defaults.accountant,
        };
        if self.steps == 0 {
            return Err(anyhow!("job {:?}: steps must be > 0", self.name));
        }
        if !(self.budget_epsilon.is_finite() && self.budget_epsilon > 0.0) {
            return Err(anyhow!(
                "job {:?}: budget_epsilon must be finite and > 0, got {}",
                self.name,
                self.budget_epsilon
            ));
        }
        Ok(TrainConfig {
            model,
            variant,
            dataset_size: self.dataset_size.unwrap_or(256),
            sampling_rate: self.sampling_rate.unwrap_or(0.25),
            physical_batch: self.physical_batch.unwrap_or(8),
            steps: self.steps,
            lr: self.lr.unwrap_or(defaults.lr),
            noise_multiplier: self.noise_multiplier,
            target_epsilon: self.budget_epsilon,
            delta: self.budget_delta.unwrap_or(defaults.delta),
            seed: self.seed.unwrap_or(0),
            eval_examples: 0,
            workers: self.workers.unwrap_or(1),
            sampler,
            accountant,
            declared_epsilon: Some(self.budget_epsilon),
            ..defaults
        })
    }
}

/// Parse a manifest from JSON text.
pub fn parse_jobs(text: &str) -> Result<JobsFile> {
    serde_json::from_str(text).context("parsing serve job manifest")
}

/// Read and parse a manifest file.
pub fn load_jobs(path: &Path) -> Result<JobsFile> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading job manifest {}", path.display()))?;
    parse_jobs(&text)
}

/// Admit every job through the PR-6 auditor: a clean plan becomes a
/// [`Tenant`], a Deny plan (or an unloadable job) becomes a
/// [`Rejection`] naming its rules. Admission order is manifest order.
pub fn admit(rt: &Runtime, jobs: &JobsFile) -> Result<(Vec<Tenant>, Vec<Rejection>)> {
    let mut seen = BTreeSet::new();
    let mut admitted = Vec::new();
    let mut rejected = Vec::new();
    for job in &jobs.tenants {
        if job.name.is_empty() {
            rejected.push(Rejection {
                name: job.name.clone(),
                reason: "tenant name must be non-empty".into(),
                rules: Vec::new(),
            });
            continue;
        }
        if !seen.insert(job.name.clone()) {
            rejected.push(Rejection {
                name: job.name.clone(),
                reason: format!("duplicate tenant name {:?}", job.name),
                rules: Vec::new(),
            });
            continue;
        }
        let config = match job.to_config(rt) {
            Ok(c) => c,
            Err(e) => {
                rejected.push(Rejection {
                    name: job.name.clone(),
                    reason: e.to_string(),
                    rules: Vec::new(),
                });
                continue;
            }
        };
        let outcome = (|| -> Result<Option<Vec<String>>> {
            let sigma = resolve_sigma(&config)?;
            let meta = rt.model(&config.model)?;
            let report =
                crate::analysis::audit_run(meta.meta(), rt.manifest().seed, &config, sigma)?;
            let denies = report.deny_rules();
            if denies.is_empty() {
                Ok(None)
            } else {
                Ok(Some(denies.iter().map(|r| r.to_string()).collect()))
            }
        })();
        match outcome {
            Ok(None) => {
                let budget = BudgetSpec {
                    epsilon: job.budget_epsilon,
                    delta: job.budget_delta.unwrap_or(config.delta),
                };
                admitted.push(Tenant { name: job.name.clone(), config, budget });
            }
            Ok(Some(rules)) => rejected.push(Rejection {
                name: job.name.clone(),
                reason: format!("plan audit denied admission ({})", rules.join(", ")),
                rules,
            }),
            Err(e) => rejected.push(Rejection {
                name: job.name.clone(),
                reason: e.to_string(),
                rules: Vec::new(),
            }),
        }
    }
    Ok((admitted, rejected))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rule;

    fn manifest(extra: &str) -> String {
        format!(
            r#"{{"tenants": [
                {{"name": "a", "steps": 2, "budget_epsilon": 8.0,
                  "noise_multiplier": 1.0, "dataset_size": 48,
                  "physical_batch": 8, "clip_method": "ghost"}}{extra}
            ]}}"#
        )
    }

    #[test]
    fn a_clean_job_is_admitted_with_its_budget() {
        let rt = Runtime::reference();
        let jobs = parse_jobs(&manifest("")).unwrap();
        let (admitted, rejected) = admit(&rt, &jobs).unwrap();
        assert!(rejected.is_empty(), "{rejected:#?}");
        assert_eq!(admitted.len(), 1);
        let t = &admitted[0];
        assert_eq!(t.name, "a");
        assert_eq!(t.config.variant, "ghost");
        assert_eq!(t.config.declared_epsilon, Some(8.0));
        assert_eq!(t.budget.epsilon, 8.0);
        assert_eq!(t.config.eval_examples, 0);
    }

    #[test]
    fn a_shuffle_job_is_rejected_at_submission_naming_the_rule() {
        let rt = Runtime::reference();
        let jobs = parse_jobs(&manifest(
            r#", {"name": "b", "steps": 2, "budget_epsilon": 8.0,
                 "noise_multiplier": 1.0, "sampler": "shuffle"}"#,
        ))
        .unwrap();
        let (admitted, rejected) = admit(&rt, &jobs).unwrap();
        assert_eq!(admitted.len(), 1);
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].name, "b");
        assert!(rejected[0].rules.iter().any(|r| r == rule::SHORTCUT_EPSILON));
    }

    #[test]
    fn an_overspending_job_is_rejected_by_the_budget_rule() {
        // 64 steps at sigma = 1, q = 0.25 spend far more than eps 0.01.
        let rt = Runtime::reference();
        let jobs = parse_jobs(
            r#"{"tenants": [{"name": "greedy", "steps": 64,
                "budget_epsilon": 0.01, "noise_multiplier": 1.0}]}"#,
        )
        .unwrap();
        let (admitted, rejected) = admit(&rt, &jobs).unwrap();
        assert!(admitted.is_empty());
        assert_eq!(rejected.len(), 1);
        assert!(
            rejected[0].rules.iter().any(|r| r == rule::BUDGET_OVERSPEND),
            "{rejected:#?}"
        );
    }

    #[test]
    fn duplicates_typos_and_bad_values_are_refused() {
        let rt = Runtime::reference();
        let dup = parse_jobs(&manifest(
            r#", {"name": "a", "steps": 2, "budget_epsilon": 8.0, "noise_multiplier": 1.0}"#,
        ))
        .unwrap();
        let (admitted, rejected) = admit(&rt, &dup).unwrap();
        assert_eq!((admitted.len(), rejected.len()), (1, 1));

        // Unknown manifest keys are a parse error, not a silent admit.
        assert!(parse_jobs(
            r#"{"tenants": [{"name": "x", "steps": 2, "budget_epsilon": 8.0,
                "budgett_delta": 1e-5}]}"#
        )
        .is_err());

        let bad = parse_jobs(
            r#"{"tenants": [{"name": "x", "steps": 0, "budget_epsilon": 8.0}]}"#,
        )
        .unwrap();
        let (a, r) = admit(&rt, &bad).unwrap();
        assert!(a.is_empty() && r.len() == 1);
    }
}
