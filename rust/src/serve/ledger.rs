//! The central privacy-budget ledger: one authority for every tenant's
//! committed epsilon.
//!
//! The commit protocol reuses the trainer's epsilon-commit discipline
//! (DESIGN.md §11) at slice granularity: spend is committed **strictly
//! after** a slice completes and its checkpoint is durable, and a
//! commit is *idempotent* — [`BudgetLedger::commit_to`] records "this
//! tenant has completed `step` steps" (monotone max), never "add k
//! steps". Replaying a commit after a crash therefore cannot
//! double-spend: however many times a resumed serve re-reconciles a
//! checkpoint, the committed step count — and with it the priced
//! epsilon — lands in the same place.
//!
//! The hard-stop lives here too: [`BudgetLedger::affordable_steps`]
//! prices the epsilon *after* each candidate step with the tenant's
//! own accountant and returns the largest run length that stays within
//! the declared budget, so the scheduler halts a tenant the step
//! before its budget would be exceeded — the committed epsilon never
//! crosses the declared line.

use super::tenant::Tenant;
use crate::privacy::AccountantKind;
use anyhow::{anyhow, Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::BTreeMap;
use std::path::Path;

/// Version of the serialized ledger snapshot.
pub const LEDGER_SCHEMA_VERSION: u32 = 1;

/// File name of the ledger snapshot under the serve checkpoint root.
pub const LEDGER_FILE: &str = "ledger.json";

/// Relative tolerance for "spend equals budget": pricing is pure
/// floating-point math, so the boundary case (a budget declared as
/// exactly k steps' epsilon) must not round into a refusal.
const BUDGET_REL_TOL: f64 = 1e-9;

/// Terminal/live state of one tenant, as the scheduler reports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Serialize, Deserialize)]
pub enum TenantStatus {
    /// Still has steps to run and budget to spend.
    Active,
    /// Ran every configured step within budget.
    Completed,
    /// Halted by the ledger: the next step would overspend the
    /// declared budget.
    BudgetExhausted,
}

impl std::fmt::Display for TenantStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TenantStatus::Active => write!(f, "Active"),
            TenantStatus::Completed => write!(f, "Completed"),
            TenantStatus::BudgetExhausted => write!(f, "BudgetExhausted"),
        }
    }
}

/// One tenant's account: the mechanism parameters its spend is priced
/// with, the declared budget, and the committed position.
#[derive(Debug, Clone, PartialEq, Serialize, Deserialize)]
pub struct LedgerEntry {
    /// Tenant name (the account key).
    pub tenant: String,
    /// Poisson sampling rate q of the tenant's mechanism.
    pub sampling_rate: f64,
    /// Resolved noise multiplier sigma.
    pub sigma: f64,
    /// Accountant name (`rdp` | `pld`) pricing this account.
    pub accountant: String,
    /// Declared epsilon cap.
    pub budget_epsilon: f64,
    /// The delta the cap is quoted at.
    pub budget_delta: f64,
    /// Completed (checkpoint-durable) steps committed so far.
    pub committed_steps: u64,
    /// Epsilon priced at `committed_steps` — the authoritative spend.
    pub committed_epsilon: f64,
}

impl LedgerEntry {
    fn kind(&self) -> AccountantKind {
        AccountantKind::parse(&self.accountant).unwrap_or(AccountantKind::Rdp)
    }

    /// Epsilon this account would have spent after `steps` total steps.
    pub fn price(&self, steps: u64) -> f64 {
        if self.sigma <= 0.0 {
            // sigma = 0 carries no finite guarantee; a budgeted tenant
            // can afford no step of it.
            return if steps == 0 { 0.0 } else { f64::INFINITY };
        }
        self.kind().epsilon_after(self.sampling_rate, self.sigma, steps, self.budget_delta)
    }

    fn within_budget(&self, epsilon: f64) -> bool {
        epsilon <= self.budget_epsilon * (1.0 + BUDGET_REL_TOL)
    }
}

/// Serializable snapshot of the whole ledger, written atomically after
/// every commit so a crashed serve resumes without double-spending.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct LedgerSnapshot {
    /// [`LEDGER_SCHEMA_VERSION`] at write time.
    pub schema_version: u32,
    /// Every account, sorted by tenant name.
    pub entries: Vec<LedgerEntry>,
}

/// The central ledger owning every tenant's accountant state.
#[derive(Debug, Clone, Default)]
pub struct BudgetLedger {
    entries: BTreeMap<String, LedgerEntry>,
}

impl BudgetLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open an account for an admitted tenant. Re-registering an
    /// existing account (a crash-resumed serve re-admitting the same
    /// manifest) is a no-op as long as the mechanism parameters and
    /// budget agree; a *conflicting* re-registration is refused — a
    /// changed mechanism would reprice already-committed spend.
    pub fn register(&mut self, tenant: &Tenant, sigma: f64) -> Result<()> {
        let fresh = LedgerEntry {
            tenant: tenant.name.clone(),
            sampling_rate: tenant.config.sampling_rate,
            sigma,
            accountant: tenant.config.accountant.as_str().to_string(),
            budget_epsilon: tenant.budget.epsilon,
            budget_delta: tenant.budget.delta,
            committed_steps: 0,
            committed_epsilon: 0.0,
        };
        if let Some(existing) = self.entries.get(&tenant.name) {
            let same = existing.sampling_rate == fresh.sampling_rate
                && existing.sigma == fresh.sigma
                && existing.accountant == fresh.accountant
                && existing.budget_epsilon == fresh.budget_epsilon
                && existing.budget_delta == fresh.budget_delta;
            if !same {
                return Err(anyhow!(
                    "tenant {:?} is already registered with different mechanism/budget \
                     parameters; refusing to reprice committed spend",
                    tenant.name
                ));
            }
            return Ok(());
        }
        self.entries.insert(tenant.name.clone(), fresh);
        Ok(())
    }

    /// The account for `tenant`, when one exists.
    pub fn entry(&self, tenant: &str) -> Option<&LedgerEntry> {
        self.entries.get(tenant)
    }

    /// Committed epsilon of `tenant` (0 for an unknown account).
    pub fn epsilon(&self, tenant: &str) -> f64 {
        self.entries.get(tenant).map_or(0.0, |e| e.committed_epsilon)
    }

    /// Committed steps of `tenant` (0 for an unknown account).
    pub fn committed_steps(&self, tenant: &str) -> u64 {
        self.entries.get(tenant).map_or(0, |e| e.committed_steps)
    }

    /// The largest `k <= want` such that running `k` more steps keeps
    /// the account within its declared budget — 0 means the very next
    /// step would overspend and the tenant must hard-stop *now*.
    pub fn affordable_steps(&self, tenant: &str, want: u64) -> u64 {
        let Some(e) = self.entries.get(tenant) else { return 0 };
        let mut k = want;
        while k > 0 {
            if e.within_budget(e.price(e.committed_steps + k)) {
                return k;
            }
            k -= 1;
        }
        0
    }

    /// Commit "tenant has completed `step` steps" — the post-slice
    /// commit and the crash-reconcile are the same idempotent call:
    /// monotone in `step`, so replays and re-reconciles never add
    /// spend. Returns the committed epsilon.
    pub fn commit_to(&mut self, tenant: &str, step: u64) -> Result<f64> {
        let e = self
            .entries
            .get_mut(tenant)
            .ok_or_else(|| anyhow!("no ledger account for tenant {tenant:?}"))?;
        if step > e.committed_steps {
            e.committed_steps = step;
            e.committed_epsilon = e.price(step);
        }
        Ok(e.committed_epsilon)
    }

    /// Snapshot every account (sorted, schema-stamped).
    pub fn snapshot(&self) -> LedgerSnapshot {
        LedgerSnapshot {
            schema_version: LEDGER_SCHEMA_VERSION,
            entries: self.entries.values().cloned().collect(),
        }
    }

    /// Rebuild a ledger from a snapshot.
    pub fn restore(snapshot: &LedgerSnapshot) -> Result<Self> {
        if snapshot.schema_version != LEDGER_SCHEMA_VERSION {
            return Err(anyhow!(
                "ledger snapshot schema v{} (expected v{LEDGER_SCHEMA_VERSION})",
                snapshot.schema_version
            ));
        }
        let mut entries = BTreeMap::new();
        for e in &snapshot.entries {
            if entries.insert(e.tenant.clone(), e.clone()).is_some() {
                return Err(anyhow!("ledger snapshot lists tenant {:?} twice", e.tenant));
            }
        }
        Ok(Self { entries })
    }

    /// Atomically persist the snapshot as `<dir>/`[`LEDGER_FILE`] via
    /// the same temp-file+rename protocol the checkpoints use.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating serve state dir {}", dir.display()))?;
        let path = dir.join(LEDGER_FILE);
        let tmp = dir.join(format!("{LEDGER_FILE}.tmp"));
        let json = serde_json::to_string_pretty(&self.snapshot())
            .context("serializing ledger snapshot")?;
        std::fs::write(&tmp, json).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        Ok(())
    }

    /// Load the snapshot written by [`BudgetLedger::save`], when one
    /// exists; `Ok(None)` when the serve root has no ledger yet.
    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let path = dir.join(LEDGER_FILE);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(anyhow::Error::new(e)
                    .context(format!("reading ledger snapshot {}", path.display())))
            }
        };
        let snapshot: LedgerSnapshot = serde_json::from_str(&text)
            .with_context(|| format!("parsing ledger snapshot {}", path.display()))?;
        Ok(Some(Self::restore(&snapshot)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::BudgetSpec;
    use crate::coordinator::config::TrainConfig;

    fn tenant(name: &str, steps_budgeted: u64) -> (Tenant, f64) {
        let config = TrainConfig {
            sampling_rate: 0.25,
            noise_multiplier: Some(1.0),
            steps: 8,
            ..TrainConfig::default()
        };
        let sigma = 1.0;
        let budget_epsilon =
            config.accountant.epsilon_after(0.25, sigma, steps_budgeted, config.delta);
        let t = Tenant {
            name: name.into(),
            config,
            budget: BudgetSpec { epsilon: budget_epsilon, delta: 2.04e-5 },
        };
        (t, sigma)
    }

    #[test]
    fn hard_stop_lands_exactly_at_the_budgeted_step() {
        // Budget = exactly 3 steps' epsilon: affordable from 0 is 3,
        // and after committing 3 the next step is unaffordable.
        let (t, sigma) = tenant("a", 3);
        let mut ledger = BudgetLedger::new();
        ledger.register(&t, sigma).unwrap();
        assert_eq!(ledger.affordable_steps("a", 10), 3);
        ledger.commit_to("a", 3).unwrap();
        assert_eq!(ledger.affordable_steps("a", 10), 0);
        assert!(ledger.epsilon("a") <= t.budget.epsilon * (1.0 + 1e-9));
    }

    #[test]
    fn commit_is_idempotent_and_monotone() {
        let (t, sigma) = tenant("a", 5);
        let mut ledger = BudgetLedger::new();
        ledger.register(&t, sigma).unwrap();
        let e2 = ledger.commit_to("a", 2).unwrap();
        // Replaying an old or equal commit never adds spend.
        assert_eq!(ledger.commit_to("a", 2).unwrap(), e2);
        assert_eq!(ledger.commit_to("a", 1).unwrap(), e2);
        assert_eq!(ledger.committed_steps("a"), 2);
        let e4 = ledger.commit_to("a", 4).unwrap();
        assert!(e4 > e2);
    }

    #[test]
    fn snapshot_roundtrips_and_conflicting_reregistration_is_refused() {
        let (t, sigma) = tenant("a", 4);
        let mut ledger = BudgetLedger::new();
        ledger.register(&t, sigma).unwrap();
        ledger.commit_to("a", 2).unwrap();

        let restored = BudgetLedger::restore(&ledger.snapshot()).unwrap();
        assert_eq!(restored.committed_steps("a"), 2);
        assert_eq!(restored.epsilon("a"), ledger.epsilon("a"));

        // Same parameters: no-op; committed spend survives.
        let mut again = restored.clone();
        again.register(&t, sigma).unwrap();
        assert_eq!(again.committed_steps("a"), 2);

        // Changed budget: refused.
        let mut conflicting = t.clone();
        conflicting.budget.epsilon *= 2.0;
        assert!(again.register(&conflicting, sigma).is_err());
    }

    #[test]
    fn sigma_zero_affords_nothing() {
        let (mut t, _) = tenant("a", 4);
        t.config.noise_multiplier = Some(0.0);
        let mut ledger = BudgetLedger::new();
        ledger.register(&t, 0.0).unwrap();
        assert_eq!(ledger.affordable_steps("a", 4), 0);
    }
}
