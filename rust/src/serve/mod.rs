//! Multi-tenant DP training service (`dpshort serve`).
//!
//! Many independent differentially-private training jobs share one
//! backend: a **job manifest** (JSON) declares each tenant's model,
//! clipping method, sampler/accountant, and `(epsilon, delta)` budget;
//! admission runs every job through the static plan auditor and
//! refuses Deny verdicts at submission ([`queue`]); a cooperative
//! scheduler time-slices the admitted sessions ([`scheduler`]); and a
//! **central privacy-budget ledger** owns every tenant's accountant
//! state, committing epsilon strictly after each durable slice and
//! hard-stopping a tenant the step before its budget would be exceeded
//! ([`ledger`]).
//!
//! Tenants are isolated at three layers:
//!
//! 1. **Privacy** — the ledger is the single budget authority; a
//!    tenant's epsilon is priced from its own `(q, sigma, accountant)`
//!    and can never draw on another tenant's budget.
//! 2. **State** — checkpoints live in per-tenant namespaces
//!    (`fault::tenant_dir`) and carry the config fingerprint, so one
//!    tenant's checkpoint can neither overwrite nor resume as
//!    another's.
//! 3. **Memory** — residency is bounded by `--max-concurrent` and an
//!    analytic `--memory-budget-bytes` priced by `MemModel::peak_bytes`
//!    ([`tenant::resident_bytes`]); under pressure the coldest session
//!    is evicted to its checkpoint and later resumed bitwise-exactly.
//!
//! Because scheduling is cooperative and each session's trajectory is
//! a pure function of its own config, every tenant's final parameters,
//! losses, and epsilon are bitwise-identical to a standalone
//! `Trainer::run` of the same config — at any concurrency level and
//! under any eviction schedule. The integration suite
//! (`rust/tests/serve_multi_tenant.rs`) pins exactly that.

pub mod ledger;
pub mod queue;
pub mod scheduler;
pub mod tenant;

pub use ledger::{BudgetLedger, LedgerEntry, LedgerSnapshot, TenantStatus, LEDGER_FILE};
pub use queue::{admit, load_jobs, parse_jobs, JobSpec, JobsFile, Rejection};
pub use scheduler::{run_serve, ServeOptions, ServeReport, SliceRecord, TenantOutcome};
pub use tenant::{arch_of, method_for_variant, resident_bytes, Tenant};
