//! The cooperative serve scheduler: time-slice admitted tenants'
//! sessions in `--steps-per-slice` chunks, bound residency by
//! `--max-concurrent` and `--memory-budget-bytes`, and commit epsilon
//! to the central ledger strictly after each durable slice.
//!
//! **Determinism.** The scheduler is deliberately cooperative (one
//! slice at a time, manifest order): each tenant's trajectory is a
//! pure function of its own config, so residency limits, eviction, and
//! `--max-concurrent` move *wall-clock and memory only* — never bits.
//! Data-parallelism stays where it already is bitwise-proven: inside
//! each session's own worker pool (DESIGN.md §8). `max_concurrent`
//! bounds how many sessions stay *resident* between slices; a
//! non-resident tenant's state lives in its checkpoint namespace and
//! is resumed (bitwise-exactly, per DESIGN.md §11) when its turn
//! comes back.
//!
//! **Crash consistency.** After every slice, in order: (1) the tenant
//! checkpoint is written atomically into its namespace, (2) the ledger
//! commits the checkpointed step (idempotent max), (3) the ledger
//! snapshot is written atomically. A crash between any two leaves a
//! resumable state: `run_serve` reconciles the ledger against each
//! tenant's newest valid checkpoint at startup, and because commits
//! are monotone-by-step, reconciliation never double-spends.

use super::ledger::{BudgetLedger, TenantStatus};
use super::queue::Rejection;
use super::tenant::{resident_bytes, Tenant};
use crate::coordinator::trainer::{TrainReport, TrainSession};
use crate::fault::{latest_valid, tenant_dir, write_checkpoint};
use crate::metrics::Quantiles;
use crate::runtime::Runtime;
use anyhow::{anyhow, Context, Result};
use serde::Serialize;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

/// Scheduler knobs (the `dpshort serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Max resident sessions between slices (>= 1).
    pub max_concurrent: usize,
    /// Total resident-session memory budget per `MemModel::peak_bytes`;
    /// 0 disables the memory-pressure eviction policy.
    pub memory_budget_bytes: f64,
    /// Steps each scheduled slice runs (>= 1).
    pub steps_per_slice: u64,
    /// Root directory for per-tenant checkpoint namespaces + the
    /// ledger snapshot.
    pub ckpt_root: PathBuf,
    /// Stop (as if crashed) after this many completed slices — the
    /// deterministic kill switch the crash-resume tests and the CI
    /// smoke use. `None` runs to completion.
    pub max_slices: Option<u64>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_concurrent: 2,
            memory_budget_bytes: 0.0,
            steps_per_slice: 2,
            ckpt_root: PathBuf::from("serve-ckpts"),
            max_slices: None,
        }
    }
}

/// One completed slice, for the synthetic-load bench.
#[derive(Debug, Clone, Serialize)]
pub struct SliceRecord {
    /// Tenant the slice ran.
    pub tenant: String,
    /// Steps the slice completed.
    pub steps: u64,
    /// Real (unpadded) examples the slice processed.
    pub examples: usize,
    /// Wall-clock seconds of the slice.
    pub secs: f64,
}

/// Final state of one tenant after a serve run.
#[derive(Debug, Serialize)]
pub struct TenantOutcome {
    /// Tenant name.
    pub name: String,
    /// Where the tenant ended up (`Active` iff the run was
    /// interrupted by `max_slices` before it finished).
    pub status: TenantStatus,
    /// Steps completed and committed.
    pub steps_done: u64,
    /// Ledger-committed epsilon.
    pub epsilon_committed: f64,
    /// The declared cap the ledger enforced.
    pub budget_epsilon: f64,
    /// Times this tenant's session was evicted while incomplete.
    pub evictions: usize,
    /// Full training report, for tenants that completed.
    pub report: Option<TrainReport>,
}

/// Everything one `run_serve` produced.
#[derive(Debug, Serialize)]
pub struct ServeReport {
    /// Per-tenant outcomes, in manifest order.
    pub outcomes: Vec<TenantOutcome>,
    /// Jobs refused at admission (populated by the CLI layer).
    pub rejections: Vec<Rejection>,
    /// Every completed slice, in schedule order.
    pub slices: Vec<SliceRecord>,
    /// Aggregate examples/second over all slices.
    pub aggregate_examples_per_sec: f64,
    /// Nearest-rank p50/p95/p99 over per-slice wall-clock seconds.
    pub slice_latency: Option<Quantiles>,
    /// Total evictions across tenants.
    pub evictions: usize,
    /// True when `max_slices` stopped the run before every tenant
    /// reached a terminal state (the simulated crash).
    pub interrupted: bool,
}

impl ServeReport {
    pub fn to_json(&self) -> Result<String> {
        serde_json::to_string_pretty(self).context("serializing serve report")
    }
}

/// Per-tenant scheduler bookkeeping.
struct Slot<'rt> {
    tenant: Tenant,
    fingerprint: String,
    bytes: f64,
    status: TenantStatus,
    evictions: usize,
    /// Live session, when resident.
    session: Option<TrainSession<'rt>>,
    /// Slice counter at last scheduling (eviction coldness key).
    last_scheduled: u64,
    report: Option<TrainReport>,
}

impl Slot<'_> {
    fn terminal(&self) -> bool {
        self.status != TenantStatus::Active
    }
}

/// Run the service over `tenants` (already admitted) with `ledger` as
/// the budget authority. The ledger may carry restored state from a
/// previous (crashed) serve: accounts are registered idempotently and
/// reconciled against each tenant's newest valid checkpoint before any
/// step runs.
pub fn run_serve(
    rt: &Runtime,
    tenants: &[Tenant],
    ledger: &mut BudgetLedger,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    if tenants.is_empty() {
        return Err(anyhow!("no admitted tenants to serve"));
    }
    let max_concurrent = opts.max_concurrent.max(1);
    let steps_per_slice = opts.steps_per_slice.max(1);

    // Open slots: resolve sigma/fingerprint/memory price once, open
    // ledger accounts, and reconcile committed steps with whatever a
    // previous serve left in each tenant's checkpoint namespace.
    let mut slots: Vec<Slot> = Vec::with_capacity(tenants.len());
    for t in tenants {
        let sigma = t.sigma()?;
        let fingerprint = t.fingerprint()?;
        let meta = rt.model(&t.config.model)?;
        ledger.register(t, sigma)?;
        let dir = tenant_dir(&opts.ckpt_root, &t.name);
        let scan = latest_valid(&dir, &fingerprint)?;
        let mut done_steps = 0;
        if let Some((_, ckpt)) = &scan.found {
            // Crash-reconcile: the checkpoint is durable, so its steps
            // are committed spend even if the crash hit before the
            // ledger snapshot landed. Idempotent — never adds spend a
            // snapshot already recorded.
            ledger.commit_to(&t.name, ckpt.step)?;
            done_steps = ckpt.step;
        }
        let status = if done_steps >= t.config.steps {
            TenantStatus::Completed
        } else {
            TenantStatus::Active
        };
        slots.push(Slot {
            fingerprint,
            bytes: resident_bytes(t, meta.meta()),
            tenant: t.clone(),
            status,
            evictions: 0,
            session: None,
            last_scheduled: 0,
            report: None,
        });
    }
    ledger.save(&opts.ckpt_root)?;

    let mut slices: Vec<SliceRecord> = Vec::new();
    let mut slice_counter: u64 = 0;
    let mut total_evictions = 0usize;
    let mut interrupted = false;

    'serve: while slots.iter().any(|s| !s.terminal()) {
        let mut progressed = false;
        for i in 0..slots.len() {
            if slots[i].terminal() {
                continue;
            }
            if let Some(max) = opts.max_slices {
                if slice_counter >= max {
                    interrupted = true;
                    break 'serve;
                }
            }

            // Budget gate BEFORE any residency work: a tenant whose
            // next step is unaffordable hard-stops here, one step
            // short of overspending.
            let remaining = slots[i]
                .tenant
                .config
                .steps
                .saturating_sub(ledger.committed_steps(&slots[i].tenant.name));
            let want = steps_per_slice.min(remaining);
            let afford = ledger.affordable_steps(&slots[i].tenant.name, want);
            if afford == 0 {
                park(&mut slots[i], ledger, opts)?;
                slots[i].status = TenantStatus::BudgetExhausted;
                progressed = true;
                continue;
            }

            // Make the tenant resident, evicting coldest sessions when
            // over the concurrency or memory limits.
            if slots[i].session.is_none() {
                make_room(&mut slots, i, max_concurrent, opts, ledger, &mut total_evictions)?;
                slots[i].session = Some(open_session(rt, &slots[i], opts)?);
            }
            slots[i].last_scheduled = slice_counter + 1;

            // Run the slice.
            let started = Instant::now();
            let mut examples = 0usize;
            let mut ran = 0u64;
            {
                let session = slots[i].session.as_mut().expect("resident session");
                for _ in 0..afford {
                    if session.done() {
                        break;
                    }
                    let log = session.step()?;
                    examples += log.logical_batch;
                    ran += 1;
                }
            }
            let secs = started.elapsed().as_secs_f64();

            // Durable-then-commit: checkpoint, ledger commit, snapshot.
            let (step_now, finished) = {
                let session = slots[i].session.as_ref().expect("resident session");
                let ckpt = session.checkpoint()?;
                let dir = tenant_dir(&opts.ckpt_root, &slots[i].tenant.name);
                write_checkpoint(&dir, &ckpt, None).with_context(|| {
                    format!("checkpointing tenant {:?} after slice", slots[i].tenant.name)
                })?;
                (ckpt.step, session.done())
            };
            ledger.commit_to(&slots[i].tenant.name, step_now)?;
            ledger.save(&opts.ckpt_root)?;

            slice_counter += 1;
            progressed = true;
            slices.push(SliceRecord {
                tenant: slots[i].tenant.name.clone(),
                steps: ran,
                examples,
                secs,
            });

            if finished {
                let session = slots[i].session.take().expect("resident session");
                slots[i].report = Some(session.finish()?);
                slots[i].status = TenantStatus::Completed;
            }
        }
        if !progressed {
            // Every non-terminal tenant failed to advance — impossible
            // by construction (afford == 0 is terminal), but never
            // spin silently.
            return Err(anyhow!("serve scheduler made no progress over a full round"));
        }
    }

    // Interrupted (simulated crash): drop live sessions on the floor —
    // every completed slice is already checkpointed and committed, so
    // a `--resume` loses nothing.

    let meter_examples: f64 = slices.iter().map(|s| s.examples as f64).sum();
    let meter_secs: f64 = slices.iter().map(|s| s.secs).sum();
    let latencies: Vec<f64> = slices.iter().map(|s| s.secs).collect();

    let outcomes = slots
        .into_iter()
        .map(|s| TenantOutcome {
            name: s.tenant.name.clone(),
            status: s.status,
            steps_done: ledger.committed_steps(&s.tenant.name),
            epsilon_committed: ledger.epsilon(&s.tenant.name),
            budget_epsilon: s.tenant.budget.epsilon,
            evictions: s.evictions,
            report: s.report,
        })
        .collect();

    let throughput = if meter_secs > 0.0 { meter_examples / meter_secs } else { 0.0 };
    Ok(ServeReport {
        outcomes,
        rejections: Vec::new(),
        aggregate_examples_per_sec: throughput,
        slice_latency: Quantiles::of(&latencies),
        slices,
        evictions: total_evictions,
        interrupted,
    })
}

/// Open (or bitwise-resume) a session for `slot` from its checkpoint
/// namespace.
fn open_session<'rt>(
    rt: &'rt Runtime,
    slot: &Slot<'rt>,
    opts: &ServeOptions,
) -> Result<TrainSession<'rt>> {
    let dir = tenant_dir(&opts.ckpt_root, &slot.tenant.name);
    let scan = latest_valid(&dir, &slot.fingerprint)?;
    match scan.found {
        Some((_, ckpt)) => TrainSession::resume(rt, slot.tenant.config.clone(), ckpt),
        None => TrainSession::new(rt, slot.tenant.config.clone()),
    }
}

/// Checkpoint-and-drop `slot`'s session (if resident), committing its
/// durable position first. Used for evictions and the budget
/// hard-stop.
fn park(slot: &mut Slot, ledger: &mut BudgetLedger, opts: &ServeOptions) -> Result<()> {
    if let Some(session) = slot.session.take() {
        let ckpt = session.checkpoint()?;
        let dir = tenant_dir(&opts.ckpt_root, &slot.tenant.name);
        write_checkpoint(&dir, &ckpt, None)
            .with_context(|| format!("checkpointing tenant {:?} for eviction", slot.tenant.name))?;
        ledger.commit_to(&slot.tenant.name, ckpt.step)?;
        ledger.save(&opts.ckpt_root)?;
    }
    Ok(())
}

/// Evict coldest resident sessions (other than `keep`) until both the
/// concurrency and the memory budget admit `keep`'s session.
fn make_room(
    slots: &mut [Slot],
    keep: usize,
    max_concurrent: usize,
    opts: &ServeOptions,
    ledger: &mut BudgetLedger,
    total_evictions: &mut usize,
) -> Result<()> {
    loop {
        let resident: Vec<usize> =
            (0..slots.len()).filter(|&j| j != keep && slots[j].session.is_some()).collect();
        let over_concurrency = resident.len() + 1 > max_concurrent;
        let over_memory = opts.memory_budget_bytes > 0.0 && {
            let held: f64 = resident.iter().map(|&j| slots[j].bytes).sum();
            held + slots[keep].bytes > opts.memory_budget_bytes
        };
        if (!over_concurrency && !over_memory) || resident.is_empty() {
            return Ok(());
        }
        // Coldest = least recently scheduled; ties break on manifest
        // order for determinism.
        let coldest = *resident
            .iter()
            .min_by_key(|&&j| (slots[j].last_scheduled, j))
            .expect("non-empty resident set");
        park(&mut slots[coldest], ledger, opts)?;
        if !slots[coldest].terminal() {
            slots[coldest].evictions += 1;
            *total_evictions += 1;
        }
    }
}

/// Summarize a [`ServeReport`] per (tenant-count, concurrency) for the
/// bench: `(slices, evictions, aggregate throughput, latency)`.
pub fn summarize(report: &ServeReport) -> (u64, usize, f64, Option<Quantiles>) {
    (
        report.slices.len() as u64,
        report.evictions,
        report.aggregate_examples_per_sec,
        report.slice_latency,
    )
}

/// Per-tenant map of committed epsilon, for assertions and the CLI
/// summary table.
pub fn committed_epsilons(report: &ServeReport) -> BTreeMap<String, f64> {
    report.outcomes.iter().map(|o| (o.name.clone(), o.epsilon_committed)).collect()
}
