//! # dp-shortcuts — DP-SGD without shortcuts
//!
//! A Rust + JAX + Pallas reproduction of *"Towards Efficient and Scalable
//! Implementation of Differentially Private Deep Learning"* (Rodriguez
//! Beltran et al., 2024): DP-SGD with **exact Poisson subsampling** (no
//! fixed-batch shortcut), virtual batching, optimized clipping methods
//! (per-example / ghost / Book Keeping), the paper's masked fixed-shape
//! JAX variant (Algorithm 2), an RDP privacy accountant, an analytic
//! memory planner, and a multi-GPU cluster simulator for the scaling
//! study.
//!
//! Architecture (see DESIGN.md): Python/JAX/Pallas exist only at build
//! time (`make artifacts`); this crate owns the entire training loop and
//! executes models through a pluggable [`runtime::Backend`] — the
//! pure-Rust reference executor by default, or the AOT-lowered HLO via
//! the PJRT C API behind the `pjrt` feature.
//!
//! ```text
//! L3 (this crate)   sampler -> batcher -> session.accum ->
//!                   session.apply -> accountant.step()
//! L2 (jax, AOT)     model fwd/bwd variants, flat-param ABI
//! L1 (pallas, AOT)  clip-mask-accumulate / ghost-norm / noisy-step
//! ```

pub mod benchreport;
pub mod clipping;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod memory;
pub mod metrics;
pub mod models;
pub mod precision;
pub mod privacy;
pub mod report;
pub mod runtime;
pub mod util;

pub use coordinator::batcher::{BatchMemoryManager, BatchingMode, PhysicalBatch};
pub use coordinator::config::TrainConfig;
pub use coordinator::sampler::{PoissonSampler, Sampler, ShuffleSampler};
pub use coordinator::trainer::{
    SectionTimes, TrainCheckpoint, TrainReport, TrainSession, Trainer,
};
pub use privacy::{DpParams, RdpAccountant};
pub use runtime::{
    AccumArgs, ApplyArgs, Backend, ExecSession, ReferenceBackend, Runtime, Tensor,
};
