//! # dp-shortcuts — DP-SGD without shortcuts
//!
//! A Rust + JAX + Pallas reproduction of *"Towards Efficient and Scalable
//! Implementation of Differentially Private Deep Learning"* (Rodriguez
//! Beltran et al., 2024): DP-SGD with **exact Poisson subsampling** (no
//! fixed-batch shortcut), virtual batching, optimized clipping methods
//! (per-example / ghost / Book Keeping), the paper's masked fixed-shape
//! JAX variant (Algorithm 2), an RDP privacy accountant, an analytic
//! memory planner, and the multi-GPU scaling study both **simulated**
//! ([`cluster::simulator`]) and **executed** ([`cluster::parallel`]: a
//! data-parallel multi-session trainer whose trajectory is
//! bitwise-identical for every worker count).
//!
//! Architecture (see DESIGN.md; quickstart in README.md): Python/JAX/
//! Pallas exist only at build time (`make artifacts`); this crate owns
//! the entire training loop and executes models through a pluggable
//! [`runtime::Backend`] — the pure-Rust reference executor by default,
//! or the AOT-lowered HLO via the PJRT C API behind the `pjrt` feature.
//! Models are described in a **layered IR** ([`models::LayerSpec`] →
//! [`runtime::LayerPlan`], DESIGN.md §9): the reference backend
//! executes real multi-layer networks (`--model mlp-small`) with
//! per-example gradients across all layers, global-norm clipping, and
//! executable ghost / per-example / mix clipping branches
//! (`--clip-method`) that are bitwise-identical in trajectory.
//!
//! ```text
//! L3 (this crate)   sampler -> group planner -> [session.accum x N workers]
//!                   -> tree-reduce -> session.apply -> accountant.step()
//! L2 (jax, AOT)     model fwd/bwd variants, flat-param ABI
//! L1 (pallas, AOT)  clip-mask-accumulate / ghost-norm / noisy-step
//! ```
//!
//! ## Worked example
//!
//! Train the offline reference model for two DP-SGD steps, once with
//! two data-parallel workers and once single-session — the paper's
//! scaling setup in miniature. The determinism contract (DESIGN.md §8)
//! makes the two trajectories bit-for-bit identical; only wall-clock
//! differs:
//!
//! ```
//! use dp_shortcuts::runtime::REFERENCE_MODEL;
//! use dp_shortcuts::{Runtime, TrainConfig, Trainer};
//!
//! # fn main() -> anyhow::Result<()> {
//! let rt = Runtime::reference(); // pure-Rust backend, no artifacts
//! let cfg = TrainConfig {
//!     model: REFERENCE_MODEL.into(),   // "ref-linear"
//!     dataset_size: 64,
//!     sampling_rate: 0.25,             // E[L] = 16, Poisson-sampled
//!     physical_batch: 8,               // Algorithm-2 masked shapes
//!     steps: 2,
//!     noise_multiplier: Some(1.0),
//!     eval_examples: 0,
//!     workers: 2,                      // data-parallel sessions
//!     ..TrainConfig::default()
//! };
//! let parallel = Trainer::new(&rt, cfg.clone())?.run()?;
//! assert_eq!(parallel.steps.len(), 2);
//! assert!(parallel.epsilon_spent > 0.0); // RDP accounting ran
//!
//! // Same run, one worker: bitwise-identical parameters.
//! let solo_cfg = TrainConfig { workers: 1, ..cfg };
//! let solo = Trainer::new(&Runtime::reference(), solo_cfg)?.run()?;
//! assert_eq!(solo.final_params, parallel.final_params);
//! # Ok(())
//! # }
//! ```
//!
//! Longer-running entry points: `dpshort train --workers N` (the CLI
//! over [`TrainSession`]), `dpshort bench --workers 1,2,4` (measured
//! scaling curve, DESIGN.md §6), and `examples/scaling_study.rs`
//! (measured curve overlaid on the cluster simulator's prediction).

pub mod analysis;
pub mod benchreport;
pub mod clipping;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod memory;
pub mod metrics;
pub mod models;
pub mod precision;
pub mod privacy;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;

pub use analysis::{audit_run, AuditReport, Diagnostic, Severity};
pub use cluster::parallel::{RecoveryEvent, WorkerFailure};
pub use coordinator::batcher::{BatchMemoryManager, BatchingMode, PhysicalBatch};
pub use coordinator::config::{RetryPolicy, TrainConfig};
pub use fault::{faulty_runtime, CheckpointError, FaultPlan, InjectedFault};
pub use coordinator::sampler::{
    AnySampler, PoissonSampler, Sampler, SamplerChoice, ShuffleSampler,
};
pub use coordinator::trainer::{
    SectionTimes, TrainCheckpoint, TrainReport, TrainSession, Trainer,
};
pub use privacy::{AccountantKind, DpParams, RdpAccountant};
pub use runtime::{
    AccumArgs, ApplyArgs, Backend, ExecSession, ReferenceBackend, Runtime, Tensor,
};
pub use serve::{
    run_serve, BudgetLedger, JobsFile, ServeOptions, ServeReport, Tenant, TenantStatus,
};
