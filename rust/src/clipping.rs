//! Clipping-method registry + analytic cost models (paper Section 2.2).
//!
//! Two roles:
//!
//! 1. Map each method the paper benchmarks (Table A1) to the executable
//!    variant the AOT pipeline lowered for it, and to its memory-model
//!    branch.
//! 2. Implement the **mix-ghost decision rule** (Bu et al. 2022): per
//!    layer, apply ghost clipping iff the ghost-norm cost `2 T^2` beats
//!    the per-example outer-product cost `d_in * d_out`. This is what
//!    makes MixGhost pick ghost for *every* ViT layer (so it never helps
//!    there — paper Section 5.1) but split ResNets roughly half/half
//!    (per-example early where feature maps are large, ghost deep where
//!    channels dominate).
//!
//! The time model expresses each method as multiples of the non-private
//! forward cost F (bwd ~ 2F), with per-example/ghost overhead terms whose
//! constants come straight from the paper's Table 2 profile; it powers
//! the paper-scale throughput *predictions* that complement our measured
//! CPU numbers.

use crate::models::{Arch, Family, LinearDims};

/// Every clipping mode benchmarked in the paper (Table A1), plus the two
/// JAX implementations of Section 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClippingMethod {
    /// Non-private SGD baseline (PyTorch / JAX non-private).
    NonPrivate,
    /// Opacus-style per-example gradients.
    PerExample,
    /// Ghost clipping (PrivateVision; Li et al. 2022).
    Ghost,
    /// Mixed ghost clipping (PrivateVision; Bu et al. 2022).
    MixGhost,
    /// Book-Keeping ghost (FastDP; Bu et al. 2023).
    BkGhost,
    /// BK + mixed decision rule (FastDP).
    BkMixGhost,
    /// BK + mixed + second-pass opt decision (FastDP).
    BkMixOpt,
    /// JAX naive per-example clipping (recompiles per batch size).
    NaiveJax,
    /// JAX masked DP-SGD — Algorithm 2 (the paper's contribution).
    MaskedJax,
}

impl ClippingMethod {
    pub const ALL: &'static [ClippingMethod] = &[
        ClippingMethod::NonPrivate,
        ClippingMethod::PerExample,
        ClippingMethod::Ghost,
        ClippingMethod::MixGhost,
        ClippingMethod::BkGhost,
        ClippingMethod::BkMixGhost,
        ClippingMethod::BkMixOpt,
        ClippingMethod::NaiveJax,
        ClippingMethod::MaskedJax,
    ];

    /// Name of the AOT variant implementing this method (the paper's
    /// Table A1 "which library implements what", mapped onto the
    /// lowered graphs — see `runtime::reference::ACCUM_VARIANTS`).
    /// `perex` is the materializing per-example graph, `mix` the
    /// per-layer decision-rule graph; both are executed for real by the
    /// reference backend (`runtime::layers::executed_choices`).
    pub fn variant(&self) -> &'static str {
        match self {
            ClippingMethod::NonPrivate => "nonprivate",
            ClippingMethod::PerExample => "perex", // materializing per-example grads
            ClippingMethod::Ghost => "ghost",
            ClippingMethod::MixGhost
            | ClippingMethod::BkMixGhost
            | ClippingMethod::BkMixOpt => "mix", // per-layer decision rule, executed
            ClippingMethod::BkGhost => "bk",
            ClippingMethod::NaiveJax => "naive",
            ClippingMethod::MaskedJax => "masked",
        }
    }

    /// Whether this method is DP (adds noise, needs accounting).
    pub fn is_private(&self) -> bool {
        !matches!(self, ClippingMethod::NonPrivate)
    }

    /// Paper Table A1: ghost-style methods do not support BiT-ResNets
    /// (weight-standardized convs).
    pub fn supports(&self, family: Family) -> bool {
        match self {
            ClippingMethod::Ghost
            | ClippingMethod::MixGhost
            | ClippingMethod::BkGhost
            | ClippingMethod::BkMixGhost
            | ClippingMethod::BkMixOpt => family == Family::ViT,
            _ => true,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ClippingMethod::NonPrivate => "non-private",
            ClippingMethod::PerExample => "per-example (Opacus)",
            ClippingMethod::Ghost => "ghost (PrivateVision)",
            ClippingMethod::MixGhost => "mix ghost (PrivateVision)",
            ClippingMethod::BkGhost => "BK ghost (FastDP)",
            ClippingMethod::BkMixGhost => "BK mix ghost (FastDP)",
            ClippingMethod::BkMixOpt => "BK mix opt (FastDP)",
            ClippingMethod::NaiveJax => "JAX naive DP-SGD",
            ClippingMethod::MaskedJax => "JAX masked DP-SGD (Alg. 2)",
        }
    }
}

/// The `--clip-method` names the CLI accepts, each paired with the
/// executable accum variant that implements it. This is the *executed*
/// subset of the Table-A1 registry: every name here maps onto a graph
/// the reference backend actually runs (and whose per-layer branch
/// `runtime::layers::executed_choices` resolves).
pub const CLI_CLIP_METHODS: &[(&str, &str)] = &[
    ("nonprivate", "nonprivate"),
    ("per-example", "perex"),
    ("ghost", "ghost"),
    ("bk", "bk"),
    ("mix", "mix"),
];

/// Resolve a CLI `--clip-method` name to its executable accum variant
/// (`None` for unknown names — the caller owns the error message).
pub fn clip_method_variant(name: &str) -> Option<&'static str> {
    CLI_CLIP_METHODS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, v)| *v)
}

/// True iff `name` is a CLI clip-method name ([`CLI_CLIP_METHODS`]) —
/// the schema-v3 bench validator's notion of "known method".
pub fn is_clip_method(name: &str) -> bool {
    clip_method_variant(name).is_some()
}

/// Which norm method the mix-ghost rule picks for one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerChoice {
    Ghost,
    PerExample,
}

/// Bu et al. (2022) decision rule: ghost-norm costs O(2 T^2) extra space
/// / work per layer-example; materializing the per-example grad costs
/// O(d_in * d_out). Pick ghost iff 2 T^2 <= d_in * d_out.
pub fn mix_ghost_choice(l: &LinearDims) -> LayerChoice {
    if 2 * l.t * l.t <= l.d_in * l.d_out {
        LayerChoice::Ghost
    } else {
        LayerChoice::PerExample
    }
}

/// Fraction of layers for which mix-ghost picks ghost.
pub fn ghost_fraction(arch: &Arch) -> f64 {
    let total = arch.linears.len();
    let ghost = arch
        .linears
        .iter()
        .filter(|l| mix_ghost_choice(l) == LayerChoice::Ghost)
        .count();
    ghost as f64 / total as f64
}

/// Analytic per-step time model, in units of the non-private forward
/// cost of one example. Constants derive from the paper's Table 2
/// profile (A100, same physical batch): fwd 101/81 = 1.25x, bwd
/// 681/164 = 4.2x for per-example hooks, clip+acc and optimizer-step
/// overheads as fractions of fwd.
#[derive(Debug, Clone, Copy)]
pub struct TimeModel {
    /// backward/forward cost ratio of plain training.
    pub bwd_over_fwd: f64,
    /// forward slowdown under DP hooks (Table 2: 1.25).
    pub dp_fwd_mult: f64,
    /// backward slowdown under per-example hooks (Table 2: 4.2).
    pub perexample_bwd_mult: f64,
    /// clip+accumulate cost as fraction of fwd (Table 2: 26.76/81).
    pub clip_acc_frac: f64,
    /// DP optimizer-step extra as fraction of fwd ((99.65-38.17)/81).
    pub dp_step_frac: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        Self {
            bwd_over_fwd: 2.0,
            dp_fwd_mult: 101.53 / 81.14,
            perexample_bwd_mult: 681.48 / 163.85,
            clip_acc_frac: 26.76 / 81.14,
            dp_step_frac: (99.65 - 38.17) / 81.14,
        }
    }
}

impl TimeModel {
    /// Relative per-example step cost of `method` on `arch`
    /// (non-private == 1.0). Figure 2's private/non-private relative
    /// throughput is the reciprocal of this.
    pub fn relative_cost(&self, arch: &Arch, method: ClippingMethod) -> f64 {
        let base = 1.0 + self.bwd_over_fwd; // fwd + bwd
        self.step_cost(arch, method) / base
    }

    /// Un-normalized per-example step cost, in units of one non-private
    /// forward. Kept separate from [`Self::relative_cost`] so the
    /// mix-ghost arm can combine *raw* ghost / per-example costs —
    /// recursing through the normalized value would divide by `base`
    /// twice (and on ViTs mix must degenerate to *exactly* ghost,
    /// bitwise — cross-checked in `rust/tests/layered_models.rs`).
    fn step_cost(&self, arch: &Arch, method: ClippingMethod) -> f64 {
        let t = arch.tokens.max(1) as f64;
        // ghost-norm extra flops relative to the whole forward
        let ghost_extra: f64 = arch
            .linears
            .iter()
            .map(|l| 2.0 * t * t * (l.d_in + l.d_out) as f64)
            .sum::<f64>()
            / arch.fwd_flops_per_example.max(1.0);
        match method {
            ClippingMethod::NonPrivate => 1.0 + self.bwd_over_fwd,
            ClippingMethod::PerExample => {
                self.dp_fwd_mult + self.bwd_over_fwd * self.perexample_bwd_mult
                    + self.clip_acc_frac
                    + self.dp_step_frac
            }
            ClippingMethod::Ghost => {
                // two backward passes + ghost norms, no per-example grads
                self.dp_fwd_mult
                    + 2.0 * self.bwd_over_fwd
                    + ghost_extra
                    + self.dp_step_frac
            }
            ClippingMethod::MixGhost => {
                // per-layer best of ghost vs per-example; for ViT it
                // degenerates to exactly ghost (paper Section 5.1).
                let g = self.step_cost(arch, ClippingMethod::Ghost);
                if arch.family == Family::ViT {
                    g
                } else {
                    let frac = ghost_fraction(arch);
                    let pe = self.step_cost(arch, ClippingMethod::PerExample);
                    frac * g + (1.0 - frac) * pe
                }
            }
            ClippingMethod::BkGhost | ClippingMethod::BkMixGhost | ClippingMethod::BkMixOpt => {
                // one backward + einsum rebuild (~ the weight-grad share
                // of a backward, ~ bwd/2) + ghost norms
                self.dp_fwd_mult
                    + self.bwd_over_fwd
                    + 0.5 * self.bwd_over_fwd
                    + ghost_extra
                    + self.dp_step_frac
            }
            ClippingMethod::NaiveJax | ClippingMethod::MaskedJax => {
                // vmapped per-example grads compile into batched kernels:
                // fwd + bwd + fused clip/accumulate.
                1.0 + self.bwd_over_fwd + self.clip_acc_frac + self.dp_step_frac
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{bit_resnet, vit};

    #[test]
    fn vit_mix_ghost_always_picks_ghost() {
        // Paper: "despite continually evaluating which method to apply,
        // it always uses ghost clipping" for ViT.
        let a = vit("base", 12, 768, 4);
        assert_eq!(ghost_fraction(&a), 1.0);
    }

    #[test]
    fn resnet_mix_ghost_splits_layers() {
        // Paper: "for ResNets, each clipping method will be applied for
        // half of the layers" — per-example early (large feature maps),
        // ghost deep (large channel counts).
        let a = bit_resnet("r50", &[3, 4, 6, 3], 1);
        let f = ghost_fraction(&a);
        assert!(f > 0.2 && f < 0.8, "ghost fraction {f}");
        // First conv: per-example; a deep bottleneck: ghost.
        assert_eq!(mix_ghost_choice(&a.linears[0]), LayerChoice::PerExample);
        assert_eq!(
            mix_ghost_choice(a.linears.last().unwrap()),
            LayerChoice::Ghost
        );
    }

    #[test]
    fn executed_ladder_layers_get_the_expected_mix_choice() {
        // The decision rule over the *executed* layer kinds' ghost
        // views (`LayerSpec::linear_dims`), on the shipped non-dense
        // rungs: cnn-small's convs have big spatial T and small
        // channels, so both go per-example while the dense head goes
        // ghost (the first executed split decision); attn-tiny's
        // attention and layernorm are both firmly ghost.
        use crate::models::cpu_ladder;
        let ladder = cpu_ladder();
        let cnn = ladder.iter().find(|m| m.name == "cnn-small").unwrap();
        let choices: Vec<LayerChoice> =
            cnn.layers.iter().map(|l| mix_ghost_choice(&l.linear_dims())).collect();
        assert_eq!(
            choices,
            vec![LayerChoice::PerExample, LayerChoice::PerExample, LayerChoice::Ghost]
        );
        let attn = ladder.iter().find(|m| m.name == "attn-tiny").unwrap();
        for l in &attn.layers {
            assert_eq!(mix_ghost_choice(&l.linear_dims()), LayerChoice::Ghost, "{:?}", l.kind);
        }
        // The conv ghost view is the im2col unfolding: T = spatial
        // positions, d_in = c_in*kh*kw patch width, d_out = c_out.
        let dims = cnn.layers[0].linear_dims();
        assert_eq!((dims.t, dims.d_in, dims.d_out), (64, 27, 4));
    }

    #[test]
    fn cost_ordering_matches_figure4() {
        // Fig 4 (ViT-Base): BK > Ghost > per-example in throughput, i.e.
        // the reverse in cost; everything private costs more than 1.
        let a = vit("base", 12, 768, 4);
        let tm = TimeModel::default();
        let pe = tm.relative_cost(&a, ClippingMethod::PerExample);
        let gh = tm.relative_cost(&a, ClippingMethod::Ghost);
        let bk = tm.relative_cost(&a, ClippingMethod::BkGhost);
        assert!(pe > gh && gh > bk && bk > 1.0, "{pe} {gh} {bk}");
        // Paper Fig 2: Opacus 2.6-3.2x for ViTs.
        assert!(pe > 2.0 && pe < 4.5, "per-example rel cost {pe}");
    }

    #[test]
    fn masked_jax_is_cheapest_private() {
        let a = vit("base", 12, 768, 4);
        let tm = TimeModel::default();
        let masked = tm.relative_cost(&a, ClippingMethod::MaskedJax);
        for m in [
            ClippingMethod::PerExample,
            ClippingMethod::Ghost,
            ClippingMethod::BkGhost,
        ] {
            assert!(masked < tm.relative_cost(&a, m));
        }
        // Paper headline: ~1.2x of non-private.
        assert!(masked > 1.0 && masked < 1.6, "masked rel cost {masked}");
    }

    #[test]
    fn ghost_unsupported_for_resnets() {
        assert!(!ClippingMethod::Ghost.supports(Family::BiTResNet));
        assert!(ClippingMethod::PerExample.supports(Family::BiTResNet));
        assert!(ClippingMethod::BkMixOpt.supports(Family::ViT));
    }

    #[test]
    fn cli_clip_methods_map_to_lowered_variants() {
        assert_eq!(clip_method_variant("per-example"), Some("perex"));
        assert_eq!(clip_method_variant("ghost"), Some("ghost"));
        assert_eq!(clip_method_variant("mix"), Some("mix"));
        assert_eq!(clip_method_variant("nonprivate"), Some("nonprivate"));
        assert_eq!(clip_method_variant("bk"), Some("bk"));
        assert_eq!(clip_method_variant("opacus"), None);
        assert!(is_clip_method("ghost") && !is_clip_method("masked"));
        // Every CLI name's variant agrees with the Table-A1 registry's
        // mapping for the corresponding method.
        assert_eq!(clip_method_variant("per-example"), Some(ClippingMethod::PerExample.variant()));
        assert_eq!(clip_method_variant("ghost"), Some(ClippingMethod::Ghost.variant()));
        assert_eq!(clip_method_variant("mix"), Some(ClippingMethod::MixGhost.variant()));
    }
}
