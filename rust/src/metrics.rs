//! Throughput metering and bootstrap confidence intervals.
//!
//! The paper's headline metric is **throughput** — processed training
//! examples per second — and its Figure 6 reports medians with 95%
//! bootstrap confidence intervals (JAX runs are notably more variable
//! than PyTorch's, which the error bars make visible). Both utilities
//! live here, seeded for reproducibility.

use crate::util::rng::ChaChaRng;
use std::time::Duration;

/// Accumulates (examples, seconds) observations for one configuration.
#[derive(Debug, Clone, Default)]
pub struct ThroughputMeter {
    /// Per-observation throughput samples (examples/second).
    samples: Vec<f64>,
    total_examples: f64,
    total_seconds: f64,
}

impl ThroughputMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one timed segment that processed `examples` examples.
    pub fn record(&mut self, examples: usize, elapsed: Duration) {
        let secs = elapsed.as_secs_f64();
        if secs > 0.0 {
            self.samples.push(examples as f64 / secs);
        }
        self.total_examples += examples as f64;
        self.total_seconds += secs;
    }

    pub fn record_secs(&mut self, examples: usize, secs: f64) {
        self.record(examples, Duration::from_secs_f64(secs));
    }

    /// Aggregate throughput = total examples / total time.
    pub fn aggregate(&self) -> f64 {
        if self.total_seconds == 0.0 {
            0.0
        } else {
            self.total_examples / self.total_seconds
        }
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Median + bootstrap 95% CI of the per-observation throughput
    /// (the Figure 6 estimator).
    pub fn median_ci(&self, seed: u64) -> Summary {
        summary_with_ci(&self.samples, seed)
    }

    /// Nearest-rank latency quantiles over the recorded samples.
    pub fn quantiles(&self) -> Option<Quantiles> {
        Quantiles::of(&self.samples)
    }
}

/// Deterministic nearest-rank p50/p95/p99 quantiles.
///
/// Nearest-rank (the `ceil(p·n)`-th order statistic, 1-indexed) always
/// returns an *observed* sample, so two implementations can never
/// disagree about interpolation — which matters because serve bench
/// rows are validated bit-for-bit by `bench --check`.
#[derive(Debug, Clone, Copy, PartialEq, serde::Serialize, serde::Deserialize)]
pub struct Quantiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub n: usize,
}

impl Quantiles {
    /// Compute nearest-rank quantiles; `None` on an empty sample set.
    pub fn of(samples: &[f64]) -> Option<Self> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Self {
            p50: nearest_rank(&sorted, 0.50),
            p95: nearest_rank(&sorted, 0.95),
            p99: nearest_rank(&sorted, 0.99),
            n: sorted.len(),
        })
    }
}

/// The nearest-rank quantile of an ascending-sorted non-empty slice:
/// the smallest value with at least `p`-fraction of the samples ≤ it.
fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    debug_assert!(n > 0);
    let rank = (p * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Median and bootstrap 95% confidence interval.
#[derive(Debug, Clone, Copy, PartialEq, serde::Serialize, serde::Deserialize)]
pub struct Summary {
    pub median: f64,
    pub ci_low: f64,
    pub ci_high: f64,
    pub n: usize,
}

fn median_of(sorted: &[f64]) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return f64::NAN;
    }
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

/// Seeded bootstrap (1000 resamples) of the median with a percentile
/// 95% interval — the paper's Figure 6 estimator.
pub fn summary_with_ci(samples: &[f64], seed: u64) -> Summary {
    let n = samples.len();
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = median_of(&sorted);
    if n < 2 {
        return Summary { median: med, ci_low: med, ci_high: med, n };
    }
    let mut rng = ChaChaRng::from_seed_stream(seed, 0, b"bootstrp");
    const RESAMPLES: usize = 1000;
    let mut medians = Vec::with_capacity(RESAMPLES);
    let mut buf = vec![0.0; n];
    for _ in 0..RESAMPLES {
        for slot in buf.iter_mut() {
            *slot = samples[rng.gen_range(n)];
        }
        buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        medians.push(median_of(&buf));
    }
    medians.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = medians[(0.025 * RESAMPLES as f64) as usize];
    let hi = medians[((0.975 * RESAMPLES as f64) as usize).min(RESAMPLES - 1)];
    Summary { median: med, ci_low: lo, ci_high: hi, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_throughput() {
        let mut m = ThroughputMeter::new();
        m.record_secs(100, 1.0);
        m.record_secs(300, 1.0);
        assert!((m.aggregate() - 200.0).abs() < 1e-9);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn ci_covers_true_median_and_is_deterministic() {
        let samples: Vec<f64> = (0..200).map(|i| 100.0 + (i % 17) as f64).collect();
        let s1 = summary_with_ci(&samples, 42);
        let s2 = summary_with_ci(&samples, 42);
        assert_eq!(s1, s2, "seeded bootstrap must be deterministic");
        assert!(s1.ci_low <= s1.median && s1.median <= s1.ci_high);
        // True median of the pattern is 108; CI tight for 200 samples.
        assert!((s1.median - 108.0).abs() <= 1.0);
        assert!(s1.ci_high - s1.ci_low < 4.0);
    }

    #[test]
    fn tiny_sample_degenerates_gracefully() {
        let s = summary_with_ci(&[5.0], 1);
        assert_eq!(s.median, 5.0);
        assert_eq!((s.ci_low, s.ci_high), (5.0, 5.0));
        assert!(summary_with_ci(&[], 1).median.is_nan());
    }

    #[test]
    fn nearest_rank_semantics_pinned() {
        // 1..=100: ceil(p*100) picks exactly the 50th/95th/99th order
        // statistic, i.e. the values 50, 95, 99.
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let q = Quantiles::of(&samples).unwrap();
        assert_eq!((q.p50, q.p95, q.p99, q.n), (50.0, 95.0, 99.0, 100));
    }

    #[test]
    fn quantiles_always_return_observed_samples() {
        // Nearest-rank never interpolates: every quantile is a member
        // of the input, even for awkward n.
        for n in [1usize, 2, 3, 7, 19, 101] {
            let samples: Vec<f64> = (0..n).map(|i| 3.0 + (i as f64) * 0.25).collect();
            let q = Quantiles::of(&samples).unwrap();
            for v in [q.p50, q.p95, q.p99] {
                assert!(samples.contains(&v), "n={n}: {v} not an observed sample");
            }
        }
        // Single sample: every quantile is that sample.
        let q = Quantiles::of(&[7.5]).unwrap();
        assert_eq!((q.p50, q.p95, q.p99), (7.5, 7.5, 7.5));
    }

    #[test]
    fn quantiles_are_order_invariant_and_monotone() {
        let fwd: Vec<f64> = (0..250).map(|i| ((i * 37) % 250) as f64).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let qf = Quantiles::of(&fwd).unwrap();
        let qr = Quantiles::of(&rev).unwrap();
        assert_eq!(qf, qr, "quantiles must not depend on arrival order");
        assert!(qf.p50 <= qf.p95 && qf.p95 <= qf.p99);
        assert!(Quantiles::of(&[]).is_none());
    }

    #[test]
    fn meter_quantiles_match_free_function() {
        let mut m = ThroughputMeter::new();
        for i in 1..=20 {
            m.record_secs(100, 1.0 / i as f64);
        }
        let q = m.quantiles().unwrap();
        assert_eq!(Some(q), Quantiles::of(m.samples()));
        assert_eq!(q.n, 20);
    }

    #[test]
    fn wider_spread_wider_ci() {
        let tight: Vec<f64> = (0..100).map(|i| 100.0 + (i % 3) as f64).collect();
        let wide: Vec<f64> = (0..100).map(|i| 100.0 + (i % 37) as f64 * 3.0).collect();
        let st = summary_with_ci(&tight, 7);
        let sw = summary_with_ci(&wide, 7);
        assert!(sw.ci_high - sw.ci_low > st.ci_high - st.ci_low);
    }
}
