//! Differential-privacy accounting for DP-SGD.
//!
//! The theoretical object the paper is about: DP-SGD's guarantee is the
//! composition of `T` **Poisson-subsampled Gaussian mechanisms** with
//! rate `q = L/N` and noise multiplier `sigma`. This module implements
//! the standard Rényi-DP accountant for that mechanism (Abadi et al.
//! 2016; Mironov, Talwar & Zhang 2019) together with the RDP -> (eps,
//! delta) conversion of Balle et al. (2020) — the same pipeline Opacus
//! and TensorFlow-Privacy use — plus noise calibration (binary-searching
//! sigma for a target epsilon, e.g. the paper's Table A2 settings:
//! eps = 8, delta = 2.04e-5, q = 0.5, T = 4).
//!
//! The accountant is *exactly* why Poisson subsampling matters: the
//! amplification-by-subsampling step of the analysis assumes each example
//! is included independently with probability `q`. A shuffled fixed-size
//! batch does not satisfy that assumption (Lebeda et al. 2024), which is
//! what the paper calls implementations "ignoring this requirement".

pub mod calibrate;
pub mod pld;
pub mod rdp;

pub use calibrate::calibrate_sigma;
pub use pld::{pld_epsilon, Pld};
pub use rdp::RdpAccountant;

/// Which accountant reports epsilon for a run (`dpshort train
/// --accountant rdp|pld`). Both analyse the *Poisson*-subsampled
/// Gaussian mechanism, so the sampler↔accountant audit rule
/// (`accountant.shortcut-epsilon`) rejects either of them over a
/// shuffle sampler. Deliberately excluded from the checkpoint
/// fingerprint: the accountant changes the *reported* epsilon, never
/// the trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccountantKind {
    /// Rényi-DP composition + the Balle et al. conversion (the
    /// Opacus / TensorFlow-Privacy default pipeline).
    Rdp,
    /// Privacy-loss-distribution (Fourier) accounting — tighter bounds
    /// for the same mechanism, priced once at `finish()`.
    Pld,
}

impl AccountantKind {
    /// Parse a CLI name (`rdp` | `pld`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rdp" => Some(Self::Rdp),
            "pld" => Some(Self::Pld),
            _ => None,
        }
    }

    /// The CLI / report name.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Rdp => "rdp",
            Self::Pld => "pld",
        }
    }

    /// Price the epsilon this accountant reports after `steps`
    /// compositions of the Poisson-subsampled Gaussian mechanism at
    /// `(q, sigma)`, quoted at `delta`. Zero for sigma <= 0 guard-free
    /// callers is NOT provided: sigma <= 0 means no finite guarantee,
    /// reported here as infinity. One shared pricing function so the
    /// `budget.overspend` audit rule and the serve ledger can never
    /// disagree about what a step costs.
    pub fn epsilon_after(self, q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
        if steps == 0 {
            return 0.0;
        }
        if sigma <= 0.0 {
            return f64::INFINITY;
        }
        match self {
            Self::Rdp => RdpAccountant::default().epsilon(q, sigma, steps, delta),
            Self::Pld => pld_epsilon(q, sigma, steps.min(u64::from(u32::MAX)) as u32, delta),
        }
    }
}

/// The (mechanism-level) parameters of one DP-SGD run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DpParams {
    /// Poisson sampling rate q = expected logical batch / dataset size.
    pub sampling_rate: f64,
    /// Noise multiplier sigma (noise stddev = sigma * clip_norm).
    pub noise_multiplier: f64,
    /// Number of optimizer steps (= logical batches) taken.
    pub steps: u64,
    /// Target delta for reporting epsilon.
    pub delta: f64,
}

impl DpParams {
    /// Privacy spent: epsilon at this delta after `steps` compositions.
    pub fn epsilon(&self) -> f64 {
        RdpAccountant::default()
            .epsilon(self.sampling_rate, self.noise_multiplier, self.steps, self.delta)
    }
}
