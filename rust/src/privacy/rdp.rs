//! Rényi-DP accountant for the Poisson-subsampled Gaussian mechanism.
//!
//! For integer order `alpha >= 2`, the RDP of one step of the sampled
//! Gaussian mechanism with rate `q` and noise multiplier `sigma` is
//! (Mironov, Talwar & Zhang 2019, Sec. 3.3):
//!
//! ```text
//! eps_alpha = 1/(alpha-1) * ln( sum_{k=0}^{alpha}
//!               C(alpha,k) (1-q)^(alpha-k) q^k exp(k(k-1)/(2 sigma^2)) )
//! ```
//!
//! RDP composes additively over steps; the final conversion to
//! `(epsilon, delta)`-DP uses the improved bound of Balle et al. (2020)
//! as implemented by Opacus / TF-Privacy:
//!
//! ```text
//! eps(delta) = min_alpha  T*eps_alpha + ln((alpha-1)/alpha)
//!                         - (ln delta + ln alpha) / (alpha - 1)
//! ```
//!
//! Everything is computed in log-space with incremental log-binomials so
//! the q = 0.5, sigma < 1 corner the paper's hyperparameters sit in is
//! numerically exact.

/// RDP accountant over a fixed grid of integer Rényi orders.
#[derive(Debug, Clone)]
pub struct RdpAccountant {
    orders: Vec<u32>,
}

impl Default for RdpAccountant {
    /// Default order grid: dense low orders (where subsampled mechanisms
    /// optimize) plus a geometric tail for the large-sigma regime.
    fn default() -> Self {
        let mut orders: Vec<u32> = (2..=64).collect();
        orders.extend([72, 80, 96, 128, 160, 192, 256, 384, 512, 1024]);
        Self { orders }
    }
}

/// Numerically stable log(sum(exp(xs))).
fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

impl RdpAccountant {
    pub fn new(orders: Vec<u32>) -> Self {
        assert!(orders.iter().all(|&a| a >= 2), "orders must be >= 2");
        Self { orders }
    }

    pub fn orders(&self) -> &[u32] {
        &self.orders
    }

    /// Per-step RDP at integer order `alpha` for rate `q`, noise `sigma`.
    pub fn rdp_single(q: f64, sigma: f64, alpha: u32) -> f64 {
        assert!(alpha >= 2);
        assert!(sigma > 0.0, "sigma must be positive");
        assert!((0.0..=1.0).contains(&q));
        if q == 0.0 {
            return 0.0; // nothing is ever sampled
        }
        if (q - 1.0).abs() < f64::EPSILON {
            // No subsampling: plain Gaussian mechanism, RDP = alpha/(2 sigma^2).
            return alpha as f64 / (2.0 * sigma * sigma);
        }
        let a = alpha as f64;
        let log_q = q.ln();
        let log_1mq = (1.0 - q).ln();
        let inv2s2 = 1.0 / (2.0 * sigma * sigma);
        // terms[k] = ln C(alpha,k) + (alpha-k) ln(1-q) + k ln q + k(k-1)/(2s^2)
        let mut terms = Vec::with_capacity(alpha as usize + 1);
        let mut log_binom = 0.0_f64; // ln C(alpha, 0)
        for k in 0..=alpha {
            let kf = k as f64;
            terms.push(log_binom + (a - kf) * log_1mq + kf * log_q + kf * (kf - 1.0) * inv2s2);
            // ln C(alpha, k+1) = ln C(alpha,k) + ln(alpha-k) - ln(k+1)
            if k < alpha {
                log_binom += (a - kf).ln() - (kf + 1.0).ln();
            }
        }
        let log_moment = log_sum_exp(&terms);
        (log_moment / (a - 1.0)).max(0.0)
    }

    /// RDP curve (one value per order) after `steps` compositions.
    pub fn rdp_curve(&self, q: f64, sigma: f64, steps: u64) -> Vec<f64> {
        self.orders
            .iter()
            .map(|&a| steps as f64 * Self::rdp_single(q, sigma, a))
            .collect()
    }

    /// Convert a composed RDP curve to epsilon at `delta` (Balle et al.
    /// 2020 / Opacus formula), minimizing over orders.
    ///
    /// The minimum runs over **all** orders and is clamped at zero
    /// afterwards (the Opacus convention): a negative candidate means
    /// the mechanism is (0, delta)-DP at that order, not that the order
    /// is invalid. Filtering negatives out and returning `+inf` when
    /// every candidate was negative silently destroyed the tiny-T /
    /// large-sigma corner, reporting an infinite budget for mechanisms
    /// that are in fact essentially free.
    pub fn eps_from_rdp(&self, rdp: &[f64], delta: f64) -> f64 {
        assert!(delta > 0.0 && delta < 1.0);
        let mut best = f64::INFINITY;
        for (&alpha, &r) in self.orders.iter().zip(rdp) {
            let a = alpha as f64;
            let eps = r + ((a - 1.0) / a).ln() - (delta.ln() + a.ln()) / (a - 1.0);
            if eps < best {
                best = eps;
            }
        }
        best.max(0.0)
    }

    /// End-to-end: epsilon spent by `steps` Poisson-subsampled Gaussian
    /// steps with rate `q` and noise multiplier `sigma`, at `delta`.
    pub fn epsilon(&self, q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
        let rdp = self.rdp_curve(q, sigma, steps);
        self.eps_from_rdp(&rdp, delta)
    }

    /// The order achieving the minimum in [`Self::epsilon`] — useful for
    /// diagnosing whether the order grid is wide enough.
    pub fn optimal_order(&self, q: f64, sigma: f64, steps: u64, delta: f64) -> u32 {
        let rdp = self.rdp_curve(q, sigma, steps);
        let mut best = (f64::INFINITY, self.orders[0]);
        for (&alpha, &r) in self.orders.iter().zip(&rdp) {
            let a = alpha as f64;
            let eps = r + ((a - 1.0) / a).ln() - (delta.ln() + a.ln()) / (a - 1.0);
            if eps < best.0 {
                best = (eps, alpha);
            }
        }
        best.1
    }
}

/// Streaming accountant: tracks RDP totals as the trainer takes steps,
/// possibly with varying (q, sigma) per step (e.g. schedule ablations).
#[derive(Debug, Clone)]
pub struct StreamingAccountant {
    inner: RdpAccountant,
    totals: Vec<f64>,
    steps: u64,
}

impl StreamingAccountant {
    pub fn new(inner: RdpAccountant) -> Self {
        let n = inner.orders().len();
        Self { inner, totals: vec![0.0; n], steps: 0 }
    }

    /// Record one optimizer step with rate `q` and noise `sigma`.
    pub fn record_step(&mut self, q: f64, sigma: f64) {
        for (t, &a) in self.totals.iter_mut().zip(self.inner.orders()) {
            *t += RdpAccountant::rdp_single(q, sigma, a);
        }
        self.steps += 1;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Epsilon spent so far at `delta`.
    pub fn epsilon(&self, delta: f64) -> f64 {
        self.inner.eps_from_rdp(&self.totals, delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsubsampled_gaussian_closed_form() {
        // q = 1: RDP(alpha) = alpha / (2 sigma^2) exactly.
        for &(sigma, alpha) in &[(1.0, 2u32), (2.0, 8), (0.5, 16)] {
            let got = RdpAccountant::rdp_single(1.0, sigma, alpha);
            let want = alpha as f64 / (2.0 * sigma * sigma);
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_rate_is_free() {
        assert_eq!(RdpAccountant::rdp_single(0.0, 1.0, 8), 0.0);
    }

    #[test]
    fn rdp_monotone_in_q_and_sigma() {
        for alpha in [2u32, 4, 16, 64] {
            let mut prev = 0.0;
            for q in [0.01, 0.05, 0.2, 0.5, 0.9] {
                let r = RdpAccountant::rdp_single(q, 1.0, alpha);
                assert!(r >= prev, "RDP must grow with q (alpha={alpha})");
                prev = r;
            }
            let mut prev = f64::INFINITY;
            for sigma in [0.6, 0.8, 1.0, 2.0, 4.0] {
                let r = RdpAccountant::rdp_single(0.1, sigma, alpha);
                assert!(r <= prev, "RDP must shrink with sigma (alpha={alpha})");
                prev = r;
            }
        }
    }

    #[test]
    fn epsilon_linear_in_steps_upper_bound() {
        // Composition: eps(2T) <= 2*eps(T) + slack (RDP totals are linear,
        // conversion is concave-ish; check monotonicity and sublinearity).
        let acc = RdpAccountant::default();
        let e1 = acc.epsilon(0.01, 1.0, 1000, 1e-5);
        let e2 = acc.epsilon(0.01, 1.0, 2000, 1e-5);
        assert!(e2 > e1);
        assert!(e2 < 2.0 * e1 + 1.0);
    }

    #[test]
    fn golden_values_vs_independent_reference() {
        // Golden values computed with an independent Python
        // implementation of the same integer-order formulas + the Balle
        // et al. (2020) conversion (see EXPERIMENTS.md §Accountant):
        //   q=0.01 sigma=4.0 T=10000 delta=1e-5 -> eps = 1.03549
        //   q=0.01 sigma=1.1 T=10000 delta=1e-5 -> eps = 5.65431
        // (The classic Mironov conversion reports ~1.25 for the first
        // setting; the improved bound is tighter, matching Opacus.)
        let acc = RdpAccountant::default();
        let e1 = acc.epsilon(0.01, 4.0, 10_000, 1e-5);
        assert!((e1 - 1.03549).abs() < 1e-3, "eps = {e1}");
        let e2 = acc.epsilon(0.01, 1.1, 10_000, 1e-5);
        assert!((e2 - 5.65431).abs() < 1e-3, "eps = {e2}");
    }

    #[test]
    fn paper_setting_sigma_golden() {
        // Paper Table A2 (ViT): eps=8, delta=2.04e-5, q=0.5, T=4 steps.
        // Independent reference calibrates sigma = 0.92378.
        let acc = RdpAccountant::default();
        let eps = acc.epsilon(0.5, 0.92378, 4, 2.04e-5);
        assert!((eps - 8.0).abs() < 0.01, "eps = {eps}");
    }

    #[test]
    fn all_negative_candidates_clamp_to_zero_not_infinity() {
        // Regression (tiny-T / large-sigma corner): with one nearly
        // noiseless-in-epsilon step and a loose delta, every order's
        // conversion candidate is negative. The accountant must report
        // 0 (the mechanism is (0, delta)-DP), matching Opacus — the old
        // `eps >= 0` filter fell through to +infinity.
        let acc = RdpAccountant::default();
        let eps = acc.epsilon(0.01, 100.0, 1, 0.9);
        assert_eq!(eps, 0.0, "expected clamped epsilon, got {eps}");
        // The streaming accountant goes through the same conversion.
        let mut s = StreamingAccountant::new(acc.clone());
        s.record_step(0.01, 100.0);
        assert_eq!(s.epsilon(0.9), 0.0);
        // Ordinary settings are untouched by the fallback.
        let normal = acc.epsilon(0.01, 1.1, 10_000, 1e-5);
        assert!((normal - 5.65431).abs() < 1e-3, "eps = {normal}");
        // Epsilon can never be negative either.
        assert!(acc.epsilon(0.001, 50.0, 1, 0.5) >= 0.0);
    }

    #[test]
    fn streaming_matches_batch() {
        let acc = RdpAccountant::default();
        let mut s = StreamingAccountant::new(acc.clone());
        for _ in 0..50 {
            s.record_step(0.1, 1.2);
        }
        let want = acc.epsilon(0.1, 1.2, 50, 1e-5);
        assert!((s.epsilon(1e-5) - want).abs() < 1e-9);
    }
}
