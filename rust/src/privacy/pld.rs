//! Privacy-Loss-Distribution (Fourier) accountant for the
//! Poisson-subsampled Gaussian mechanism — the tighter alternative to
//! RDP (Koskela, Jälkö & Honkela 2020 — the paper's own group; also the
//! approach behind Google's `dp_accounting.pld`).
//!
//! One DP-SGD step (remove-adjacency) compares
//!
//! ```text
//! P(x) = (1-q) N(x; 0, sigma^2) + q N(x; 1, sigma^2)   vs   Q(x) = N(x; 0, sigma^2)
//! ```
//!
//! The privacy loss l(x) = ln(P(x)/Q(x)) induces a distribution over
//! losses when x ~ P; `T`-fold composition is the T-fold convolution of
//! that distribution, computed in O(n log n) with an in-tree radix-2 FFT
//! (offline environment — no rustfft). Finally
//!
//! ```text
//! delta(eps) = E_{l ~ PLD_T}[ (1 - e^{eps - l})_+ ]
//! ```
//!
//! and eps(delta) by bisection. The PLD bound is *tighter* than RDP for
//! the same mechanism (asserted in tests), which is exactly why modern
//! DP-SGD releases quote PLD epsilons; we ship both so the RDP-vs-PLD
//! gap is measurable (`bench_accountant`).

use std::f64::consts::PI;

/// Complex number (minimal, for the FFT).
#[derive(Debug, Clone, Copy, PartialEq)]
struct C64 {
    re: f64,
    im: f64,
}

impl C64 {
    const ZERO: C64 = C64 { re: 0.0, im: 0.0 };

    fn mul(self, o: C64) -> C64 {
        C64 { re: self.re * o.re - self.im * o.im, im: self.re * o.im + self.im * o.re }
    }

    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }

    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }

    /// Principal complex power by magnitude/angle (for T-fold
    /// composition: pld_hat^T). T is a positive integer, so the result
    /// is well-defined and branch-stable for |z| > 0.
    fn powi(self, t: u32) -> C64 {
        // exponentiation by squaring keeps accuracy for large T
        let mut base = self;
        let mut acc = C64 { re: 1.0, im: 0.0 };
        let mut e = t;
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(base);
            }
            base = base.mul(base);
            e >>= 1;
        }
        acc
    }
}

/// In-place iterative radix-2 Cooley-Tukey FFT. `inverse` applies the
/// conjugate transform and 1/n scaling.
fn fft(buf: &mut [C64], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = C64 { re: ang.cos(), im: ang.sin() };
        let mut i = 0;
        while i < n {
            let mut w = C64 { re: 1.0, im: 0.0 };
            for k in 0..len / 2 {
                let u = buf[i + k];
                let v = buf[i + k + len / 2].mul(w);
                buf[i + k] = u.add(v);
                buf[i + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        for x in buf.iter_mut() {
            x.re /= n as f64;
            x.im /= n as f64;
        }
    }
}

/// Standard normal pdf.
fn phi(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * PI).sqrt()
}

/// Discretized privacy loss distribution of ONE subsampled-Gaussian step.
#[derive(Debug, Clone)]
pub struct Pld {
    /// Probability mass per loss bucket; bucket k covers loss
    /// `l0 + k*dl` (bucket mass rounded UP in loss => valid upper bound).
    pmf: Vec<f64>,
    l0: f64,
    dl: f64,
    /// Mass at l = +infinity (distinguishing events). Zero for the
    /// subsampled Gaussian (supports coincide) but kept for generality.
    inf_mass: f64,
}

impl Pld {
    /// Build the PLD for rate `q`, noise multiplier `sigma`, with `n`
    /// buckets over the loss range `[-l_max, l_max]` (n rounded up to a
    /// power of two; ceiling-rounding of losses keeps the bound valid).
    pub fn subsampled_gaussian(q: f64, sigma: f64, l_max: f64, n: usize) -> Self {
        assert!(sigma > 0.0 && (0.0..=1.0).contains(&q));
        let n = n.next_power_of_two();
        let dl = 2.0 * l_max / n as f64;
        let l0 = -l_max;
        let mut pmf = vec![0.0f64; n];
        let mut inf_mass = 0.0f64;
        if q == 0.0 {
            // identical distributions: all mass at loss 0
            let k = ((0.0 - l0) / dl).ceil() as usize;
            pmf[k.min(n - 1)] = 1.0;
            return Self { pmf, l0, dl, inf_mass };
        }
        // integrate x ~ P over a wide grid; loss
        //   l(x) = ln( (1-q) + q e^{(2x-1)/(2 sigma^2)} )
        let x_lo = -30.0 * sigma - 1.0;
        let x_hi = 30.0 * sigma + 1.0;
        let steps = 400_000usize;
        let dx = (x_hi - x_lo) / steps as f64;
        for i in 0..steps {
            let x = x_lo + (i as f64 + 0.5) * dx;
            let p = (1.0 - q) * phi(x / sigma) / sigma + q * phi((x - 1.0) / sigma) / sigma;
            let mass = p * dx;
            if mass <= 0.0 {
                continue;
            }
            let l = ((1.0 - q) + q * ((2.0 * x - 1.0) / (2.0 * sigma * sigma)).exp()).ln();
            if l >= l_max {
                inf_mass += mass; // out of range: treat as infinite loss (upper bound)
            } else {
                // ceiling rounding (round loss UP to the next bucket edge)
                let k = ((l - l0) / dl).ceil();
                let k = k.clamp(0.0, (n - 1) as f64) as usize;
                pmf[k] += mass;
            }
        }
        // normalize tiny integration error onto the zero-loss bucket
        let total: f64 = pmf.iter().sum::<f64>() + inf_mass;
        let fix = 1.0 - total;
        let k0 = ((0.0 - l0) / dl).ceil() as usize;
        pmf[k0.min(n - 1)] += fix;
        Self { pmf, l0, dl, inf_mass }
    }

    /// `steps`-fold homogeneous composition via the periodised Fourier
    /// accountant (Koskela et al. 2020): the pmf lives on a ring of
    /// fixed size n covering [-L, L); raising its DFT to the T-th power
    /// composes T steps with wraparound (periodisation) error that is
    /// negligible as long as the composed distribution concentrates
    /// inside [-L, L) — which the loss-range choice in
    /// [`pld_epsilon`] guarantees for the regimes benchmarked here.
    pub fn compose(&self, steps: u32) -> Pld {
        if steps <= 1 {
            return self.clone();
        }
        // Rotate so bucket 0 sits at loss 0: the ring convolution then
        // composes losses around 0 and the wraparound lands at +/-L.
        let n = self.pmf.len();
        let k0 = ((0.0 - self.l0) / self.dl).round() as usize;
        let mut buf = vec![C64::ZERO; n];
        for (k, &p) in self.pmf.iter().enumerate() {
            buf[(k + n - k0) % n] = C64 { re: p, im: 0.0 };
        }
        fft(&mut buf, false);
        for x in buf.iter_mut() {
            *x = x.powi(steps);
        }
        fft(&mut buf, true);
        let mut pmf = vec![0.0f64; n];
        for (k, c) in buf.iter().enumerate() {
            pmf[(k + k0) % n] = c.re.max(0.0);
        }
        let inf = 1.0 - (1.0 - self.inf_mass).powi(steps as i32);
        Pld { pmf, l0: self.l0, dl: self.dl, inf_mass: inf }
    }

    /// delta(eps) = inf_mass + sum_{l > eps} (1 - e^{eps - l}) pmf(l).
    pub fn delta_at(&self, eps: f64) -> f64 {
        let mut delta = self.inf_mass;
        for (k, &p) in self.pmf.iter().enumerate() {
            if p <= 0.0 {
                continue;
            }
            let l = self.l0 + k as f64 * self.dl;
            if l > eps {
                delta += p * (1.0 - (eps - l).exp());
            }
        }
        delta.clamp(0.0, 1.0)
    }

    /// eps(delta) by bisection over the (monotone) delta_at curve.
    pub fn epsilon(&self, delta: f64) -> f64 {
        assert!(delta > 0.0 && delta < 1.0);
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        while self.delta_at(hi) > delta {
            hi *= 2.0;
            if hi > 1e4 {
                return f64::INFINITY;
            }
        }
        if self.delta_at(lo) <= delta {
            return 0.0;
        }
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            if self.delta_at(mid) > delta {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    }
}

/// One-call convenience mirroring [`super::RdpAccountant::epsilon`].
///
/// Grid choice: L = 30 covers every composed loss the eps(delta) query
/// can care about (delta floors at e^{-L}); n = 2^20 buckets give
/// dl = 5.7e-5, so the worst-case ceiling-rounding drift over T steps is
/// T * dl (0.06 at T = 1000) — well under the RDP-PLD gap it measures.
pub fn pld_epsilon(q: f64, sigma: f64, steps: u32, delta: f64) -> f64 {
    let l_max = 30.0;
    let pld = Pld::subsampled_gaussian(q, sigma, l_max, 1 << 20);
    pld.compose(steps).epsilon(delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::privacy::RdpAccountant;

    #[test]
    fn fft_roundtrip() {
        let mut buf: Vec<C64> = (0..16)
            .map(|i| C64 { re: (i as f64).sin(), im: 0.0 })
            .collect();
        let orig = buf.clone();
        fft(&mut buf, false);
        fft(&mut buf, true);
        for (a, b) in buf.iter().zip(&orig) {
            assert!((a.re - b.re).abs() < 1e-12 && a.im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_convolution_matches_direct() {
        // [1,2,0,0] * [3,4,0,0] = [3,10,8,0]
        let mut a: Vec<C64> = [1.0, 2.0, 0.0, 0.0]
            .iter()
            .map(|&x| C64 { re: x, im: 0.0 })
            .collect();
        let mut b = vec![
            C64 { re: 3.0, im: 0.0 },
            C64 { re: 4.0, im: 0.0 },
            C64::ZERO,
            C64::ZERO,
        ];
        fft(&mut a, false);
        fft(&mut b, false);
        let mut c: Vec<C64> = a.iter().zip(&b).map(|(x, y)| x.mul(*y)).collect();
        fft(&mut c, true);
        let want = [3.0, 10.0, 8.0, 0.0];
        for (got, w) in c.iter().zip(want) {
            assert!((got.re - w).abs() < 1e-9, "{got:?} vs {w}");
        }
    }

    #[test]
    fn single_step_pld_mass_is_one() {
        let pld = Pld::subsampled_gaussian(0.1, 1.0, 20.0, 2048);
        let total: f64 = pld.pmf.iter().sum::<f64>() + pld.inf_mass;
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn delta_monotone_decreasing_in_eps() {
        let pld = Pld::subsampled_gaussian(0.2, 1.0, 20.0, 2048).compose(10);
        let mut prev = 1.0;
        for eps in [0.0, 0.5, 1.0, 2.0, 4.0] {
            let d = pld.delta_at(eps);
            assert!(d <= prev + 1e-12);
            prev = d;
        }
    }

    #[test]
    fn pld_at_most_slightly_above_rdp_and_usually_tighter() {
        // PLD is the tighter accountant; allow a small discretization
        // slack above RDP but expect strict improvement in the classic
        // large-T regime.
        let rdp = RdpAccountant::default();
        let (q, sigma, t, delta) = (0.01, 1.1, 1000u32, 1e-5);
        let e_rdp = rdp.epsilon(q, sigma, t as u64, delta);
        let e_pld = pld_epsilon(q, sigma, t, delta);
        assert!(e_pld.is_finite());
        assert!(
            e_pld <= e_rdp * 1.05,
            "PLD {e_pld} should not exceed RDP {e_rdp} materially"
        );
    }

    #[test]
    fn pld_epsilon_monotone_in_steps() {
        let e1 = pld_epsilon(0.1, 1.0, 10, 1e-5);
        let e2 = pld_epsilon(0.1, 1.0, 100, 1e-5);
        assert!(e2 > e1, "{e1} -> {e2}");
    }

    #[test]
    fn q_zero_is_free() {
        let pld = Pld::subsampled_gaussian(0.0, 1.0, 10.0, 1024).compose(100);
        assert!(pld.epsilon(1e-9) < 0.05);
    }

    #[test]
    fn gaussian_q1_close_to_analytic() {
        // q = 1, single step: classic Gaussian mechanism. For sigma = 2,
        // delta(eps) = Phi(1/(2 sigma) - eps sigma) - e^eps Phi(-1/(2 sigma) - eps sigma)
        // (Balle & Wang 2018). Check epsilon at delta=1e-5 within 5%.
        let pld = Pld::subsampled_gaussian(1.0, 2.0, 30.0, 8192);
        let eps = pld.epsilon(1e-5);
        // analytic reference via bisection on the closed form
        let norm_cdf = |x: f64| 0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2));
        let delta_exact = |e: f64| {
            norm_cdf(1.0 / (2.0 * 2.0) - e * 2.0) - e.exp() * norm_cdf(-1.0 / (2.0 * 2.0) - e * 2.0)
        };
        let (mut lo, mut hi) = (0.0, 10.0);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if delta_exact(mid) > 1e-5 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        assert!((eps - hi).abs() / hi < 0.05, "pld {eps} vs analytic {hi}");
    }

    /// Abramowitz-Stegun erf (tests only).
    fn erf(x: f64) -> f64 {
        let s = if x < 0.0 { -1.0 } else { 1.0 };
        let x = x.abs();
        let t = 1.0 / (1.0 + 0.3275911 * x);
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        s * y
    }
}
