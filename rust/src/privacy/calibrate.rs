//! Noise calibration: find the noise multiplier sigma that spends a
//! target (epsilon, delta) budget for given sampling rate and steps.
//!
//! This is how the paper's hyperparameters (Table A2: eps = 8,
//! delta = 2.04e-5 with q = 0.5 and four optimizer steps) turn into the
//! sigma actually passed to the `apply` executable (noise_mult =
//! sigma * C).

use super::rdp::RdpAccountant;

/// Binary-search the smallest sigma with epsilon(sigma) <= target_eps.
///
/// Epsilon is strictly decreasing in sigma for the subsampled Gaussian,
/// so bisection over a bracket is exact. Returns an error string if the
/// target is unreachable within the bracket.
pub fn calibrate_sigma(
    target_eps: f64,
    delta: f64,
    q: f64,
    steps: u64,
) -> Result<f64, String> {
    assert!(target_eps > 0.0);
    let acc = RdpAccountant::default();
    let eps_at = |sigma: f64| acc.epsilon(q, sigma, steps, delta);

    let (mut lo, mut hi) = (0.1_f64, 1.0_f64);
    // Grow hi until the budget is met (or give up at an absurd sigma).
    while eps_at(hi) > target_eps {
        hi *= 2.0;
        if hi > 1e6 {
            return Err(format!(
                "cannot reach eps={target_eps} at delta={delta}, q={q}, T={steps}"
            ));
        }
    }
    // Shrink lo if even sigma=0.1 meets the budget (very loose targets).
    if eps_at(lo) <= target_eps {
        return Ok(lo);
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if eps_at(mid) > target_eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_meets_and_saturates_budget() {
        let (eps, delta, q, steps) = (8.0, 2.04e-5, 0.5, 4);
        let sigma = calibrate_sigma(eps, delta, q, steps).unwrap();
        let acc = RdpAccountant::default();
        let spent = acc.epsilon(q, sigma, steps, delta);
        assert!(spent <= eps + 1e-6, "budget exceeded: {spent}");
        // Tight: 1% less noise must blow the budget.
        let spent_tighter = acc.epsilon(q, sigma * 0.99, steps, delta);
        assert!(spent_tighter > eps - 0.15, "calibration too loose: {spent_tighter}");
    }

    #[test]
    fn paper_table_a2_setting_is_feasible() {
        // The paper's ViT hyperparameters: eps=8, delta=2.04e-5, q=0.5, 4 steps.
        let sigma = calibrate_sigma(8.0, 2.04e-5, 0.5, 4).unwrap();
        assert!(sigma > 0.5 && sigma < 20.0, "sigma = {sigma}");
    }

    #[test]
    fn more_steps_need_more_noise() {
        let s4 = calibrate_sigma(8.0, 1e-5, 0.1, 4).unwrap();
        let s400 = calibrate_sigma(8.0, 1e-5, 0.1, 400).unwrap();
        assert!(s400 > s4);
    }

    #[test]
    fn unreachable_target_errors() {
        // eps so tiny at q=1 that even huge sigma fails within bracket…
        // actually large sigma always reaches any eps>0, so test q=1 with
        // eps extremely small but positive still succeeds; instead check
        // the error path via steps explosion + epsilon floor at 0:
        let r = calibrate_sigma(1e-12, 1e-9, 1.0, 1_000_000);
        assert!(r.is_err() || r.unwrap() > 100.0);
    }
}
