//! Seeded ChaCha20 PRNG + distributions — built in-tree because the
//! environment is offline (no `rand`); DP experiment reproducibility
//! demands a counter-based, splittable, cross-platform-stable stream,
//! which ChaCha20 provides (it is also what `rand_chacha` implements, so
//! the design translates directly).
//!
//! The implementation follows RFC 7539's block function; we use the
//! 32-byte seed as the key and widen the block counter to 64 bits by
//! also occupying the first nonce word (words 12 and 13 of the state;
//! the remaining nonce words stay zero), giving
//! [`STREAM_CAPACITY_BYTES`] = 2^70 bytes per stream. The counter
//! originally stopped at 32 bits and `refill()` *wrapped*, silently
//! replaying the keystream after [`LEGACY_STREAM_CAPACITY_BYTES`] =
//! 2^38 bytes — enough for every shipped ladder model, but a silent
//! correctness cliff at scale. Exhausting even the widened counter is
//! now a hard panic instead of a wrap, and `dpshort audit` flags runs
//! whose largest statically-predicted stream draw crosses either bound
//! (`stream.exhaustion` / `stream.legacy-exhaustion`). Streams with
//! counter < 2^32 emit bitwise-identical keystream to the old
//! generator (word 13 was always zero there), so every pinned seeded
//! artifact is unchanged.

/// Keystream bytes one `(seed, stream, label)` key can produce with the
/// 64-bit block counter: 2^64 blocks of 64 bytes.
pub const STREAM_CAPACITY_BYTES: u128 = (u64::MAX as u128 + 1) * 64;

/// Keystream bytes before the pre-widening 32-bit counter wrapped
/// (2^32 blocks of 64 bytes = 2^38): the old silent-replay bound.
pub const LEGACY_STREAM_CAPACITY_BYTES: u128 = (u32::MAX as u128 + 1) * 64;

/// ChaCha20-based deterministic RNG.
#[derive(Debug, Clone)]
pub struct ChaChaRng {
    key: [u32; 8],
    counter: u64,
    buf: [u32; 16],
    /// Next unread word in `buf` (16 = exhausted).
    pos: usize,
    /// Cached second normal deviate (Box-Muller produces pairs).
    spare_normal: Option<f64>,
}

#[inline(always)]
fn quarter_round(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

impl ChaChaRng {
    /// RFC 7539 constants: "expand 32-byte k".
    const SIGMA: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];

    /// Construct from a 32-byte seed (the key).
    pub fn from_seed(seed: [u8; 32]) -> Self {
        let mut key = [0u32; 8];
        for (i, k) in key.iter_mut().enumerate() {
            *k = u32::from_le_bytes([
                seed[4 * i],
                seed[4 * i + 1],
                seed[4 * i + 2],
                seed[4 * i + 3],
            ]);
        }
        Self { key, counter: 0, buf: [0; 16], pos: 16, spare_normal: None }
    }

    /// Domain-separated stream from (seed, stream-id, label): the
    /// convenience constructor every subsystem uses so samples never
    /// collide across (experiment seed, step, purpose).
    pub fn from_seed_stream(seed: u64, stream: u64, label: &[u8; 8]) -> Self {
        let mut key = [0u8; 32];
        key[..8].copy_from_slice(&seed.to_le_bytes());
        key[8..16].copy_from_slice(&stream.to_le_bytes());
        key[16..24].copy_from_slice(label);
        Self::from_seed(key)
    }

    /// Produce the next 16-word block.
    fn refill(&mut self) {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&Self::SIGMA);
        state[4..12].copy_from_slice(&self.key);
        state[12] = self.counter as u32;
        // Counter high word lives in the first nonce word; words 14..16
        // stay zero. For counter < 2^32 this is bitwise-identical to
        // the original 32-bit-counter + zero-nonce layout.
        state[13] = (self.counter >> 32) as u32;
        let initial = state;
        for _ in 0..10 {
            // column rounds
            quarter_round(&mut state, 0, 4, 8, 12);
            quarter_round(&mut state, 1, 5, 9, 13);
            quarter_round(&mut state, 2, 6, 10, 14);
            quarter_round(&mut state, 3, 7, 11, 15);
            // diagonal rounds
            quarter_round(&mut state, 0, 5, 10, 15);
            quarter_round(&mut state, 1, 6, 11, 12);
            quarter_round(&mut state, 2, 7, 8, 13);
            quarter_round(&mut state, 3, 4, 9, 14);
        }
        for (o, i) in state.iter_mut().zip(initial) {
            *o = o.wrapping_add(i);
        }
        self.buf = state;
        // Exhaustion is a hard error, never a silent keystream replay
        // (the pre-widening u32 counter wrapped here after 2^38 bytes).
        self.counter = self
            .counter
            .checked_add(1)
            .expect("ChaCha stream exhausted: 2^70 bytes drawn from one (seed, stream, label)");
        self.pos = 0;
    }

    /// Next uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        if self.pos >= 16 {
            self.refill();
        }
        let w = self.buf[self.pos];
        self.pos += 1;
        w
    }

    /// Next uniform u64.
    pub fn next_u64(&mut self) -> u64 {
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        (hi << 32) | lo
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire-style rejection (unbiased).
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        // zone = largest multiple of n that fits in u64
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal deviate (Box-Muller, pair-cached).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] so ln(u) is finite.
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = std::f64::consts::TAU * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill `out` with standard normal deviates — bulk Box-Muller for
    /// the noisy step's P-length Gaussian vector. Exactly equivalent to
    /// `for o in out { *o = self.next_normal() as f32 }` in **every**
    /// RNG state (a pending spare is drained first, an odd tail caches
    /// its sine partner), but pairs are written straight into the
    /// output with no per-element `Option` bookkeeping, draining each
    /// 16-word ChaCha keystream block across four pairs. The
    /// determinism regression tests below pin the equivalence, so
    /// swapping the scalar loop for the bulk fill cannot change any
    /// seeded noise.
    pub fn fill_normals(&mut self, out: &mut [f32]) {
        const TAU: f64 = std::f64::consts::TAU;
        if out.is_empty() {
            return;
        }
        let mut i = 0;
        if let Some(z) = self.spare_normal.take() {
            out[0] = z as f32;
            i = 1;
        }
        while i + 1 < out.len() {
            let u = 1.0 - self.next_f64();
            let v = self.next_f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = TAU * v;
            out[i] = (r * theta.cos()) as f32;
            out[i + 1] = (r * theta.sin()) as f32;
            i += 2;
        }
        if i < out.len() {
            let u = 1.0 - self.next_f64();
            let v = self.next_f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = TAU * v;
            out[i] = (r * theta.cos()) as f32;
            self.spare_normal = Some(r * theta.sin());
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc7539_block_vector() {
        // RFC 7539 §2.3.2 test vector: key = 00 01 02 .. 1f, counter = 1,
        // nonce = 00:00:00:09:00:00:00:4a:00:00:00:00. Our nonce is fixed
        // to zero, so instead verify the keystream is stable and
        // non-degenerate, plus known-answer for the all-zero key/counter0
        // first word of the zero-key block (precomputed with this code
        // and cross-checked against a python chacha20 implementation):
        let mut rng = ChaChaRng::from_seed([0u8; 32]);
        let w = rng.next_u32();
        assert_eq!(w, 0xade0b876, "zero-key first keystream word");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a1 = ChaChaRng::from_seed_stream(1, 2, b"testing\0");
        let mut a2 = ChaChaRng::from_seed_stream(1, 2, b"testing\0");
        let mut b = ChaChaRng::from_seed_stream(1, 3, b"testing\0");
        let xs1: Vec<u32> = (0..100).map(|_| a1.next_u32()).collect();
        let xs2: Vec<u32> = (0..100).map(|_| a2.next_u32()).collect();
        let ys: Vec<u32> = (0..100).map(|_| b.next_u32()).collect();
        assert_eq!(xs1, xs2);
        assert_ne!(xs1, ys);
    }

    #[test]
    fn uniform_f64_in_range_and_mean() {
        let mut rng = ChaChaRng::from_seed_stream(7, 0, b"uniform\0");
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_range_unbiased_small_n() {
        let mut rng = ChaChaRng::from_seed_stream(9, 0, b"range\0\0\0");
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.gen_range(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = ChaChaRng::from_seed_stream(11, 0, b"normal\0\0");
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.next_normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fill_normals_matches_scalar_sequence() {
        // The bulk fill must reproduce the scalar next_normal stream
        // exactly — the noisy step's output is part of the seeded-run
        // determinism contract.
        for n in [0usize, 1, 2, 7, 64, 129] {
            let mut bulk_rng = ChaChaRng::from_seed_stream(5, 9, b"normblk\0");
            let mut buf = vec![0.0f32; n];
            bulk_rng.fill_normals(&mut buf);
            let mut scalar_rng = ChaChaRng::from_seed_stream(5, 9, b"normblk\0");
            for (i, &b) in buf.iter().enumerate() {
                let want = scalar_rng.next_normal() as f32;
                assert_eq!(b.to_bits(), want.to_bits(), "n={n} slot {i}");
            }
        }
    }

    #[test]
    fn fill_normals_equivalent_in_every_rng_state() {
        // Interleaving scalar and bulk draws must stay on the scalar
        // stream: a pending spare is drained into the fill, and an odd
        // tail leaves its sine partner cached for the next scalar call.
        for prefix in [0usize, 1, 2, 3] {
            for n in [0usize, 1, 5, 8] {
                let mut a = ChaChaRng::from_seed_stream(6, 2, b"normmix\0");
                let mut b = ChaChaRng::from_seed_stream(6, 2, b"normmix\0");
                for _ in 0..prefix {
                    let za = a.next_normal();
                    let zb = b.next_normal();
                    assert_eq!(za.to_bits(), zb.to_bits());
                }
                let mut buf = vec![0.0f32; n];
                a.fill_normals(&mut buf);
                for (i, &got) in buf.iter().enumerate() {
                    let want = b.next_normal() as f32;
                    assert_eq!(got.to_bits(), want.to_bits(), "prefix={prefix} n={n} slot {i}");
                }
                // Both sides continue on the same stream afterwards.
                let za = (a.next_normal() as f32).to_bits();
                let zb = (b.next_normal() as f32).to_bits();
                assert_eq!(za, zb, "prefix={prefix} n={n} post-fill");
            }
        }
    }

    #[test]
    fn fill_normals_moments() {
        let mut rng = ChaChaRng::from_seed_stream(13, 0, b"normblk\0");
        let mut buf = vec![0.0f32; 50_000];
        rng.fill_normals(&mut buf);
        let n = buf.len() as f64;
        let mean = buf.iter().map(|&z| z as f64).sum::<f64>() / n;
        let var = buf.iter().map(|&z| (z as f64) * (z as f64)).sum::<f64>() / n - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn counter_widening_preserves_low_blocks_and_fixes_the_wrap() {
        // Below 2^32 blocks the widened counter must emit the exact
        // keystream the old 32-bit-counter generator did (state word 13
        // is zero there) — pinned by the zero-key known answer above
        // and by cross-block continuity here.
        let mut a = ChaChaRng::from_seed_stream(17, 4, b"widen\0\0\0");
        let first_block: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();

        // Regression: the old refill() wrapped the u32 counter, so
        // block index 2^32 replayed block 0's keystream byte for byte.
        // With the widened counter it must differ (and not panic).
        let mut b = ChaChaRng::from_seed_stream(17, 4, b"widen\0\0\0");
        b.counter = u64::from(u32::MAX) + 1; // the first once-wrapped block
        b.pos = 16; // force a refill on the next draw
        let wrapped_block: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        assert_ne!(
            first_block, wrapped_block,
            "block 2^32 replayed block 0: the counter wrapped"
        );
        assert_eq!(b.counter, u64::from(u32::MAX) + 2, "counter advanced past 2^32");

        // Capacity constants match the counter widths.
        assert_eq!(STREAM_CAPACITY_BYTES, 1u128 << 70);
        assert_eq!(LEGACY_STREAM_CAPACITY_BYTES, 1u128 << 38);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = ChaChaRng::from_seed_stream(3, 0, b"shuffle\0");
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
