//! In-tree substrates kept from the fully-offline seed. `serde` /
//! `serde_json` now serialize the training report and `proptest` backs
//! the dev-only invariant tests, but these stay deliberately
//! dependency-free (the manifest parser predates serde and remains the
//! reference for its format):
//!
//! * [`rng`]   — seeded ChaCha20 PRNG + uniform/normal/shuffle (no `rand`)
//! * [`json`]  — JSON parser/writer for the artifact manifest
//! * [`cli`]   — flag parsing for the `dpshort` launcher (no `clap`)
//! * [`bench`] — timing harness with warmup + robust stats (no `criterion`)
//! * [`prop`]  — in-tree randomized property-test runner

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use rng::ChaChaRng;
