//! In-tree substrates that would normally come from crates.io — the
//! build environment is fully offline (only the `xla` bindings and
//! `anyhow` are vendored), so the reproduction builds its own:
//!
//! * [`rng`]   — seeded ChaCha20 PRNG + uniform/normal/shuffle (no `rand`)
//! * [`json`]  — JSON parser/writer for the artifact manifest (no `serde`)
//! * [`cli`]   — flag parsing for the `dpshort` launcher (no `clap`)
//! * [`bench`] — timing harness with warmup + robust stats (no `criterion`)
//! * [`prop`]  — randomized property-test runner (no `proptest`)

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use rng::ChaChaRng;
