//! Randomized property testing (offline stand-in for proptest).
//!
//! A seeded case generator + assertion runner: properties run over a few
//! hundred random cases; on failure the failing case's seed and
//! description are printed so the case can be replayed exactly. No
//! shrinking — cases are kept small instead.

use crate::util::rng::ChaChaRng;

/// Run `cases` random property checks. `gen_and_check` receives a
/// per-case RNG; return `Err(description)` to fail.
pub fn check<F>(name: &str, cases: u64, mut gen_and_check: F)
where
    F: FnMut(&mut ChaChaRng) -> Result<(), String>,
{
    // Base seed fixed for reproducibility; override with PROP_SEED.
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CE);
    for case in 0..cases {
        let mut rng = ChaChaRng::from_seed_stream(base, case, b"proptest");
        if let Err(msg) = gen_and_check(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} (PROP_SEED={base}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u32 roundtrip", 100, |rng| {
            let x = rng.next_u32();
            if x as u64 <= u32::MAX as u64 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn failing_property_panics_with_case() {
        check("always fails eventually", 10, |rng| {
            if rng.next_f64() < 0.999 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }
}
