//! Tiny CLI argument parser for the `dpshort` launcher (no `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; collects unknown flags as errors with the
//! usage string attached.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order + flag map.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]); `bool_flags` lists flags
    /// that take no value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.insert(name.to_string(), "true".to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = Args::parse(
            &v(&["train", "--model", "vit-micro", "--steps=4", "--bf16", "extra"]),
            &["bf16"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("model"), Some("vit-micro"));
        assert_eq!(a.get_parse_or::<u64>("steps", 0).unwrap(), 4);
        assert!(a.get_bool("bf16"));
        assert!(!a.get_bool("nope"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&v(&["--model"]), &[]).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(&v(&["--steps", "abc"]), &[]).unwrap();
        assert!(a.get_parse::<u64>("steps").is_err());
    }
}
