//! Minimal JSON parser + writer (offline environment: no serde).
//!
//! Covers the full JSON grammar; used to read artifacts/manifest.json
//! (written by python/compile/aot.py) and to emit machine-readable
//! reports. Numbers are kept as f64 (the manifest has no 2^53+ ints).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

/// Small builder helpers for emitting report JSON.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr(vs: Vec<Value>) -> Value {
    Value::Arr(vs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
          "version": 1, "seed": 0,
          "models": {"vit-micro": {"n_params": 120100,
            "flops_fwd_per_example": 1.5e7,
            "executables": [{"path": "a.hlo.txt", "batch": 8, "ok": true, "x": null}]}}
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
        let m = v.get("models").unwrap().get("vit-micro").unwrap();
        assert_eq!(m.get("n_params").unwrap().as_usize(), Some(120100));
        assert_eq!(m.get("flops_fwd_per_example").unwrap().as_f64(), Some(1.5e7));
        let e = &m.get("executables").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("path").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(e.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(e.get("x"), Some(&Value::Null));
        // roundtrip
        let again = parse(&v.to_json()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        let parsed = parse(&v.to_json()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("+5").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        let v = parse("[-1.5, 2e3, 0.25, -0]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert_eq!(a[2].as_f64(), Some(0.25));
    }
}
