//! Micro-benchmark harness (offline stand-in for criterion).
//!
//! `cargo bench` targets in benches/ are plain binaries (harness = false)
//! that use this module: warmup iterations, then timed iterations, then
//! median / mean / min and a simple MAD-based spread. Good enough to
//! regenerate the paper's tables, deterministic enough for the perf log.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    /// Seconds per iteration.
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
}

impl BenchStats {
    pub fn per_sec(&self) -> f64 {
        if self.median > 0.0 {
            1.0 / self.median
        } else {
            f64::INFINITY
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.3} ms/iter (median; min {:.3}, mad {:.3}, n={})",
            self.name,
            self.median * 1e3,
            self.min * 1e3,
            self.mad * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    stats_from(name, &samples)
}

/// Build stats from externally collected per-iteration seconds.
pub fn stats_from(name: &str, samples: &[f64]) -> BenchStats {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len().max(1);
    let median = sorted[n / 2];
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let min = *sorted.first().unwrap_or(&0.0);
    let mut dev: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[n / 2];
    BenchStats { name: name.to_string(), iters: samples.len(), mean, median, min, mad }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let s = stats_from("t", &[0.2, 0.1, 0.3, 0.1, 0.1]);
        assert_eq!(s.min, 0.1);
        assert!(s.median <= 0.2 && s.median >= 0.1);
        assert!((s.mean - 0.16).abs() < 1e-12);
        assert!(s.per_sec() > 0.0);
    }

    #[test]
    fn bench_runs_the_closure() {
        let mut count = 0usize;
        let s = bench("count", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }
}
