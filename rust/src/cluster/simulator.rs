//! Data-parallel scaling simulator (paper Figures 7 and A.4).
//!
//! Per step, every worker computes its share of the logical batch (time
//! from measured single-worker throughput), then the ring all-reduce of
//! the flat gradient runs; a configurable fraction of the all-reduce
//! overlaps with the tail of the backward pass (bucketed DDP-style
//! overlap). A fixed per-step serial overhead (host-side sampling,
//! optimizer bookkeeping, data loading without workers — the paper notes
//! multi-GPU runs cannot use loader workers) gives the Amdahl serial
//! term.

use super::allreduce::{ring_allreduce_seconds, Interconnect};

/// One point of the scaling curve.
#[derive(Debug, Clone, Copy)]
pub struct ScalingPoint {
    /// Worker (GPU) count of this point.
    pub gpus: usize,
    /// Achieved examples/second over the whole cluster.
    pub throughput: f64,
    /// Ideal linear scaling from 1 GPU.
    pub ideal: f64,
    /// throughput / ideal.
    pub efficiency: f64,
}

/// Simulator configuration for one training setup.
#[derive(Debug, Clone)]
pub struct ClusterSim {
    /// Per-worker examples/second measured on a single device (the real
    /// measured CPU throughput of the AOT executable feeds this).
    pub single_worker_throughput: f64,
    /// Per-worker physical batch size.
    pub local_batch: usize,
    /// Gradient bytes all-reduced each step (4 * n_params).
    pub grad_bytes: f64,
    /// Fraction of the all-reduce hidden behind compute (0..1).
    pub overlap: f64,
    /// Serial per-step seconds that never parallelize (host sampling,
    /// step bookkeeping, single-process data loading).
    pub serial_overhead: f64,
    /// Link topology and speeds of the modeled cluster.
    pub interconnect: Interconnect,
}

impl ClusterSim {
    /// Seconds of pure compute for one local physical batch.
    fn compute_seconds(&self) -> f64 {
        self.local_batch as f64 / self.single_worker_throughput
    }

    /// Simulate one step's wall-clock on `n` GPUs.
    pub fn step_seconds(&self, n: usize) -> f64 {
        let compute = self.compute_seconds();
        let ar = ring_allreduce_seconds(&self.interconnect, n, self.grad_bytes);
        let exposed_comm = (ar - self.overlap * compute).max(0.0);
        self.serial_overhead + compute + exposed_comm
    }

    /// Cluster throughput (examples/s) at `n` GPUs.
    pub fn throughput(&self, n: usize) -> f64 {
        (n * self.local_batch) as f64 / self.step_seconds(n)
    }

    /// Full scaling curve over the given GPU counts.
    pub fn curve(&self, gpu_counts: &[usize]) -> Vec<ScalingPoint> {
        let t1 = self.throughput(1);
        gpu_counts
            .iter()
            .map(|&n| {
                let thr = self.throughput(n);
                let ideal = t1 * n as f64;
                ScalingPoint {
                    gpus: n,
                    throughput: thr,
                    ideal,
                    efficiency: thr / ideal,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(throughput: f64, params: f64) -> ClusterSim {
        ClusterSim {
            single_worker_throughput: throughput,
            local_batch: 32,
            grad_bytes: params * 4.0,
            overlap: 0.5,
            serial_overhead: 2.0e-3,
            interconnect: Interconnect::default(),
        }
    }

    #[test]
    fn never_exceeds_ideal() {
        let s = sim(500.0, 86.6e6);
        for p in s.curve(&[1, 2, 4, 8, 16, 32, 64, 80]) {
            assert!(p.throughput <= p.ideal * 1.0 + 1e-9);
            assert!(p.efficiency <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn private_scales_better_than_nonprivate() {
        // The paper's headline scaling result: slower per-example compute
        // => comm is relatively smaller => higher parallel efficiency.
        // Non-private ViT-Base is ~2.8x faster per example than Opacus.
        let nonpriv = sim(1400.0, 86.6e6);
        let priv_ = sim(500.0, 86.6e6);
        let e_np = nonpriv.curve(&[80])[0].efficiency;
        let e_p = priv_.curve(&[80])[0].efficiency;
        assert!(e_p > e_np, "private {e_p} vs nonprivate {e_np}");
        // Paper: 69.2% (private) vs 53.3% (non-private) of ideal at 80.
        // The simulator preserves the mechanism and the private
        // magnitude; the non-private point is directionally right.
        assert!(e_p > 0.55 && e_p < 0.9, "{e_p}");
        assert!(e_np > 0.2 && e_np < e_p, "{e_np}");
    }

    #[test]
    fn intra_node_scaling_is_near_linear() {
        let s = sim(500.0, 86.6e6);
        let e4 = s.curve(&[4])[0].efficiency;
        assert!(e4 > 0.9, "within-node efficiency {e4}");
    }

    #[test]
    fn throughput_monotone_beyond_node_boundary() {
        // A dip is physically possible exactly at the 4->8 transition
        // (onto the slow inter-node fabric, paper Fig. 7's knee); past
        // it, adding nodes must keep increasing total throughput.
        let s = sim(800.0, 300e6);
        let curve = s.curve(&[1, 2, 4, 8, 16, 32, 64, 80]);
        for w in curve.windows(2) {
            if w[0].gpus >= 8 || w[1].gpus <= 4 {
                assert!(
                    w[1].throughput > w[0].throughput,
                    "{} -> {} gpus: {} -> {}",
                    w[0].gpus,
                    w[1].gpus,
                    w[0].throughput,
                    w[1].throughput
                );
            }
        }
    }
}
