//! Multi-GPU cluster substrate: the paper's scaling study (Section 7,
//! Figures 7 / A.4 / A.5), both **simulated** and **executed**.
//!
//! The paper's result — **DP-SGD scales better than SGD** (69.2% vs
//! 53.3% of ideal at 80 V100s; Amdahl parallel fractions 99.5% vs
//! 98.9%) — is a bandwidth-vs-compute phenomenon: private steps compute
//! longer per example, so the fixed-size gradient all-reduce is a
//! smaller fraction of each step and the interconnect saturates later.
//!
//! Two substrates reproduce it:
//!
//! * **Model** ([`simulator`], [`allreduce`], [`amdahl`]) — a discrete
//!   cost model: data-parallel workers, hierarchical ring all-reduce
//!   (fast intra-node links, slow inter-node links, 4 GPUs per node as
//!   on the paper's HPC system), per-step compute times taken from
//!   *measured* single-worker runs of the real executables.
//! * **Execution** ([`parallel`]) — a real data-parallel driver:
//!   worker threads each owning an
//!   [`ExecSession`](crate::runtime::ExecSession), one global Poisson
//!   draw sharded across ranks, and a fixed-shape binary-tree
//!   reduction that keeps N-worker runs bitwise-identical to the
//!   single-session trainer (DESIGN.md §8). `dpshort bench --workers`
//!   measures its scaling so the simulator's Amdahl predictions can be
//!   overlaid with reality (`examples/scaling_study.rs`).

#![warn(missing_docs)]

pub mod allreduce;
pub mod amdahl;
pub mod parallel;
pub mod simulator;

pub use allreduce::{ring_allreduce_seconds, Interconnect};
pub use amdahl::{amdahl_speedup, fit_parallel_fraction};
pub use parallel::{
    plan_groups, reduce_fixed_tree, run_groups, shard_ranges, GroupPlan, RecoveryEvent, StepRuns,
    WorkerFailure, WorkerFailureKind,
};
pub use simulator::{ClusterSim, ScalingPoint};
