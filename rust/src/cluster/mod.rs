//! Simulated multi-GPU cluster: the substrate for the paper's scaling
//! study (Section 7, Figures 7 / A.4 / A.5).
//!
//! The paper's result — **DP-SGD scales better than SGD** (69.2% vs
//! 53.3% of ideal at 80 V100s; Amdahl parallel fractions 99.5% vs
//! 98.9%) — is a bandwidth-vs-compute phenomenon: private steps compute
//! longer per example, so the fixed-size gradient all-reduce is a
//! smaller fraction of each step and the interconnect saturates later.
//!
//! We reproduce the mechanism with a discrete model: data-parallel
//! workers, hierarchical ring all-reduce (fast intra-node links, slow
//! inter-node links, 4 GPUs per node as on the paper's HPC system), and
//! per-step compute times taken from *measured* single-worker runs of
//! the real AOT executables.

pub mod allreduce;
pub mod amdahl;
pub mod simulator;

pub use allreduce::{Interconnect, ring_allreduce_seconds};
pub use amdahl::{amdahl_speedup, fit_parallel_fraction};
pub use simulator::{ClusterSim, ScalingPoint};
