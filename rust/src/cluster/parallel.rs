//! Data-parallel multi-session execution: the *real* counterpart of the
//! scaling study that [`super::simulator`] only models (DESIGN.md §8).
//!
//! One optimizer step is decomposed into **accumulation groups** —
//! contiguous, `physical_batch`-aligned slices of the globally sampled
//! logical batch. Groups are the unit of everything:
//!
//! * **Sharding** — worker `r` executes a contiguous range of groups
//!   ([`shard_ranges`]) on its own [`ExecSession`]; there is exactly
//!   one global sampler draw per step, never per-rank subsampling
//!   (shard-local Poisson would silently change the privacy
//!   amplification the accountant assumes — the Chua et al. shortcut).
//! * **Reduction** — each group yields a partial gradient accumulator
//!   (folded from zero over the group's examples), and the step's
//!   accumulator is the fixed-shape binary-tree combine of those
//!   partials ([`reduce_fixed_tree`]). The tree's pairing depends only
//!   on the group count — a pure function of the sampled batch and the
//!   physical batch size — so the reduced sum is **bitwise-identical
//!   for every worker count**, extending the kernel-level thread-count
//!   determinism contract (DESIGN.md §3) one level up to whole
//!   sessions.
//! * **Mode neutrality** — a group's partial is a sequential
//!   per-example fold, which the reference kernels keep invariant to
//!   how the group is chunked into executable calls. Masked mode runs
//!   a group as one padded fixed-shape call; Variable mode decomposes
//!   the same examples into lowered sizes ([`plan_groups`]) — both
//!   land on the same partial bits, so Algorithm-2 padding neutrality
//!   survives the data-parallel redesign.
//!
//! The driver ([`run_groups`]) spawns one scoped thread per worker;
//! each worker owns its session (`ExecSession: Send`) opened from the
//! shared `Arc<dyn Backend>`. Results are written into disjoint
//! per-rank slices, then combined by the coordinator strictly in group
//! order, so timing jitter can never reorder anything that feeds the
//! model state, the loss log, or the privacy accounting.
//!
//! ## Fault tolerance (DESIGN.md §11)
//!
//! Worker failures never propagate as panics: each group runs under
//! `catch_unwind`, and both panics and errors surface as a typed
//! [`WorkerFailure`] carrying the failing rank, step, and group. A
//! failed group is then **re-run on a surviving session** under the
//! configured [`RetryPolicy`] (bounded attempts, exponential backoff).
//! Recovery is bitwise-lossless because a group's partial is a pure
//! function of the step's parameters and the group's examples — every
//! session holds identical parameters during the accumulation phase,
//! so *any* rank reproduces the exact bits — and the fixed-tree
//! reduction pairs by group index, not by rank. A rank whose thread
//! panicked is treated as **permanently lost** ([`StepRuns::lost_ranks`]):
//! the trainer drops its session and continues on the smaller pool,
//! again bitwise-identically. Only when every rank is lost, or a
//! group's retry budget is exhausted, does the step abort — with the
//! typed failure, never a panic.
//!
//! Memory profile: the coordinator holds one P-length partial per
//! group (`k = ceil(E[L] / B)`) until the reduction — ~2 MB at this
//! repo's reference scale, deliberate and documented. A device-resident
//! backend replaces the whole read/reduce/write round-trip with an
//! in-fabric collective honoring the same pairing order (see
//! [`ExecSession`]'s `read_acc` docs), which is also where a
//! paper-scale model's partials would live.

use crate::coordinator::batcher::{BatchMemoryManager, BatchingMode, PhysicalBatch};
use crate::coordinator::config::RetryPolicy;
use crate::runtime::{ExecSession, Tensor};
use anyhow::{anyhow, Result};
use serde::Serialize;
use std::collections::BTreeSet;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// One accumulation group: the executable chunks covering one
/// `physical_batch`-aligned slice of the logical batch. In Masked mode
/// this is a single padded fixed-shape call; in Variable mode it is the
/// naive decomposition of the same examples into lowered batch sizes.
#[derive(Debug, Clone)]
pub struct GroupPlan {
    /// Executable calls of this group, run in order on one session
    /// without re-zeroing the accumulator between them.
    pub chunks: Vec<PhysicalBatch>,
}

impl GroupPlan {
    /// Examples computed by this group, including mask padding.
    pub fn computed(&self) -> usize {
        self.chunks.iter().map(|c| c.indices.len()).sum()
    }
}

/// Decompose one globally sampled logical batch into accumulation
/// groups — the worker-count-independent unit of sharding and
/// reduction.
///
/// Group `g` covers logical examples `[g*B, (g+1)*B)` (`B` =
/// `physical_batch`), so the group count — and therefore the reduction
/// tree of [`reduce_fixed_tree`] — depends only on the sampler draw
/// and the configuration, never on how many workers execute it:
///
/// * [`BatchingMode::Masked`] — one group per Algorithm-2 physical
///   batch (full shape, padding masked); the existing
///   [`BatchMemoryManager::split`] decomposition *is* the group grid.
/// * [`BatchingMode::Variable`] — the naive decomposition
///   ([`BatchMemoryManager::split_naive`]) applied **per group**, so
///   no chunk ever crosses a group boundary (and, as a side effect, no
///   chunk ever exceeds the configured physical batch — the memory
///   budget the physical batch models).
///
/// An empty logical batch (possible under Poisson) yields exactly one
/// group in both modes: the noise-only step still happens, and both
/// modes reduce the same all-zero partial.
pub fn plan_groups(
    logical: &[u32],
    physical_batch: usize,
    mode: BatchingMode,
    available: &[usize],
) -> Vec<GroupPlan> {
    match mode {
        BatchingMode::Masked => BatchMemoryManager::new(physical_batch, mode)
            .split(logical)
            .into_iter()
            .map(|pb| GroupPlan { chunks: vec![pb] })
            .collect(),
        BatchingMode::Variable => {
            if logical.is_empty() {
                return vec![GroupPlan {
                    chunks: BatchMemoryManager::split_naive(logical, available),
                }];
            }
            logical
                .chunks(physical_batch)
                .map(|group| GroupPlan {
                    chunks: BatchMemoryManager::split_naive(group, available),
                })
                .collect()
        }
    }
}

/// Contiguous near-even assignment of `items` work units to `workers`
/// ranks: the first `items % workers` ranks take one extra unit.
/// Deterministic, order-preserving, and exhaustive; ranks beyond the
/// work count receive empty ranges.
pub fn shard_ranges(items: usize, workers: usize) -> Vec<Range<usize>> {
    let workers = workers.max(1);
    let base = items / workers;
    let extra = items % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for r in 0..workers {
        let len = base + usize::from(r < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, items);
    out
}

/// `dst += src`, elementwise (one edge of the reduction tree).
fn add_into(dst: &mut Tensor, src: &Tensor) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.as_mut_slice().iter_mut().zip(src.as_slice()) {
        *d += s;
    }
}

/// Fixed-shape binary-tree reduction of partial accumulators: adjacent
/// pairs combine per round until one tensor remains (an odd tail is
/// carried up unmodified).
///
/// The association depends **only on `partials.len()`** — the schedule
/// a real all-reduce would follow for that many leaves — so any
/// assignment of the leaves to workers produces the same bits. This is
/// the determinism contract that makes N-worker training
/// bitwise-identical to the single-session run (DESIGN.md §8).
///
/// Returns `None` for an empty input.
pub fn reduce_fixed_tree(mut partials: Vec<Tensor>) -> Option<Tensor> {
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(2));
        let mut it = partials.into_iter();
        while let Some(mut left) = it.next() {
            if let Some(right) = it.next() {
                add_into(&mut left, &right);
            }
            next.push(left);
        }
        partials = next;
    }
    partials.pop()
}

/// Timed outcome of one executable chunk within a group.
#[derive(Debug, Clone)]
pub struct ChunkRun {
    /// Masked per-example loss sum reported by the accum call.
    pub loss_sum: f32,
    /// Real (unmasked) examples of the chunk.
    pub real: usize,
    /// Examples computed including Algorithm-2 padding.
    pub computed: usize,
    /// Seconds materializing the chunk's data.
    pub data_secs: f64,
    /// Seconds inside the accum executable.
    pub accum_secs: f64,
}

/// One group's execution result: the partial accumulator read back
/// through the session's all-reduce seam, plus per-chunk statistics in
/// chunk order.
#[derive(Debug)]
pub struct GroupRun {
    /// Partial gradient accumulator (folded from zero over the group).
    pub partial: Tensor,
    /// Per-chunk outcomes, in the group's chunk order.
    pub chunks: Vec<ChunkRun>,
}

/// How a worker failed executing one accumulation group.
#[derive(Debug, Clone)]
pub enum WorkerFailureKind {
    /// The worker's thread panicked; the payload is rendered to a
    /// string. The rank's session is considered permanently lost.
    Panic(String),
    /// The session returned a typed error; the rank survives and the
    /// group is retryable.
    Error(String),
}

/// Typed failure of one worker executing one accumulation group:
/// carries the failing rank, optimizer step, and group index so the
/// coordinator (and the operator reading the abort message) knows
/// exactly which unit of work died. This is what `run_groups` reports
/// instead of propagating a worker panic or a bare error.
#[derive(Debug, Clone)]
pub struct WorkerFailure {
    /// Failing worker rank (`0` = the session that applies the update).
    pub rank: usize,
    /// Optimizer step being executed.
    pub step: u64,
    /// Index of the failed accumulation group within the step.
    pub group: usize,
    /// Panic or typed error.
    pub kind: WorkerFailureKind,
}

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            WorkerFailureKind::Panic(msg) => write!(
                f,
                "worker rank {} panicked at step {} group {}: {msg}",
                self.rank, self.step, self.group
            ),
            WorkerFailureKind::Error(msg) => write!(
                f,
                "worker rank {} failed at step {} group {}: {msg}",
                self.rank, self.step, self.group
            ),
        }
    }
}

impl std::error::Error for WorkerFailure {}

/// One recovery action taken by the fault-tolerant executor or the
/// trainer; collected into `TrainReport::recovery_events`.
#[derive(Debug, Clone, Serialize)]
pub struct RecoveryEvent {
    /// Optimizer step during which the action happened.
    pub step: u64,
    /// Worker rank the action concerns.
    pub rank: usize,
    /// Accumulation group index, when the action is about a group.
    pub group: Option<usize>,
    /// What happened: `group-failed`, `rank-lost`, `group-recovered`,
    /// or (from the trainer) `apply-retried`.
    pub action: String,
    /// Human-readable context (the failure message, or where the group
    /// was re-run).
    pub detail: String,
}

/// Everything one fault-tolerant step execution produced.
#[derive(Debug)]
pub struct StepRuns {
    /// Per-group results in group order (independent of which rank ran
    /// what, or when, or after how many retries).
    pub runs: Vec<GroupRun>,
    /// Recovery actions taken; empty in a clean step.
    pub recoveries: Vec<RecoveryEvent>,
    /// Ranks whose worker thread panicked this step. Their sessions
    /// must be dropped by the caller — the pool continues degraded.
    pub lost_ranks: Vec<usize>,
}

/// Render a panic payload (the `Box<dyn Any>` from `catch_unwind`).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one group on `sess`: zero the bound accumulator, run the
/// chunks in order (the in-group fold), and read the partial back out.
fn run_one_group(
    sess: &mut dyn ExecSession,
    group: &GroupPlan,
    exec_chunk: &(dyn Fn(&mut dyn ExecSession, &PhysicalBatch) -> Result<ChunkRun> + Sync),
) -> Result<GroupRun> {
    sess.zero_acc()?;
    let mut chunks = Vec::with_capacity(group.chunks.len());
    for pb in &group.chunks {
        chunks.push(exec_chunk(sess, pb)?);
    }
    Ok(GroupRun { partial: sess.read_acc()?, chunks })
}

/// [`run_one_group`] with both failure modes converted to a typed
/// [`WorkerFailure`]: a panic anywhere in the group (session call or
/// `exec_chunk`) is caught instead of unwinding across the scope.
///
/// `AssertUnwindSafe` is sound here: after a panic the session is never
/// reused (the rank is reported lost and the caller drops it), and on a
/// plain error the backend contract guarantees the bound buffers are
/// left unmodified — a retry re-zeros the accumulator anyway.
fn run_one_group_caught(
    sess: &mut dyn ExecSession,
    group: &GroupPlan,
    exec_chunk: &(dyn Fn(&mut dyn ExecSession, &PhysicalBatch) -> Result<ChunkRun> + Sync),
    rank: usize,
    step: u64,
    group_idx: usize,
) -> Result<GroupRun, WorkerFailure> {
    match catch_unwind(AssertUnwindSafe(|| run_one_group(sess, group, exec_chunk))) {
        Ok(Ok(run)) => Ok(run),
        Ok(Err(e)) => Err(WorkerFailure {
            rank,
            step,
            group: group_idx,
            kind: WorkerFailureKind::Error(format!("{e:#}")),
        }),
        Err(payload) => Err(WorkerFailure {
            rank,
            step,
            group: group_idx,
            kind: WorkerFailureKind::Panic(panic_message(payload)),
        }),
    }
}

/// Record a failure: a panic permanently retires the rank.
fn note_failure(f: &WorkerFailure, lost: &mut BTreeSet<usize>, recoveries: &mut Vec<RecoveryEvent>) {
    let (action, detail) = match &f.kind {
        WorkerFailureKind::Panic(msg) => {
            lost.insert(f.rank);
            ("rank-lost", msg.clone())
        }
        WorkerFailureKind::Error(msg) => ("group-failed", msg.clone()),
    };
    recoveries.push(RecoveryEvent {
        step: f.step,
        rank: f.rank,
        group: Some(f.group),
        action: action.to_string(),
        detail,
    });
}

/// Run every group across the worker sessions, recovering from worker
/// failures, and return the results **in group order** (independent of
/// which rank ran what, when, or after how many retries).
///
/// `sessions[0]` is rank 0 (the session that will later apply the
/// update); `sessions[r]` executes the `r`-th contiguous shard of
/// `groups` ([`shard_ranges`]). With a single session everything runs
/// inline on the calling thread; otherwise one scoped thread per rank
/// drives that rank's session (`ExecSession: Send` is exactly this).
/// `exec_chunk` performs one accum call (data fetch + execution +
/// timing) and must be `Sync` — it is shared read-only across ranks.
///
/// Failures are handled per the module-level fault-tolerance contract:
/// every failed (or skipped-after-failure) group is re-run in group
/// order on the lowest-numbered surviving rank, each group bounded by
/// `retry.max_attempts` total attempts with exponential backoff
/// between them. Panicked ranks are retired and reported in
/// [`StepRuns::lost_ranks`]. The step aborts — with the typed
/// [`WorkerFailure`] as the error source — only when a group exhausts
/// its attempts or no rank survives.
pub fn run_groups(
    mut sessions: Vec<&mut dyn ExecSession>,
    groups: &[GroupPlan],
    exec_chunk: &(dyn Fn(&mut dyn ExecSession, &PhysicalBatch) -> Result<ChunkRun> + Sync),
    step: u64,
    retry: &RetryPolicy,
) -> Result<StepRuns> {
    if sessions.is_empty() {
        return Err(anyhow!("run_groups needs at least one session"));
    }
    let nranks = sessions.len();
    let max_attempts = retry.max_attempts.max(1);
    let mut slots: Vec<Option<Result<GroupRun, WorkerFailure>>> = Vec::with_capacity(groups.len());
    slots.resize_with(groups.len(), || None);

    if nranks == 1 || groups.len() <= 1 {
        // Single-rank fast path: no thread spawn, same group walk.
        let sess = &mut *sessions[0];
        for (g, (slot, group)) in slots.iter_mut().zip(groups).enumerate() {
            *slot = Some(run_one_group_caught(sess, group, exec_chunk, 0, step, g));
            if matches!(slot, Some(Err(_))) {
                break;
            }
        }
    } else {
        let ranges = shard_ranges(groups.len(), nranks);
        std::thread::scope(|scope| {
            let mut rest: &mut [Option<Result<GroupRun, WorkerFailure>>] = &mut slots;
            for (rank, (sess, range)) in sessions.iter_mut().zip(&ranges).enumerate() {
                let (mine, tail) = rest.split_at_mut(range.len());
                rest = tail;
                if range.is_empty() {
                    continue; // more workers than groups this step
                }
                let shard = &groups[range.start..range.end];
                let base = range.start;
                scope.spawn(move || {
                    let sess: &mut dyn ExecSession = &mut **sess;
                    for (i, (slot, group)) in mine.iter_mut().zip(shard).enumerate() {
                        *slot = Some(run_one_group_caught(
                            sess,
                            group,
                            exec_chunk,
                            rank,
                            step,
                            base + i,
                        ));
                        if matches!(slot, Some(Err(_))) {
                            break; // this rank's later groups go to recovery
                        }
                    }
                });
            }
        });
    }

    // Recovery pass: sweep first-pass failures, then re-run every
    // not-yet-successful group in group order on a surviving rank.
    let mut recoveries: Vec<RecoveryEvent> = Vec::new();
    let mut lost: BTreeSet<usize> = BTreeSet::new();
    let mut attempts: Vec<u32> = vec![0; groups.len()];
    for (g, slot) in slots.iter_mut().enumerate() {
        match slot {
            Some(Ok(_)) => attempts[g] = 1,
            Some(Err(f)) => {
                attempts[g] = 1;
                note_failure(f, &mut lost, &mut recoveries);
                *slot = None; // pending re-run
            }
            None => {} // skipped after an earlier failure on its rank
        }
    }

    for g in 0..groups.len() {
        while !matches!(slots[g], Some(Ok(_))) {
            let Some(rank) = (0..nranks).find(|r| !lost.contains(r)) else {
                return Err(anyhow!(
                    "step {step}: all {nranks} worker ranks lost; group {g} cannot be re-run"
                ));
            };
            if attempts[g] >= max_attempts {
                // The last failure of this group is the abort cause.
                let f = WorkerFailure {
                    rank,
                    step,
                    group: g,
                    kind: WorkerFailureKind::Error(format!(
                        "retry budget exhausted after {} attempts",
                        attempts[g]
                    )),
                };
                return Err(anyhow::Error::new(f)
                    .context(format!("step {step}: group {g} failed permanently")));
            }
            if attempts[g] > 0 {
                std::thread::sleep(retry.backoff_before(attempts[g] - 1));
            }
            attempts[g] += 1;
            match run_one_group_caught(&mut *sessions[rank], &groups[g], exec_chunk, rank, step, g)
            {
                Ok(run) => {
                    recoveries.push(RecoveryEvent {
                        step,
                        rank,
                        group: Some(g),
                        action: "group-recovered".to_string(),
                        detail: format!("group {g} re-run on rank {rank}"),
                    });
                    slots[g] = Some(Ok(run));
                }
                Err(f) => {
                    note_failure(&f, &mut lost, &mut recoveries);
                    if matches!(f.kind, WorkerFailureKind::Error(_)) && attempts[g] >= max_attempts
                    {
                        return Err(anyhow::Error::new(f)
                            .context(format!("step {step}: group {g} failed permanently")));
                    }
                }
            }
        }
    }

    let mut runs = Vec::with_capacity(groups.len());
    for slot in slots {
        match slot {
            Some(Ok(run)) => runs.push(run),
            // Unreachable: the recovery loop either fills every slot
            // with Ok or returns the typed failure above.
            _ => return Err(anyhow!("data-parallel step incomplete after recovery")),
        }
    }
    Ok(StepRuns { runs, recoveries, lost_ranks: lost.into_iter().collect() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::vec1(v)
    }

    #[test]
    fn shard_ranges_cover_contiguously() {
        for (items, workers) in [(0, 1), (1, 4), (7, 3), (8, 4), (64, 5), (3, 8)] {
            let ranges = shard_ranges(items, workers);
            assert_eq!(ranges.len(), workers);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "{items}/{workers}");
                next = r.end;
            }
            assert_eq!(next, items);
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "near-even: {lens:?}");
        }
    }

    #[test]
    fn tree_shape_depends_only_on_leaf_count() {
        // Values chosen so float association is observable: summing
        // left-to-right vs tree differs in the last bits.
        let vals = [1.0e8f32, 1.0, -1.0e8, 1.0, 3.0, -7.5, 0.25, 1.0e7];
        for n in 1..=vals.len() {
            let leaves: Vec<Tensor> = vals[..n].iter().map(|&v| t(&[v])).collect();
            let reduced = reduce_fixed_tree(leaves.clone()).unwrap();
            // Any re-run over the same leaves gives the same bits.
            let again = reduce_fixed_tree(leaves).unwrap();
            assert_eq!(reduced, again, "n={n}");
        }
        // And the 4-leaf tree is ((a+b)+(c+d)), not sequential.
        let leaves = vec![t(&[1.0e8]), t(&[1.0]), t(&[-1.0e8]), t(&[1.0])];
        let tree = reduce_fixed_tree(leaves).unwrap();
        let want = (1.0e8f32 + 1.0) + (-1.0e8 + 1.0);
        assert_eq!(tree.as_slice()[0].to_bits(), want.to_bits());
    }

    #[test]
    fn tree_of_one_is_identity_and_empty_is_none() {
        let x = t(&[1.5, -2.0]);
        assert_eq!(reduce_fixed_tree(vec![x.clone()]).unwrap(), x);
        assert!(reduce_fixed_tree(Vec::new()).is_none());
    }

    #[test]
    fn plan_groups_has_mode_independent_group_count() {
        let available = [1usize, 2, 4, 8, 16];
        for tl in [0usize, 1, 7, 8, 9, 23, 32] {
            let logical: Vec<u32> = (0..tl as u32).collect();
            let masked = plan_groups(&logical, 8, BatchingMode::Masked, &available);
            let naive = plan_groups(&logical, 8, BatchingMode::Variable, &available);
            assert_eq!(masked.len(), naive.len(), "tl={tl}");
            assert_eq!(masked.len(), tl.div_ceil(8).max(1));
            // Masked groups are exactly one full-shape chunk each.
            assert!(masked.iter().all(|g| g.chunks.len() == 1));
            assert!(masked.iter().all(|g| g.chunks[0].indices.len() == 8));
            // Variable chunks never cross a group boundary and never
            // exceed the physical batch.
            for g in &naive {
                assert!(g.chunks.iter().all(|c| c.indices.len() <= 8));
            }
            // Both modes cover exactly the logical examples (mask 1.0).
            let real = |groups: &[GroupPlan]| -> Vec<u32> {
                groups
                    .iter()
                    .flat_map(|g| &g.chunks)
                    .flat_map(|c| {
                        c.indices
                            .iter()
                            .zip(&c.mask)
                            .filter(|(_, &m)| m > 0.0)
                            .map(|(&i, _)| i)
                    })
                    .collect()
            };
            assert_eq!(real(&masked), logical, "tl={tl}");
            assert_eq!(real(&naive), logical, "tl={tl}");
        }
    }

    #[test]
    fn empty_logical_batch_plans_one_noise_only_group() {
        for mode in [BatchingMode::Masked, BatchingMode::Variable] {
            let groups = plan_groups(&[], 8, mode, &[2, 4, 8]);
            assert_eq!(groups.len(), 1);
            assert_eq!(groups[0].chunks.len(), 1);
            assert_eq!(groups[0].chunks[0].real_count(), 0);
        }
    }
}
