//! Ring all-reduce cost model over a hierarchical interconnect.
//!
//! Standard alpha-beta model: a ring all-reduce of S bytes over n ranks
//! moves `2 S (n-1)/n` bytes across every link (reduce-scatter +
//! all-gather) in `2 (n-1)` latency-bound steps. On a multi-node
//! machine the ring necessarily crosses node boundaries, so the slowest
//! (inter-node) link sets the pace once n exceeds the node size — which
//! is exactly the knee the paper sees past 4 GPUs ("communication inside
//! the node is fast, but communication between nodes will always be
//! slower; the bottleneck is the bandwidth").


/// Interconnect description (defaults follow the paper's HPC testbed:
/// 4 GPUs/node, NVLink-class intra-node, ~100 Gb/s InfiniBand between
/// nodes).
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// Intra-node per-link bandwidth, bytes/s.
    pub intra_bw: f64,
    /// Inter-node per-link bandwidth, bytes/s.
    pub inter_bw: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
}

impl Default for Interconnect {
    fn default() -> Self {
        Self {
            gpus_per_node: 4,
            intra_bw: 130.0e9, // NVLink-class effective
            inter_bw: 12.5e9,  // 100 Gb/s IB
            latency: 15.0e-6,
        }
    }
}

/// Time for one all-reduce of `bytes` over `n` ranks.
///
/// Within a node: plain ring over NVLink. Across nodes: hierarchical
/// (NCCL-style) two-level all-reduce — intra-node reduce + inter-node
/// ring among node leaders + intra-node broadcast — so the inter-node
/// volume term depends on the *node* count, not the GPU count.
pub fn ring_allreduce_seconds(ic: &Interconnect, n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    if n <= ic.gpus_per_node {
        let volume = 2.0 * bytes * (nf - 1.0) / nf;
        return volume / ic.intra_bw + 2.0 * (nf - 1.0) * ic.latency;
    }
    let g = ic.gpus_per_node as f64;
    let nodes = (n as f64 / g).ceil();
    let intra = 2.0 * bytes * (g - 1.0) / g / ic.intra_bw;
    let inter = 2.0 * bytes * (nodes - 1.0) / nodes / ic.inter_bw;
    intra + inter + 2.0 * (nf - 1.0) * ic.latency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(ring_allreduce_seconds(&Interconnect::default(), 1, 1e9), 0.0);
    }

    #[test]
    fn knee_at_node_boundary() {
        // Crossing from 4 to 8 GPUs jumps onto the slow inter-node links
        // (the paper: scaling departs from ideal past one node).
        let ic = Interconnect::default();
        let t4 = ring_allreduce_seconds(&ic, 4, 400e6);
        let t8 = ring_allreduce_seconds(&ic, 8, 400e6);
        assert!(t8 > 4.0 * t4, "t4={t4} t8={t8}");
    }

    #[test]
    fn volume_term_saturates_with_n() {
        // Hierarchical all-reduce: inter-node volume 2S(nodes-1)/nodes
        // approaches 2S — the time asymptotes rather than exploding.
        let ic = Interconnect::default();
        let t16 = ring_allreduce_seconds(&ic, 16, 1e9);
        let t64 = ring_allreduce_seconds(&ic, 64, 1e9);
        assert!(t64 < t16 * 1.5);
        assert!(t64 > t16); // latency term still grows
    }
}
