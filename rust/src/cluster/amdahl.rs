//! Amdahl's-law fit (paper Figure A.5).
//!
//! The paper summarizes its scaling curves by the Amdahl parallel
//! fraction `p`: speedup(n) = 1 / ((1-p) + p/n), reporting p = 99.5%
//! for private vs 98.9% for non-private training.

/// Amdahl speedup at `n` processors with parallel fraction `p`.
pub fn amdahl_speedup(p: f64, n: f64) -> f64 {
    1.0 / ((1.0 - p) + p / n)
}

/// Least-squares fit of the parallel fraction from measured speedups
/// `(n_i, s_i)` (s_i = throughput(n_i) / throughput(1)).
///
/// Each point gives a closed-form estimate
/// `p_i = (1 - 1/s_i) / (1 - 1/n_i)`; we return the n-weighted mean
/// (large-n points constrain p most), clamped to [0, 1].
pub fn fit_parallel_fraction(points: &[(f64, f64)]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for &(n, s) in points {
        if n <= 1.0 || s <= 0.0 {
            continue;
        }
        let p_i = (1.0 - 1.0 / s) / (1.0 - 1.0 / n);
        num += n * p_i;
        den += n;
    }
    if den == 0.0 {
        return 1.0;
    }
    (num / den).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_recovery_on_synthetic_curve() {
        let p = 0.995;
        let pts: Vec<(f64, f64)> = [2.0, 4.0, 8.0, 16.0, 32.0, 80.0]
            .iter()
            .map(|&n| (n, amdahl_speedup(p, n)))
            .collect();
        let got = fit_parallel_fraction(&pts);
        assert!((got - p).abs() < 1e-9, "{got}");
    }

    #[test]
    fn speedup_sanity() {
        assert!((amdahl_speedup(1.0, 80.0) - 80.0).abs() < 1e-9);
        assert!((amdahl_speedup(0.0, 80.0) - 1.0).abs() < 1e-9);
        // Paper's numbers: p=0.995 at n=80 gives ~57.6x (~72% efficiency).
        let s = amdahl_speedup(0.995, 80.0);
        assert!(s > 50.0 && s < 60.0, "{s}");
    }

    #[test]
    fn higher_p_means_better_scaling() {
        assert!(amdahl_speedup(0.995, 64.0) > amdahl_speedup(0.989, 64.0));
    }
}
