//! Fault-tolerant training runtime: deterministic fault injection,
//! bitwise-exact recovery, and crash-consistent checkpoints
//! (DESIGN.md §11).
//!
//! Three layers, composed but independently usable:
//!
//! 1. **Injection** ([`plan`], [`inject`]): a seeded or hand-written
//!    [`FaultPlan`] arms typed failures — accum/apply errors, worker
//!    panics, slow-worker stalls, checkpoint truncation and bit
//!    flips — at exact `(step, rank, call)` sites, and
//!    [`faulty_runtime`] wraps any [`crate::runtime::Runtime`] so its
//!    sessions fire them. Exposed as `dpshort train --inject-faults`.
//! 2. **Recovery** (`cluster::parallel::run_groups` +
//!    `coordinator::trainer`): per-worker panics and errors are caught
//!    and the failed shard's group partials are recomputed on a
//!    surviving session under the `RetryPolicy`; permanent rank loss
//!    degrades to a smaller pool. The fixed-tree reduction contract
//!    makes every recovered trajectory bitwise-identical to the
//!    fault-free one, and the epsilon spend commits exactly once per
//!    completed step.
//! 3. **Durability** ([`checkpoint`]): atomic temp-file+rename
//!    checkpoint writes with a content checksum; `--resume-latest`
//!    skips torn/corrupt/mismatched files with typed errors and
//!    resumes from the newest valid one.

pub mod checkpoint;
pub mod inject;
pub mod plan;

pub use checkpoint::{
    checkpoint_file_name, latest_valid, load_checkpoint, tenant_dir, write_checkpoint,
    CheckpointError, ScanOutcome,
};
pub use inject::{faulty_runtime, FaultyBackend, FaultySession, InjectedFault};
pub use plan::{FaultKind, FaultPlan, FaultSite};
