//! Crash-consistent checkpoint files: atomic writes, typed load
//! errors, and newest-valid-first scanning (DESIGN.md §11).
//!
//! The write protocol is the classic temp-file+rename: serialize,
//! write to a `.tmp` sibling, rename into place. A crash mid-write
//! leaves either the previous file or a `.tmp` the scanner ignores —
//! never a torn file under the final name. On top of that,
//! [`TrainCheckpoint`] carries a content checksum (sealed by
//! `TrainSession::checkpoint`), so corruption that slips past the
//! filesystem (bit rot, a torn write under a non-atomic filesystem)
//! still surfaces as a typed [`CheckpointError`] at load instead of a
//! silently wrong resume.
//!
//! [`write_checkpoint`] optionally consults a [`FaultPlan`]: a
//! `ckpt-truncate` or `ckpt-flip` site armed at the checkpoint's step
//! makes the writer *deliberately* produce the corresponding torn or
//! bit-rotted file (bypassing the atomic protocol), which is how the
//! chaos suite and the CI chaos-smoke job exercise the load-side
//! defenses end to end.

use super::plan::{FaultKind, FaultPlan};
use crate::coordinator::trainer::TrainCheckpoint;
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// Why a checkpoint file failed to load. Every variant is a defense:
/// resume must reject damage with a typed error, never panic, and
/// never silently continue a corrupted trajectory.
#[derive(Debug)]
pub enum CheckpointError {
    /// The file could not be read at all.
    Io {
        /// Offending path.
        path: PathBuf,
        /// Underlying I/O error, rendered.
        detail: String,
    },
    /// The file is not valid checkpoint JSON — the signature of a torn
    /// (truncated/interleaved) write.
    Torn {
        /// Offending path.
        path: PathBuf,
        /// Parser error, rendered.
        detail: String,
    },
    /// The JSON parsed but the content does not match its seal —
    /// bit rot, or a hand-edited file.
    Checksum {
        /// Offending path.
        path: PathBuf,
        /// Checksum stored in the file.
        stored: String,
        /// Checksum recomputed from the content.
        computed: String,
    },
    /// The checkpoint was taken under a different trajectory-shaping
    /// configuration (or a pre-`v5` format) than the resume expects.
    Fingerprint {
        /// Offending path.
        path: PathBuf,
        /// Fingerprint the resume config demands.
        want: String,
        /// Fingerprint stored in the file.
        found: String,
    },
}

impl CheckpointError {
    /// The file the error concerns.
    pub fn path(&self) -> &Path {
        match self {
            CheckpointError::Io { path, .. }
            | CheckpointError::Torn { path, .. }
            | CheckpointError::Checksum { path, .. }
            | CheckpointError::Fingerprint { path, .. } => path,
        }
    }
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io { path, detail } => {
                write!(f, "checkpoint {}: unreadable: {detail}", path.display())
            }
            CheckpointError::Torn { path, detail } => {
                write!(f, "checkpoint {}: torn/unparseable JSON: {detail}", path.display())
            }
            CheckpointError::Checksum { path, stored, computed } => write!(
                f,
                "checkpoint {}: content checksum mismatch (stored {stored}, computed \
                 {computed}): corrupted file",
                path.display()
            ),
            CheckpointError::Fingerprint { path, want, found } => write!(
                f,
                "checkpoint {}: fingerprint {found:?} does not match this configuration \
                 ({want:?})",
                path.display()
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Checkpoint file name for a step counter: `ckpt_step00000042.json`.
/// Zero-padded so lexicographic order is step order.
pub fn checkpoint_file_name(step: u64) -> String {
    format!("ckpt_step{step:08}.json")
}

/// Per-tenant checkpoint namespace: `<root>/<sanitized tenant>/`.
///
/// Multi-tenant serve co-locates every tenant's checkpoints under one
/// root; scoping each tenant to its own subdirectory means
/// [`latest_valid`] can never even *see* another tenant's files, so a
/// cross-tenant resume is impossible by construction (the config
/// fingerprint remains the second, content-level defense). Tenant
/// names are sanitized to `[A-Za-z0-9._-]` (anything else becomes `_`)
/// so a hostile name like `../other` cannot escape the root.
pub fn tenant_dir(root: &Path, tenant: &str) -> PathBuf {
    let sanitized: String = tenant
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect();
    // A name that sanitizes to dots only ("." / "..") would still walk
    // the tree; flatten those to underscores too.
    let sanitized = if sanitized.chars().all(|c| c == '.') {
        sanitized.replace('.', "_")
    } else {
        sanitized
    };
    root.join(sanitized)
}

/// Atomically write `ckpt` into `dir` (created if missing) as
/// [`checkpoint_file_name`]`(ckpt.step)`, via the temp-file+rename
/// protocol. When `faults` has a checkpoint-corruption site armed at
/// `ckpt.step`, the writer instead simulates the corresponding crash:
/// `ckpt-truncate` writes only the first half of the JSON straight to
/// the final name (a torn write), `ckpt-flip` flips the low bit of a
/// parameter digit after sealing (bit rot). Returns the final path.
pub fn write_checkpoint(
    dir: &Path,
    ckpt: &TrainCheckpoint,
    faults: Option<&FaultPlan>,
) -> Result<PathBuf> {
    fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let path = dir.join(checkpoint_file_name(ckpt.step));
    let mut json = ckpt.to_json().context("serializing checkpoint")?;
    match faults.and_then(|p| p.take_checkpoint(ckpt.step)) {
        Some(FaultKind::CheckpointTruncate) => {
            // A crash mid-write under a filesystem without atomic
            // rename: half the payload lands under the final name.
            json.truncate(json.len() / 2);
            fs::write(&path, json)
                .with_context(|| format!("writing torn checkpoint {}", path.display()))?;
            return Ok(path);
        }
        Some(FaultKind::CheckpointBitFlip) => {
            // Flip the low bit of a digit inside the params array: for
            // ASCII digits this always yields another digit, so the
            // JSON stays parseable and only the checksum can object.
            let mut bytes = json.into_bytes();
            let start = bytes
                .windows(10)
                .position(|w| w == b"\"params\":[")
                .map(|p| p + 10)
                .unwrap_or(0);
            if let Some(pos) =
                bytes[start..].iter().position(|b| b.is_ascii_digit()).map(|p| p + start)
            {
                bytes[pos] ^= 1;
            }
            fs::write(&path, bytes)
                .with_context(|| format!("writing bit-flipped checkpoint {}", path.display()))?;
            return Ok(path);
        }
        _ => {}
    }
    let tmp = dir.join(format!("{}.tmp", checkpoint_file_name(ckpt.step)));
    fs::write(&tmp, json).with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(path)
}

/// Load and validate one checkpoint file: readable → parses → checksum
/// holds → (when `expect_fingerprint` is given) fingerprint matches.
/// Every failure is a typed [`CheckpointError`]; nothing panics.
pub fn load_checkpoint(
    path: &Path,
    expect_fingerprint: Option<&str>,
) -> Result<TrainCheckpoint, CheckpointError> {
    let text = fs::read_to_string(path).map_err(|e| CheckpointError::Io {
        path: path.to_path_buf(),
        detail: e.to_string(),
    })?;
    let ckpt = TrainCheckpoint::from_json(&text).map_err(|e| CheckpointError::Torn {
        path: path.to_path_buf(),
        detail: e.to_string(),
    })?;
    if !ckpt.checksum_ok() {
        return Err(CheckpointError::Checksum {
            path: path.to_path_buf(),
            stored: ckpt.checksum.clone(),
            computed: ckpt.content_checksum(),
        });
    }
    if let Some(want) = expect_fingerprint {
        if ckpt.fingerprint != want {
            return Err(CheckpointError::Fingerprint {
                path: path.to_path_buf(),
                want: want.to_string(),
                found: ckpt.fingerprint,
            });
        }
    }
    Ok(ckpt)
}

/// Outcome of a `--resume-latest` scan.
#[derive(Debug)]
pub struct ScanOutcome {
    /// Newest (highest-step) valid checkpoint, when one exists.
    pub found: Option<(PathBuf, TrainCheckpoint)>,
    /// Files that looked like checkpoints but failed validation, each
    /// with its typed rejection — surfaced so an operator sees the
    /// damage instead of a silent skip.
    pub skipped: Vec<(PathBuf, CheckpointError)>,
}

/// Scan `dir` for the newest valid checkpoint: candidate files
/// (`ckpt_step*.json`, `.tmp` leftovers ignored) are tried
/// newest-first; torn, corrupt, or fingerprint-mismatched files are
/// recorded in [`ScanOutcome::skipped`] and the scan falls back to the
/// next-newest. A missing directory is an empty scan, not an error.
pub fn latest_valid(dir: &Path, expect_fingerprint: &str) -> Result<ScanOutcome> {
    let mut candidates: Vec<PathBuf> = Vec::new();
    match fs::read_dir(dir) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(ScanOutcome { found: None, skipped: Vec::new() })
        }
        Err(e) => {
            return Err(anyhow::Error::new(e)
                .context(format!("scanning checkpoint dir {}", dir.display())))
        }
        Ok(entries) => {
            for entry in entries {
                let entry = entry
                    .with_context(|| format!("scanning checkpoint dir {}", dir.display()))?;
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if name.starts_with("ckpt_step") && name.ends_with(".json") {
                    candidates.push(entry.path());
                }
            }
        }
    }
    // Zero-padded names: lexicographic descending == newest first.
    candidates.sort();
    candidates.reverse();
    let mut skipped = Vec::new();
    for path in candidates {
        match load_checkpoint(&path, Some(expect_fingerprint)) {
            Ok(ckpt) => return Ok(ScanOutcome { found: Some((path, ckpt)), skipped }),
            Err(e) => skipped.push((path, e)),
        }
    }
    Ok(ScanOutcome { found: None, skipped })
}
