//! Deterministic fault schedules: what fails, where, and when.
//!
//! A [`FaultPlan`] is a finite list of [`FaultSite`]s, each addressed
//! by `(step, rank, call)` — the optimizer step, the worker rank
//! (session-open order; rank 0 is the apply session), and the 0-based
//! accum-call index the rank has issued within that step. Sites fire
//! **at most once**: the injector consumes a site the first time its
//! coordinates come up, so a retried group or apply call sails through
//! — exactly the transient-fault shape the recovery layer is built
//! for. Plans are either written explicitly (the
//! `--inject-faults` spec grammar, [`FaultPlan::from_spec`]) or drawn
//! from a dedicated ChaCha stream ([`FaultPlan::seeded`]), so every
//! chaos schedule is reproducible from a seed — the property the
//! `fault_recovery` proptest suite leans on.
//!
//! The fault stream uses its own domain-separation label
//! (`b"faultpln"`), so it can never collide with the sampling or noise
//! streams — injection timing is independent of everything the privacy
//! analysis consumes.

use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// What a fault site does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The accum call returns a typed error (transient worker fault;
    /// the bound buffers are untouched, per the backend contract).
    AccumError,
    /// The apply call returns a typed error (the parameters are
    /// untouched; the trainer retries with the *same* noise tuple).
    ApplyError,
    /// The worker thread panics mid-accum; the rank's session is
    /// permanently lost and the pool degrades.
    WorkerPanic,
    /// The accum call stalls for `millis` before proceeding normally —
    /// a straggler, not a failure; recovery must not engage and the
    /// bits must not move.
    SlowWorker {
        /// Injected delay in milliseconds.
        millis: u64,
    },
    /// The checkpoint file for the matching `TrainCheckpoint::step` is
    /// written torn: truncated mid-JSON, bypassing the atomic
    /// temp-file+rename protocol (simulating a crash mid-write).
    CheckpointTruncate,
    /// One bit of a parameter digit in the checkpoint JSON is flipped
    /// after sealing (simulating bit rot; the file still parses, the
    /// content checksum catches it).
    CheckpointBitFlip,
}

impl FaultKind {
    /// The spec-grammar name of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::AccumError => "accum-err",
            FaultKind::ApplyError => "apply-err",
            FaultKind::WorkerPanic => "panic",
            FaultKind::SlowWorker { .. } => "slow",
            FaultKind::CheckpointTruncate => "ckpt-truncate",
            FaultKind::CheckpointBitFlip => "ckpt-flip",
        }
    }
}

/// One planned failure: a [`FaultKind`] armed at `(step, rank, call)`.
///
/// For [`FaultKind::ApplyError`] the `rank`/`call` coordinates are
/// ignored (apply runs once per step on the apply session); for the
/// checkpoint kinds, `step` addresses the checkpoint's step counter
/// and `rank`/`call` are ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSite {
    /// Optimizer step (or checkpoint step counter) the site arms at.
    pub step: u64,
    /// Worker rank (session-open order; rank 0 = the apply session).
    pub rank: usize,
    /// 0-based accum-call index within `(step, rank)`.
    pub call: u64,
    /// What happens when the site fires.
    pub kind: FaultKind,
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}@s{}.r{}.c{}",
            self.kind.name(),
            self.step,
            self.rank,
            self.call
        )?;
        if let FaultKind::SlowWorker { millis } = self.kind {
            write!(f, ".ms{millis}")?;
        }
        Ok(())
    }
}

/// A reproducible fault schedule plus its firing state. Shared as
/// `Arc<FaultPlan>` between the fault-wrapped backend (which consumes
/// sites), the trainer (which announces the step counter), and the
/// checkpoint writer (which consumes the checkpoint kinds).
#[derive(Debug)]
pub struct FaultPlan {
    sites: Vec<FaultSite>,
    /// Parallel to `sites`: true once a site has fired.
    fired: Mutex<Vec<bool>>,
    /// Step counter announced by the trainer before each step.
    current_step: AtomicU64,
}

/// Lock with poison recovery: a `Vec<bool>` of fire flags has no
/// invariant a panicking holder could break mid-update.
fn lock_fired(m: &Mutex<Vec<bool>>) -> std::sync::MutexGuard<'_, Vec<bool>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl FaultPlan {
    /// A plan over an explicit site list.
    pub fn new(sites: Vec<FaultSite>) -> Self {
        let fired = Mutex::new(vec![false; sites.len()]);
        Self { sites, fired, current_step: AtomicU64::new(0) }
    }

    /// Draw `count` worker-phase sites (accum errors, panics, slow
    /// workers, apply errors) from a dedicated ChaCha stream, over
    /// `steps` optimizer steps and `workers` ranks. Same
    /// `(seed, count, steps, workers)` → same schedule, always.
    pub fn seeded(seed: u64, count: usize, steps: u64, workers: usize) -> Self {
        let mut rng = ChaChaRng::from_seed_stream(seed, 0, b"faultpln");
        let steps = steps.max(1);
        let workers = workers.max(1);
        let mut sites = Vec::with_capacity(count);
        for _ in 0..count {
            let step = rng.gen_range(steps as usize) as u64;
            let rank = rng.gen_range(workers);
            let call = rng.gen_range(2) as u64;
            let kind = match rng.gen_range(4) {
                0 => FaultKind::AccumError,
                1 => FaultKind::WorkerPanic,
                2 => FaultKind::SlowWorker { millis: 1 + rng.gen_range(20) as u64 },
                _ => FaultKind::ApplyError,
            };
            sites.push(FaultSite { step, rank, call, kind });
        }
        Self::new(sites)
    }

    /// Parse an `--inject-faults` spec: comma-separated entries, each
    ///
    /// ```text
    /// KIND@sSTEP[.rRANK][.cCALL][.msMILLIS]
    /// random.seedN.countM
    /// ```
    ///
    /// where `KIND` is one of `accum-err`, `apply-err`, `panic`,
    /// `slow` (with optional `.msMILLIS`, default 20), `ckpt-truncate`,
    /// `ckpt-flip`; `rRANK` and `cCALL` default to 0. A `random.` entry
    /// appends a [`Self::seeded`] schedule drawn over `steps` ×
    /// `workers`.
    ///
    /// Examples: `panic@s1.r2`, `slow@s0.r1.c0.ms50`,
    /// `accum-err@s2.r0.c1,apply-err@s3`, `random.seed7.count4`.
    pub fn from_spec(spec: &str, steps: u64, workers: usize) -> Result<Self> {
        let mut sites = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            if let Some(rest) = entry.strip_prefix("random.") {
                let (mut seed, mut count) = (None, None);
                for tok in rest.split('.') {
                    if let Some(v) = tok.strip_prefix("seed") {
                        seed = Some(v.parse::<u64>().map_err(|_| bad_token(entry, tok))?);
                    } else if let Some(v) = tok.strip_prefix("count") {
                        count = Some(v.parse::<usize>().map_err(|_| bad_token(entry, tok))?);
                    } else {
                        return Err(bad_token(entry, tok));
                    }
                }
                let seed = seed.ok_or_else(|| anyhow!("`{entry}`: missing seedN"))?;
                let count = count.ok_or_else(|| anyhow!("`{entry}`: missing countM"))?;
                sites.extend(Self::seeded(seed, count, steps, workers).sites);
                continue;
            }
            let (kind_name, coords) = entry
                .split_once('@')
                .ok_or_else(|| anyhow!("`{entry}`: expected KIND@sSTEP[...]"))?;
            let (mut step, mut rank, mut call, mut millis) = (None, 0usize, 0u64, 20u64);
            for tok in coords.split('.') {
                if let Some(v) = tok.strip_prefix("ms") {
                    millis = v.parse().map_err(|_| bad_token(entry, tok))?;
                } else if let Some(v) = tok.strip_prefix('s') {
                    step = Some(v.parse::<u64>().map_err(|_| bad_token(entry, tok))?);
                } else if let Some(v) = tok.strip_prefix('r') {
                    rank = v.parse().map_err(|_| bad_token(entry, tok))?;
                } else if let Some(v) = tok.strip_prefix('c') {
                    call = v.parse().map_err(|_| bad_token(entry, tok))?;
                } else {
                    return Err(bad_token(entry, tok));
                }
            }
            let step = step.ok_or_else(|| anyhow!("`{entry}`: missing sSTEP"))?;
            let kind = match kind_name {
                "accum-err" => FaultKind::AccumError,
                "apply-err" => FaultKind::ApplyError,
                "panic" => FaultKind::WorkerPanic,
                "slow" => FaultKind::SlowWorker { millis },
                "ckpt-truncate" => FaultKind::CheckpointTruncate,
                "ckpt-flip" => FaultKind::CheckpointBitFlip,
                other => {
                    return Err(anyhow!(
                        "`{entry}`: unknown fault kind `{other}` (expected accum-err, \
                         apply-err, panic, slow, ckpt-truncate, or ckpt-flip)"
                    ))
                }
            };
            sites.push(FaultSite { step, rank, call, kind });
        }
        if sites.is_empty() {
            return Err(anyhow!("fault spec `{spec}` contains no sites"));
        }
        Ok(Self::new(sites))
    }

    /// Announce the optimizer step about to execute; injection sites
    /// are matched against this counter.
    pub fn begin_step(&self, step: u64) {
        self.current_step.store(step, Ordering::SeqCst);
    }

    /// The step counter most recently announced via [`Self::begin_step`].
    pub fn current_step(&self) -> u64 {
        self.current_step.load(Ordering::SeqCst)
    }

    /// Consume the first un-fired worker-phase site (accum error,
    /// panic, slow worker) armed at `(current step, rank, call)`.
    pub fn take_worker(&self, rank: usize, call: u64) -> Option<FaultKind> {
        let step = self.current_step();
        self.take(|s| {
            matches!(
                s.kind,
                FaultKind::AccumError | FaultKind::WorkerPanic | FaultKind::SlowWorker { .. }
            ) && s.step == step
                && s.rank == rank
                && s.call == call
        })
    }

    /// Consume the first un-fired apply-error site armed at the current
    /// step (rank/call are ignored: apply runs once per step).
    pub fn take_apply(&self) -> Option<FaultKind> {
        let step = self.current_step();
        self.take(|s| s.kind == FaultKind::ApplyError && s.step == step)
    }

    /// Consume the first un-fired checkpoint-corruption site whose
    /// `step` matches the checkpoint's step counter.
    pub fn take_checkpoint(&self, ckpt_step: u64) -> Option<FaultKind> {
        self.take(|s| {
            matches!(s.kind, FaultKind::CheckpointTruncate | FaultKind::CheckpointBitFlip)
                && s.step == ckpt_step
        })
    }

    fn take(&self, matches: impl Fn(&FaultSite) -> bool) -> Option<FaultKind> {
        let mut fired = lock_fired(&self.fired);
        for (i, site) in self.sites.iter().enumerate() {
            if !fired[i] && matches(site) {
                fired[i] = true;
                return Some(site.kind);
            }
        }
        None
    }

    /// Every planned site, fired or not.
    pub fn sites(&self) -> &[FaultSite] {
        &self.sites
    }

    /// The sites that have fired so far, in plan order.
    pub fn fired(&self) -> Vec<FaultSite> {
        let fired = lock_fired(&self.fired);
        self.sites
            .iter()
            .zip(fired.iter())
            .filter(|(_, &f)| f)
            .map(|(s, _)| *s)
            .collect()
    }
}

fn bad_token(entry: &str, tok: &str) -> anyhow::Error {
    anyhow!("`{entry}`: bad token `{tok}`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip_and_defaults() {
        let plan =
            FaultPlan::from_spec("panic@s1.r2, slow@s0.r1.c0.ms50,accum-err@s2.c1", 4, 4).unwrap();
        assert_eq!(
            plan.sites(),
            &[
                FaultSite { step: 1, rank: 2, call: 0, kind: FaultKind::WorkerPanic },
                FaultSite { step: 0, rank: 1, call: 0, kind: FaultKind::SlowWorker { millis: 50 } },
                FaultSite { step: 2, rank: 0, call: 1, kind: FaultKind::AccumError },
            ]
        );
        // Display renders back into parseable spec entries.
        for site in plan.sites() {
            let re = FaultPlan::from_spec(&site.to_string(), 4, 4).unwrap();
            assert_eq!(re.sites()[0], *site);
        }
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(FaultPlan::from_spec("", 4, 1).is_err());
        assert!(FaultPlan::from_spec("panic", 4, 1).is_err(), "missing @");
        assert!(FaultPlan::from_spec("panic@r1", 4, 1).is_err(), "missing step");
        assert!(FaultPlan::from_spec("explode@s1", 4, 1).is_err(), "unknown kind");
        assert!(FaultPlan::from_spec("panic@s1.x9", 4, 1).is_err(), "bad token");
        assert!(FaultPlan::from_spec("random.seed1", 4, 1).is_err(), "missing count");
    }

    #[test]
    fn seeded_schedules_are_reproducible_and_in_range() {
        let a = FaultPlan::seeded(7, 16, 5, 4);
        let b = FaultPlan::seeded(7, 16, 5, 4);
        assert_eq!(a.sites(), b.sites());
        assert_ne!(a.sites(), FaultPlan::seeded(8, 16, 5, 4).sites());
        for s in a.sites() {
            assert!(s.step < 5);
            assert!(s.rank < 4);
        }
    }

    #[test]
    fn sites_fire_at_most_once_and_only_at_their_address() {
        let plan = FaultPlan::from_spec("accum-err@s1.r1.c0,apply-err@s1", 4, 2).unwrap();
        plan.begin_step(0);
        assert_eq!(plan.take_worker(1, 0), None, "wrong step");
        assert_eq!(plan.take_apply(), None);
        plan.begin_step(1);
        assert_eq!(plan.take_worker(0, 0), None, "wrong rank");
        assert_eq!(plan.take_worker(1, 1), None, "wrong call");
        assert_eq!(plan.take_worker(1, 0), Some(FaultKind::AccumError));
        assert_eq!(plan.take_worker(1, 0), None, "consumed: the retry passes");
        assert_eq!(plan.take_apply(), Some(FaultKind::ApplyError));
        assert_eq!(plan.take_apply(), None);
        assert_eq!(plan.fired().len(), 2);
    }

    #[test]
    fn checkpoint_sites_address_the_checkpoint_step() {
        let plan = FaultPlan::from_spec("ckpt-truncate@s2,ckpt-flip@s3", 4, 1).unwrap();
        assert_eq!(plan.take_checkpoint(1), None);
        assert_eq!(plan.take_checkpoint(2), Some(FaultKind::CheckpointTruncate));
        assert_eq!(plan.take_checkpoint(2), None, "consumed");
        assert_eq!(plan.take_checkpoint(3), Some(FaultKind::CheckpointBitFlip));
    }
}
