//! The fault injector: a decorator over `Arc<dyn Backend>` whose
//! sessions consume a [`FaultPlan`] at the exact `(step, rank, call)`
//! sites the plan arms.
//!
//! [`FaultyBackend`] assigns injection **rank ids in session-open
//! order** — the trainer opens its apply session first, so rank 0 is
//! always the session that applies updates; open at most one
//! `TrainSession` per fault-wrapped runtime so ids stay aligned.
//! [`FaultySession`] intercepts `accum` (accum errors, worker panics,
//! slow-worker stalls) and `apply` (apply errors); all other calls
//! pass through. A session that took an injected panic marks itself
//! **dead**: every later call returns a typed [`InjectedFault`] — the
//! same observable behaviour as a worker whose process is gone, which
//! is what lets the recovery layer treat "panicked rank" as
//! "permanently lost rank" without special-casing the injector.
//!
//! Only the session path is faulted: the legacy copying entry points
//! (`run_accum`/`run_apply`) pass through untouched, because the
//! fault-tolerant executor (`cluster::parallel::run_groups`) drives
//! sessions exclusively.

use super::plan::{FaultKind, FaultPlan};
use crate::runtime::{
    AccumArgs, AccumOut, AccumStats, ApplyArgs, Backend, ExecSession, Prepared, Runtime, Tensor,
};
use crate::runtime::{ExecutableMeta, ModelMeta};
use anyhow::Result;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Typed error for an injected failure (or a call on a session the
/// injector already killed). Downcastable from the `anyhow` chain, so
/// tests and operators can tell injected faults from real ones.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    /// Optimizer step the fault fired at.
    pub step: u64,
    /// Rank of the faulted session.
    pub rank: usize,
    /// Which call was faulted ("accum error", "apply error", or
    /// "session lost to an injected panic").
    pub what: &'static str,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected {} (step {}, rank {})", self.what, self.step, self.rank)
    }
}

impl std::error::Error for InjectedFault {}

/// [`Backend`] decorator that wraps every opened session in a
/// [`FaultySession`] sharing one [`FaultPlan`].
pub struct FaultyBackend {
    inner: Arc<dyn Backend + Send + Sync>,
    plan: Arc<FaultPlan>,
    next_rank: AtomicUsize,
}

impl FaultyBackend {
    /// Decorate `inner` with the fault plan.
    pub fn new(inner: Arc<dyn Backend + Send + Sync>, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan, next_rank: AtomicUsize::new(0) }
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn prepare(&self, dir: &Path, meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared> {
        self.inner.prepare(dir, meta, exe)
    }

    fn is_compiled(&self, key: &str) -> bool {
        self.inner.is_compiled(key)
    }

    fn compile_records(&self) -> Vec<crate::runtime::CompileRecord> {
        self.inner.compile_records()
    }

    fn init_params(&self, dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        self.inner.init_params(dir, meta)
    }

    fn open_session(
        &self,
        dir: &Path,
        meta: &ModelMeta,
        params: Tensor,
    ) -> Result<Box<dyn ExecSession + '_>> {
        let rank = self.next_rank.fetch_add(1, Ordering::SeqCst);
        let inner = self.inner.open_session(dir, meta, params)?;
        Ok(Box::new(FaultySession {
            inner,
            plan: Arc::clone(&self.plan),
            rank,
            last_step: u64::MAX,
            calls: 0,
            dead: false,
        }))
    }

    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumOut> {
        self.inner.run_accum(prep, meta, params, acc, args)
    }

    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<Tensor> {
        self.inner.run_apply(prep, meta, params, acc, args)
    }

    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        self.inner.run_eval(prep, meta, params, x, y)
    }
}

/// [`ExecSession`] decorator that fires the plan's sites for its rank.
pub struct FaultySession<'a> {
    inner: Box<dyn ExecSession + 'a>,
    plan: Arc<FaultPlan>,
    rank: usize,
    /// Step counter at the last accum call (resets the call index).
    last_step: u64,
    /// Accum calls this session has issued within `last_step`.
    calls: u64,
    /// True after an injected panic: the session is permanently lost.
    dead: bool,
}

impl FaultySession<'_> {
    fn check_alive(&self) -> Result<()> {
        if self.dead {
            return Err(InjectedFault {
                step: self.plan.current_step(),
                rank: self.rank,
                what: "session lost to an injected panic",
            }
            .into());
        }
        Ok(())
    }
}

impl ExecSession for FaultySession<'_> {
    fn accum(&mut self, prep: &Prepared, args: &AccumArgs<'_>) -> Result<AccumStats> {
        self.check_alive()?;
        let step = self.plan.current_step();
        if step != self.last_step {
            self.last_step = step;
            self.calls = 0;
        }
        let call = self.calls;
        self.calls += 1;
        match self.plan.take_worker(self.rank, call) {
            Some(FaultKind::SlowWorker { millis }) => {
                // A straggler, not a failure: stall, then run normally.
                std::thread::sleep(std::time::Duration::from_millis(millis));
            }
            Some(FaultKind::AccumError) => {
                return Err(InjectedFault { step, rank: self.rank, what: "accum error" }.into());
            }
            Some(FaultKind::WorkerPanic) => {
                self.dead = true;
                panic!("injected worker panic (step {step}, rank {})", self.rank);
            }
            _ => {}
        }
        self.inner.accum(prep, args)
    }

    fn apply(&mut self, prep: &Prepared, args: &ApplyArgs) -> Result<()> {
        self.check_alive()?;
        if self.plan.take_apply().is_some() {
            return Err(InjectedFault {
                step: self.plan.current_step(),
                rank: self.rank,
                what: "apply error",
            }
            .into());
        }
        self.inner.apply(prep, args)
    }

    fn zero_acc(&mut self) -> Result<()> {
        self.check_alive()?;
        self.inner.zero_acc()
    }

    fn eval(&self, prep: &Prepared, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        self.check_alive()?;
        self.inner.eval(prep, x, y)
    }

    fn read_params(&self) -> Result<Tensor> {
        self.check_alive()?;
        self.inner.read_params()
    }

    fn write_params(&mut self, params: Tensor) -> Result<()> {
        self.check_alive()?;
        self.inner.write_params(params)
    }

    fn read_acc(&self) -> Result<Tensor> {
        self.check_alive()?;
        self.inner.read_acc()
    }

    fn write_acc(&mut self, acc: Tensor) -> Result<()> {
        self.check_alive()?;
        self.inner.write_acc(acc)
    }
}

/// Re-assemble `runtime` around a fault-wrapped copy of its backend.
/// The artifacts directory and manifest are shared; only the backend
/// seam is decorated, so the faulty runtime drives the same compiled
/// executables and produces the same bits wherever no fault fires.
pub fn faulty_runtime(runtime: &Runtime, plan: Arc<FaultPlan>) -> Runtime {
    Runtime::with_backend(
        runtime.artifacts_dir().to_path_buf(),
        runtime.manifest().clone(),
        Arc::new(FaultyBackend::new(runtime.backend_handle(), plan)),
    )
}
