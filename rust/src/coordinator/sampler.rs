//! Subsampling schemes for DP-SGD.
//!
//! The privacy accountant (see [`crate::privacy`]) analyses the *sampled
//! Gaussian mechanism*, which assumes every example is included in each
//! logical batch independently with probability `q = L / N` — **Poisson
//! subsampling**. Implementations that instead shuffle the dataset and
//! take fixed-size batches (the common shortcut, e.g. De et al. 2022's
//! JAX pipeline) can have *significantly weaker* privacy than accounted
//! (Lebeda et al. 2024). This module provides both so the gap can be
//! studied, but the trainer defaults to Poisson.
//!
//! Sampling is seeded and per-step deterministic: step `t` derives its
//! own ChaCha20 stream from `(seed, t)`, so logical batches are
//! reproducible regardless of how many times or in which order steps are
//! sampled — this mirrors how Opacus' `UniformWithReplacementSampler`
//! behaves under a fixed torch generator seed, and it is what makes the
//! cross-variant comparisons in the paper "seeded with the same logical
//! batch sizes" (Section 2.1).

use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};

/// Which subsampling scheme a run uses (`dpshort train --sampler`).
/// Shuffle is the studied shortcut: executable for the ablation, but
/// the plan audit raises a Deny-severity `accountant.shortcut-epsilon`
/// diagnostic when it is paired with Poisson (RDP/PLD) accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerChoice {
    /// Exact Poisson subsampling (the accounted mechanism; default).
    Poisson,
    /// Shuffle-once-per-epoch fixed-size batches (the shortcut).
    Shuffle,
}

impl SamplerChoice {
    /// Parse a CLI name (`poisson` | `shuffle`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "poisson" => Some(Self::Poisson),
            "shuffle" => Some(Self::Shuffle),
            _ => None,
        }
    }

    /// The CLI / report name.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Poisson => "poisson",
            Self::Shuffle => "shuffle",
        }
    }
}

/// A subsampling scheme producing the logical batch for each step.
pub trait Sampler {
    /// Indices of the examples in step `t`'s logical batch.
    fn sample(&self, step: u64) -> Vec<u32>;

    /// Expected logical batch size (used for sizing / reporting).
    fn expected_batch_size(&self) -> f64;

    /// The subsampling probability this scheme *actually* provides for
    /// accounting purposes, if any. `None` marks schemes whose privacy
    /// amplification is NOT the accounted Poisson one (the "shortcut").
    fn poisson_rate(&self) -> Option<f64>;
}

/// Exact Poisson subsampling: each of the `n` examples enters the batch
/// independently with probability `q`.
#[derive(Debug, Clone)]
pub struct PoissonSampler {
    n: u32,
    q: f64,
    seed: u64,
}

impl PoissonSampler {
    /// `n` dataset size, `q` per-example sampling rate (`L/N`), `seed`
    /// the experiment seed.
    pub fn new(n: u32, q: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&q), "sampling rate must be in [0,1]");
        Self { n, q, seed }
    }

    fn rng_for_step(&self, step: u64) -> ChaChaRng {
        // Derive a unique, stable stream per (seed, step).
        ChaChaRng::from_seed_stream(self.seed, step, b"poisson\0")
    }
}

impl Sampler for PoissonSampler {
    fn sample(&self, step: u64) -> Vec<u32> {
        let mut rng = self.rng_for_step(step);
        // One uniform draw per example: the straightforward O(N) Bernoulli
        // scan. (A geometric-skip sampler is implemented below for the
        // hot path when q is small; both are property-tested equal in
        // distribution.)
        if self.q <= 0.1 {
            return self.sample_by_skips(&mut rng);
        }
        let mut out = Vec::with_capacity((self.n as f64 * self.q * 1.25) as usize + 8);
        for i in 0..self.n {
            if rng.next_f64() < self.q {
                out.push(i);
            }
        }
        out
    }

    fn expected_batch_size(&self) -> f64 {
        self.n as f64 * self.q
    }

    fn poisson_rate(&self) -> Option<f64> {
        Some(self.q)
    }
}

impl PoissonSampler {
    /// Geometric-jump Bernoulli sampling: instead of N uniform draws,
    /// draw the gap to the next success ~ Geometric(q). O(qN) expected
    /// work — the classic trick for sparse Poisson subsampling.
    fn sample_by_skips(&self, rng: &mut ChaChaRng) -> Vec<u32> {
        let mut out = Vec::with_capacity((self.n as f64 * self.q * 1.25) as usize + 8);
        if self.q <= 0.0 {
            return out;
        }
        let log1mq = (1.0 - self.q).ln();
        let mut i: f64 = 0.0;
        loop {
            // skip ~ floor(log(U) / log(1-q)) failures before next success
            let u: f64 = rng.next_f64().max(f64::MIN_POSITIVE);
            i += (u.ln() / log1mq).floor();
            if i >= self.n as f64 {
                break;
            }
            out.push(i as u32);
            i += 1.0;
        }
        out
    }
}

/// The fixed-batch "shortcut": shuffle once per epoch, take consecutive
/// fixed-size batches. Efficient (static shapes) but its privacy
/// amplification is NOT what Poisson accounting assumes — kept here to
/// reproduce the paper's discussion and for ablation benches.
#[derive(Debug, Clone)]
pub struct ShuffleSampler {
    n: u32,
    batch: u32,
    seed: u64,
}

impl ShuffleSampler {
    pub fn new(n: u32, batch: u32, seed: u64) -> Self {
        assert!(batch > 0 && batch <= n);
        Self { n, batch, seed }
    }

    fn epoch_perm(&self, epoch: u64) -> Vec<u32> {
        let mut rng = ChaChaRng::from_seed_stream(self.seed, epoch, b"shuffle\0");
        let mut perm: Vec<u32> = (0..self.n).collect();
        rng.shuffle(&mut perm);
        perm
    }
}

impl Sampler for ShuffleSampler {
    fn sample(&self, step: u64) -> Vec<u32> {
        // ceil(n / batch) steps per epoch, so the permutation tail forms
        // a partial final batch instead of being dropped. (Truncating
        // division silently excluded the last `n % batch` positions of
        // every epoch — those examples were never trained on and got
        // more privacy than accounted.)
        let steps_per_epoch = (self.n as u64).div_ceil(self.batch as u64);
        let epoch = step / steps_per_epoch;
        let pos = (step % steps_per_epoch) as usize * self.batch as usize;
        let end = (pos + self.batch as usize).min(self.n as usize);
        let perm = self.epoch_perm(epoch);
        perm[pos..end].to_vec()
    }

    fn expected_batch_size(&self) -> f64 {
        // Average over the epoch, counting the partial final batch.
        let steps_per_epoch = (self.n as u64).div_ceil(self.batch as u64);
        self.n as f64 / steps_per_epoch as f64
    }

    fn poisson_rate(&self) -> Option<f64> {
        None // the shortcut: no valid Poisson rate for accounting
    }
}

/// The configured sampler as one concrete type the trainer can own.
#[derive(Debug, Clone)]
pub enum AnySampler {
    /// Exact Poisson subsampling.
    Poisson(PoissonSampler),
    /// The shuffle shortcut.
    Shuffle(ShuffleSampler),
}

impl AnySampler {
    /// Build the configured sampler from the run parameters: `n`
    /// dataset size, `q` sampling rate, `seed` the experiment seed. The
    /// shuffle batch size is `round(q * n)` clamped to `[1, n]` — the
    /// same expected logical batch the Poisson path targets, which is
    /// exactly what makes the shortcut comparison apples-to-apples.
    pub fn from_config(choice: SamplerChoice, n: u32, q: f64, seed: u64) -> Result<Self> {
        match choice {
            SamplerChoice::Poisson => Ok(Self::Poisson(PoissonSampler::new(n, q, seed))),
            SamplerChoice::Shuffle => {
                if n == 0 {
                    return Err(anyhow!("shuffle sampler needs a non-empty dataset"));
                }
                let batch = ((f64::from(n) * q).round() as u32).clamp(1, n);
                Ok(Self::Shuffle(ShuffleSampler::new(n, batch, seed)))
            }
        }
    }
}

impl Sampler for AnySampler {
    fn sample(&self, step: u64) -> Vec<u32> {
        match self {
            Self::Poisson(s) => s.sample(step),
            Self::Shuffle(s) => s.sample(step),
        }
    }

    fn expected_batch_size(&self) -> f64 {
        match self {
            Self::Poisson(s) => s.expected_batch_size(),
            Self::Shuffle(s) => s.expected_batch_size(),
        }
    }

    fn poisson_rate(&self) -> Option<f64> {
        match self {
            Self::Poisson(s) => s.poisson_rate(),
            Self::Shuffle(s) => s.poisson_rate(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_deterministic_per_seed_and_step() {
        let s = PoissonSampler::new(10_000, 0.5, 42);
        assert_eq!(s.sample(3), s.sample(3));
        assert_ne!(s.sample(3), s.sample(4));
        let s2 = PoissonSampler::new(10_000, 0.5, 43);
        assert_ne!(s.sample(3), s2.sample(3));
    }

    #[test]
    fn poisson_batch_size_concentrates() {
        // Binomial(n, q): mean nq, sd sqrt(nq(1-q)). 6 sigma bound.
        let n = 50_000u32;
        let q = 0.5;
        let s = PoissonSampler::new(n, q, 7);
        let mean = n as f64 * q;
        let sd = (n as f64 * q * (1.0 - q)).sqrt();
        for t in 0..20 {
            let b = s.sample(t).len() as f64;
            assert!((b - mean).abs() < 6.0 * sd, "step {t}: {b} vs {mean}");
        }
    }

    #[test]
    fn poisson_indices_sorted_unique_in_range() {
        let s = PoissonSampler::new(1000, 0.3, 1);
        let idx = s.sample(0);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 1000));
    }

    #[test]
    fn skip_sampler_matches_bernoulli_rate() {
        // q below the 0.1 threshold exercises the geometric-skip path.
        let n = 200_000u32;
        let q = 0.01;
        let s = PoissonSampler::new(n, q, 9);
        let mean = n as f64 * q;
        let sd = (n as f64 * q * (1.0 - q)).sqrt();
        let mut total = 0.0;
        for t in 0..30 {
            total += s.sample(t).len() as f64;
        }
        let avg = total / 30.0;
        assert!((avg - mean).abs() < 3.0 * sd / 30f64.sqrt());
    }

    #[test]
    fn zero_and_one_rates() {
        assert!(PoissonSampler::new(100, 0.0, 0).sample(0).is_empty());
        assert_eq!(PoissonSampler::new(100, 1.0, 0).sample(0).len(), 100);
    }

    #[test]
    fn shuffle_covers_whole_epoch_when_batch_divides_n() {
        let s = ShuffleSampler::new(100, 10, 5);
        assert_eq!(s.expected_batch_size(), 10.0);
        let mut seen: Vec<u32> = (0..10).flat_map(|t| s.sample(t)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn shuffle_uses_whole_dataset_when_batch_does_not_divide_n() {
        // Regression: n % batch != 0 used to drop the tail of every
        // epoch's permutation — those examples were never sampled.
        let s = ShuffleSampler::new(105, 10, 9);
        let steps_per_epoch = 11; // ceil(105 / 10)
        for epoch in 0..2u64 {
            let lo = epoch * steps_per_epoch;
            let mut seen: Vec<u32> =
                (lo..lo + steps_per_epoch).flat_map(|t| s.sample(t)).collect();
            assert_eq!(seen.len(), 105, "epoch {epoch} must touch all examples");
            seen.sort_unstable();
            assert_eq!(seen, (0..105).collect::<Vec<u32>>());
        }
        // Full batches first, partial tail last.
        assert_eq!(s.sample(0).len(), 10);
        assert_eq!(s.sample(10).len(), 5);
        assert_eq!(s.sample(11).len(), 10); // next epoch restarts
        assert!((s.expected_batch_size() - 105.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn sampler_choice_round_trips() {
        for c in [SamplerChoice::Poisson, SamplerChoice::Shuffle] {
            assert_eq!(SamplerChoice::parse(c.as_str()), Some(c));
        }
        assert_eq!(SamplerChoice::parse("sequential"), None);
    }

    #[test]
    fn any_sampler_delegates_to_the_chosen_scheme() {
        let p = AnySampler::from_config(SamplerChoice::Poisson, 1000, 0.3, 1).unwrap();
        assert_eq!(p.sample(0), PoissonSampler::new(1000, 0.3, 1).sample(0));
        assert_eq!(p.poisson_rate(), Some(0.3));

        let s = AnySampler::from_config(SamplerChoice::Shuffle, 100, 0.1, 5).unwrap();
        assert_eq!(s.sample(3), ShuffleSampler::new(100, 10, 5).sample(3));
        assert!(s.poisson_rate().is_none());
        assert_eq!(s.expected_batch_size(), 10.0);

        // Batch derivation clamps to [1, n]; empty datasets are an error.
        let tiny = AnySampler::from_config(SamplerChoice::Shuffle, 4, 0.01, 0).unwrap();
        assert_eq!(tiny.sample(0).len(), 1);
        assert!(AnySampler::from_config(SamplerChoice::Shuffle, 0, 0.5, 0).is_err());
    }

    #[test]
    fn shuffle_partitions_epoch() {
        let s = ShuffleSampler::new(100, 10, 5);
        let mut seen: Vec<u32> = (0..10).flat_map(|t| s.sample(t)).collect();
        seen.sort_unstable();
        let want: Vec<u32> = (0..100).collect();
        assert_eq!(seen, want);
        assert!(s.poisson_rate().is_none());
    }
}
