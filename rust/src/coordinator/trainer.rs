//! The training-loop driver: virtual-batching DP-SGD (Algorithms 1 & 2)
//! over any execution [`Backend`](crate::runtime::Backend), with
//! per-section timing and optional data-parallel execution.
//!
//! The hot loop lives in one place: the step-driven [`TrainSession`].
//! A session binds one [`ExecSession`] per worker (params + gradient
//! accumulator owned by each backend session for the whole run — the
//! `donate_argnums` analogue) and exposes:
//!
//! * [`TrainSession::step`] — one optimizer step:
//!   1. **sample**  — one *global* draw from the configured
//!                    [`AnySampler`] (Poisson by default); never
//!                    per-rank subsampling, whatever `workers` is
//!   2. **plan**    — decompose into accumulation groups
//!                    ([`plan_groups`]): `physical_batch`-aligned
//!                    slices of the logical batch (masked mode =
//!                    Algorithm 2 full shapes, variable mode = naive
//!                    JAX chunking *within* each group)
//!   3. **accum**   — shard the groups contiguously across the worker
//!                    sessions ([`run_groups`]); each group folds a
//!                    partial accumulator from zero (fwd + per-example
//!                    bwd + clip + accumulate)
//!   4. **reduce**  — combine the partials with the fixed-shape binary
//!                    tree ([`reduce_fixed_tree`]) whose pairing
//!                    depends only on the group count, and install the
//!                    sum on rank 0 (`write_acc`)
//!   5. **apply**   — rank 0 runs `apply` (noise + SGD step) and
//!                    broadcasts the new parameters to the other ranks
//!                    through the `read_params`/`write_params` seam
//!   6. **account** — record the (q, sigma) step; epsilon is reported
//!                    by the configured accountant (RDP streaming, or
//!                    PLD priced at finish)
//! * [`TrainSession::eval`] — held-out evaluation at the current
//!   parameters (mid-run cadence or final; rank 0 only).
//! * [`TrainSession::checkpoint`] / [`TrainSession::resume`] — the
//!   save → drop → load → resume seam; a resumed session is
//!   bitwise-identical to an uninterrupted run (property-tested in
//!   `rust/tests/session_api.rs`).
//! * [`TrainSession::finish`] — close out into a [`TrainReport`].
//!
//! Because the group decomposition and the reduction tree are pure
//! functions of the sampled batch and the configuration — never of the
//! worker count — the whole trajectory (parameters, losses, epsilon)
//! is **bitwise-identical for every `workers` value** (DESIGN.md §8;
//! property-tested in `rust/tests/parallel_train.rs`). `workers` is
//! therefore a wall-clock knob like the kernel thread count, and is
//! excluded from the checkpoint fingerprint.
//!
//! [`Trainer::run`] is a thin loop over a session; the bench entry
//! points (`bench_accum`/`bench_apply`) and `benchreport.rs` drive the
//! same session hot path, so there is exactly one copy of the loop.
//!
//! The per-section breakdown is this codebase's analogue of the
//! paper's Nsight profile (Table 2); compile time is tracked
//! separately (Fig. A.2) and excluded from throughput, mirroring how the
//! paper discounts JAX compilation when comparing steady-state rates.
//! Every compile this loop causes — accum, apply, *and eval* — is
//! attributed to `SectionTimes::compile` from the single
//! `Prepared::compile_seconds` lookup. Section times sum each call's
//! seconds across workers, so with `workers > 1` they are aggregate
//! worker-seconds (the `time(1)` "user" view), not wall-clock —
//! wall-clock scaling is what `dpshort bench --workers` measures.

#![warn(missing_docs)]

use crate::cluster::parallel::{
    plan_groups, reduce_fixed_tree, run_groups, ChunkRun, RecoveryEvent,
};
use crate::coordinator::batcher::{BatchingMode, PhysicalBatch};
use crate::coordinator::config::TrainConfig;
use crate::coordinator::sampler::{AnySampler, Sampler};
use crate::data::SyntheticDataset;
use crate::fault::FaultPlan;
use crate::metrics::{Quantiles, Summary, ThroughputMeter};
use crate::privacy::rdp::StreamingAccountant;
use crate::privacy::{calibrate_sigma, pld_epsilon, AccountantKind, RdpAccountant};
use crate::runtime::{
    AccumArgs, ApplyArgs, ExecSession, ModelRuntime, Prepared, Runtime, Tensor,
};
use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Full-width per-step noise seed: the high 32 bits are a per-experiment
/// stream id (ChaCha20-derived, the same domain separation the samplers
/// use), the low 32 bits the step counter.
///
/// The old derivation `(seed * 1_000_003 + step) as i32` wrapped through
/// 32 bits and could collide between steps — silently reusing Gaussian
/// noise between optimizer steps, which voids the privacy analysis
/// (noise must be independent across compositions). The structured
/// layout guarantees what the analysis needs: **within one run the seed
/// is injective in `step`** (for the < 2^32 steps any run takes), and it
/// stays injective even after the PJRT backend folds it into the ABI's
/// 32-bit seed slot (xor of the halves = stream-id ^ step, a bijection
/// in `step`). Across *different* experiment seeds the 32-bit stream id
/// collides with probability 2^-32 per pair — harmless for DP (each
/// run's composition uses independent noise) but worth knowing when
/// comparing runs.
pub fn per_step_noise_seed(experiment_seed: u64, step: u64) -> u64 {
    debug_assert!(step < 1u64 << 32, "runs are bounded far below 2^32 steps");
    let mut rng = ChaChaRng::from_seed_stream(experiment_seed, 0, b"noisesd\0");
    let stream_id = rng.next_u32() as u64;
    (stream_id << 32) | (step & 0xffff_ffff)
}

/// Wall-clock seconds per pipeline section (the Table-2 analogue).
///
/// Each call's seconds are summed wherever it ran, so with
/// data-parallel `workers > 1` the `data`/`accum` sections are
/// aggregate worker-seconds (the `time(1)` "user" view), not elapsed
/// wall-clock.
#[derive(Debug, Clone, Copy, Default, Serialize, Deserialize)]
pub struct SectionTimes {
    /// Poisson sampling + batch splitting (host).
    pub sampling: f64,
    /// Synthetic-data materialization (the "data loading" stand-in).
    pub data: f64,
    /// accum executions (forward + backward + clip + accumulate).
    pub accum: f64,
    /// apply executions (noise + optimizer step).
    pub apply: f64,
    /// Executable compilations (jit analogue; excluded from throughput).
    pub compile: f64,
}

impl SectionTimes {
    /// Total training-loop seconds (every section except compile —
    /// the throughput denominator).
    pub fn training_total(&self) -> f64 {
        self.sampling + self.data + self.accum + self.apply
    }
}

/// One optimizer step's log entry.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct StepLog {
    /// Step index (0-based).
    pub step: u64,
    /// True sampled logical batch size (varies under Poisson!).
    pub logical_batch: usize,
    /// Number of physical batches executed (including padded ones).
    pub physical_batches: usize,
    /// Examples computed including Algorithm-2 padding.
    pub computed_examples: usize,
    /// Mean training loss over the real examples of this step.
    pub loss: f64,
}

/// Result of a training run.
#[derive(Debug, Serialize)]
pub struct TrainReport {
    /// Model name the run trained.
    pub model: String,
    /// Clipping variant
    /// (nonprivate | naive | masked | ghost | bk | perex | mix).
    pub variant: String,
    /// Batching mode the run used (Algorithm 2 vs naive).
    pub mode: BatchingMode,
    /// Resolved noise multiplier sigma (0 for the non-private baseline).
    pub noise_multiplier: f64,
    /// Epsilon spent over the run's compositions at `delta`.
    pub epsilon_spent: f64,
    /// Privacy parameter delta of the accounting.
    pub delta: f64,
    /// Accountant that priced `epsilon_spent` (`rdp` | `pld`).
    pub accountant: String,
    /// Per-step logs, in step order (resumed steps included).
    pub steps: Vec<StepLog>,
    /// Per-section timing breakdown (see [`SectionTimes`]).
    pub sections: SectionTimes,
    /// Real examples per second over sample+data+accum+apply time.
    pub throughput: f64,
    /// Including Algorithm-2 padding (the "wasted" gradient computation).
    pub computed_throughput: f64,
    /// Per-accum-call throughput samples (for bootstrap CIs).
    pub accum_samples: Vec<f64>,
    /// Aggregate accum throughput: real examples / total accum seconds
    /// (the [`ThroughputMeter`] view the hot loop feeds).
    pub accum_throughput_aggregate: f64,
    /// Median + bootstrap 95% CI over the per-accum-call samples
    /// (`None` when no accum call produced a timed sample).
    pub accum_throughput: Option<Summary>,
    /// Deterministic nearest-rank p50/p95/p99 over the same per-call
    /// samples (`None` when no sample exists) — the serve bench rows
    /// report the identical estimator over slice latencies.
    pub accum_quantiles: Option<Quantiles>,
    /// Mean held-out loss, when evaluation ran.
    pub eval_loss: Option<f64>,
    /// Held-out accuracy in [0, 1], when evaluation ran.
    pub eval_accuracy: Option<f64>,
    /// Held-out examples the eval metrics actually averaged over. The
    /// eval executable has a fixed AOT batch size, so a request that is
    /// not a multiple of it can only cover `floor(requested / eb) * eb`
    /// examples — this field makes that coverage exact instead of
    /// silently pretending the tail was evaluated.
    pub eval_covered: u32,
    /// (artifact, seconds) for every compilation this run caused.
    pub compiles: Vec<(String, f64)>,
    /// True when the run executed with `--allow-unsound` past Deny
    /// audit diagnostics (or resumed from a checkpoint that did): the
    /// reported epsilon carries no static-audit backing.
    pub unaudited: bool,
    /// Every fault-recovery action the run took (failed groups re-run
    /// on surviving ranks, apply retries, permanently lost ranks —
    /// DESIGN.md §11). Empty for a clean run; recovery never changes
    /// the trajectory, so a non-empty log with the same final params is
    /// the expected signature of a survived fault.
    pub recovery_events: Vec<RecoveryEvent>,
    /// Worker sessions still alive at finish: `config.workers` minus
    /// permanently lost ranks (a degraded-but-completed run reports
    /// fewer than it started with).
    pub final_workers: usize,
    /// Flat parameter vector after the final step (checkpointable via
    /// [`ModelRuntime::save_params`]).
    pub final_params: Vec<f32>,
}

impl TrainReport {
    /// Serialize the whole report (steps, sections, privacy, params).
    pub fn to_json(&self) -> serde_json::Result<String> {
        serde_json::to_string_pretty(self)
    }
}

/// Portable mid-run state of a [`TrainSession`] — everything a fresh
/// process needs to continue a run bitwise-identically: the step
/// counter, the flat parameter vector (via the session's `read_params`
/// checkpoint seam), and the completed step logs. Sampling, per-step
/// noise seeds, and the accountant replay all re-derive from
/// `(TrainConfig, step)`, so they need no state here.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct TrainCheckpoint {
    /// Fingerprint of every config field that shapes the trajectory
    /// (model/variant/mode/dtype, dataset size, sampling rate, physical
    /// batch, lr, clip norm, resolved sigma, seed). [`TrainSession::resume`]
    /// rejects a checkpoint whose fingerprint does not match the config
    /// it is resumed under — otherwise the accountant would replay the
    /// completed compositions at the *new* `(q, sigma)` and silently
    /// mis-report epsilon (a DP-correctness violation, not a nuisance).
    pub fingerprint: String,
    /// Optimizer steps already taken.
    pub step: u64,
    /// Flat parameter vector after `step` steps.
    pub params: Vec<f32>,
    /// Per-step logs of the completed steps (so the finished report is
    /// identical to an uninterrupted run's).
    pub steps: Vec<StepLog>,
    /// The run that took this checkpoint executed past Deny audit
    /// diagnostics (`--allow-unsound`). Sticky: resuming propagates it
    /// into the session and the final report. `serde(default)` keeps
    /// pre-audit checkpoints loading (they audited clean or predate
    /// the auditor).
    #[serde(default)]
    pub unaudited: bool,
    /// FNV-1a-64 content checksum (hex) over every other field — the
    /// crash-consistency seal (fingerprint `v5`, DESIGN.md §11). A torn
    /// or bit-rotted file that still parses as JSON fails this check at
    /// resume instead of silently continuing a corrupted trajectory.
    /// [`TrainSession::checkpoint`] always seals; `serde(default)`
    /// (empty = unsealed) keeps hand-built and pre-`v5` checkpoints
    /// loading. After mutating a checkpoint in tests, re-seal with
    /// [`Self::seal`].
    #[serde(default)]
    pub checksum: String,
}

/// One FNV-1a-64 absorption step.
fn fnv1a64(hash: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *hash ^= b as u64;
        *hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

impl TrainCheckpoint {
    /// Serialize to compact JSON (exact: serde's ryu formatting
    /// round-trips every finite float bit-for-bit).
    pub fn to_json(&self) -> serde_json::Result<String> {
        serde_json::to_string(self)
    }

    /// Parse a checkpoint serialized by [`Self::to_json`].
    pub fn from_json(text: &str) -> serde_json::Result<Self> {
        serde_json::from_str(text)
    }

    /// Compute the content checksum over every field except `checksum`
    /// itself: fingerprint, step counter, parameter bits, step logs,
    /// and the unaudited stamp, each length-prefixed or separated so
    /// distinct contents can never collide by concatenation.
    pub fn content_checksum(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        fnv1a64(&mut h, self.fingerprint.as_bytes());
        fnv1a64(&mut h, &[0xff]);
        fnv1a64(&mut h, &self.step.to_le_bytes());
        fnv1a64(&mut h, &(self.params.len() as u64).to_le_bytes());
        for p in &self.params {
            fnv1a64(&mut h, &p.to_bits().to_le_bytes());
        }
        fnv1a64(&mut h, &(self.steps.len() as u64).to_le_bytes());
        for s in &self.steps {
            fnv1a64(&mut h, &s.step.to_le_bytes());
            fnv1a64(&mut h, &(s.logical_batch as u64).to_le_bytes());
            fnv1a64(&mut h, &(s.physical_batches as u64).to_le_bytes());
            fnv1a64(&mut h, &(s.computed_examples as u64).to_le_bytes());
            fnv1a64(&mut h, &s.loss.to_bits().to_le_bytes());
        }
        fnv1a64(&mut h, &[u8::from(self.unaudited)]);
        format!("{h:016x}")
    }

    /// Stamp `checksum` with the current content checksum.
    pub fn seal(&mut self) {
        self.checksum = self.content_checksum();
    }

    /// Does the stored checksum match the content? Unsealed checkpoints
    /// (empty checksum: hand-built, or pre-`v5` — which the fingerprint
    /// check rejects anyway) pass vacuously.
    pub fn checksum_ok(&self) -> bool {
        self.checksum.is_empty() || self.checksum == self.content_checksum()
    }
}

/// Resolve the noise multiplier for a config: explicit, or calibrated
/// to the (epsilon, delta) target (paper Table A2 style). Public so
/// `dpshort audit` prices the plan with exactly the sigma the trainer
/// will execute.
pub fn resolve_sigma(config: &TrainConfig) -> Result<f64> {
    if !config.is_private() {
        return Ok(0.0);
    }
    match config.noise_multiplier {
        Some(s) => Ok(s),
        None => calibrate_sigma(
            config.target_epsilon,
            config.delta,
            config.sampling_rate,
            config.steps,
        )
        .map_err(|e| anyhow!(e)),
    }
}

fn dtype_of(config: &TrainConfig) -> &'static str {
    if config.bf16 {
        "bf16"
    } else {
        "f32"
    }
}

/// The trajectory-shaping identity of a run, for checkpoint/resume
/// validation. `{:?}` on the floats is the shortest round-trip (ryu)
/// form, so distinct values never collide through formatting.
///
/// Deliberately **excludes** `workers` (and the kernel thread count):
/// both are wall-clock knobs whose trajectories are bitwise-identical,
/// so a checkpoint taken at 4 workers must resume at 1 (and vice
/// versa). The accountant is likewise excluded: it changes the
/// *reported* epsilon, never a sampled batch or parameter bit. Tag
/// history: `v2` redefined the step's accumulation semantics
/// (fixed-tree group reduction, DESIGN.md §8); `v3` is the layered
/// model IR (DESIGN.md §9) — the flat parameter vector is now laid out
/// by the model's `LayerPlan` (per-layer `[W | b]` blocks) and the
/// variant set grew the executed `perex`/`mix` graphs, so a `v2`
/// checkpoint's params may describe a different layout and must not
/// silently continue under the new one; `v4` adds the sampler choice —
/// shuffle and Poisson draw *different logical batches* from the same
/// seed, so a checkpoint must never resume under the other scheme;
/// `v5` is the crash-consistency generation — checkpoints carry a
/// content checksum ([`TrainCheckpoint::seal`]) and are written
/// atomically (`crate::fault::checkpoint`), so a `v4` file, which no
/// checksum ever protected, does not resume under the new contract;
/// `v6` extends the layer IR to non-dense kinds (conv2d / layernorm /
/// attention, DESIGN.md §13) — the flat parameter layout of a model
/// name can now contain kind-shaped blocks a `v5` build never laid
/// out, so cross-generation resumes must fail the fingerprint check;
/// `v7` makes `--param-dtype bf16` an *executed* storage mode — the
/// bf16 apply executable re-quantizes parameter storage after every
/// update and the session quantizes the initial parameters, so a `v6`
/// bf16 checkpoint (whose params were full-precision f32 under the
/// same dtype tag) would continue a different trajectory and must not
/// resume. The kernel selection (`--kernel`) is excluded like
/// `workers`: scalar and SIMD paths are bitwise-identical by
/// construction (DESIGN.md §14).
///
/// Public so the `--resume-latest` scanner and the audit tooling can
/// compute the fingerprint a config will demand without opening a
/// session.
pub fn config_fingerprint(config: &TrainConfig, sigma: f64) -> String {
    format!(
        "v7|{}|{}|{:?}|{}|N={}|q={:?}|B={}|lr={:?}|C={:?}|sigma={:?}|seed={}|sampler={}",
        config.model,
        config.variant,
        config.mode,
        dtype_of(config),
        config.dataset_size,
        config.sampling_rate,
        config.physical_batch,
        config.lr,
        config.clip_norm,
        sigma,
        config.seed,
        config.sampler.as_str(),
    )
}

fn training_dataset(config: &TrainConfig, model: &ModelRuntime) -> SyntheticDataset {
    SyntheticDataset::new(
        config.dataset_size,
        model.meta().num_classes as u32,
        model.meta().image,
        model.meta().channels,
        config.seed,
    )
}

fn held_out_dataset(config: &TrainConfig, model: &ModelRuntime, examples: u32) -> SyntheticDataset {
    SyntheticDataset::new(
        config.dataset_size + examples,
        model.meta().num_classes as u32,
        model.meta().image,
        model.meta().channels,
        config.seed,
    )
}

/// Drives configured training/bench runs over the runtime. Thin: the
/// hot loop is [`TrainSession`]; this type owns the config + dataset
/// and hands out sessions.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    model: ModelRuntime,
    config: TrainConfig,
    dataset: SyntheticDataset,
}

impl<'rt> Trainer<'rt> {
    /// Build a trainer for `config` over `runtime` (resolves the model
    /// view and synthesizes the training dataset).
    pub fn new(runtime: &'rt Runtime, config: TrainConfig) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = training_dataset(&config, &model);
        Ok(Self { runtime, model, config, dataset })
    }

    /// The model view this trainer drives.
    pub fn model(&self) -> &ModelRuntime {
        &self.model
    }

    /// Resolve the noise multiplier: explicit, or calibrated to the
    /// (epsilon, delta) target (paper Table A2 style).
    pub fn resolve_sigma(&self) -> Result<f64> {
        resolve_sigma(&self.config)
    }

    fn dtype(&self) -> &'static str {
        dtype_of(&self.config)
    }

    /// Open a fresh step-driven session for this configuration. The
    /// trainer's already-built model view and dataset are handed to the
    /// session (clones are cheap: the dataset's class patterns are the
    /// only real payload and the backend rides the shared `Arc`).
    pub fn session(&self) -> Result<TrainSession<'rt>> {
        TrainSession::build(
            self.runtime,
            self.config.clone(),
            self.model.clone(),
            self.dataset.clone(),
            None,
            None,
        )
    }

    /// Run the configured number of optimizer steps: a thin loop over
    /// one [`TrainSession`].
    pub fn run(&self) -> Result<TrainReport> {
        let mut session = self.session()?;
        while !session.done() {
            session.step()?;
        }
        session.finish()
    }

    /// Steady-state accum throughput sweep for one (variant, batch):
    /// `repeats` timed executions of the same compiled executable on
    /// fresh data, through the session hot path (bound buffers, zero
    /// per-call P-length copies) — the measurement behind Figures
    /// 1/2/4/6. Returns examples/second per call.
    pub fn bench_accum(
        &self,
        variant: &str,
        batch: usize,
        repeats: usize,
    ) -> Result<Vec<f64>> {
        let prep = self.model.prepare_accum(variant, batch, self.dtype())?;
        let mut sess = self.model.open_session(self.model.init_params()?)?;
        let mask = vec![1.0f32; batch];
        let mut samples = Vec::with_capacity(repeats);
        for r in 0..repeats {
            let idx: Vec<u32> = (0..batch)
                .map(|i| bench_index(r, batch, i, self.config.dataset_size))
                .collect();
            let (x, y) = self.dataset.batch(&idx);
            // Re-zero the bound accumulator outside the timed region
            // so every call measures the same accumulate workload.
            sess.zero_acc()?;
            let t = Instant::now();
            let _ = sess.accum(&prep, &AccumArgs { x: &x, y: &y, mask: &mask })?;
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.0 {
                samples.push(batch as f64 / dt);
            }
        }
        Ok(samples)
    }

    /// Steady-state apply throughput: `repeats` timed executions of the
    /// noisy step through the session hot path, with the Gaussian path
    /// exercised (`noise_mult = 1`) and `lr = 0` so the parameters stay
    /// put across repeats. Returns calls/second per call.
    pub fn bench_apply(&self, repeats: usize) -> Result<Vec<f64>> {
        let prep = self.model.prepare_apply_dtype(self.dtype())?;
        let mut sess = self.model.open_session(self.model.init_params()?)?;
        let mut samples = Vec::with_capacity(repeats);
        for r in 0..repeats {
            let seed = per_step_noise_seed(self.config.seed, r as u64);
            let args = ApplyArgs { seed, denom: 1.0, lr: 0.0, noise_mult: 1.0 };
            let t = Instant::now();
            sess.apply(&prep, &args)?;
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.0 {
                samples.push(1.0 / dt);
            }
        }
        Ok(samples)
    }
}

/// A resumable, step-driven training run over a bound-buffer
/// [`ExecSession`]. See the module docs for the step anatomy.
///
/// The exec session's lifetime is tied to the [`Runtime`] (not to the
/// owned [`ModelRuntime`] view), which is what lets this struct own its
/// model view, config, and dataset while borrowing only the runtime.
pub struct TrainSession<'rt> {
    runtime: &'rt Runtime,
    model: ModelRuntime,
    config: TrainConfig,
    dataset: SyntheticDataset,
    /// Held-out eval dataset, synthesized once on the first eval call
    /// (mid-run eval cadence must not re-generate the class patterns
    /// per call).
    held_out: Option<SyntheticDataset>,
    /// Rank 0: the session that applies the noisy update and serves
    /// eval/checkpoint.
    exec: Box<dyn ExecSession + 'rt>,
    /// Ranks 1..workers — each is owned by one worker thread during the
    /// accumulation phase of a step and receives the parameter
    /// broadcast after every apply.
    peers: Vec<Box<dyn ExecSession + 'rt>>,
    sampler: AnySampler,
    /// Batch sizes lowered for (variant, dtype) — the Variable-mode
    /// chunking menu.
    available: Vec<usize>,
    /// True when the plan audit raised Deny diagnostics and the run was
    /// forced through with `allow_unsound`, or when resuming from a
    /// checkpoint that was stamped unaudited. Sticky: propagated into
    /// every checkpoint and the final report.
    unaudited: bool,
    apply_prep: Prepared,
    accountant: StreamingAccountant,
    sections: SectionTimes,
    meter: ThroughputMeter,
    steps_log: Vec<StepLog>,
    sigma: f64,
    denom: f32,
    noise_mult: f32,
    /// Next step index (== number of steps taken, counting resumed-over
    /// ones).
    step: u64,
    /// Compile-record count at session open, for the report's compile
    /// attribution slice.
    compiled_before: usize,
    /// Step-log entries restored from a checkpoint (0 for a fresh
    /// session). Those steps carry no section time in this process, so
    /// throughput denominators must exclude them.
    restored_steps: usize,
    /// Deterministic fault-injection plan, when this session runs over
    /// a fault-wrapped runtime ([`crate::fault::faulty_runtime`]). The
    /// session's only duty is announcing the step counter to the plan
    /// so injection sites fire at their planned `(step, rank, call)`.
    fault_plan: Option<Arc<FaultPlan>>,
    /// Recovery actions this process took (group re-runs, apply
    /// retries, lost ranks); drained into the final report.
    recovery: Vec<RecoveryEvent>,
}

impl<'rt> TrainSession<'rt> {
    /// Open a fresh session at step 0 with the backend's initial
    /// parameters.
    pub fn new(runtime: &'rt Runtime, config: TrainConfig) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = training_dataset(&config, &model);
        Self::build(runtime, config, model, dataset, None, None)
    }

    /// Open a fresh session over a fault-wrapped runtime
    /// ([`crate::fault::faulty_runtime`] built from the same `plan`).
    /// The session announces each step to the plan so injection sites
    /// fire at their planned `(step, rank, call)` coordinates. Rank ids
    /// follow session-open order (rank 0 = the apply session), so build
    /// at most one session per fault-wrapped runtime.
    pub fn with_faults(
        runtime: &'rt Runtime,
        config: TrainConfig,
        plan: Arc<FaultPlan>,
    ) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = training_dataset(&config, &model);
        Self::build(runtime, config, model, dataset, None, Some(plan))
    }

    /// Reopen a session from a [`TrainCheckpoint`]: parameters are
    /// written back through the session's resume seam, the privacy
    /// accountant replays the completed steps, and stepping continues
    /// at `checkpoint.step` — bitwise-identical to never having
    /// stopped. Wall-clock sections and throughput meters restart at
    /// zero (they describe this process's work, not the whole run).
    pub fn resume(
        runtime: &'rt Runtime,
        config: TrainConfig,
        checkpoint: TrainCheckpoint,
    ) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = training_dataset(&config, &model);
        Self::build(runtime, config, model, dataset, Some(checkpoint), None)
    }

    /// [`Self::resume`] over a fault-wrapped runtime (see
    /// [`Self::with_faults`]).
    pub fn resume_with_faults(
        runtime: &'rt Runtime,
        config: TrainConfig,
        checkpoint: TrainCheckpoint,
        plan: Arc<FaultPlan>,
    ) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = training_dataset(&config, &model);
        Self::build(runtime, config, model, dataset, Some(checkpoint), Some(plan))
    }

    fn build(
        runtime: &'rt Runtime,
        config: TrainConfig,
        model: ModelRuntime,
        dataset: SyntheticDataset,
        start: Option<TrainCheckpoint>,
        fault_plan: Option<Arc<FaultPlan>>,
    ) -> Result<Self> {
        let sigma = resolve_sigma(&config)?;
        // The group grid divides the logical batch by this (previously
        // asserted by the BatchMemoryManager constructor): fail at
        // session construction, not with a panic mid-step.
        if config.physical_batch == 0 {
            return Err(anyhow!("physical batch size must be positive"));
        }
        let sampler = AnySampler::from_config(
            config.sampler,
            config.dataset_size,
            config.sampling_rate,
            config.seed,
        )?;
        let available = model.accum_batches(&config.variant, dtype_of(&config));
        if available.is_empty() {
            return Err(anyhow!(
                "no accum artifacts for {} variant={} dtype={}",
                config.model,
                config.variant,
                dtype_of(&config)
            ));
        }

        // Static plan audit (DESIGN.md §10): the run must prove — before
        // any example is touched — that per-example gradients cross into
        // shared state only through the global clip, that noise lands
        // exactly once post-aggregation at sigma*C, that RNG streams are
        // disjoint, and that the accountant matches the sampler. Deny
        // diagnostics abort construction unless `--allow-unsound`, which
        // instead stamps the report and every checkpoint.
        let audit =
            crate::analysis::audit_run(model.meta(), runtime.manifest().seed, &config, sigma)?;
        let audit_unaudited = if audit.deny_rules().is_empty() {
            false
        } else if config.allow_unsound {
            true
        } else {
            return Err(anyhow!(
                "plan audit rejected this run ({}); run `dpshort audit` for details \
                 or pass --allow-unsound to proceed with an unaudited stamp",
                audit.deny_rules().join(", ")
            ));
        };

        let mut sections = SectionTimes::default();
        let compiled_before = runtime.compile_records().len();
        // Pre-compile the fixed-shape executables (apply + the masked
        // accum shape) so their one-time compile cost lands in
        // `sections.compile`, not in the steady-state sections — the
        // same discount the paper applies to JAX compilation.
        if config.mode == BatchingMode::Masked {
            let prep =
                model.prepare_accum(&config.variant, config.physical_batch, dtype_of(&config))?;
            sections.compile += prep.compile_seconds.unwrap_or(0.0);
        }
        // The apply executable is dtype-selected: the bf16 variant
        // re-quantizes parameter storage after the f32 update
        // (`--param-dtype bf16`, DESIGN.md §14).
        let apply_prep = model.prepare_apply_dtype(dtype_of(&config))?;
        sections.compile += apply_prep.compile_seconds.unwrap_or(0.0);

        let mut accountant = StreamingAccountant::new(RdpAccountant::default());
        let (step, steps_log, params, restored_unaudited) = match start {
            None => {
                let t0 = Instant::now();
                let p = model.init_params()?;
                sections.data += t0.elapsed().as_secs_f64();
                (0, Vec::new(), p, false)
            }
            Some(ckpt) => {
                // Checksum before anything else: a torn or bit-rotted
                // file must surface as corruption, not as whichever
                // downstream validation its damage happens to trip.
                if !ckpt.checksum_ok() {
                    return Err(anyhow!(
                        "checkpoint failed its content checksum (stored {}, computed {}): \
                         torn or corrupted file",
                        ckpt.checksum,
                        ckpt.content_checksum()
                    ));
                }
                let want = config_fingerprint(&config, sigma);
                if ckpt.fingerprint != want {
                    return Err(anyhow!(
                        "checkpoint was taken under a different configuration \
                         (checkpoint {:?}, resume config {:?}); resuming would \
                         mis-replay the privacy accounting",
                        ckpt.fingerprint,
                        want
                    ));
                }
                if ckpt.step > config.steps {
                    return Err(anyhow!(
                        "checkpoint is already past this config: step {} > steps {}",
                        ckpt.step,
                        config.steps
                    ));
                }
                if ckpt.params.len() != model.n_params() {
                    return Err(anyhow!(
                        "checkpoint params length {} != n_params {}",
                        ckpt.params.len(),
                        model.n_params()
                    ));
                }
                // A truncated/edited checkpoint would otherwise resume
                // with accountant, step logs, and throughput all
                // disagreeing about how many steps happened.
                if ckpt.steps.len() as u64 != ckpt.step {
                    return Err(anyhow!(
                        "checkpoint is inconsistent: step counter {} but {} step logs",
                        ckpt.step,
                        ckpt.steps.len()
                    ));
                }
                // Replay the completed compositions so epsilon_spent at
                // finish() equals the uninterrupted run's.
                if config.is_private() && sigma > 0.0 {
                    for _ in 0..ckpt.step {
                        accountant.record_step(config.sampling_rate, sigma);
                    }
                }
                (ckpt.step, ckpt.steps, Tensor::from_vec(ckpt.params), ckpt.unaudited)
            }
        };
        // bf16 storage mode: parameters live quantized from step 0.
        // A bf16 checkpoint's params are already quantized (the apply
        // executable re-quantizes every step), so this is a no-op on
        // resume — quantization is idempotent.
        let mut params = params;
        if config.bf16 {
            params.quantize_bf16();
        }
        // The sessions own params + accumulator from here on (the
        // donate_argnums analogue). Rank 0 is the apply/eval/checkpoint
        // session; ranks 1.. are the data-parallel peers, opened from
        // the same shared backend with the same starting parameters
        // (the step loop re-broadcasts after every apply). Open order
        // is rank order: a fault-wrapped backend assigns injection
        // rank ids as sessions open, and rank 0 must be `exec`.
        let workers = config.workers.max(1);
        let exec = runtime.open_session(&config.model, params.clone())?;
        let mut peers = Vec::with_capacity(workers - 1);
        for _ in 1..workers {
            peers.push(runtime.open_session(&config.model, params.clone())?);
        }

        // denom = E[L] (Algorithm 1's 1/|L| with the expected batch — the
        // standard Opacus convention). Only the degenerate q = 0 case is
        // substituted (1.0, keeping noise-only steps well-defined);
        // fractional E[L] < 1 is a legitimate divisor and passes through.
        let expected = config.expected_logical_batch() as f32;
        let denom = if expected > 0.0 { expected } else { 1.0 };
        let noise_mult = (sigma * config.clip_norm) as f32;
        let restored_steps = steps_log.len();

        Ok(Self {
            runtime,
            model,
            config,
            dataset,
            held_out: None,
            exec,
            peers,
            sampler,
            available,
            unaudited: audit_unaudited || restored_unaudited,
            apply_prep,
            accountant,
            sections,
            meter: ThroughputMeter::new(),
            steps_log,
            sigma,
            denom,
            noise_mult,
            step,
            compiled_before,
            restored_steps,
            fault_plan,
            recovery: Vec::new(),
        })
    }

    /// The model view this session drives (checkpoint file helpers,
    /// artifact queries).
    pub fn model(&self) -> &ModelRuntime {
        &self.model
    }

    /// The configuration this session runs.
    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    /// Resolved noise multiplier for this run.
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Next step index (== optimizer steps completed so far, counting
    /// steps a checkpoint resumed over).
    pub fn step_index(&self) -> u64 {
        self.step
    }

    /// True once the configured number of steps has run. [`Self::step`]
    /// may be driven past this — the config's step count bounds
    /// [`Trainer::run`], not the session.
    pub fn done(&self) -> bool {
        self.step >= self.config.steps
    }

    /// Sections timed so far (compile/sampling/data/accum/apply).
    pub fn sections(&self) -> SectionTimes {
        self.sections
    }

    /// Epsilon spent so far at the configured delta (mid-run budget
    /// monitoring). Matches the finished report's accounting: the RDP
    /// accountant composes streamingly; PLD re-prices the completed
    /// step count on every call (both analyse the same
    /// Poisson-subsampled Gaussian mechanism, so the step counts agree
    /// by construction).
    pub fn epsilon_spent(&self) -> f64 {
        if !self.config.is_private() {
            return 0.0;
        }
        if self.sigma <= 0.0 {
            return f64::INFINITY;
        }
        match self.config.accountant {
            AccountantKind::Rdp => self.accountant.epsilon(self.config.delta),
            AccountantKind::Pld => {
                let steps = self.accountant.steps();
                if steps == 0 {
                    0.0
                } else {
                    pld_epsilon(
                        self.config.sampling_rate,
                        self.sigma,
                        steps as u32,
                        self.config.delta,
                    )
                }
            }
        }
    }

    /// Was this run (or any run in its checkpoint chain) forced past a
    /// Deny-severity plan audit with `--allow-unsound`?
    pub fn unaudited(&self) -> bool {
        self.unaudited
    }

    /// Copy the current parameters out of the session (the checkpoint
    /// seam — a device-to-host transfer on a device-resident backend).
    pub fn read_params(&self) -> Result<Tensor> {
        self.exec.read_params()
    }

    /// Replace the session's parameters (the resume/warm-start seam).
    /// Broadcast to every rank, so a warm start behaves identically at
    /// any worker count.
    pub fn write_params(&mut self, params: Tensor) -> Result<()> {
        for peer in &mut self.peers {
            peer.write_params(params.clone())?;
        }
        self.exec.write_params(params)
    }

    /// Number of data-parallel worker sessions this run drives
    /// (`config.workers` floored at 1, minus permanently lost ranks).
    pub fn workers(&self) -> usize {
        self.peers.len() + 1
    }

    /// Recovery actions taken so far (group re-runs, apply retries,
    /// lost ranks); the final report carries the same list.
    pub fn recovery_events(&self) -> &[RecoveryEvent] {
        &self.recovery
    }

    /// Retire permanently lost ranks and continue on the smaller pool.
    /// Bitwise-sound mid-step: during the accumulation phase every
    /// session holds the identical pre-apply parameters (the broadcast
    /// invariant), and the reduced accumulator is installed through
    /// `write_acc` before apply — so when rank 0 itself is lost, the
    /// first surviving peer is promoted and produces exactly the bits
    /// rank 0 would have.
    fn degrade(&mut self, lost: &[usize]) -> Result<()> {
        let lost: std::collections::BTreeSet<usize> = lost.iter().copied().collect();
        let peers = std::mem::take(&mut self.peers);
        let mut survivors: Vec<Box<dyn ExecSession + 'rt>> = Vec::with_capacity(peers.len());
        for (i, p) in peers.into_iter().enumerate() {
            if !lost.contains(&(i + 1)) {
                survivors.push(p);
            }
        }
        if lost.contains(&0) {
            // run_groups only returns Ok while at least one rank
            // survives, so a promotion candidate exists; keep the
            // invariant checked anyway.
            if survivors.is_empty() {
                return Err(anyhow!(
                    "rank 0 lost at step {} with no surviving peer to promote",
                    self.step
                ));
            }
            let promoted = survivors.remove(0);
            drop(std::mem::replace(&mut self.exec, promoted));
        }
        self.peers = survivors;
        Ok(())
    }

    /// Snapshot the resumable state: step counter, parameters, and the
    /// completed step logs. Serialize with
    /// [`TrainCheckpoint::to_json`]; reopen with [`Self::resume`].
    ///
    /// Refuses to snapshot a diverged run: JSON has no NaN/inf, so
    /// serde would silently write `null`s that only fail at resume —
    /// surfacing the corruption at save time instead.
    pub fn checkpoint(&self) -> Result<TrainCheckpoint> {
        let params = self.exec.read_params()?.into_vec();
        if params.iter().any(|p| !p.is_finite()) {
            return Err(anyhow!(
                "refusing to checkpoint non-finite parameters (diverged run); \
                 JSON cannot represent NaN/inf"
            ));
        }
        if self.steps_log.iter().any(|s| !s.loss.is_finite()) {
            return Err(anyhow!(
                "refusing to checkpoint non-finite step losses (diverged run); \
                 JSON cannot represent NaN/inf"
            ));
        }
        let mut ckpt = TrainCheckpoint {
            fingerprint: config_fingerprint(&self.config, self.sigma),
            step: self.step,
            params,
            steps: self.steps_log.clone(),
            unaudited: self.unaudited,
            checksum: String::new(),
        };
        ckpt.seal();
        Ok(ckpt)
    }

    /// Take one optimizer step (see the module docs for the anatomy:
    /// sample → plan → accum → reduce → apply → account). With
    /// `workers > 1` the accumulation groups run concurrently, one
    /// worker thread per peer session; results are recombined strictly
    /// in group order, so the log, the reduced accumulator, and the
    /// parameter trajectory are bitwise-identical for every worker
    /// count.
    pub fn step(&mut self) -> Result<StepLog> {
        // Announce the step to the fault plan (injection sites are
        // addressed by (step, rank, call)). Doing this before sampling
        // keeps the addressing aligned with the sampler's step index.
        if let Some(plan) = &self.fault_plan {
            plan.begin_step(self.step);
        }
        let t0 = Instant::now();
        let logical = self.sampler.sample(self.step);
        let groups = plan_groups(
            &logical,
            self.config.physical_batch,
            self.config.mode,
            &self.available,
        );
        self.sections.sampling += t0.elapsed().as_secs_f64();

        // One cache lookup per distinct chunk shape, *before* the
        // workers fan out: compiles on first use of a size (the
        // naive-JAX recompile cost, Fig A.2) are attributed here, so
        // concurrent ranks can never race a compilation or double-count
        // its seconds.
        let dtype = dtype_of(&self.config);
        let mut preps: BTreeMap<usize, Prepared> = BTreeMap::new();
        for pb in groups.iter().flat_map(|g| &g.chunks) {
            let b = pb.indices.len();
            if !preps.contains_key(&b) {
                let prep = self.model.prepare_accum(&self.config.variant, b, dtype)?;
                self.sections.compile += prep.compile_seconds.unwrap_or(0.0);
                preps.insert(b, prep);
            }
        }

        // Shard the groups across the rank sessions and fold each
        // group's partial accumulator (concurrently when peers exist).
        let dataset = &self.dataset;
        let exec_chunk = |sess: &mut dyn ExecSession, pb: &PhysicalBatch| -> Result<ChunkRun> {
            let prep = &preps[&pb.indices.len()];
            let t = Instant::now();
            let (x, y) = dataset.batch(&pb.indices);
            let data_secs = t.elapsed().as_secs_f64();
            let t = Instant::now();
            let stats = sess.accum(prep, &AccumArgs { x: &x, y: &y, mask: &pb.mask })?;
            Ok(ChunkRun {
                loss_sum: stats.loss_sum,
                real: pb.real_count(),
                computed: pb.indices.len(),
                data_secs,
                accum_secs: t.elapsed().as_secs_f64(),
            })
        };
        let mut sessions: Vec<&mut dyn ExecSession> = Vec::with_capacity(1 + self.peers.len());
        sessions.push(self.exec.as_mut());
        for peer in &mut self.peers {
            sessions.push(peer.as_mut());
        }
        let outcome = run_groups(sessions, &groups, &exec_chunk, self.step, &self.config.retry)?;
        self.recovery.extend(outcome.recoveries);
        if !outcome.lost_ranks.is_empty() {
            self.degrade(&outcome.lost_ranks)?;
        }
        let runs = outcome.runs;

        // Deterministic recombination in group/chunk order: the loss
        // log, the meter samples, and — through the fixed tree — the
        // reduced accumulator never depend on rank timing.
        let mut loss_sum = 0.0f64;
        let mut computed = 0usize;
        let mut physical_batches = 0usize;
        let mut partials = Vec::with_capacity(runs.len());
        for run in runs {
            for c in &run.chunks {
                loss_sum += c.loss_sum as f64;
                computed += c.computed;
                physical_batches += 1;
                self.sections.data += c.data_secs;
                self.sections.accum += c.accum_secs;
                self.meter.record_secs(c.real, c.accum_secs);
            }
            partials.push(run.partial);
        }
        let reduced = reduce_fixed_tree(partials)
            .ok_or_else(|| anyhow!("step produced no accumulation groups"))?;
        self.exec.write_acc(reduced)?;

        let t = Instant::now();
        let args = ApplyArgs {
            seed: per_step_noise_seed(self.config.seed, self.step),
            denom: self.denom,
            lr: self.config.lr as f32,
            noise_mult: self.noise_mult,
        };
        // Apply with bounded retries. The backend contract leaves the
        // bound buffers unmodified on error and `args` is reused
        // verbatim, so a retry replays the *same* noise (seed, stream)
        // tuple for the *same* reduced gradient — never a fresh draw
        // (the retry.fresh-draw audit contract, DESIGN.md §11).
        let max_attempts = self.config.retry.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            match self.exec.apply(&self.apply_prep, &args) {
                Ok(()) => break,
                Err(e) if attempt < max_attempts => {
                    self.recovery.push(RecoveryEvent {
                        step: self.step,
                        rank: 0,
                        group: None,
                        action: "apply-retried".to_string(),
                        detail: format!("attempt {attempt} failed: {e:#}"),
                    });
                    std::thread::sleep(self.config.retry.backoff_before(attempt - 1));
                }
                Err(e) => {
                    return Err(e.context(format!(
                        "apply failed at step {} after {attempt} attempts",
                        self.step
                    )));
                }
            }
        }
        self.sections.apply += t.elapsed().as_secs_f64();

        // Parameter broadcast: rank 0 applied the update; the peers'
        // next accum calls must see the same parameters.
        if !self.peers.is_empty() {
            let params = self.exec.read_params()?;
            for peer in &mut self.peers {
                peer.write_params(params.clone())?;
            }
        }

        if self.config.is_private() && self.sigma > 0.0 {
            self.accountant.record_step(self.config.sampling_rate, self.sigma);
        }
        let log = StepLog {
            step: self.step,
            logical_batch: logical.len(),
            physical_batches,
            computed_examples: computed,
            loss: loss_sum / logical.len().max(1) as f64,
        };
        self.step += 1;
        self.steps_log.push(log.clone());
        Ok(log)
    }

    /// Held-out evaluation at the current parameters: same data
    /// distribution (same class patterns), indices disjoint from the
    /// training range. Returns `(loss, accuracy, covered)` where
    /// `covered` is the exact number of examples averaged over: the
    /// eval executable's batch size is fixed at AOT time, so only
    /// `floor(examples / eb)` full batches can run — the remainder is
    /// reported, never silently folded into the average.
    ///
    /// The eval executable is prepared **once** per call and its
    /// compile time (first call only) attributed to
    /// `SectionTimes::compile`, exactly like the accum/apply paths —
    /// the old per-batch `prepare_eval` was never attributed at all.
    pub fn eval(&mut self) -> Result<(Option<f64>, Option<f64>, u32)> {
        self.evaluate()
    }

    fn evaluate(&mut self) -> Result<(Option<f64>, Option<f64>, u32)> {
        let examples = self.config.eval_examples;
        let Some(eb) = self.model.eval_batch() else {
            return Ok((None, None, 0));
        };
        if eb == 0 || (eb as u32) > examples {
            return Ok((None, None, 0));
        }
        let prep = self.model.prepare_eval()?;
        self.sections.compile += prep.compile_seconds.unwrap_or(0.0);
        let held_out = self
            .held_out
            .get_or_insert_with(|| held_out_dataset(&self.config, &self.model, examples));
        let offset = self.config.dataset_size;
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0u32;
        let mut start = 0u32;
        // The guard above ensures eb <= examples, so at least one full
        // batch always runs (n >= eb > 0).
        while start + eb as u32 <= examples {
            let idx: Vec<u32> = (offset + start..offset + start + eb as u32).collect();
            let (x, y) = held_out.batch(&idx);
            let (ls, nc) = self.exec.eval(&prep, &x, &y)?;
            loss += ls as f64;
            correct += nc as f64;
            n += eb as u32;
            start += eb as u32;
        }
        Ok((Some(loss / n as f64), Some(correct / n as f64), n))
    }

    /// Close the session out into a [`TrainReport`]: run the configured
    /// held-out evaluation, read the final parameters back through the
    /// checkpoint seam, and aggregate throughput + privacy accounting.
    pub fn finish(mut self) -> Result<TrainReport> {
        let (eval_loss, eval_accuracy, eval_covered) = if self.config.eval_examples > 0 {
            self.evaluate()?
        } else {
            (None, None, 0)
        };
        let epsilon_spent = self.epsilon_spent();
        let final_params = self.exec.read_params()?.into_vec();
        // Throughput denominators describe *this process's* timed work:
        // steps restored from a checkpoint carry no section time here,
        // so only the live steps enter the rate (the restored logs still
        // appear in `steps` for the full training record).
        let live = &self.steps_log[self.restored_steps.min(self.steps_log.len())..];
        let real: f64 = live.iter().map(|s| s.logical_batch as f64).sum();
        let comp: f64 = live.iter().map(|s| s.computed_examples as f64).sum();
        let total = self.sections.training_total();
        let compiles = self.runtime.compile_records()[self.compiled_before..]
            .iter()
            .map(|r| (r.path.clone(), r.seconds))
            .collect();
        Ok(TrainReport {
            model: self.config.model.clone(),
            variant: self.config.variant.clone(),
            mode: self.config.mode,
            noise_multiplier: self.sigma,
            // sigma == 0 on a private variant (debug/ablation runs) means
            // no DP guarantee at all: epsilon_spent() reports infinity
            // there, never 0.
            epsilon_spent,
            delta: self.config.delta,
            accountant: self.config.accountant.as_str().to_string(),
            steps: self.steps_log,
            sections: self.sections,
            throughput: if total > 0.0 { real / total } else { 0.0 },
            computed_throughput: if total > 0.0 { comp / total } else { 0.0 },
            accum_throughput_aggregate: self.meter.aggregate(),
            accum_throughput: if self.meter.is_empty() {
                None
            } else {
                Some(self.meter.median_ci(self.config.seed))
            },
            accum_quantiles: self.meter.quantiles(),
            accum_samples: self.meter.samples().to_vec(),
            eval_loss,
            eval_accuracy,
            eval_covered,
            compiles,
            unaudited: self.unaudited,
            recovery_events: self.recovery,
            final_workers: self.peers.len() + 1,
            final_params,
        })
    }
}

/// Dataset index for bench repeat `r`, slot `i` at batch size `batch`,
/// wrapping over `dataset_size`. Widened to `u64` before the modulo:
/// the old `r as u32 * batch as u32` product overflowed once
/// `repeats * batch` crossed 2^32, silently re-benching a skewed index
/// pattern.
pub fn bench_index(r: usize, batch: usize, i: usize, dataset_size: u32) -> u32 {
    debug_assert!(dataset_size > 0);
    ((r as u64 * batch as u64 + i as u64) % dataset_size as u64) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn per_step_noise_seeds_do_not_collide() {
        // Seeds chosen to include the pair that collided under the old
        // i32 folding (see below): 4295 * 1_000_003 wraps past 2^32.
        let mut seen = HashSet::new();
        for &seed in &[0u64, 1, 4295, 4296] {
            for step in 0..50_000u64 {
                assert!(
                    seen.insert(per_step_noise_seed(seed, step)),
                    "seed collision at ({seed}, {step})"
                );
            }
        }
        assert_eq!(seen.len(), 4 * 50_000);
    }

    #[test]
    fn per_step_noise_seed_is_deterministic() {
        assert_eq!(per_step_noise_seed(7, 3), per_step_noise_seed(7, 3));
        assert_ne!(per_step_noise_seed(7, 3), per_step_noise_seed(7, 4));
        assert_ne!(per_step_noise_seed(7, 3), per_step_noise_seed(8, 3));
    }

    #[test]
    fn old_i32_seed_folding_collided() {
        // Documents the bug the 64-bit derivation replaces: the i32 cast
        // of `seed * 1_000_003 + step` wraps, so distinct (seed, step)
        // pairs shared a noise stream.
        let old = |seed: i64, step: i64| (seed * 1_000_003 + step) as i32;
        // 4295 * 1_000_003 = 4_295_012_885 ≡ 45_589 (mod 2^32).
        assert_eq!(old(4295, 0), old(0, 45_589));
    }

    #[test]
    fn bench_index_survives_large_repeats_times_batch() {
        // The old derivation computed `r as u32 * batch as u32`, which
        // wraps once repeats * batch crosses 2^32. 2^20 repeats at batch
        // 2^13 puts the product at 2^33: the u64 path must still agree
        // with exact arithmetic.
        let (r, batch, n) = (1usize << 20, 1usize << 13, 1_000_003u32);
        let exact = ((r as u128 * batch as u128 + 5) % n as u128) as u32;
        assert_eq!(bench_index(r, batch, 5, n), exact);
        // The u32 product would have wrapped to 0 here: 2^20 * 2^13 ≡ 0
        // (mod 2^32), i.e. the old code would return 5 — the new result
        // must differ from that wrapped value.
        assert_ne!(bench_index(r, batch, 5, n), 5 % n);
        // Small cases keep the obvious value.
        assert_eq!(bench_index(2, 8, 3, 1000), 19);
        assert_eq!(bench_index(0, 64, 63, 64), 63);
        assert_eq!(bench_index(3, 4, 0, 5), 12 % 5);
    }

    #[test]
    fn abi_fold_of_noise_seed_is_injective_within_a_run() {
        // The PJRT backend folds the u64 seed to the ABI's i32 slot by
        // xoring the halves; with the structured layout that is
        // stream-id ^ step — a bijection in step, so one run can never
        // reuse a noise seed on the 32-bit path either.
        let fold = |s: u64| ((s >> 32) ^ (s & 0xffff_ffff)) as u32;
        let mut seen = HashSet::new();
        for step in 0..100_000u64 {
            assert!(
                seen.insert(fold(per_step_noise_seed(12345, step))),
                "folded seed collision at step {step}"
            );
        }
    }

    fn test_checkpoint() -> TrainCheckpoint {
        let mut ckpt = TrainCheckpoint {
            fingerprint: "v1|test".into(),
            step: 3,
            params: vec![0.1f32, -2.5e-8, 3.0, f32::MIN_POSITIVE],
            steps: vec![StepLog {
                step: 2,
                logical_batch: 17,
                physical_batches: 3,
                computed_examples: 24,
                loss: 2.302_585_092_994_046,
            }],
            unaudited: false,
            checksum: String::new(),
        };
        ckpt.seal();
        ckpt
    }

    #[test]
    fn checkpoint_json_roundtrip_is_exact() {
        let ckpt = test_checkpoint();
        let json = ckpt.to_json().unwrap();
        let back = TrainCheckpoint::from_json(&json).unwrap();
        assert_eq!(back.step, ckpt.step);
        assert!(!back.unaudited);
        assert!(back.checksum_ok(), "seal survives the JSON roundtrip");
        // Pre-audit checkpoints (no `unaudited` key) still load.
        let legacy: TrainCheckpoint =
            serde_json::from_str(&json.replace(",\"unaudited\":false", "")).unwrap();
        assert!(!legacy.unaudited);
        // serde_json uses ryu shortest-roundtrip formatting: every f32
        // and f64 must come back bit-exact (the resume contract).
        let bits: Vec<u32> = ckpt.params.iter().map(|f| f.to_bits()).collect();
        let back_bits: Vec<u32> = back.params.iter().map(|f| f.to_bits()).collect();
        assert_eq!(bits, back_bits);
        assert_eq!(back.steps[0].loss.to_bits(), ckpt.steps[0].loss.to_bits());
    }

    #[test]
    fn checkpoint_checksum_detects_every_field() {
        // Unsealed (hand-built / pre-v5) passes vacuously.
        let mut unsealed = test_checkpoint();
        unsealed.checksum.clear();
        assert!(unsealed.checksum_ok());

        // Any single-field mutation after sealing is detected...
        let base = test_checkpoint();
        assert!(base.checksum_ok());
        let mut c = base.clone();
        c.step += 1;
        assert!(!c.checksum_ok(), "step covered");
        let mut c = base.clone();
        c.params[1] = f32::from_bits(c.params[1].to_bits() ^ 1);
        assert!(!c.checksum_ok(), "a single flipped param bit is covered");
        let mut c = base.clone();
        c.steps[0].loss += 1e-9;
        assert!(!c.checksum_ok(), "step-log losses covered");
        let mut c = base.clone();
        c.unaudited = true;
        assert!(!c.checksum_ok(), "the unaudited stamp is covered");
        let mut c = base.clone();
        c.fingerprint.push('x');
        assert!(!c.checksum_ok(), "fingerprint covered");
        // ...and re-sealing accepts the new content.
        c.seal();
        assert!(c.checksum_ok());
    }
}
