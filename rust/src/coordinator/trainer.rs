//! The training-loop driver: virtual-batching DP-SGD (Algorithms 1 & 2)
//! over any execution [`Backend`](crate::runtime::Backend), with
//! per-section timing.
//!
//! Per optimizer step:
//!
//! 1. **sample**  — Poisson-sample the logical batch (L3, [`PoissonSampler`])
//! 2. **split**   — into physical batches + masks ([`BatchMemoryManager`];
//!                  masked mode = Algorithm 2, variable mode = naive JAX)
//! 3. **accum**   — per physical batch: fetch data, run the `accum`
//!                  executable (fwd + per-example bwd + clip + accumulate)
//! 4. **apply**   — at the step boundary: run `apply` (noise + SGD step)
//! 5. **account** — record the (q, sigma) step in the RDP accountant
//!
//! The per-section wall-clock breakdown is this codebase's analogue of
//! the paper's Nsight profile (Table 2); compile time is tracked
//! separately (Fig. A.2) and excluded from throughput, mirroring how the
//! paper discounts JAX compilation when comparing steady-state rates.

use crate::coordinator::batcher::{BatchMemoryManager, BatchingMode, PhysicalBatch};
use crate::coordinator::config::TrainConfig;
use crate::coordinator::sampler::{PoissonSampler, Sampler};
use crate::data::SyntheticDataset;
use crate::metrics::ThroughputMeter;
use crate::privacy::rdp::StreamingAccountant;
use crate::privacy::{calibrate_sigma, RdpAccountant};
use crate::runtime::{ModelRuntime, Runtime, Tensor};
use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};
use serde::Serialize;
use std::time::Instant;

/// Full-width per-step noise seed: the high 32 bits are a per-experiment
/// stream id (ChaCha20-derived, the same domain separation the samplers
/// use), the low 32 bits the step counter.
///
/// The old derivation `(seed * 1_000_003 + step) as i32` wrapped through
/// 32 bits and could collide between steps — silently reusing Gaussian
/// noise between optimizer steps, which voids the privacy analysis
/// (noise must be independent across compositions). The structured
/// layout guarantees what the analysis needs: **within one run the seed
/// is injective in `step`** (for the < 2^32 steps any run takes), and it
/// stays injective even after the PJRT backend folds it into the ABI's
/// 32-bit seed slot (xor of the halves = stream-id ^ step, a bijection
/// in `step`). Across *different* experiment seeds the 32-bit stream id
/// collides with probability 2^-32 per pair — harmless for DP (each
/// run's composition uses independent noise) but worth knowing when
/// comparing runs.
pub fn per_step_noise_seed(experiment_seed: u64, step: u64) -> u64 {
    debug_assert!(step < 1u64 << 32, "runs are bounded far below 2^32 steps");
    let mut rng = ChaChaRng::from_seed_stream(experiment_seed, 0, b"noisesd\0");
    let stream_id = rng.next_u32() as u64;
    (stream_id << 32) | (step & 0xffff_ffff)
}

/// Wall-clock seconds per pipeline section (the Table-2 analogue).
#[derive(Debug, Clone, Copy, Default, Serialize)]
pub struct SectionTimes {
    /// Poisson sampling + batch splitting (host).
    pub sampling: f64,
    /// Synthetic-data materialization (the "data loading" stand-in).
    pub data: f64,
    /// accum executions (forward + backward + clip + accumulate).
    pub accum: f64,
    /// apply executions (noise + optimizer step).
    pub apply: f64,
    /// Executable compilations (jit analogue; excluded from throughput).
    pub compile: f64,
}

impl SectionTimes {
    pub fn training_total(&self) -> f64 {
        self.sampling + self.data + self.accum + self.apply
    }
}

/// One optimizer step's log entry.
#[derive(Debug, Clone, Serialize)]
pub struct StepLog {
    pub step: u64,
    /// True sampled logical batch size (varies under Poisson!).
    pub logical_batch: usize,
    /// Number of physical batches executed (including padded ones).
    pub physical_batches: usize,
    /// Examples computed including Algorithm-2 padding.
    pub computed_examples: usize,
    /// Mean training loss over the real examples of this step.
    pub loss: f64,
}

/// Result of a training run.
#[derive(Debug, Serialize)]
pub struct TrainReport {
    pub model: String,
    pub variant: String,
    pub mode: BatchingMode,
    pub noise_multiplier: f64,
    pub epsilon_spent: f64,
    pub delta: f64,
    pub steps: Vec<StepLog>,
    pub sections: SectionTimes,
    /// Real examples per second over sample+data+accum+apply time.
    pub throughput: f64,
    /// Including Algorithm-2 padding (the "wasted" gradient computation).
    pub computed_throughput: f64,
    /// Per-accum-call throughput samples (for bootstrap CIs).
    pub accum_samples: Vec<f64>,
    pub eval_loss: Option<f64>,
    pub eval_accuracy: Option<f64>,
    /// (artifact, seconds) for every compilation this run caused.
    pub compiles: Vec<(String, f64)>,
    /// Flat parameter vector after the final step (checkpointable via
    /// [`ModelRuntime::save_params`]).
    pub final_params: Vec<f32>,
}

impl TrainReport {
    /// Serialize the whole report (steps, sections, privacy, params).
    pub fn to_json(&self) -> serde_json::Result<String> {
        serde_json::to_string_pretty(self)
    }
}

/// Drives one configured training run over the runtime.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    model: ModelRuntime,
    config: TrainConfig,
    dataset: SyntheticDataset,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, config: TrainConfig) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = SyntheticDataset::new(
            config.dataset_size,
            model.meta().num_classes as u32,
            model.meta().image,
            model.meta().channels,
            config.seed,
        );
        Ok(Self { runtime, model, config, dataset })
    }

    pub fn model(&self) -> &ModelRuntime {
        &self.model
    }

    /// Resolve the noise multiplier: explicit, or calibrated to the
    /// (epsilon, delta) target (paper Table A2 style).
    pub fn resolve_sigma(&self) -> Result<f64> {
        if !self.config.is_private() {
            return Ok(0.0);
        }
        match self.config.noise_multiplier {
            Some(s) => Ok(s),
            None => calibrate_sigma(
                self.config.target_epsilon,
                self.config.delta,
                self.config.sampling_rate,
                self.config.steps,
            )
            .map_err(|e| anyhow!(e)),
        }
    }

    fn dtype(&self) -> &'static str {
        if self.config.bf16 {
            "bf16"
        } else {
            "f32"
        }
    }

    /// Run the configured number of optimizer steps.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.config;
        let sigma = self.resolve_sigma()?;
        let sampler = PoissonSampler::new(cfg.dataset_size, cfg.sampling_rate, cfg.seed);
        let bmm = BatchMemoryManager::new(cfg.physical_batch, cfg.mode);
        let available = self.model.accum_batches(&cfg.variant, self.dtype());
        if available.is_empty() {
            return Err(anyhow!(
                "no accum artifacts for {} variant={} dtype={}",
                cfg.model,
                cfg.variant,
                self.dtype()
            ));
        }

        let mut sections = SectionTimes::default();
        let mut meter = ThroughputMeter::new();
        let mut accum_samples = Vec::new();
        let mut steps_log = Vec::new();
        let mut accountant = StreamingAccountant::new(RdpAccountant::default());

        let compiled_before = self.runtime.compile_records().len();
        // Pre-compile the fixed-shape executables (apply + the masked
        // accum shape) so their one-time compile cost lands in
        // `sections.compile`, not in the steady-state sections — the
        // same discount the paper applies to JAX compilation.
        if cfg.mode == BatchingMode::Masked {
            let prep =
                self.model.prepare_accum(&cfg.variant, cfg.physical_batch, self.dtype())?;
            sections.compile += prep.compile_seconds.unwrap_or(0.0);
        }
        let apply_prep = self.model.prepare_apply()?;
        sections.compile += apply_prep.compile_seconds.unwrap_or(0.0);
        let mut params = {
            let t0 = Instant::now();
            let p = self.model.init_params()?;
            sections.data += t0.elapsed().as_secs_f64();
            p
        };
        // denom = E[L] (Algorithm 1's 1/|L| with the expected batch — the
        // standard Opacus convention). Only the degenerate q = 0 case is
        // substituted (1.0, keeping noise-only steps well-defined);
        // fractional E[L] < 1 is a legitimate divisor and passes through.
        let expected = cfg.expected_logical_batch() as f32;
        let denom = if expected > 0.0 { expected } else { 1.0 };
        let noise_mult = (sigma * cfg.clip_norm) as f32;

        for step in 0..cfg.steps {
            let t0 = Instant::now();
            let logical = sampler.sample(step);
            let batches: Vec<PhysicalBatch> = match cfg.mode {
                BatchingMode::Masked => bmm.split(&logical),
                BatchingMode::Variable => {
                    BatchMemoryManager::split_naive(&logical, &available)
                }
            };
            sections.sampling += t0.elapsed().as_secs_f64();

            let mut acc = self.model.zero_acc();
            let mut loss_sum = 0.0f64;
            let mut computed = 0usize;
            for pb in &batches {
                let b = pb.indices.len();
                // One cache lookup: compiles on first use of this size
                // (the naive-JAX recompile cost, Fig A.2) and reports
                // the compile time it spent, if any, so the attribution
                // cannot drift from the execution.
                let prep = self.model.prepare_accum(&cfg.variant, b, self.dtype())?;
                sections.compile += prep.compile_seconds.unwrap_or(0.0);

                let t = Instant::now();
                let (x, y) = self.dataset.batch(&pb.indices);
                sections.data += t.elapsed().as_secs_f64();

                let t = Instant::now();
                let out = self.model.run_accum(&prep, &params, &acc, &x, &y, &pb.mask)?;
                let dt = t.elapsed().as_secs_f64();
                sections.accum += dt;
                meter.record_secs(pb.real_count(), dt);
                if dt > 0.0 {
                    accum_samples.push(pb.real_count() as f64 / dt);
                }
                acc = out.acc;
                loss_sum += out.loss_sum as f64;
                computed += b;
            }

            let t = Instant::now();
            let seed = per_step_noise_seed(cfg.seed, step);
            params = self.model.run_apply(
                &apply_prep,
                &params,
                &acc,
                seed,
                denom,
                cfg.lr as f32,
                noise_mult,
            )?;
            sections.apply += t.elapsed().as_secs_f64();

            if cfg.is_private() && sigma > 0.0 {
                accountant.record_step(cfg.sampling_rate, sigma);
            }
            steps_log.push(StepLog {
                step,
                logical_batch: logical.len(),
                physical_batches: batches.len(),
                computed_examples: computed,
                loss: loss_sum / logical.len().max(1) as f64,
            });
        }

        // Held-out evaluation with the fixed-size eval executable.
        let (eval_loss, eval_accuracy) = if cfg.eval_examples > 0 {
            self.evaluate(&params, cfg.eval_examples)?
        } else {
            (None, None)
        };

        let real: f64 = steps_log.iter().map(|s| s.logical_batch as f64).sum();
        let comp: f64 = steps_log.iter().map(|s| s.computed_examples as f64).sum();
        let total = sections.training_total();
        let compiles = self.runtime.compile_records()[compiled_before..]
            .iter()
            .map(|r| (r.path.clone(), r.seconds))
            .collect();
        Ok(TrainReport {
            model: cfg.model.clone(),
            variant: cfg.variant.clone(),
            mode: cfg.mode,
            noise_multiplier: sigma,
            // sigma == 0 on a private variant (debug/ablation runs) means
            // no DP guarantee at all: report eps = infinity, not 0.
            epsilon_spent: if !cfg.is_private() {
                0.0
            } else if sigma > 0.0 {
                accountant.epsilon(cfg.delta)
            } else {
                f64::INFINITY
            },
            delta: cfg.delta,
            steps: steps_log,
            sections,
            throughput: if total > 0.0 { real / total } else { 0.0 },
            computed_throughput: if total > 0.0 { comp / total } else { 0.0 },
            accum_samples,
            eval_loss,
            eval_accuracy,
            compiles,
            final_params: params.to_vec(),
        })
    }

    /// Evaluate on held-out examples: same data distribution (same
    /// class patterns), indices disjoint from the training range.
    fn evaluate(
        &self,
        params: &Tensor,
        examples: u32,
    ) -> Result<(Option<f64>, Option<f64>)> {
        let Some(eb) = self.model.eval_batch() else {
            return Ok((None, None));
        };
        let held_out = SyntheticDataset::new(
            self.config.dataset_size + examples,
            self.model.meta().num_classes as u32,
            self.model.meta().image,
            self.model.meta().channels,
            self.config.seed,
        );
        let offset = self.config.dataset_size;
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0u32;
        let mut start = 0u32;
        while start + eb as u32 <= examples {
            let idx: Vec<u32> = (offset + start..offset + start + eb as u32).collect();
            let (x, y) = held_out.batch(&idx);
            let (ls, nc) = self.model.run_eval(params, &x, &y)?;
            loss += ls as f64;
            correct += nc as f64;
            n += eb as u32;
            start += eb as u32;
        }
        if n == 0 {
            return Ok((None, None));
        }
        Ok((Some(loss / n as f64), Some(correct / n as f64)))
    }

    /// Steady-state accum throughput sweep for one (variant, batch):
    /// `repeats` timed executions of the same compiled executable on
    /// fresh data — the measurement behind Figures 1/2/4/6.
    pub fn bench_accum(
        &self,
        variant: &str,
        batch: usize,
        repeats: usize,
    ) -> Result<Vec<f64>> {
        let prep = self.model.prepare_accum(variant, batch, self.dtype())?;
        let params = self.model.init_params()?;
        let acc = self.model.zero_acc();
        let mask = vec![1.0f32; batch];
        let mut samples = Vec::with_capacity(repeats);
        for r in 0..repeats {
            let idx: Vec<u32> =
                (0..batch as u32).map(|i| (r as u32 * batch as u32 + i) % self.config.dataset_size).collect();
            let (x, y) = self.dataset.batch(&idx);
            let t = Instant::now();
            let _ = self.model.run_accum(&prep, &params, &acc, &x, &y, &mask)?;
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.0 {
                samples.push(batch as f64 / dt);
            }
        }
        Ok(samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn per_step_noise_seeds_do_not_collide() {
        // Seeds chosen to include the pair that collided under the old
        // i32 folding (see below): 4295 * 1_000_003 wraps past 2^32.
        let mut seen = HashSet::new();
        for &seed in &[0u64, 1, 4295, 4296] {
            for step in 0..50_000u64 {
                assert!(
                    seen.insert(per_step_noise_seed(seed, step)),
                    "seed collision at ({seed}, {step})"
                );
            }
        }
        assert_eq!(seen.len(), 4 * 50_000);
    }

    #[test]
    fn per_step_noise_seed_is_deterministic() {
        assert_eq!(per_step_noise_seed(7, 3), per_step_noise_seed(7, 3));
        assert_ne!(per_step_noise_seed(7, 3), per_step_noise_seed(7, 4));
        assert_ne!(per_step_noise_seed(7, 3), per_step_noise_seed(8, 3));
    }

    #[test]
    fn old_i32_seed_folding_collided() {
        // Documents the bug the 64-bit derivation replaces: the i32 cast
        // of `seed * 1_000_003 + step` wraps, so distinct (seed, step)
        // pairs shared a noise stream.
        let old = |seed: i64, step: i64| (seed * 1_000_003 + step) as i32;
        // 4295 * 1_000_003 = 4_295_012_885 ≡ 45_589 (mod 2^32).
        assert_eq!(old(4295, 0), old(0, 45_589));
    }

    #[test]
    fn abi_fold_of_noise_seed_is_injective_within_a_run() {
        // The PJRT backend folds the u64 seed to the ABI's i32 slot by
        // xoring the halves; with the structured layout that is
        // stream-id ^ step — a bijection in step, so one run can never
        // reuse a noise seed on the 32-bit path either.
        let fold = |s: u64| ((s >> 32) ^ (s & 0xffff_ffff)) as u32;
        let mut seen = HashSet::new();
        for step in 0..100_000u64 {
            assert!(
                seen.insert(fold(per_step_noise_seed(12345, step))),
                "folded seed collision at step {step}"
            );
        }
    }
}
