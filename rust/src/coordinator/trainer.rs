//! The training-loop driver: virtual-batching DP-SGD (Algorithms 1 & 2)
//! over any execution [`Backend`](crate::runtime::Backend), with
//! per-section timing.
//!
//! Per optimizer step:
//!
//! 1. **sample**  — Poisson-sample the logical batch (L3, [`PoissonSampler`])
//! 2. **split**   — into physical batches + masks ([`BatchMemoryManager`];
//!                  masked mode = Algorithm 2, variable mode = naive JAX)
//! 3. **accum**   — per physical batch: fetch data, run the `accum`
//!                  executable (fwd + per-example bwd + clip + accumulate)
//! 4. **apply**   — at the step boundary: run `apply` (noise + SGD step)
//! 5. **account** — record the (q, sigma) step in the RDP accountant
//!
//! The per-section wall-clock breakdown is this codebase's analogue of
//! the paper's Nsight profile (Table 2); compile time is tracked
//! separately (Fig. A.2) and excluded from throughput, mirroring how the
//! paper discounts JAX compilation when comparing steady-state rates.

use crate::coordinator::batcher::{BatchMemoryManager, BatchingMode, PhysicalBatch};
use crate::coordinator::config::TrainConfig;
use crate::coordinator::sampler::{PoissonSampler, Sampler};
use crate::data::SyntheticDataset;
use crate::metrics::{Summary, ThroughputMeter};
use crate::privacy::rdp::StreamingAccountant;
use crate::privacy::{calibrate_sigma, RdpAccountant};
use crate::runtime::{ModelRuntime, Runtime, Tensor};
use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};
use serde::{Deserialize, Serialize};
use std::time::Instant;

/// Full-width per-step noise seed: the high 32 bits are a per-experiment
/// stream id (ChaCha20-derived, the same domain separation the samplers
/// use), the low 32 bits the step counter.
///
/// The old derivation `(seed * 1_000_003 + step) as i32` wrapped through
/// 32 bits and could collide between steps — silently reusing Gaussian
/// noise between optimizer steps, which voids the privacy analysis
/// (noise must be independent across compositions). The structured
/// layout guarantees what the analysis needs: **within one run the seed
/// is injective in `step`** (for the < 2^32 steps any run takes), and it
/// stays injective even after the PJRT backend folds it into the ABI's
/// 32-bit seed slot (xor of the halves = stream-id ^ step, a bijection
/// in `step`). Across *different* experiment seeds the 32-bit stream id
/// collides with probability 2^-32 per pair — harmless for DP (each
/// run's composition uses independent noise) but worth knowing when
/// comparing runs.
pub fn per_step_noise_seed(experiment_seed: u64, step: u64) -> u64 {
    debug_assert!(step < 1u64 << 32, "runs are bounded far below 2^32 steps");
    let mut rng = ChaChaRng::from_seed_stream(experiment_seed, 0, b"noisesd\0");
    let stream_id = rng.next_u32() as u64;
    (stream_id << 32) | (step & 0xffff_ffff)
}

/// Wall-clock seconds per pipeline section (the Table-2 analogue).
#[derive(Debug, Clone, Copy, Default, Serialize, Deserialize)]
pub struct SectionTimes {
    /// Poisson sampling + batch splitting (host).
    pub sampling: f64,
    /// Synthetic-data materialization (the "data loading" stand-in).
    pub data: f64,
    /// accum executions (forward + backward + clip + accumulate).
    pub accum: f64,
    /// apply executions (noise + optimizer step).
    pub apply: f64,
    /// Executable compilations (jit analogue; excluded from throughput).
    pub compile: f64,
}

impl SectionTimes {
    pub fn training_total(&self) -> f64 {
        self.sampling + self.data + self.accum + self.apply
    }
}

/// One optimizer step's log entry.
#[derive(Debug, Clone, Serialize)]
pub struct StepLog {
    pub step: u64,
    /// True sampled logical batch size (varies under Poisson!).
    pub logical_batch: usize,
    /// Number of physical batches executed (including padded ones).
    pub physical_batches: usize,
    /// Examples computed including Algorithm-2 padding.
    pub computed_examples: usize,
    /// Mean training loss over the real examples of this step.
    pub loss: f64,
}

/// Result of a training run.
#[derive(Debug, Serialize)]
pub struct TrainReport {
    pub model: String,
    pub variant: String,
    pub mode: BatchingMode,
    pub noise_multiplier: f64,
    pub epsilon_spent: f64,
    pub delta: f64,
    pub steps: Vec<StepLog>,
    pub sections: SectionTimes,
    /// Real examples per second over sample+data+accum+apply time.
    pub throughput: f64,
    /// Including Algorithm-2 padding (the "wasted" gradient computation).
    pub computed_throughput: f64,
    /// Per-accum-call throughput samples (for bootstrap CIs).
    pub accum_samples: Vec<f64>,
    /// Aggregate accum throughput: real examples / total accum seconds
    /// (the [`ThroughputMeter`] view the hot loop feeds).
    pub accum_throughput_aggregate: f64,
    /// Median + bootstrap 95% CI over the per-accum-call samples
    /// (`None` when no accum call produced a timed sample).
    pub accum_throughput: Option<Summary>,
    pub eval_loss: Option<f64>,
    pub eval_accuracy: Option<f64>,
    /// Held-out examples the eval metrics actually averaged over. The
    /// eval executable has a fixed AOT batch size, so a request that is
    /// not a multiple of it can only cover `floor(requested / eb) * eb`
    /// examples — this field makes that coverage exact instead of
    /// silently pretending the tail was evaluated.
    pub eval_covered: u32,
    /// (artifact, seconds) for every compilation this run caused.
    pub compiles: Vec<(String, f64)>,
    /// Flat parameter vector after the final step (checkpointable via
    /// [`ModelRuntime::save_params`]).
    pub final_params: Vec<f32>,
}

impl TrainReport {
    /// Serialize the whole report (steps, sections, privacy, params).
    pub fn to_json(&self) -> serde_json::Result<String> {
        serde_json::to_string_pretty(self)
    }
}

/// Drives one configured training run over the runtime.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    model: ModelRuntime,
    config: TrainConfig,
    dataset: SyntheticDataset,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, config: TrainConfig) -> Result<Self> {
        let model = runtime.model(&config.model)?;
        let dataset = SyntheticDataset::new(
            config.dataset_size,
            model.meta().num_classes as u32,
            model.meta().image,
            model.meta().channels,
            config.seed,
        );
        Ok(Self { runtime, model, config, dataset })
    }

    pub fn model(&self) -> &ModelRuntime {
        &self.model
    }

    /// Resolve the noise multiplier: explicit, or calibrated to the
    /// (epsilon, delta) target (paper Table A2 style).
    pub fn resolve_sigma(&self) -> Result<f64> {
        if !self.config.is_private() {
            return Ok(0.0);
        }
        match self.config.noise_multiplier {
            Some(s) => Ok(s),
            None => calibrate_sigma(
                self.config.target_epsilon,
                self.config.delta,
                self.config.sampling_rate,
                self.config.steps,
            )
            .map_err(|e| anyhow!(e)),
        }
    }

    fn dtype(&self) -> &'static str {
        if self.config.bf16 {
            "bf16"
        } else {
            "f32"
        }
    }

    /// Run the configured number of optimizer steps.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.config;
        let sigma = self.resolve_sigma()?;
        let sampler = PoissonSampler::new(cfg.dataset_size, cfg.sampling_rate, cfg.seed);
        let bmm = BatchMemoryManager::new(cfg.physical_batch, cfg.mode);
        let available = self.model.accum_batches(&cfg.variant, self.dtype());
        if available.is_empty() {
            return Err(anyhow!(
                "no accum artifacts for {} variant={} dtype={}",
                cfg.model,
                cfg.variant,
                self.dtype()
            ));
        }

        let mut sections = SectionTimes::default();
        let mut meter = ThroughputMeter::new();
        let mut steps_log = Vec::new();
        let mut accountant = StreamingAccountant::new(RdpAccountant::default());

        let compiled_before = self.runtime.compile_records().len();
        // Pre-compile the fixed-shape executables (apply + the masked
        // accum shape) so their one-time compile cost lands in
        // `sections.compile`, not in the steady-state sections — the
        // same discount the paper applies to JAX compilation.
        if cfg.mode == BatchingMode::Masked {
            let prep =
                self.model.prepare_accum(&cfg.variant, cfg.physical_batch, self.dtype())?;
            sections.compile += prep.compile_seconds.unwrap_or(0.0);
        }
        let apply_prep = self.model.prepare_apply()?;
        sections.compile += apply_prep.compile_seconds.unwrap_or(0.0);
        let mut params = {
            let t0 = Instant::now();
            let p = self.model.init_params()?;
            sections.data += t0.elapsed().as_secs_f64();
            p
        };
        // denom = E[L] (Algorithm 1's 1/|L| with the expected batch — the
        // standard Opacus convention). Only the degenerate q = 0 case is
        // substituted (1.0, keeping noise-only steps well-defined);
        // fractional E[L] < 1 is a legitimate divisor and passes through.
        let expected = cfg.expected_logical_batch() as f32;
        let denom = if expected > 0.0 { expected } else { 1.0 };
        let noise_mult = (sigma * cfg.clip_norm) as f32;

        // The gradient accumulator is allocated once and *donated* to
        // every accum call (updated in place, re-zeroed per step) — the
        // `donate_argnums` analogue: the hot loop never copies the
        // P-length vector.
        let mut acc = self.model.zero_acc();

        for step in 0..cfg.steps {
            let t0 = Instant::now();
            let logical = sampler.sample(step);
            let batches: Vec<PhysicalBatch> = match cfg.mode {
                BatchingMode::Masked => bmm.split(&logical),
                BatchingMode::Variable => {
                    BatchMemoryManager::split_naive(&logical, &available)
                }
            };
            sections.sampling += t0.elapsed().as_secs_f64();

            acc.fill(0.0);
            let mut loss_sum = 0.0f64;
            let mut computed = 0usize;
            for pb in &batches {
                let b = pb.indices.len();
                // One cache lookup: compiles on first use of this size
                // (the naive-JAX recompile cost, Fig A.2) and reports
                // the compile time it spent, if any, so the attribution
                // cannot drift from the execution.
                let prep = self.model.prepare_accum(&cfg.variant, b, self.dtype())?;
                sections.compile += prep.compile_seconds.unwrap_or(0.0);

                let t = Instant::now();
                let (x, y) = self.dataset.batch(&pb.indices);
                sections.data += t.elapsed().as_secs_f64();

                let t = Instant::now();
                let stats =
                    self.model.run_accum_into(&prep, &params, &mut acc, &x, &y, &pb.mask)?;
                let dt = t.elapsed().as_secs_f64();
                sections.accum += dt;
                meter.record_secs(pb.real_count(), dt);
                loss_sum += stats.loss_sum as f64;
                computed += b;
            }

            let t = Instant::now();
            let seed = per_step_noise_seed(cfg.seed, step);
            self.model.run_apply_into(
                &apply_prep,
                &mut params,
                &acc,
                seed,
                denom,
                cfg.lr as f32,
                noise_mult,
            )?;
            sections.apply += t.elapsed().as_secs_f64();

            if cfg.is_private() && sigma > 0.0 {
                accountant.record_step(cfg.sampling_rate, sigma);
            }
            steps_log.push(StepLog {
                step,
                logical_batch: logical.len(),
                physical_batches: batches.len(),
                computed_examples: computed,
                loss: loss_sum / logical.len().max(1) as f64,
            });
        }

        // Held-out evaluation with the fixed-size eval executable.
        let (eval_loss, eval_accuracy, eval_covered) = if cfg.eval_examples > 0 {
            self.evaluate(&params, cfg.eval_examples)?
        } else {
            (None, None, 0)
        };

        let real: f64 = steps_log.iter().map(|s| s.logical_batch as f64).sum();
        let comp: f64 = steps_log.iter().map(|s| s.computed_examples as f64).sum();
        let total = sections.training_total();
        let compiles = self.runtime.compile_records()[compiled_before..]
            .iter()
            .map(|r| (r.path.clone(), r.seconds))
            .collect();
        Ok(TrainReport {
            model: cfg.model.clone(),
            variant: cfg.variant.clone(),
            mode: cfg.mode,
            noise_multiplier: sigma,
            // sigma == 0 on a private variant (debug/ablation runs) means
            // no DP guarantee at all: report eps = infinity, not 0.
            epsilon_spent: if !cfg.is_private() {
                0.0
            } else if sigma > 0.0 {
                accountant.epsilon(cfg.delta)
            } else {
                f64::INFINITY
            },
            delta: cfg.delta,
            steps: steps_log,
            sections,
            throughput: if total > 0.0 { real / total } else { 0.0 },
            computed_throughput: if total > 0.0 { comp / total } else { 0.0 },
            accum_throughput_aggregate: meter.aggregate(),
            accum_throughput: if meter.is_empty() {
                None
            } else {
                Some(meter.median_ci(cfg.seed))
            },
            accum_samples: meter.samples().to_vec(),
            eval_loss,
            eval_accuracy,
            eval_covered,
            compiles,
            final_params: params.to_vec(),
        })
    }

    /// Evaluate on held-out examples: same data distribution (same
    /// class patterns), indices disjoint from the training range.
    /// Returns `(loss, accuracy, covered)` where `covered` is the exact
    /// number of examples averaged over: the eval executable's batch
    /// size is fixed at AOT time, so only `floor(examples / eb)` full
    /// batches can run — the remainder is reported, never silently
    /// folded into the average.
    fn evaluate(
        &self,
        params: &Tensor,
        examples: u32,
    ) -> Result<(Option<f64>, Option<f64>, u32)> {
        let Some(eb) = self.model.eval_batch() else {
            return Ok((None, None, 0));
        };
        let held_out = SyntheticDataset::new(
            self.config.dataset_size + examples,
            self.model.meta().num_classes as u32,
            self.model.meta().image,
            self.model.meta().channels,
            self.config.seed,
        );
        let offset = self.config.dataset_size;
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0u32;
        let mut start = 0u32;
        while start + eb as u32 <= examples {
            let idx: Vec<u32> = (offset + start..offset + start + eb as u32).collect();
            let (x, y) = held_out.batch(&idx);
            let (ls, nc) = self.model.run_eval(params, &x, &y)?;
            loss += ls as f64;
            correct += nc as f64;
            n += eb as u32;
            start += eb as u32;
        }
        if n == 0 {
            return Ok((None, None, 0));
        }
        Ok((Some(loss / n as f64), Some(correct / n as f64), n))
    }

    /// Steady-state accum throughput sweep for one (variant, batch):
    /// `repeats` timed executions of the same compiled executable on
    /// fresh data, through the donating (`run_accum_into`) hot path —
    /// the measurement behind Figures 1/2/4/6. Returns examples/second
    /// per call.
    pub fn bench_accum(
        &self,
        variant: &str,
        batch: usize,
        repeats: usize,
    ) -> Result<Vec<f64>> {
        let prep = self.model.prepare_accum(variant, batch, self.dtype())?;
        let params = self.model.init_params()?;
        let mut acc = self.model.zero_acc();
        let mask = vec![1.0f32; batch];
        let mut samples = Vec::with_capacity(repeats);
        for r in 0..repeats {
            let idx: Vec<u32> = (0..batch)
                .map(|i| bench_index(r, batch, i, self.config.dataset_size))
                .collect();
            let (x, y) = self.dataset.batch(&idx);
            // Re-zero the donated accumulator outside the timed region
            // so every call measures the same accumulate workload.
            acc.fill(0.0);
            let t = Instant::now();
            let _ = self.model.run_accum_into(&prep, &params, &mut acc, &x, &y, &mask)?;
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.0 {
                samples.push(batch as f64 / dt);
            }
        }
        Ok(samples)
    }

    /// Steady-state apply throughput: `repeats` timed executions of the
    /// noisy step through the donating hot path, with the Gaussian path
    /// exercised (`noise_mult = 1`) and `lr = 0` so the parameters stay
    /// put across repeats. Returns calls/second per call.
    pub fn bench_apply(&self, repeats: usize) -> Result<Vec<f64>> {
        let prep = self.model.prepare_apply()?;
        let mut params = self.model.init_params()?;
        let acc = self.model.zero_acc();
        let mut samples = Vec::with_capacity(repeats);
        for r in 0..repeats {
            let seed = per_step_noise_seed(self.config.seed, r as u64);
            let t = Instant::now();
            self.model.run_apply_into(&prep, &mut params, &acc, seed, 1.0, 0.0, 1.0)?;
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.0 {
                samples.push(1.0 / dt);
            }
        }
        Ok(samples)
    }
}

/// Dataset index for bench repeat `r`, slot `i` at batch size `batch`,
/// wrapping over `dataset_size`. Widened to `u64` before the modulo:
/// the old `r as u32 * batch as u32` product overflowed once
/// `repeats * batch` crossed 2^32, silently re-benching a skewed index
/// pattern.
pub fn bench_index(r: usize, batch: usize, i: usize, dataset_size: u32) -> u32 {
    debug_assert!(dataset_size > 0);
    ((r as u64 * batch as u64 + i as u64) % dataset_size as u64) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn per_step_noise_seeds_do_not_collide() {
        // Seeds chosen to include the pair that collided under the old
        // i32 folding (see below): 4295 * 1_000_003 wraps past 2^32.
        let mut seen = HashSet::new();
        for &seed in &[0u64, 1, 4295, 4296] {
            for step in 0..50_000u64 {
                assert!(
                    seen.insert(per_step_noise_seed(seed, step)),
                    "seed collision at ({seed}, {step})"
                );
            }
        }
        assert_eq!(seen.len(), 4 * 50_000);
    }

    #[test]
    fn per_step_noise_seed_is_deterministic() {
        assert_eq!(per_step_noise_seed(7, 3), per_step_noise_seed(7, 3));
        assert_ne!(per_step_noise_seed(7, 3), per_step_noise_seed(7, 4));
        assert_ne!(per_step_noise_seed(7, 3), per_step_noise_seed(8, 3));
    }

    #[test]
    fn old_i32_seed_folding_collided() {
        // Documents the bug the 64-bit derivation replaces: the i32 cast
        // of `seed * 1_000_003 + step` wraps, so distinct (seed, step)
        // pairs shared a noise stream.
        let old = |seed: i64, step: i64| (seed * 1_000_003 + step) as i32;
        // 4295 * 1_000_003 = 4_295_012_885 ≡ 45_589 (mod 2^32).
        assert_eq!(old(4295, 0), old(0, 45_589));
    }

    #[test]
    fn bench_index_survives_large_repeats_times_batch() {
        // The old derivation computed `r as u32 * batch as u32`, which
        // wraps once repeats * batch crosses 2^32. 2^20 repeats at batch
        // 2^13 puts the product at 2^33: the u64 path must still agree
        // with exact arithmetic.
        let (r, batch, n) = (1usize << 20, 1usize << 13, 1_000_003u32);
        let exact = ((r as u128 * batch as u128 + 5) % n as u128) as u32;
        assert_eq!(bench_index(r, batch, 5, n), exact);
        // The u32 product would have wrapped to 0 here: 2^20 * 2^13 ≡ 0
        // (mod 2^32), i.e. the old code would return 5 — the new result
        // must differ from that wrapped value.
        assert_ne!(bench_index(r, batch, 5, n), 5 % n);
        // Small cases keep the obvious value.
        assert_eq!(bench_index(2, 8, 3, 1000), 19);
        assert_eq!(bench_index(0, 64, 63, 64), 63);
        assert_eq!(bench_index(3, 4, 0, 5), 12 % 5);
    }

    #[test]
    fn abi_fold_of_noise_seed_is_injective_within_a_run() {
        // The PJRT backend folds the u64 seed to the ABI's i32 slot by
        // xoring the halves; with the structured layout that is
        // stream-id ^ step — a bijection in step, so one run can never
        // reuse a noise seed on the 32-bit path either.
        let fold = |s: u64| ((s >> 32) ^ (s & 0xffff_ffff)) as u32;
        let mut seen = HashSet::new();
        for step in 0..100_000u64 {
            assert!(
                seen.insert(fold(per_step_noise_seed(12345, step))),
                "folded seed collision at step {step}"
            );
        }
    }
}
