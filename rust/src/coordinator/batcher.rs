//! Virtual batching: the BatchMemoryManager (paper Section 2.1 / Alg. 1-2).
//!
//! DP utility wants *logical* batches of thousands of examples (the paper
//! samples `E[L]` = 25 000) while the accelerator fits a few hundred — so
//! logical batches are split into *physical* batches, gradients are
//! accumulated across them, and the optimizer steps once per logical
//! batch. This does not change the privacy accounting (same noise, same
//! sensitivity).
//!
//! Two modes, matching the paper's two JAX implementations:
//!
//! * [`BatchingMode::Variable`] — "naive": the trailing physical batch has
//!   whatever size is left over. Every new size is a new compiled graph
//!   (the recompilation the paper profiles in Fig. A.2); the runtime's
//!   compile cache makes that cost observable.
//! * [`BatchingMode::Masked`] — Algorithm 2: round the logical batch up to
//!   `k` **full** physical batches and mask out the padding examples, so
//!   the compiled shapes never change. A few surplus per-example
//!   gradients are computed and multiplied by zero — the price of never
//!   recompiling.

use crate::coordinator::sampler::Sampler;

/// How logical batches are split into physical ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq, serde::Serialize)]
pub enum BatchingMode {
    /// Trailing partial physical batch keeps its natural (variable) size.
    Variable,
    /// Algorithm 2: pad to full physical batches, mask the padding.
    Masked,
}

/// One physical batch handed to the runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct PhysicalBatch {
    /// Dataset indices; length is the *shape* of the executable input.
    /// In `Masked` mode padding slots repeat index 0 with mask 0.
    pub indices: Vec<u32>,
    /// Algorithm-2 masks: 1.0 for real examples, 0.0 for padding.
    pub mask: Vec<f32>,
    /// True when this is the final physical batch of the logical batch —
    /// the signal to add noise and take the optimizer step (this is the
    /// paper's custom "flag when it is time to take a step").
    pub step_boundary: bool,
}

impl PhysicalBatch {
    /// Number of real (unmasked) examples.
    pub fn real_count(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }
}

/// Splits logical batches into physical batches (the Opacus
/// `BatchMemoryManager` role, plus Algorithm-2 masking).
#[derive(Debug, Clone)]
pub struct BatchMemoryManager {
    physical: usize,
    mode: BatchingMode,
}

impl BatchMemoryManager {
    pub fn new(physical: usize, mode: BatchingMode) -> Self {
        assert!(physical > 0, "physical batch size must be positive");
        Self { physical, mode }
    }

    pub fn physical_batch_size(&self) -> usize {
        self.physical
    }

    pub fn mode(&self) -> BatchingMode {
        self.mode
    }

    /// Split one logical batch (dataset indices from the sampler) into
    /// physical batches. The final batch carries `step_boundary = true`.
    ///
    /// An empty logical batch (possible under Poisson!) yields a single
    /// all-masked physical batch in `Masked` mode — the step still
    /// happens, with noise only, exactly as Algorithm 1 prescribes — and
    /// a single empty batch in `Variable` mode.
    pub fn split(&self, logical: &[u32]) -> Vec<PhysicalBatch> {
        let tl = logical.len();
        match self.mode {
            BatchingMode::Variable => {
                if tl == 0 {
                    return vec![PhysicalBatch {
                        indices: vec![],
                        mask: vec![],
                        step_boundary: true,
                    }];
                }
                let mut out = Vec::with_capacity(tl.div_ceil(self.physical));
                for chunk in logical.chunks(self.physical) {
                    out.push(PhysicalBatch {
                        indices: chunk.to_vec(),
                        mask: vec![1.0; chunk.len()],
                        step_boundary: false,
                    });
                }
                out.last_mut().unwrap().step_boundary = true;
                out
            }
            BatchingMode::Masked => {
                // k = min k with k*p >= tl ; m = k*p (Algorithm 2)
                let k = tl.div_ceil(self.physical).max(1);
                let m = k * self.physical;
                let mut out = Vec::with_capacity(k);
                for c in 0..k {
                    let lo = c * self.physical;
                    let mut indices = Vec::with_capacity(self.physical);
                    let mut mask = Vec::with_capacity(self.physical);
                    for j in lo..lo + self.physical {
                        if j < tl {
                            indices.push(logical[j]);
                            mask.push(1.0);
                        } else {
                            indices.push(*logical.first().unwrap_or(&0));
                            mask.push(0.0);
                        }
                    }
                    out.push(PhysicalBatch {
                        indices,
                        mask,
                        step_boundary: c == k - 1,
                    });
                }
                debug_assert_eq!(out.len() * self.physical, m);
                out
            }
        }
    }

    /// Convenience: sample step `t` with `sampler` and split it.
    pub fn batches_for_step(&self, sampler: &dyn Sampler, step: u64) -> Vec<PhysicalBatch> {
        self.split(&sampler.sample(step))
    }

    /// Naive-JAX decomposition: split the logical batch into chunks whose
    /// sizes come from `available` (the batch sizes that were AOT-lowered
    /// / jit-compiled), greedily largest-first; the remainder uses the
    /// smallest size that fits it, padded with masked slots.
    ///
    /// This mirrors what a naive JAX DP-SGD implementation does at run
    /// time: every *new* chunk size triggers a compilation (jit retrace)
    /// — the runtime's compile cache measures exactly that (Fig. A.2).
    pub fn split_naive(logical: &[u32], available: &[usize]) -> Vec<PhysicalBatch> {
        assert!(!available.is_empty(), "need at least one lowered batch size");
        let mut sizes = available.to_vec();
        sizes.sort_unstable();
        let smallest = sizes[0];
        let mut out = Vec::new();
        let mut rest = logical;
        if logical.is_empty() {
            return vec![PhysicalBatch {
                indices: vec![0; smallest],
                mask: vec![0.0; smallest],
                step_boundary: true,
            }];
        }
        while !rest.is_empty() {
            // Largest lowered size that still fits entirely.
            let fit = sizes.iter().rev().find(|&&s| s <= rest.len()).copied();
            match fit {
                Some(s) => {
                    let (chunk, tail) = rest.split_at(s);
                    out.push(PhysicalBatch {
                        indices: chunk.to_vec(),
                        mask: vec![1.0; s],
                        step_boundary: false,
                    });
                    rest = tail;
                }
                None => {
                    // Remainder smaller than every size: pad the smallest.
                    let s = smallest;
                    let mut indices: Vec<u32> = rest.to_vec();
                    let mut mask = vec![1.0f32; rest.len()];
                    while indices.len() < s {
                        indices.push(rest[0]);
                        mask.push(0.0);
                    }
                    out.push(PhysicalBatch { indices, mask, step_boundary: false });
                    rest = &[];
                }
            }
        }
        out.last_mut().unwrap().step_boundary = true;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_pads_to_full_batches() {
        let bmm = BatchMemoryManager::new(4, BatchingMode::Masked);
        let batches = bmm.split(&[10, 11, 12, 13, 14, 15]);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.indices.len() == 4));
        assert_eq!(batches[1].mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert!(batches[1].step_boundary && !batches[0].step_boundary);
        let real: usize = batches.iter().map(|b| b.real_count()).sum();
        assert_eq!(real, 6);
    }

    #[test]
    fn variable_keeps_partial_tail() {
        let bmm = BatchMemoryManager::new(4, BatchingMode::Variable);
        let batches = bmm.split(&[1, 2, 3, 4, 5]);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[1].indices, vec![5]);
        assert_eq!(batches[1].mask, vec![1.0]);
    }

    #[test]
    fn empty_logical_batch_still_steps() {
        for mode in [BatchingMode::Masked, BatchingMode::Variable] {
            let bmm = BatchMemoryManager::new(8, mode);
            let batches = bmm.split(&[]);
            assert_eq!(batches.len(), 1);
            assert!(batches[0].step_boundary);
            assert_eq!(batches[0].real_count(), 0);
        }
    }

    #[test]
    fn naive_split_covers_all_examples_once() {
        let logical: Vec<u32> = (0..77).collect();
        let batches = BatchMemoryManager::split_naive(&logical, &[2, 4, 8, 16, 32]);
        // 77 = 32 + 32 + 8 + 4 + (1 padded to 2)
        let sizes: Vec<usize> = batches.iter().map(|b| b.indices.len()).collect();
        assert_eq!(sizes, vec![32, 32, 8, 4, 2]);
        let real: Vec<u32> = batches
            .iter()
            .flat_map(|b| {
                b.indices
                    .iter()
                    .zip(&b.mask)
                    .filter(|(_, &m)| m > 0.0)
                    .map(|(&i, _)| i)
            })
            .collect();
        assert_eq!(real, logical);
        assert!(batches.last().unwrap().step_boundary);
    }

    #[test]
    fn naive_split_empty_logical_batch() {
        let batches = BatchMemoryManager::split_naive(&[], &[4, 8]);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].indices.len(), 4);
        assert_eq!(batches[0].real_count(), 0);
    }

    #[test]
    fn exact_multiple_has_no_padding() {
        let bmm = BatchMemoryManager::new(3, BatchingMode::Masked);
        let batches = bmm.split(&[1, 2, 3, 4, 5, 6]);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.real_count() == 3));
    }
}
