//! The Layer-3 coordinator: everything the paper's training pipeline does
//! *outside* the jitted step function.
//!
//! This is where "DP-SGD without shortcuts" actually lives: the
//! [`sampler::PoissonSampler`] draws true per-example Bernoulli samples
//! (variable logical batch sizes — the part most implementations skip),
//! the [`batcher::BatchMemoryManager`] splits logical batches into
//! fixed-shape physical batches with Algorithm-2 masks, and the
//! step-driven [`trainer::TrainSession`] (wrapped by
//! [`trainer::Trainer`]) drives the accum/apply executables through a
//! bound-buffer runtime session while timing each section (paper
//! Table 2), with checkpoint/resume built into the loop.

pub mod batcher;
pub mod config;
pub mod sampler;
pub mod trainer;
