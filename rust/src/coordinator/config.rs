//! Training-run configuration (the "config system" a launcher consumes).
//!
//! Defaults mirror the paper's experimental setup (Section 3): sampling
//! rate 0.5 over a 50k-example dataset (`E[L]` = 25k at paper scale —
//! scaled down here), four optimizer steps for benchmarking, eps = 8 /
//! delta = 2.04e-5 privacy budget, clip norm from Table A2.

use crate::coordinator::batcher::BatchingMode;
use crate::coordinator::sampler::SamplerChoice;
use crate::privacy::AccountantKind;

/// Fault-tolerance retry policy for the data-parallel executor and the
/// trainer (DESIGN.md §11).
///
/// Failed accumulation groups are re-run on a surviving session, and a
/// failed apply call is re-issued on the same session, up to
/// `max_attempts` total attempts per unit with exponential backoff.
/// Retries are **bitwise-lossless**: a group's partial is a pure
/// function of the step's parameters and the sampled batch, and a step
/// retry replays the *same* per-step Poisson draw and noise
/// `(seed, stream)` tuple (both are pure functions of
/// `(experiment seed, step)`), so a recovered trajectory is identical
/// to the fault-free one. Like `workers`, this knob moves wall-clock
/// only — never bits — and is excluded from the checkpoint fingerprint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts per failed unit (group or apply call), counting
    /// the first. `1` disables retries; `0` is treated as 1.
    pub max_attempts: u32,
    /// Backoff before the second attempt, in milliseconds; doubles on
    /// each further attempt (capped at `backoff_ms << 6`).
    pub backoff_ms: u64,
    /// UNSOUND (audit-demo knob, `--retry-fresh-draw`): declare a
    /// policy that re-draws the Poisson mask and noise on step retry
    /// instead of replaying the same tuple. The executor never
    /// implements this — redrawing on retry is the silent sampling
    /// shortcut of arXiv 2411.04205 — but declaring it lets the static
    /// auditor demonstrate the `retry.fresh-draw` Deny, exactly like
    /// `--sampler shuffle`.
    pub fresh_draw_on_retry: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 3, backoff_ms: 10, fresh_draw_on_retry: false }
    }
}

impl RetryPolicy {
    /// Backoff before attempt `attempt + 1` (0-based failed attempt):
    /// `backoff_ms * 2^attempt`, exponent capped at 6.
    pub fn backoff_before(&self, attempt: u32) -> std::time::Duration {
        std::time::Duration::from_millis(self.backoff_ms << attempt.min(6))
    }
}

/// Everything needed to launch one training/benchmark run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model ladder name (must exist in artifacts/manifest.json).
    pub model: String,
    /// AOT step variant: nonprivate | naive | masked | ghost | bk |
    /// perex | mix (the CLI's `--clip-method` resolves to one of these
    /// via `clipping::clip_method_variant`). Every variant's
    /// *trajectory* is bitwise-identical; they differ in executed
    /// accumulate strategy — wall-clock and memory traffic only
    /// (DESIGN.md §9).
    pub variant: String,
    /// Use the bf16 param-storage executables (`--param-dtype bf16`):
    /// bf16 storage, f32 compute, round-to-nearest-even on store
    /// (DESIGN.md §14). Changes the trajectory, so it is part of the
    /// checkpoint fingerprint (through the dtype tag).
    pub bf16: bool,
    /// Reference-kernel selection (`--kernel scalar|simd|auto`). The
    /// scalar and SIMD paths share the fixed 8-lane reduction tree, so
    /// this is a wall-clock knob only — bits never change (DESIGN.md
    /// §14) — and it is excluded from the checkpoint fingerprint like
    /// `workers`.
    pub kernel: String,
    /// Dataset size N.
    pub dataset_size: u32,
    /// Poisson sampling rate q (expected logical batch = q * N).
    pub sampling_rate: f64,
    /// Physical batch size (must match a lowered executable).
    pub physical_batch: usize,
    /// Batching mode: Masked (Algorithm 2) or Variable (naive).
    pub mode: BatchingMode,
    /// Optimizer steps to take.
    pub steps: u64,
    /// Learning rate.
    pub lr: f64,
    /// Clipping norm C (informational: baked into accum at AOT time).
    pub clip_norm: f64,
    /// Noise multiplier sigma; if None, calibrated from (eps, delta).
    pub noise_multiplier: Option<f64>,
    /// Target privacy budget used when noise_multiplier is None.
    pub target_epsilon: f64,
    pub delta: f64,
    /// Experiment seed (drives sampling, noise, and the dataset).
    pub seed: u64,
    /// Evaluate on this many held-out examples after training (0 = skip).
    pub eval_examples: u32,
    /// Data-parallel worker sessions (`dpshort --workers`). Each worker
    /// thread owns its own execution session; the globally sampled
    /// batch is sharded across them and gradients combine through the
    /// fixed-tree reduction (DESIGN.md §8), so the trajectory is
    /// **bitwise-identical for every value** — this knob moves
    /// wall-clock only, never bits, and is therefore excluded from the
    /// checkpoint fingerprint (a checkpoint taken at 4 workers resumes
    /// correctly at 1). `0` is treated as 1.
    pub workers: usize,
    /// Subsampling scheme (`--sampler poisson|shuffle`). Shuffle is the
    /// studied shortcut: the plan audit denies it under Poisson
    /// accounting unless `allow_unsound` is set. Changes the sampled
    /// batches, so it IS part of the checkpoint fingerprint.
    pub sampler: SamplerChoice,
    /// Accountant reporting epsilon (`--accountant rdp|pld`). Reporting
    /// only — never changes the trajectory, so it is excluded from the
    /// checkpoint fingerprint.
    pub accountant: AccountantKind,
    /// Run even when the plan audit raises Deny diagnostics
    /// (`--allow-unsound`); the TrainReport and every checkpoint are
    /// then stamped `unaudited`.
    pub allow_unsound: bool,
    /// Fault-tolerance retry policy (`--retries`, `--retry-backoff-ms`).
    /// Wall-clock only — excluded from the checkpoint fingerprint.
    pub retry: RetryPolicy,
    /// Declared epsilon *budget* (quoted at `delta`), when this run
    /// promises to stay within one — the serve ledger's admission
    /// contract. Unlike `target_epsilon` (a calibration input), a
    /// declared budget is enforced: the `budget.overspend` audit rule
    /// denies a plan whose configured steps would already overspend it,
    /// and the ledger hard-stops the run before any step that would.
    /// `None` (the default, and every standalone `dpshort train` run)
    /// declares no budget and is never denied for spend. Reporting/
    /// enforcement only — never changes the trajectory, so it is
    /// excluded from the checkpoint fingerprint.
    pub declared_epsilon: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "vit-micro".into(),
            variant: "masked".into(),
            bf16: false,
            kernel: "auto".into(),
            dataset_size: 2048,
            sampling_rate: 0.5,
            physical_batch: 16,
            mode: BatchingMode::Masked,
            steps: 4,
            lr: 3.0e-4,
            clip_norm: 1.0,
            noise_multiplier: None,
            target_epsilon: 8.0,
            delta: 2.04e-5,
            seed: 0,
            eval_examples: 256,
            workers: 1,
            sampler: SamplerChoice::Poisson,
            accountant: AccountantKind::Rdp,
            allow_unsound: false,
            retry: RetryPolicy::default(),
            declared_epsilon: None,
        }
    }
}

impl TrainConfig {
    /// Expected logical batch size `E[L] = q * N`.
    pub fn expected_logical_batch(&self) -> f64 {
        self.sampling_rate * self.dataset_size as f64
    }

    /// Is this configuration differentially private?
    pub fn is_private(&self) -> bool {
        self.variant != "nonprivate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paperlike() {
        let c = TrainConfig::default();
        assert_eq!(c.sampling_rate, 0.5);
        assert_eq!(c.steps, 4);
        assert_eq!(c.target_epsilon, 8.0);
        assert!(c.is_private());
        assert_eq!(c.expected_logical_batch(), 1024.0);
        assert_eq!(c.sampler, SamplerChoice::Poisson);
        assert_eq!(c.accountant, AccountantKind::Rdp);
        assert!(!c.allow_unsound);
        assert_eq!(c.retry, RetryPolicy::default());
        assert!(!c.retry.fresh_draw_on_retry, "sound retries by default");
        assert_eq!(c.kernel, "auto");
        assert!(!c.bf16, "f32 param storage by default");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy { max_attempts: 8, backoff_ms: 10, fresh_draw_on_retry: false };
        assert_eq!(p.backoff_before(0).as_millis(), 10);
        assert_eq!(p.backoff_before(1).as_millis(), 20);
        assert_eq!(p.backoff_before(3).as_millis(), 80);
        // Exponent cap: no unbounded sleep however many attempts.
        assert_eq!(p.backoff_before(40).as_millis(), 10 * 64);
    }

    #[test]
    fn logical_batch_tracks_rate() {
        let mut c = TrainConfig::default();
        c.sampling_rate = 0.25;
        c.dataset_size = 4000;
        assert_eq!(c.expected_logical_batch(), 1000.0);
        c.variant = "nonprivate".into();
        assert!(!c.is_private());
    }
}
