//! artifacts/manifest.json schema — written by python/compile/aot.py,
//! the single source of truth about what was lowered.

use crate::models::{conv_out, Activation, LayerKind, LayerSpec};
use crate::util::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered executable.
#[derive(Debug, Clone)]
pub struct ExecutableMeta {
    /// File name under the artifacts dir.
    pub path: String,
    /// "accum" | "apply" | "eval".
    pub kind: String,
    /// Step variant for accum executables.
    pub variant: Option<String>,
    /// Physical batch size for accum/eval executables.
    pub batch: Option<usize>,
    /// "f32" (default) or "bf16".
    pub dtype: Option<String>,
}

impl ExecutableMeta {
    pub fn dtype_or_f32(&self) -> &str {
        self.dtype.as_deref().unwrap_or("f32")
    }
}

/// One model's artifact set.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub family: String,
    pub n_params: usize,
    pub image: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub clip_norm: f64,
    pub flops_fwd_per_example: f64,
    pub init_params: String,
    pub executables: Vec<ExecutableMeta>,
    /// Executable layer IR (manifest key `"layers"`): the dense chain
    /// the flat parameter vector lays out, in order. Empty for pre-IR
    /// manifests — [`ModelMeta::layer_specs`] then resolves the legacy
    /// single dense layer `image² * channels -> num_classes` (exactly
    /// the seed `ref-linear` shape), so old artifact catalogs keep
    /// loading and executing unchanged.
    pub layers: Vec<LayerSpec>,
}

impl ModelMeta {
    /// The executable layer chain: the explicit `layers` list, or the
    /// legacy single-dense fallback when the manifest predates the
    /// layer IR. Never empty.
    pub fn layer_specs(&self) -> Vec<LayerSpec> {
        if self.layers.is_empty() {
            vec![LayerSpec::dense(
                self.image * self.image * self.channels,
                self.num_classes,
            )]
        } else {
            self.layers.clone()
        }
    }
    /// Find the accum executable for (variant, batch, dtype).
    pub fn find_accum(&self, variant: &str, batch: usize, dtype: &str) -> Option<&ExecutableMeta> {
        self.executables.iter().find(|e| {
            e.kind == "accum"
                && e.variant.as_deref() == Some(variant)
                && e.batch == Some(batch)
                && e.dtype_or_f32() == dtype
        })
    }

    pub fn find_apply(&self) -> Option<&ExecutableMeta> {
        self.executables.iter().find(|e| e.kind == "apply")
    }

    /// Find the apply executable for a parameter dtype (`"f32"` |
    /// `"bf16"`): the dtype-less legacy entry counts as f32, so old
    /// manifests keep resolving.
    pub fn find_apply_dtype(&self, dtype: &str) -> Option<&ExecutableMeta> {
        self.executables.iter().find(|e| e.kind == "apply" && e.dtype_or_f32() == dtype)
    }

    pub fn find_eval(&self) -> Option<&ExecutableMeta> {
        self.executables.iter().find(|e| e.kind == "eval")
    }

    /// Batch sizes lowered for (variant, dtype), ascending.
    pub fn accum_batches(&self, variant: &str, dtype: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .iter()
            .filter(|e| {
                e.kind == "accum"
                    && e.variant.as_deref() == Some(variant)
                    && e.dtype_or_f32() == dtype
            })
            .filter_map(|e| e.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// All accum variants present (f32).
    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .executables
            .iter()
            .filter(|e| e.kind == "accum" && e.dtype_or_f32() == "f32")
            .filter_map(|e| e.variant.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub seed: u64,
    /// BTreeMap for stable iteration order in reports.
    pub models: BTreeMap<String, ModelMeta>,
}

fn need<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow!("manifest: missing key {key:?}"))
}

fn need_usize(v: &Value, key: &str) -> Result<usize> {
    need(v, key)?.as_usize().ok_or_else(|| anyhow!("manifest: {key:?} not a number"))
}

fn need_f64(v: &Value, key: &str) -> Result<f64> {
    need(v, key)?.as_f64().ok_or_else(|| anyhow!("manifest: {key:?} not a number"))
}

fn need_str(v: &Value, key: &str) -> Result<String> {
    Ok(need(v, key)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: {key:?} not a string"))?
        .to_string())
}

impl ExecutableMeta {
    fn from_value(v: &Value) -> Result<Self> {
        Ok(Self {
            path: need_str(v, "path")?,
            kind: need_str(v, "kind")?,
            variant: v.get("variant").and_then(|x| x.as_str()).map(str::to_string),
            batch: v.get("batch").and_then(|x| x.as_usize()),
            dtype: v.get("dtype").and_then(|x| x.as_str()).map(str::to_string),
        })
    }
}

fn layer_from_value(v: &Value) -> Result<LayerSpec> {
    let activation = match v.get("activation").and_then(|a| a.as_str()) {
        None => Activation::None,
        Some(s) => Activation::parse(s)
            .ok_or_else(|| anyhow!("manifest: unknown activation {s:?} (none|relu)"))?,
    };
    // The "kind" discriminator is optional and defaults to "dense", so
    // every pre-PR-9 layered manifest parses unchanged. Non-dense kinds
    // carry their structural fields and derive the flat widths, which
    // keeps a manifest from lying about `d_in`/`d_out`.
    match v.get("kind").and_then(|k| k.as_str()).unwrap_or("dense") {
        "dense" => Ok(LayerSpec {
            d_in: need_usize(v, "d_in")?,
            d_out: need_usize(v, "d_out")?,
            activation,
            kind: LayerKind::Dense,
        }),
        "conv2d" => {
            let (c_in, h_in, w_in) =
                (need_usize(v, "c_in")?, need_usize(v, "h_in")?, need_usize(v, "w_in")?);
            let (c_out, kh, kw) =
                (need_usize(v, "c_out")?, need_usize(v, "kh")?, need_usize(v, "kw")?);
            let (stride, pad) = (need_usize(v, "stride")?, need_usize(v, "pad")?);
            if stride == 0 || kh == 0 || kw == 0 || kh > h_in + 2 * pad || kw > w_in + 2 * pad {
                return Err(anyhow!(
                    "manifest: conv2d kernel {kh}x{kw} stride {stride} does not fit \
                     a {h_in}x{w_in} input with padding {pad}"
                ));
            }
            let (ho, wo) = (conv_out(h_in, kh, stride, pad), conv_out(w_in, kw, stride, pad));
            Ok(LayerSpec {
                d_in: c_in * h_in * w_in,
                d_out: c_out * ho * wo,
                activation,
                kind: LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad },
            })
        }
        "layernorm" => {
            let d = need_usize(v, "d")?;
            Ok(LayerSpec { d_in: d, d_out: d, activation, kind: LayerKind::LayerNorm })
        }
        "attention" => {
            let (t, d_model, d_head) =
                (need_usize(v, "t")?, need_usize(v, "d_model")?, need_usize(v, "d_head")?);
            Ok(LayerSpec {
                d_in: t * d_model,
                d_out: t * d_model,
                activation,
                kind: LayerKind::Attention { t, d_model, d_head },
            })
        }
        other => Err(anyhow!(
            "manifest: unknown layer kind {other:?} (dense|conv2d|layernorm|attention)"
        )),
    }
}

impl ModelMeta {
    fn from_value(v: &Value) -> Result<Self> {
        let executables = need(v, "executables")?
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: executables not an array"))?
            .iter()
            .map(ExecutableMeta::from_value)
            .collect::<Result<Vec<_>>>()?;
        // Optional: absent in pre-IR manifests (layer_specs() falls
        // back to the legacy single dense layer).
        let layers = match v.get("layers") {
            None => Vec::new(),
            Some(lv) => lv
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: layers not an array"))?
                .iter()
                .map(layer_from_value)
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Self {
            family: need_str(v, "family")?,
            n_params: need_usize(v, "n_params")?,
            image: need_usize(v, "image")?,
            channels: need_usize(v, "channels")?,
            num_classes: need_usize(v, "num_classes")?,
            clip_norm: need_f64(v, "clip_norm")?,
            flops_fwd_per_example: need_f64(v, "flops_fwd_per_example")?,
            init_params: need_str(v, "init_params")?,
            executables,
            layers,
        })
    }
}

impl Manifest {
    /// Parse manifest JSON text (in-tree parser; offline, no serde).
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow!("parsing manifest.json: {e}"))?;
        let mut models = BTreeMap::new();
        for (name, mv) in need(&v, "models")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: models not an object"))?
        {
            models.insert(
                name.clone(),
                ModelMeta::from_value(mv).with_context(|| format!("model {name:?}"))?,
            );
        }
        Ok(Self {
            version: need_usize(&v, "version")? as u32,
            seed: need_usize(&v, "seed")? as u64,
            models,
        })
    }

    pub fn load(artifacts_dir: &Path) -> Result<(Self, PathBuf)> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let m = Manifest::parse(&text).context("parsing manifest.json")?;
        Ok((m, artifacts_dir.to_path_buf()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest::parse(
            r#"{
            "version": 1, "seed": 0,
            "models": {"m": {
              "family": "vit", "n_params": 10, "image": 32, "channels": 3,
              "num_classes": 100, "clip_norm": 1.0,
              "flops_fwd_per_example": 1000.0, "init_params": "m_init.bin",
              "executables": [
                {"path": "a", "kind": "accum", "variant": "masked", "batch": 8, "dtype": "f32"},
                {"path": "b", "kind": "accum", "variant": "masked", "batch": 4, "dtype": "f32"},
                {"path": "c", "kind": "accum", "variant": "masked", "batch": 8, "dtype": "bf16"},
                {"path": "d", "kind": "apply"},
                {"path": "e", "kind": "eval", "batch": 8}
              ]}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn lookup_by_variant_batch_dtype() {
        let m = sample();
        let mm = m.model("m").unwrap();
        assert_eq!(mm.find_accum("masked", 8, "f32").unwrap().path, "a");
        assert_eq!(mm.find_accum("masked", 8, "bf16").unwrap().path, "c");
        assert!(mm.find_accum("masked", 16, "f32").is_none());
        assert!(mm.find_apply().is_some());
        assert_eq!(mm.accum_batches("masked", "f32"), vec![4, 8]);
        assert_eq!(mm.variants(), vec!["masked".to_string()]);
    }

    #[test]
    fn missing_model_is_an_error() {
        assert!(sample().model("nope").is_err());
    }

    #[test]
    fn pre_ir_manifests_fall_back_to_one_dense_layer() {
        let m = sample();
        let mm = m.model("m").unwrap();
        assert!(mm.layers.is_empty(), "sample manifest predates the layer IR");
        let specs = mm.layer_specs();
        assert_eq!(specs, vec![LayerSpec::dense(32 * 32 * 3, 100)]);
    }

    #[test]
    fn layered_manifests_parse_the_layer_chain() {
        let m = Manifest::parse(
            r#"{
            "version": 2, "seed": 0,
            "models": {"mlp": {
              "family": "mlp", "n_params": 100, "image": 2, "channels": 3,
              "num_classes": 4, "clip_norm": 1.0,
              "flops_fwd_per_example": 1.0, "init_params": "mlp_init.bin",
              "layers": [
                {"d_in": 12, "d_out": 6, "activation": "relu"},
                {"d_in": 6, "d_out": 4}
              ],
              "executables": []}}}"#,
        )
        .unwrap();
        let specs = m.model("mlp").unwrap().layer_specs();
        assert_eq!(
            specs,
            vec![LayerSpec::dense_relu(12, 6), LayerSpec::dense(6, 4)]
        );
        // Unknown activations are a parse error, not a silent identity.
        assert!(Manifest::parse(
            r#"{
            "version": 2, "seed": 0,
            "models": {"m": {
              "family": "mlp", "n_params": 1, "image": 1, "channels": 1,
              "num_classes": 1, "clip_norm": 1.0,
              "flops_fwd_per_example": 1.0, "init_params": "i.bin",
              "layers": [{"d_in": 1, "d_out": 1, "activation": "gelu"}],
              "executables": []}}}"#,
        )
        .is_err());
    }

    fn model_with_layers(layers_json: &str) -> Result<Manifest> {
        Manifest::parse(&format!(
            r#"{{
            "version": 2, "seed": 0,
            "models": {{"m": {{
              "family": "resnet", "n_params": 1, "image": 4, "channels": 3,
              "num_classes": 2, "clip_norm": 1.0,
              "flops_fwd_per_example": 1.0, "init_params": "i.bin",
              "layers": [{layers_json}],
              "executables": []}}}}}}"#
        ))
    }

    #[test]
    fn kind_discriminated_layers_parse_with_derived_widths() {
        let m = model_with_layers(
            r#"{"kind": "conv2d", "c_in": 3, "h_in": 4, "w_in": 4, "c_out": 2,
                "kh": 3, "kw": 3, "stride": 2, "pad": 1, "activation": "relu"},
               {"kind": "attention", "t": 2, "d_model": 4, "d_head": 3},
               {"kind": "layernorm", "d": 8},
               {"d_in": 8, "d_out": 2}"#,
        )
        .unwrap();
        let specs = m.model("m").unwrap().layer_specs();
        assert_eq!(
            specs,
            vec![
                LayerSpec::conv2d(3, 4, 2, 3, 2, 1, Activation::Relu),
                LayerSpec::attention(2, 4, 3),
                LayerSpec::layernorm(8),
                LayerSpec::dense(8, 2),
            ]
        );
        // Derived flat widths, not manifest-claimed ones.
        assert_eq!(specs[0].d_in, 48);
        assert_eq!(specs[0].d_out, 2 * 2 * 2);
    }

    #[test]
    fn malformed_layer_kinds_are_parse_errors() {
        // Unknown discriminator.
        assert!(model_with_layers(r#"{"kind": "pool", "d_in": 1, "d_out": 1}"#).is_err());
        // conv2d kernel larger than the padded input (would underflow
        // the floor output size).
        assert!(model_with_layers(
            r#"{"kind": "conv2d", "c_in": 1, "h_in": 2, "w_in": 2, "c_out": 1,
                "kh": 5, "kw": 5, "stride": 1, "pad": 0}"#
        )
        .is_err());
        // conv2d stride zero.
        assert!(model_with_layers(
            r#"{"kind": "conv2d", "c_in": 1, "h_in": 2, "w_in": 2, "c_out": 1,
                "kh": 1, "kw": 1, "stride": 0, "pad": 0}"#
        )
        .is_err());
        // Non-dense kinds still demand their structural fields.
        assert!(model_with_layers(r#"{"kind": "attention", "t": 2}"#).is_err());
        assert!(model_with_layers(r#"{"kind": "layernorm"}"#).is_err());
    }
}
