//! Static analysis of HLO text — the reproduction's stand-in for the
//! paper's Nsight memory profiling.
//!
//! Parses the shapes out of an AOT-lowered module and reports:
//!
//! * the largest live tensor and total declared tensor bytes (a proxy
//!   for the activation/grad footprint that determines Fig. 3's max
//!   physical batch), and
//! * whether any tensor of shape `[B, P]` (per-example gradients for
//!   the full parameter vector) exists — the **structural proof** that
//!   ghost clipping / Book Keeping never materialize per-example grads
//!   while the per-example variants do (paper Section 2.2).
//!
//! The parser is deliberately small: HLO text lines look like
//! `  %name = f32[16,120100]{1,0} op-name(...)` and we only need the
//! result dtype/shape of each instruction.

use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Summary of one HLO module's tensor population.
#[derive(Debug, Clone)]
pub struct HloStats {
    /// Instruction count by opcode.
    pub op_counts: BTreeMap<String, usize>,
    /// Total bytes across all instruction result shapes.
    pub total_tensor_bytes: u64,
    /// Largest single tensor (bytes, rendered shape).
    pub largest_tensor_bytes: u64,
    pub largest_tensor_shape: String,
    /// All distinct result shapes (dims only) and their counts.
    pub shapes: BTreeMap<Vec<u64>, usize>,
    /// Dtypes [`dtype_bytes`] did not recognize. Their tensors are
    /// priced at 4 bytes/element in the totals; `dpshort audit` turns
    /// a non-empty set into a `dtype.unknown` diagnostic instead of
    /// letting the assumption stay silent.
    pub unknown_dtypes: BTreeSet<String>,
}

/// Element width of an HLO dtype, or `None` for dtypes the memory
/// model does not know (callers decide how to surface the gap; the
/// analyzer's totals fall back to 4 bytes and record the name in
/// [`HloStats::unknown_dtypes`]).
pub fn dtype_bytes(ty: &str) -> Option<u64> {
    match ty {
        "f64" | "s64" | "u64" | "c64" => Some(8),
        "f32" | "s32" | "u32" => Some(4),
        "f16" | "bf16" | "s16" | "u16" => Some(2),
        "s8" | "u8" | "pred" => Some(1),
        _ => None,
    }
}

/// Parse ` f32[16,120100]{...}` -> (elem_bytes, dims, dtype). Returns
/// None for tuple/opaque/token results.
fn parse_shape(s: &str) -> Option<(u64, Vec<u64>, String)> {
    let s = s.trim_start();
    let bracket = s.find('[')?;
    let ty = &s[..bracket];
    if !ty.chars().all(|c| c.is_ascii_alphanumeric()) || ty.is_empty() {
        return None;
    }
    let close = s.find(']')?;
    let dims_str = &s[bracket + 1..close];
    let dims: Vec<u64> = if dims_str.is_empty() {
        vec![]
    } else {
        dims_str
            .split(',')
            .map(|d| d.trim().parse::<u64>().ok())
            .collect::<Option<_>>()?
    };
    Some((dtype_bytes(ty).unwrap_or(4), dims, ty.to_string()))
}

/// Analyze an HLO text module.
pub fn analyze(text: &str) -> HloStats {
    let mut stats = HloStats {
        op_counts: BTreeMap::new(),
        total_tensor_bytes: 0,
        largest_tensor_bytes: 0,
        largest_tensor_shape: String::new(),
        shapes: BTreeMap::new(),
        unknown_dtypes: BTreeSet::new(),
    };
    for line in text.lines() {
        let line = line.trim_start();
        // instruction lines: [ROOT] [%]name = <shape> opcode(...)
        // (jax-emitted HLO text omits the % sigil on instruction names)
        let rest = line.strip_prefix("ROOT ").unwrap_or(line);
        let named = rest.starts_with('%')
            || rest
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_alphabetic() || c == '_');
        if !named {
            continue;
        }
        let Some(eq) = rest.find(" = ") else { continue };
        // the name must not contain spaces (rules out header lines)
        if rest[..eq].contains(' ') {
            continue;
        }
        let rhs = &rest[eq + 3..];
        let Some((bytes_per, dims, ty)) = parse_shape(rhs) else { continue };
        if dtype_bytes(&ty).is_none() {
            stats.unknown_dtypes.insert(ty);
        }
        // opcode: token after the shape's layout annotation
        let after_shape = rhs
            .find(' ')
            .map(|i| rhs[i + 1..].trim_start())
            .unwrap_or("");
        let opcode: String = after_shape
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '.')
            .collect();
        let opcode = opcode
            .split('.')
            .next()
            .unwrap_or("")
            .to_string();
        if !opcode.is_empty() {
            *stats.op_counts.entry(opcode).or_insert(0) += 1;
        }
        let total: u64 = bytes_per * dims.iter().product::<u64>().max(1);
        stats.total_tensor_bytes += total;
        if total > stats.largest_tensor_bytes {
            stats.largest_tensor_bytes = total;
            stats.largest_tensor_shape = format!("{dims:?}");
        }
        *stats.shapes.entry(dims).or_insert(0) += 1;
    }
    stats
}

/// Analyze an artifact file.
pub fn analyze_file(path: &Path) -> Result<HloStats> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(analyze(&text))
}

impl HloStats {
    /// Does any tensor have exactly the shape [batch, n_params]?
    /// (The per-example gradient matrix ghost clipping avoids.)
    pub fn has_tensor(&self, dims: &[u64]) -> bool {
        self.shapes.contains_key(&dims.to_vec())
    }

    /// Count of instructions with a given opcode.
    pub fn ops(&self, opcode: &str) -> usize {
        self.op_counts.get(opcode).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule jit_f, entry_computation_layout={(f32[10]{0})->f32[10]{0}}

ENTRY main.5 {
  %p0 = f32[10]{0} parameter(0)
  %c = f32[] constant(2)
  %b = f32[10]{0} broadcast(%c), dimensions={}
  %big = f32[16,120100]{1,0} broadcast(%c), dimensions={}
  %m = bf16[4,8]{1,0} convert(%p0)
  ROOT %mul = f32[10]{0} multiply(%p0, %b)
}
"#;

    #[test]
    fn parses_shapes_and_ops() {
        let s = analyze(SAMPLE);
        assert_eq!(s.ops("parameter"), 1);
        assert_eq!(s.ops("broadcast"), 2);
        assert_eq!(s.ops("multiply"), 1);
        assert!(s.has_tensor(&[16, 120100]));
        assert!(s.has_tensor(&[10]));
        assert!(!s.has_tensor(&[9, 9]));
        assert_eq!(s.largest_tensor_bytes, 16 * 120100 * 4);
        assert_eq!(s.largest_tensor_shape, "[16, 120100]");
    }

    #[test]
    fn bf16_bytes_counted() {
        let s = analyze(SAMPLE);
        // bf16[4,8] = 64 bytes contributes to the total
        assert!(s.total_tensor_bytes >= 16 * 120100 * 4 + 64);
    }

    #[test]
    fn scalar_and_empty_shapes() {
        let s = analyze("ENTRY e {\n  %c = f32[] constant(1)\n}\n");
        assert!(s.has_tensor(&[]));
        assert_eq!(s.total_tensor_bytes, 4);
    }

    #[test]
    fn ignores_non_instruction_lines() {
        let s = analyze("HloModule foo\n\nsome comment\n");
        assert_eq!(s.total_tensor_bytes, 0);
    }

    #[test]
    fn known_dtypes_leave_the_unknown_set_empty() {
        assert!(analyze(SAMPLE).unknown_dtypes.is_empty());
        assert_eq!(dtype_bytes("bf16"), Some(2));
        assert_eq!(dtype_bytes("q8"), None);
    }

    #[test]
    fn unknown_dtypes_are_recorded_not_silently_priced() {
        let s = analyze("ENTRY e {\n  %q = q8[8]{0} custom-call(%p)\n  %f = f32[2]{0} add(%a, %b)\n}\n");
        assert_eq!(
            s.unknown_dtypes.iter().collect::<Vec<_>>(),
            vec![&"q8".to_string()]
        );
        // Totals still count the unknown tensor at the 4-byte fallback.
        assert_eq!(s.total_tensor_bytes, 8 * 4 + 2 * 4);
    }
}
