//! The model-facing runtime facade: typed wrappers over the session
//! API and the legacy flat-param entry points, generic over the
//! execution [`Backend`].
//!
//! [`Runtime`] pairs a manifest (what was lowered) with a backend (how
//! to run it); [`ModelRuntime`] is the per-model view the trainer
//! drives. Hot loops run on an [`ExecSession`] opened through
//! [`Runtime::open_session`] (lifetime tied to the runtime, so a
//! step-driven trainer can own its model view and the session side by
//! side) or [`ModelRuntime::open_session`]. Artifact-backed runtimes
//! come from [`Runtime::load`] (PJRT, feature `pjrt`); the
//! dependency-free default is [`Runtime::reference`], whose manifest
//! and executables are synthesized in-memory by the pure-Rust
//! reference backend.
//!
//! The backend is held as `Arc<dyn Backend + Send + Sync>` (not `Rc`)
//! so sessions can later be driven from worker threads — the sharding
//! seam the ROADMAP asks for.

use super::backend::{AccumArgs, AccumOut, AccumStats, ApplyArgs, Backend, ExecSession, Prepared};
use super::compile_cache::CompileRecord;
use super::manifest::{Manifest, ModelMeta};
use super::reference::ReferenceBackend;
use super::tensor::{self, Tensor};
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Owns the manifest and the execution backend.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
    backend: Arc<dyn Backend + Send + Sync>,
}

impl Runtime {
    /// Load an artifacts directory (must contain manifest.json) and
    /// execute it through the PJRT backend. Requires the `pjrt` feature;
    /// without it this returns an error pointing at
    /// [`Runtime::reference`].
    pub fn load(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifacts_dir.into();
        let (manifest, dir) = Manifest::load(&dir)?;
        Self::artifact_backend(dir, manifest)
    }

    #[cfg(feature = "pjrt")]
    fn artifact_backend(dir: PathBuf, manifest: Manifest) -> Result<Self> {
        let backend: Arc<dyn Backend + Send + Sync> =
            Arc::new(super::pjrt::PjrtBackend::new()?);
        Ok(Self { dir, manifest, backend })
    }

    #[cfg(not(feature = "pjrt"))]
    fn artifact_backend(dir: PathBuf, _manifest: Manifest) -> Result<Self> {
        Err(anyhow!(
            "artifacts at {} need the PJRT backend; rebuild with `--features pjrt` \
             or use Runtime::reference() for the pure-Rust backend",
            dir.display()
        ))
    }

    /// The shared launcher policy: artifacts through PJRT when both the
    /// feature and `<dir>/manifest.json` are present, the pure-Rust
    /// reference backend otherwise — so every entry point (CLI,
    /// examples, benches) works on a fresh offline checkout.
    pub fn auto(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        Self::auto_with_threads(artifacts_dir, 0)
    }

    /// [`Self::auto`] with the reference worker-thread knob (`dpshort
    /// --threads`). The policy lives here — not in the CLI — so every
    /// entry point picks the same backend on the same checkout. The
    /// knob only applies when the policy selects the reference backend;
    /// selecting artifacts with `threads > 0` is an error (PJRT owns
    /// its own threading).
    pub fn auto_with_threads(artifacts_dir: impl Into<PathBuf>, threads: usize) -> Result<Self> {
        Self::auto_with_options(artifacts_dir, threads, None)
    }

    /// [`Self::auto_with_threads`] plus an explicit reference-kernel
    /// request (the `dpshort --kernel` knob). `Some` is an error when
    /// the policy selects artifacts — PJRT owns its own kernels, like
    /// its own threading; `None` lets the reference backend
    /// auto-detect.
    pub fn auto_with_options(
        artifacts_dir: impl Into<PathBuf>,
        threads: usize,
        kernel: Option<super::kernels::Kernel>,
    ) -> Result<Self> {
        let dir = artifacts_dir.into();
        if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
            if threads > 0 {
                return Err(anyhow!(
                    "a worker-thread override applies to the reference backend only; \
                     the PJRT backend manages its own threading"
                ));
            }
            if kernel.is_some() {
                return Err(anyhow!(
                    "a kernel override applies to the reference backend only; \
                     the PJRT backend owns its own kernels"
                ));
            }
            Self::load(dir)
        } else {
            let kernel = kernel.unwrap_or_else(super::kernels::Kernel::auto);
            Ok(Self::reference_with_options(0, threads, kernel))
        }
    }

    /// Offline runtime over the pure-Rust reference backend (seed 0).
    pub fn reference() -> Self {
        Self::reference_with_seed(0)
    }

    /// Reference runtime with an explicit init/manifest seed.
    pub fn reference_with_seed(seed: u64) -> Self {
        Self::reference_with_threads(seed, 0)
    }

    /// Reference runtime with an explicit worker-thread count for the
    /// accum kernels (`0` = auto-detect; the `dpshort --threads` knob).
    /// Thread count is a wall-clock knob only — bits never change.
    pub fn reference_with_threads(seed: u64, threads: usize) -> Self {
        Self::reference_with_options(seed, threads, super::kernels::Kernel::auto())
    }

    /// Reference runtime with explicit worker-thread count *and* kernel
    /// selection (`dpshort --kernel`, bench `--kernels`). Like the
    /// thread knob, the kernel is a wall-clock knob only: scalar and
    /// SIMD paths share the fixed 8-lane reduction tree, so bits never
    /// change (DESIGN.md §14).
    pub fn reference_with_options(
        seed: u64,
        threads: usize,
        kernel: super::kernels::Kernel,
    ) -> Self {
        Self::with_backend(
            PathBuf::from("."),
            ReferenceBackend::manifest(seed),
            Arc::new(ReferenceBackend::with_options(seed, threads, kernel)),
        )
    }

    /// Assemble a runtime from parts (custom backends, tests).
    pub fn with_backend(
        dir: PathBuf,
        manifest: Manifest,
        backend: Arc<dyn Backend + Send + Sync>,
    ) -> Self {
        Self { dir, manifest, backend }
    }

    /// Short name of the active backend ("reference" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The shared backend handle. For wrappers that re-assemble a
    /// runtime around a decorated backend via [`Runtime::with_backend`]
    /// (the fault injector, `crate::fault::faulty_runtime`, is the
    /// in-tree example).
    pub fn backend_handle(&self) -> Arc<dyn Backend + Send + Sync> {
        Arc::clone(&self.backend)
    }

    /// Artifacts directory this runtime resolves executables from
    /// (`"."` for the artifact-free reference backend).
    pub fn artifacts_dir(&self) -> &std::path::Path {
        &self.dir
    }

    /// The shared "no `--model` given" default: `vit-micro` when the
    /// manifest has it (the artifact ladder's canonical rung, keeping
    /// paper-figure commands stable), then the reference ladder's
    /// canonical rung (`ref-linear` — the CPU manifest now carries
    /// several models and BTreeMap order would otherwise silently move
    /// the default), otherwise the first model.
    pub fn default_model(&self) -> Option<&str> {
        for canonical in ["vit-micro", super::reference::REFERENCE_MODEL] {
            if self.manifest.models.contains_key(canonical) {
                return Some(canonical);
            }
        }
        self.manifest.models.keys().next().map(String::as_str)
    }

    /// Compile timings recorded so far (Fig A.2 data).
    pub fn compile_records(&self) -> Vec<CompileRecord> {
        self.backend.compile_records()
    }

    /// Open a bound-buffer execution session for `model`, donating
    /// `params` as the session's parameter state. The session's
    /// lifetime is tied to this runtime (not to a [`ModelRuntime`]
    /// view), so a step-driven trainer can own its model view and the
    /// session side by side.
    pub fn open_session(
        &self,
        model: &str,
        params: Tensor,
    ) -> Result<Box<dyn ExecSession + '_>> {
        let meta = self.manifest.model(model)?;
        self.backend.open_session(&self.dir, meta, params)
    }

    /// A typed view over one model's executables.
    pub fn model(&self, name: &str) -> Result<ModelRuntime> {
        let meta = self.manifest.model(name)?.clone();
        Ok(ModelRuntime {
            name: name.to_string(),
            dir: self.dir.clone(),
            meta,
            backend: self.backend.clone(),
        })
    }
}

/// Typed executor for one model. Cloning is cheap (the backend is
/// shared through the `Arc`; only the meta/name/dir copy).
#[derive(Clone)]
pub struct ModelRuntime {
    name: String,
    dir: PathBuf,
    meta: ModelMeta,
    backend: Arc<dyn Backend + Send + Sync>,
}

impl ModelRuntime {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn n_params(&self) -> usize {
        self.meta.n_params
    }

    /// Image elements per example (H*W*C).
    pub fn image_dim(&self) -> usize {
        self.meta.image * self.meta.image * self.meta.channels
    }

    /// Initial parameter vector (AOT file or backend-synthesized).
    pub fn init_params(&self) -> Result<Tensor> {
        self.backend.init_params(&self.dir, &self.meta)
    }

    /// Fresh zero accumulator (legacy host-buffered loops; sessions
    /// bind their own).
    pub fn zero_acc(&self) -> Tensor {
        Tensor::zeros(self.meta.n_params)
    }

    /// Open a bound-buffer execution session for this model, donating
    /// `params`. The session borrows this view — use
    /// [`Runtime::open_session`] when the session must outlive it.
    pub fn open_session(&self, params: Tensor) -> Result<Box<dyn ExecSession + '_>> {
        self.backend.open_session(&self.dir, &self.meta, params)
    }

    /// Checkpoint the flat parameter vector (raw little-endian f32, the
    /// same format as the AOT-written `*_init.bin`, so checkpoints and
    /// initializations are interchangeable).
    pub fn save_params(&self, params: &Tensor, path: &std::path::Path) -> Result<()> {
        if params.len() != self.meta.n_params {
            return Err(anyhow!(
                "checkpoint length {} != n_params {}",
                params.len(),
                self.meta.n_params
            ));
        }
        let mut bytes = Vec::with_capacity(params.len() * 4);
        for x in params.as_slice() {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
    }

    /// Load a checkpoint written by [`Self::save_params`] (or the AOT
    /// init file) as the flat parameter vector.
    pub fn load_params(&self, path: &std::path::Path) -> Result<Tensor> {
        tensor::read_flat_f32(path, self.meta.n_params)
            .with_context(|| format!("loading checkpoint {}", path.display()))
    }

    /// Whether the accum executable for this spec exists.
    pub fn has_accum(&self, variant: &str, batch: usize, dtype: &str) -> bool {
        self.meta.find_accum(variant, batch, dtype).is_some()
    }

    /// Batch sizes available for (variant, dtype).
    pub fn accum_batches(&self, variant: &str, dtype: &str) -> Vec<usize> {
        self.meta.accum_batches(variant, dtype)
    }

    /// Whether the given accum executable is already compiled (used to
    /// observe naive-JAX recompilation, Fig A.2).
    pub fn accum_is_compiled(&self, variant: &str, batch: usize, dtype: &str) -> bool {
        match self.meta.find_accum(variant, batch, dtype) {
            Some(e) => self.backend.is_compiled(&e.path),
            None => false,
        }
    }

    /// Compile (or fetch) the accum executable for this spec. The
    /// returned handle reports compile time iff this call compiled, so
    /// one lookup serves both the hot loop and its Fig. A.2 attribution.
    pub fn prepare_accum(&self, variant: &str, batch: usize, dtype: &str) -> Result<Prepared> {
        let e = self.meta.find_accum(variant, batch, dtype).ok_or_else(|| {
            anyhow!(
                "no accum artifact for {} variant={variant} B={batch} dtype={dtype} \
                 (lowered batches: {:?})",
                self.name,
                self.meta.accum_batches(variant, dtype)
            )
        })?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// Compile (or fetch) the apply executable.
    pub fn prepare_apply(&self) -> Result<Prepared> {
        let e = self
            .meta
            .find_apply()
            .ok_or_else(|| anyhow!("no apply artifact for {}", self.name))?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// Compile (or fetch) the apply executable for a parameter-storage
    /// dtype (`"f32"` selects the plain apply; `"bf16"` selects the
    /// variant that re-quantizes parameter storage after the f32
    /// update, the `--param-dtype bf16` path).
    pub fn prepare_apply_dtype(&self, dtype: &str) -> Result<Prepared> {
        let e = self.meta.find_apply_dtype(dtype).ok_or_else(|| {
            anyhow!("no apply artifact for {} with param dtype {dtype}", self.name)
        })?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// Compile (or fetch) the eval executable. Like the accum/apply
    /// paths, the returned handle reports compile time iff this call
    /// compiled — prepare once per eval loop and attribute that time,
    /// instead of paying an unattributed lookup per batch.
    pub fn prepare_eval(&self) -> Result<Prepared> {
        let e = self
            .meta
            .find_eval()
            .ok_or_else(|| anyhow!("no eval artifact for {}", self.name))?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// One gradient-accumulation call (the Algorithm 1/2 inner loop),
    /// copying form.
    ///
    /// **Deprecated (migration shim)** — as if
    /// `#[deprecated(note = "open an ExecSession via open_session();
    /// the bound-buffer accum is the hot path")]`: the attribute is
    /// withheld only so the bitwise-equivalence proptests can keep
    /// exercising this path warning-free until it is deleted (planned
    /// once the PJRT backend grows a device-resident session; see
    /// CHANGES.md). New code must not call it.
    pub fn run_accum(
        &self,
        prep: &Prepared,
        params: &Tensor,
        acc: &Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumOut> {
        debug_assert_eq!(args.x.len(), args.batch() * self.image_dim());
        debug_assert_eq!(args.mask.len(), args.batch());
        self.backend.run_accum(prep, &self.meta, params, acc, args)
    }

    /// Donating form of the accum call: `acc` is the donated buffer,
    /// updated in place (the `donate_argnums` analogue — no P-length
    /// copy per physical batch). Bitwise-identical to
    /// [`Self::run_accum`] and to the session path.
    ///
    /// **Deprecated (migration shim)** — same guidance as
    /// [`Self::run_accum`]: sessions bind the donated buffer once for
    /// the whole run instead of threading it through every call.
    pub fn run_accum_into(
        &self,
        prep: &Prepared,
        params: &Tensor,
        acc: &mut Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumStats> {
        debug_assert_eq!(args.x.len(), args.batch() * self.image_dim());
        debug_assert_eq!(args.mask.len(), args.batch());
        self.backend.run_accum_into(prep, &self.meta, params, acc, args)
    }

    /// The once-per-logical-batch noise + SGD step, copying form, on an
    /// executable from [`Self::prepare_apply`] (same single-lookup
    /// compile attribution as the accum path).
    ///
    /// **Deprecated (migration shim)** — as if
    /// `#[deprecated(note = "drive ExecSession::apply(); the session
    /// owns the parameter buffer")]`; kept attribute-free for the
    /// equivalence proptests only (deletion plan in CHANGES.md).
    pub fn run_apply(
        &self,
        prep: &Prepared,
        params: &Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<Tensor> {
        self.backend.run_apply(prep, &self.meta, params, acc, args)
    }

    /// Donating form of the apply call: `params` is the donated buffer,
    /// updated in place. Bitwise-identical to [`Self::run_apply`] and
    /// to the session path.
    ///
    /// **Deprecated (migration shim)** — same guidance as
    /// [`Self::run_apply`].
    pub fn run_apply_into(
        &self,
        prep: &Prepared,
        params: &mut Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<()> {
        self.backend.run_apply_into(prep, &self.meta, params, acc, args)
    }

    /// Forward-only evaluation on an already-prepared executable:
    /// `(loss_sum, ncorrect)` over the batch. Pair with
    /// [`Self::prepare_eval`] so the one-time compile is attributed
    /// exactly once per eval loop.
    pub fn run_eval_prepared(
        &self,
        prep: &Prepared,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        self.backend.run_eval(prep, &self.meta, params, x, y)
    }

    /// Forward-only evaluation: `(loss_sum, ncorrect)` over the eval
    /// batch (whose size is fixed by the lowered artifact).
    ///
    /// **Deprecated (migration shim)** — as if
    /// `#[deprecated(note = "prepare once (prepare_eval) and use
    /// run_eval_prepared or ExecSession::eval")]`: this form prepares
    /// per call and drops the compile-time attribution. Deletion plan
    /// in CHANGES.md.
    pub fn run_eval(&self, params: &Tensor, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let want = self
            .meta
            .find_eval()
            .ok_or_else(|| anyhow!("no eval artifact for {}", self.name))?
            .batch
            .unwrap_or(0);
        if y.len() != want {
            return Err(anyhow!("eval batch must be exactly {want}, got {}", y.len()));
        }
        let prep = self.prepare_eval()?;
        self.run_eval_prepared(&prep, params, x, y)
    }

    /// Eval batch size fixed at AOT time.
    pub fn eval_batch(&self) -> Option<usize> {
        self.meta.find_eval().and_then(|e| e.batch)
    }
}
