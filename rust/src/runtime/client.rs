//! The model-facing runtime: typed wrappers over the flat-param ABI.

use super::compile_cache::CompileCache;
use super::manifest::{Manifest, ModelMeta};
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Owns the PJRT client, the manifest, and the compile cache.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
    cache: Rc<RefCell<CompileCache>>,
}

impl Runtime {
    /// Load the artifacts directory (must contain manifest.json).
    pub fn load(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifacts_dir.into();
        let (manifest, dir) = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { dir, manifest, cache: Rc::new(RefCell::new(CompileCache::new(client))) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile timings recorded so far (Fig A.2 data).
    pub fn compile_records(&self) -> Vec<super::CompileRecord> {
        self.cache.borrow().records().to_vec()
    }

    /// A typed view over one model's artifacts.
    pub fn model(&self, name: &str) -> Result<ModelRuntime> {
        let meta = self.manifest.model(name)?.clone();
        Ok(ModelRuntime {
            name: name.to_string(),
            dir: self.dir.clone(),
            meta,
            cache: self.cache.clone(),
        })
    }
}

/// Decoded outputs of one accum call.
pub struct AccumOut {
    /// New gradient accumulator (kept as a Literal: it round-trips back
    /// into the next accum call without re-encoding).
    pub acc: xla::Literal,
    /// Sum of masked per-example losses.
    pub loss_sum: f32,
    /// Per-example squared gradient norms (zeros for nonprivate).
    pub sq_norms: Vec<f32>,
}

/// Typed executor for one model.
pub struct ModelRuntime {
    name: String,
    dir: PathBuf,
    meta: ModelMeta,
    cache: Rc<RefCell<CompileCache>>,
}

impl ModelRuntime {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn n_params(&self) -> usize {
        self.meta.n_params
    }

    /// Image elements per example (H*W*C).
    pub fn image_dim(&self) -> usize {
        self.meta.image * self.meta.image * self.meta.channels
    }

    /// Load the initial (AOT-initialized) parameter vector.
    pub fn init_params(&self) -> Result<xla::Literal> {
        let path = self.dir.join(&self.meta.init_params);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.meta.n_params * 4 {
            return Err(anyhow!(
                "init params size mismatch: {} bytes for {} params",
                bytes.len(),
                self.meta.n_params
            ));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(xla::Literal::vec1(&floats))
    }

    /// Fresh zero accumulator.
    pub fn zero_acc(&self) -> xla::Literal {
        xla::Literal::vec1(&vec![0.0f32; self.meta.n_params])
    }

    /// Checkpoint the flat parameter vector (raw little-endian f32, the
    /// same format as the AOT-written `*_init.bin`, so checkpoints and
    /// initializations are interchangeable).
    pub fn save_params(&self, params: &xla::Literal, path: &std::path::Path) -> Result<()> {
        let v = params.to_vec::<f32>().map_err(xerr)?;
        if v.len() != self.meta.n_params {
            return Err(anyhow!(
                "checkpoint length {} != n_params {}",
                v.len(),
                self.meta.n_params
            ));
        }
        let mut bytes = Vec::with_capacity(v.len() * 4);
        for x in &v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
    }

    /// Load a checkpoint written by [`Self::save_params`] (or the AOT
    /// init file) as the flat parameter Literal.
    pub fn load_params(&self, path: &std::path::Path) -> Result<xla::Literal> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.meta.n_params * 4 {
            return Err(anyhow!(
                "checkpoint {} has {} bytes, expected {}",
                path.display(),
                bytes.len(),
                self.meta.n_params * 4
            ));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(xla::Literal::vec1(&floats))
    }

    /// Whether the accum executable for this spec exists.
    pub fn has_accum(&self, variant: &str, batch: usize, dtype: &str) -> bool {
        self.meta.find_accum(variant, batch, dtype).is_some()
    }

    /// Batch sizes available for (variant, dtype).
    pub fn accum_batches(&self, variant: &str, dtype: &str) -> Vec<usize> {
        self.meta.accum_batches(variant, dtype)
    }

    /// Whether the given accum executable is already compiled (used to
    /// observe naive-JAX recompilation, Fig A.2).
    pub fn accum_is_compiled(&self, variant: &str, batch: usize, dtype: &str) -> bool {
        match self.meta.find_accum(variant, batch, dtype) {
            Some(e) => self.cache.borrow().is_cached(&e.path),
            None => false,
        }
    }

    fn compile(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.cache.borrow_mut().get(&self.dir, file)
    }

    /// Pre-compile (and time) the accum executable for this spec.
    pub fn prepare_accum(
        &self,
        variant: &str,
        batch: usize,
        dtype: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let e = self.meta.find_accum(variant, batch, dtype).ok_or_else(|| {
            anyhow!(
                "no accum artifact for {} variant={variant} B={batch} dtype={dtype} \
                 (lowered batches: {:?})",
                self.name,
                self.meta.accum_batches(variant, dtype)
            )
        })?;
        self.compile(&e.path)
    }

    /// One gradient-accumulation call (the Algorithm 1/2 inner loop).
    ///
    /// `x` is row-major [batch, H, W, C]; `mask` the Algorithm-2 masks.
    pub fn run_accum(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        params: &xla::Literal,
        acc: &xla::Literal,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumOut> {
        let b = y.len();
        debug_assert_eq!(x.len(), b * self.image_dim());
        debug_assert_eq!(mask.len(), b);
        let img = self.meta.image as i64;
        let xs = xla::Literal::vec1(x)
            .reshape(&[b as i64, img, img, self.meta.channels as i64])
            .map_err(xerr)?;
        let ys = xla::Literal::vec1(y);
        let ms = xla::Literal::vec1(mask);
        let out = exe
            .execute(&[params, acc, &xs, &ys, &ms])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (acc_out, loss, sq) = out.to_tuple3().map_err(xerr)?;
        Ok(AccumOut {
            acc: acc_out,
            loss_sum: loss.get_first_element::<f32>().map_err(xerr)?,
            sq_norms: sq.to_vec::<f32>().map_err(xerr)?,
        })
    }

    /// The once-per-logical-batch noise + SGD step.
    ///
    /// `denom` is the Algorithm-1 |L| divisor (expected logical batch),
    /// `noise_mult` is sigma * C (0 for the non-private baseline).
    pub fn run_apply(
        &self,
        params: &xla::Literal,
        acc: &xla::Literal,
        seed: i32,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<xla::Literal> {
        let e = self
            .meta
            .find_apply()
            .ok_or_else(|| anyhow!("no apply artifact for {}", self.name))?;
        let exe = self.compile(&e.path)?;
        let out = exe
            .execute(&[
                params,
                acc,
                &xla::Literal::vec1(&[seed]),
                &xla::Literal::vec1(&[denom]),
                &xla::Literal::vec1(&[lr]),
                &xla::Literal::vec1(&[noise_mult]),
            ])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        out.to_tuple1().map_err(xerr)
    }

    /// Forward-only evaluation: returns (loss_sum, ncorrect) over the
    /// eval batch (whose size is fixed by the lowered artifact).
    pub fn run_eval(
        &self,
        params: &xla::Literal,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let e = self
            .meta
            .find_eval()
            .ok_or_else(|| anyhow!("no eval artifact for {}", self.name))?;
        let want = e.batch.unwrap_or(0);
        if y.len() != want {
            return Err(anyhow!("eval batch must be exactly {want}, got {}", y.len()));
        }
        let exe = self.compile(&e.path)?;
        let img = self.meta.image as i64;
        let xs = xla::Literal::vec1(x)
            .reshape(&[y.len() as i64, img, img, self.meta.channels as i64])
            .map_err(xerr)?;
        let ys = xla::Literal::vec1(y);
        let out = exe.execute(&[params, &xs, &ys]).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (loss, ncorrect) = out.to_tuple2().map_err(xerr)?;
        Ok((
            loss.get_first_element::<f32>().map_err(xerr)?,
            ncorrect.get_first_element::<f32>().map_err(xerr)?,
        ))
    }

    /// Eval batch size fixed at AOT time.
    pub fn eval_batch(&self) -> Option<usize> {
        self.meta.find_eval().and_then(|e| e.batch)
    }
}
