//! The model-facing runtime facade: typed wrappers over the flat-param
//! ABI, generic over the execution [`Backend`].
//!
//! [`Runtime`] pairs a manifest (what was lowered) with a backend (how
//! to run it); [`ModelRuntime`] is the per-model view the trainer
//! drives. Artifact-backed runtimes come from [`Runtime::load`] (PJRT,
//! feature `pjrt`); the dependency-free default is
//! [`Runtime::reference`], whose manifest and executables are
//! synthesized in-memory by the pure-Rust reference backend.

use super::backend::{AccumOut, AccumStats, Backend, Prepared};
use super::compile_cache::CompileRecord;
use super::manifest::{Manifest, ModelMeta};
use super::reference::ReferenceBackend;
use super::tensor::{self, Tensor};
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::rc::Rc;

/// Owns the manifest and the execution backend.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
    backend: Rc<dyn Backend>,
}

impl Runtime {
    /// Load an artifacts directory (must contain manifest.json) and
    /// execute it through the PJRT backend. Requires the `pjrt` feature;
    /// without it this returns an error pointing at
    /// [`Runtime::reference`].
    pub fn load(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifacts_dir.into();
        let (manifest, dir) = Manifest::load(&dir)?;
        Self::artifact_backend(dir, manifest)
    }

    #[cfg(feature = "pjrt")]
    fn artifact_backend(dir: PathBuf, manifest: Manifest) -> Result<Self> {
        let backend: Rc<dyn Backend> = Rc::new(super::pjrt::PjrtBackend::new()?);
        Ok(Self { dir, manifest, backend })
    }

    #[cfg(not(feature = "pjrt"))]
    fn artifact_backend(dir: PathBuf, _manifest: Manifest) -> Result<Self> {
        Err(anyhow!(
            "artifacts at {} need the PJRT backend; rebuild with `--features pjrt` \
             or use Runtime::reference() for the pure-Rust backend",
            dir.display()
        ))
    }

    /// The shared launcher policy: artifacts through PJRT when both the
    /// feature and `<dir>/manifest.json` are present, the pure-Rust
    /// reference backend otherwise — so every entry point (CLI,
    /// examples, benches) works on a fresh offline checkout.
    pub fn auto(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifacts_dir.into();
        if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::reference())
        }
    }

    /// Offline runtime over the pure-Rust reference backend (seed 0).
    pub fn reference() -> Self {
        Self::reference_with_seed(0)
    }

    /// Reference runtime with an explicit init/manifest seed.
    pub fn reference_with_seed(seed: u64) -> Self {
        Self::with_backend(
            PathBuf::from("."),
            ReferenceBackend::manifest(seed),
            Rc::new(ReferenceBackend::new(seed)),
        )
    }

    /// Assemble a runtime from parts (custom backends, tests).
    pub fn with_backend(dir: PathBuf, manifest: Manifest, backend: Rc<dyn Backend>) -> Self {
        Self { dir, manifest, backend }
    }

    /// Short name of the active backend ("reference" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The shared "no `--model` given" default: `vit-micro` when the
    /// manifest has it (the artifact ladder's canonical rung, keeping
    /// paper-figure commands stable), otherwise the first model.
    pub fn default_model(&self) -> Option<&str> {
        if self.manifest.models.contains_key("vit-micro") {
            return Some("vit-micro");
        }
        self.manifest.models.keys().next().map(String::as_str)
    }

    /// Compile timings recorded so far (Fig A.2 data).
    pub fn compile_records(&self) -> Vec<CompileRecord> {
        self.backend.compile_records()
    }

    /// A typed view over one model's executables.
    pub fn model(&self, name: &str) -> Result<ModelRuntime> {
        let meta = self.manifest.model(name)?.clone();
        Ok(ModelRuntime {
            name: name.to_string(),
            dir: self.dir.clone(),
            meta,
            backend: self.backend.clone(),
        })
    }
}

/// Typed executor for one model.
pub struct ModelRuntime {
    name: String,
    dir: PathBuf,
    meta: ModelMeta,
    backend: Rc<dyn Backend>,
}

impl ModelRuntime {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn n_params(&self) -> usize {
        self.meta.n_params
    }

    /// Image elements per example (H*W*C).
    pub fn image_dim(&self) -> usize {
        self.meta.image * self.meta.image * self.meta.channels
    }

    /// Initial parameter vector (AOT file or backend-synthesized).
    pub fn init_params(&self) -> Result<Tensor> {
        self.backend.init_params(&self.dir, &self.meta)
    }

    /// Fresh zero accumulator.
    pub fn zero_acc(&self) -> Tensor {
        Tensor::zeros(self.meta.n_params)
    }

    /// Checkpoint the flat parameter vector (raw little-endian f32, the
    /// same format as the AOT-written `*_init.bin`, so checkpoints and
    /// initializations are interchangeable).
    pub fn save_params(&self, params: &Tensor, path: &std::path::Path) -> Result<()> {
        if params.len() != self.meta.n_params {
            return Err(anyhow!(
                "checkpoint length {} != n_params {}",
                params.len(),
                self.meta.n_params
            ));
        }
        let mut bytes = Vec::with_capacity(params.len() * 4);
        for x in params.as_slice() {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
    }

    /// Load a checkpoint written by [`Self::save_params`] (or the AOT
    /// init file) as the flat parameter vector.
    pub fn load_params(&self, path: &std::path::Path) -> Result<Tensor> {
        tensor::read_flat_f32(path, self.meta.n_params)
            .with_context(|| format!("loading checkpoint {}", path.display()))
    }

    /// Whether the accum executable for this spec exists.
    pub fn has_accum(&self, variant: &str, batch: usize, dtype: &str) -> bool {
        self.meta.find_accum(variant, batch, dtype).is_some()
    }

    /// Batch sizes available for (variant, dtype).
    pub fn accum_batches(&self, variant: &str, dtype: &str) -> Vec<usize> {
        self.meta.accum_batches(variant, dtype)
    }

    /// Whether the given accum executable is already compiled (used to
    /// observe naive-JAX recompilation, Fig A.2).
    pub fn accum_is_compiled(&self, variant: &str, batch: usize, dtype: &str) -> bool {
        match self.meta.find_accum(variant, batch, dtype) {
            Some(e) => self.backend.is_compiled(&e.path),
            None => false,
        }
    }

    /// Compile (or fetch) the accum executable for this spec. The
    /// returned handle reports compile time iff this call compiled, so
    /// one lookup serves both the hot loop and its Fig. A.2 attribution.
    pub fn prepare_accum(&self, variant: &str, batch: usize, dtype: &str) -> Result<Prepared> {
        let e = self.meta.find_accum(variant, batch, dtype).ok_or_else(|| {
            anyhow!(
                "no accum artifact for {} variant={variant} B={batch} dtype={dtype} \
                 (lowered batches: {:?})",
                self.name,
                self.meta.accum_batches(variant, dtype)
            )
        })?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// Compile (or fetch) the apply executable.
    pub fn prepare_apply(&self) -> Result<Prepared> {
        let e = self
            .meta
            .find_apply()
            .ok_or_else(|| anyhow!("no apply artifact for {}", self.name))?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// Compile (or fetch) the eval executable.
    pub fn prepare_eval(&self) -> Result<Prepared> {
        let e = self
            .meta
            .find_eval()
            .ok_or_else(|| anyhow!("no eval artifact for {}", self.name))?;
        self.backend.prepare(&self.dir, &self.meta, e)
    }

    /// One gradient-accumulation call (the Algorithm 1/2 inner loop).
    ///
    /// `x` is row-major [batch, H, W, C]; `mask` the Algorithm-2 masks.
    pub fn run_accum(
        &self,
        prep: &Prepared,
        params: &Tensor,
        acc: &Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumOut> {
        debug_assert_eq!(x.len(), y.len() * self.image_dim());
        debug_assert_eq!(mask.len(), y.len());
        self.backend.run_accum(prep, &self.meta, params, acc, x, y, mask)
    }

    /// Donating form of the accum call: `acc` is the donated buffer,
    /// updated in place (the `donate_argnums` analogue — no P-length
    /// copy per physical batch). Bitwise-identical to
    /// [`Self::run_accum`]; the trainer's hot loop uses this form.
    pub fn run_accum_into(
        &self,
        prep: &Prepared,
        params: &Tensor,
        acc: &mut Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumStats> {
        debug_assert_eq!(x.len(), y.len() * self.image_dim());
        debug_assert_eq!(mask.len(), y.len());
        self.backend.run_accum_into(prep, &self.meta, params, acc, x, y, mask)
    }

    /// The once-per-logical-batch noise + SGD step, on an executable
    /// from [`Self::prepare_apply`] (same single-lookup compile
    /// attribution as the accum path).
    ///
    /// `seed` is the full-width 64-bit per-step noise seed, `denom` the
    /// Algorithm-1 |L| divisor (expected logical batch), `noise_mult`
    /// is sigma * C (0 for the non-private baseline).
    #[allow(clippy::too_many_arguments)]
    pub fn run_apply(
        &self,
        prep: &Prepared,
        params: &Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<Tensor> {
        self.backend
            .run_apply(prep, &self.meta, params, acc, seed, denom, lr, noise_mult)
    }

    /// Donating form of the apply call: `params` is the donated buffer,
    /// updated in place. Bitwise-identical to [`Self::run_apply`]; the
    /// trainer's hot loop uses this form.
    #[allow(clippy::too_many_arguments)]
    pub fn run_apply_into(
        &self,
        prep: &Prepared,
        params: &mut Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<()> {
        self.backend
            .run_apply_into(prep, &self.meta, params, acc, seed, denom, lr, noise_mult)
    }

    /// Forward-only evaluation: returns (loss_sum, ncorrect) over the
    /// eval batch (whose size is fixed by the lowered artifact).
    pub fn run_eval(&self, params: &Tensor, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let want = self
            .meta
            .find_eval()
            .ok_or_else(|| anyhow!("no eval artifact for {}", self.name))?
            .batch
            .unwrap_or(0);
        if y.len() != want {
            return Err(anyhow!("eval batch must be exactly {want}, got {}", y.len()));
        }
        let prep = self.prepare_eval()?;
        self.backend.run_eval(&prep, &self.meta, params, x, y)
    }

    /// Eval batch size fixed at AOT time.
    pub fn eval_batch(&self) -> Option<usize> {
        self.meta.find_eval().and_then(|e| e.batch)
    }
}
