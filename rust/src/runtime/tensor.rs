//! Crate-owned tensor value type.
//!
//! Everything crossing the [`super::backend::Backend`] boundary uses
//! this type instead of a backend-specific literal (the seed hard-wired
//! `xla::Literal` here, which made the crate unbuildable without the
//! PJRT bindings). The flat-parameter ABI only ever moves rank-1 f32
//! vectors plus raw `&[f32]`/`&[i32]` batch slices, so this stays
//! deliberately small: a flat f32 buffer. Shape metadata can come back
//! when a backend actually consumes it.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A dense rank-1 f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
}

/// Read a raw little-endian f32 file (the AOT `*_init.bin` /
/// checkpoint format) of exactly `n_params` values.
pub fn read_flat_f32(path: &Path, n_params: usize) -> Result<Tensor> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != n_params * 4 {
        return Err(anyhow!(
            "{}: {} bytes, expected {} ({} f32 params)",
            path.display(),
            bytes.len(),
            n_params * 4,
            n_params
        ));
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor::from_vec(floats))
}

impl Tensor {
    /// Rank-1 tensor from a slice.
    pub fn vec1(data: &[f32]) -> Self {
        Self { data: data.to_vec() }
    }

    /// Rank-1 tensor taking ownership of the buffer.
    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { data }
    }

    /// Rank-1 zero tensor of length `n` (e.g. a fresh grad accumulator).
    pub fn zeros(n: usize) -> Self {
        Self::from_vec(vec![0.0; n])
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Set every element to `v` (e.g. re-zeroing a donated accumulator
    /// between optimizer steps without reallocating).
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Copy the elements out (row-major).
    pub fn to_vec(&self) -> Vec<f32> {
        self.data.clone()
    }

    /// Consume into the underlying buffer without copying.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Quantize every element to the nearest bf16 value in place
    /// (round-to-nearest-even) — the `--param-dtype bf16` storage step.
    pub fn quantize_bf16(&mut self) {
        quantize_bf16(&mut self.data);
    }
}

/// Round one f32 to the nearest bf16-representable value
/// (round-to-nearest-even on the dropped 16 mantissa bits), returned as
/// an f32. Every bf16 value is exactly representable in f32, so
/// bf16-storage parameters survive f32 checkpoints bit-for-bit.
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        // Keep a quiet NaN rather than risking rounding a signaling
        // payload into infinity.
        return f32::from_bits((x.to_bits() & 0xffff_0000) | 0x0040_0000);
    }
    let bits = x.to_bits();
    let rounded = bits.wrapping_add(0x7fff + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xffff_0000)
}

/// [`bf16_round`] over a whole parameter buffer.
pub fn quantize_bf16(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = bf16_round(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::vec1(&[1.0, 2.0, 3.0]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.as_slice(), &[1.0, 2.0, 3.0]);
        assert_eq!(t.to_vec(), vec![1.0, 2.0, 3.0]);
        assert!(!t.is_empty());
    }

    #[test]
    fn read_flat_f32_roundtrip_and_size_check() {
        let path = std::env::temp_dir().join("dpshort_tensor_flat_test.bin");
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(read_flat_f32(&path, 3).unwrap().to_vec(), vals.to_vec());
        assert!(read_flat_f32(&path, 4).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn zeros_and_mutation() {
        let mut t = Tensor::zeros(4);
        assert_eq!(t.as_slice(), &[0.0; 4]);
        t.as_mut_slice()[2] = 5.0;
        assert_eq!(t.into_vec(), vec![0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn fill_resets_in_place() {
        let mut t = Tensor::vec1(&[1.0, 2.0, 3.0]);
        t.fill(0.0);
        assert_eq!(t.as_slice(), &[0.0; 3]);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn from_vec_roundtrip() {
        let v = vec![0.5f32; 7];
        let t = Tensor::from_vec(v.clone());
        assert_eq!(t.into_vec(), v);
    }

    #[test]
    fn bf16_round_is_rne_and_idempotent() {
        // Exactly representable values pass through untouched.
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.15625, f32::INFINITY] {
            assert_eq!(bf16_round(v).to_bits(), v.to_bits(), "{v}");
        }
        // 1.0 + 2^-8 sits exactly halfway between bf16 neighbors
        // 1.0 (mantissa ...000) and 1.0078125 (...001): round to even.
        assert_eq!(bf16_round(f32::from_bits(0x3f80_8000)), 1.0);
        // One ulp above the halfway point rounds up.
        assert_eq!(
            bf16_round(f32::from_bits(0x3f80_8001)).to_bits(),
            0x3f81_0000
        );
        // Just below halfway rounds down.
        assert_eq!(bf16_round(f32::from_bits(0x3f80_7fff)), 1.0);
        // Idempotent: quantizing a quantized buffer is a no-op.
        let mut buf: Vec<f32> = (0..64).map(|i| (i as f32).exp2().sin() * 3.7).collect();
        quantize_bf16(&mut buf);
        let once: Vec<u32> = buf.iter().map(|v| v.to_bits()).collect();
        quantize_bf16(&mut buf);
        let twice: Vec<u32> = buf.iter().map(|v| v.to_bits()).collect();
        assert_eq!(once, twice);
        // NaN stays NaN (never rounds into infinity).
        assert!(bf16_round(f32::NAN).is_nan());
        // Low 16 bits are always clear after rounding.
        for v in &buf {
            assert_eq!(v.to_bits() & 0xffff, 0);
        }
    }
}
