//! Compile-once cache with timing — the observable behind Figure A.2.
//!
//! The paper's "naive JAX" DP-SGD recompiles whenever Poisson sampling
//! produces a physical batch size it has not seen (jit retracing); the
//! masked variant (Algorithm 2) compiles exactly once per shape. This
//! cache makes that cost a first-class measurement: every compilation is
//! recorded with its wall-clock, and the trainer's report includes the
//! per-size compile-time series.
//!
//! Generic over the compiled value so both backends share it: the PJRT
//! backend caches `xla::PjRtLoadedExecutable`s, the reference backend
//! its decoded executable specs.

use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// One recorded compilation.
#[derive(Debug, Clone)]
pub struct CompileRecord {
    /// Artifact file name.
    pub path: String,
    /// Wall-clock seconds for parse + compile.
    pub seconds: f64,
}

/// Caches compiled executables keyed by artifact file name.
pub struct CompileCache<E> {
    cache: HashMap<String, Arc<E>>,
    records: Vec<CompileRecord>,
}

impl<E> Default for CompileCache<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> CompileCache<E> {
    pub fn new() -> Self {
        Self { cache: HashMap::new(), records: Vec::new() }
    }

    /// Number of distinct executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// All compile timings observed (Fig A.2 data).
    pub fn records(&self) -> &[CompileRecord] {
        &self.records
    }

    /// True if `file` is already compiled (no cost on next use).
    pub fn is_cached(&self, file: &str) -> bool {
        self.cache.contains_key(file)
    }

    /// The cached executable for `file`, if compiled.
    pub fn get_cached(&self, file: &str) -> Option<Arc<E>> {
        self.cache.get(file).cloned()
    }

    /// Get `file`'s executable, invoking (and timing) `compile` on a
    /// miss. Returns the executable plus `Some(seconds)` iff this call
    /// compiled — the single-lookup answer to "did we just pay a
    /// compile?" that the trainer's hot loop needs.
    pub fn get_or_compile<F>(&mut self, file: &str, compile: F) -> Result<(Arc<E>, Option<f64>)>
    where
        F: FnOnce() -> Result<E>,
    {
        if let Some(exe) = self.cache.get(file) {
            return Ok((exe.clone(), None));
        }
        let t0 = Instant::now();
        let exe = compile()?;
        let seconds = t0.elapsed().as_secs_f64();
        self.records.push(CompileRecord { path: file.to_string(), seconds });
        let exe = Arc::new(exe);
        self.cache.insert(file.to_string(), exe.clone());
        Ok((exe, Some(seconds)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_once_and_records() {
        let mut cache: CompileCache<u32> = CompileCache::new();
        let mut calls = 0;
        let (a, t1) = cache
            .get_or_compile("f", || {
                calls += 1;
                Ok(7)
            })
            .unwrap();
        assert_eq!(*a, 7);
        assert!(t1.is_some());
        let (b, t2) = cache
            .get_or_compile("f", || {
                calls += 1;
                Ok(8)
            })
            .unwrap();
        assert_eq!(*b, 7, "cache hit must not recompile");
        assert!(t2.is_none());
        assert_eq!(calls, 1);
        assert_eq!(cache.compiled_count(), 1);
        assert_eq!(cache.records().len(), 1);
        assert!(cache.is_cached("f") && !cache.is_cached("g"));
        assert_eq!(cache.get_cached("f").map(|e| *e), Some(7));
    }

    #[test]
    fn failed_compile_is_not_cached() {
        let mut cache: CompileCache<u32> = CompileCache::new();
        assert!(cache.get_or_compile("f", || anyhow::bail!("nope")).is_err());
        assert!(!cache.is_cached("f"));
        assert!(cache.records().is_empty());
        assert!(cache.get_or_compile("f", || Ok(1)).is_ok());
    }
}
