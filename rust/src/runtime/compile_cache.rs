//! Compile-once cache with timing — the observable behind Figure A.2.
//!
//! The paper's "naive JAX" DP-SGD recompiles whenever Poisson sampling
//! produces a physical batch size it has not seen (jit retracing); the
//! masked variant (Algorithm 2) compiles exactly once per shape. This
//! cache makes that cost a first-class measurement: every PJRT
//! compilation is recorded with its wall-clock, and the trainer's report
//! includes the per-size compile-time series.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// One recorded compilation.
#[derive(Debug, Clone)]
pub struct CompileRecord {
    /// Artifact file name.
    pub path: String,
    /// Wall-clock seconds for parse + PJRT compile.
    pub seconds: f64,
}

/// Caches compiled executables keyed by artifact path.
pub struct CompileCache {
    client: xla::PjRtClient,
    cache: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
    records: Vec<CompileRecord>,
}

impl CompileCache {
    pub fn new(client: xla::PjRtClient) -> Self {
        Self { client, cache: HashMap::new(), records: Vec::new() }
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Number of distinct executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// All compile timings observed (Fig A.2 data).
    pub fn records(&self) -> &[CompileRecord] {
        &self.records
    }

    /// True if `file` is already compiled (no cost on next use).
    pub fn is_cached(&self, file: &str) -> bool {
        self.cache.contains_key(file)
    }

    /// Get or compile the executable for `dir/file`.
    pub fn get(&mut self, dir: &Path, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(file) {
            return Ok(exe.clone());
        }
        let full = dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&full)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("parsing HLO text {}", full.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("PJRT compile of {}", full.display()))?;
        let seconds = t0.elapsed().as_secs_f64();
        self.records.push(CompileRecord { path: file.to_string(), seconds });
        let exe = Arc::new(exe);
        self.cache.insert(file.to_string(), exe.clone());
        Ok(exe)
    }
}
