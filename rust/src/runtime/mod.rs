//! Execution runtime: manifest + pluggable backends behind one facade.
//!
//! This is the only boundary between L3 (Rust) and the L2/L1 graphs.
//! Everything crossing it uses the flat-parameter ABI described in
//! DESIGN.md §3:
//!
//! ```text
//! accum(params[P], acc[P], x[B,H,W,C], y[B], mask[B])
//!       -> (acc'[P], loss_sum, sq_norms[B])
//! apply(params[P], acc[P], seed, denom[1], lr[1], noise_mult[1])
//!       -> params'[P]
//! eval (params[P], x[B,H,W,C], y[B]) -> (loss_sum, ncorrect)
//! ```
//!
//! accum and apply each come in a copying and a *donating* form
//! (`run_accum_into` / `run_apply_into`): the round-tripping buffer
//! (acc, params) is updated in place — the `donate_argnums` / XLA
//! input-output-aliasing analogue the hot loop runs on (DESIGN.md §3).
//!
//! The [`Backend`] trait (DESIGN.md §2) seams the executor out of the
//! coordinator: the default build ships the pure-Rust
//! [`ReferenceBackend`] (linear+softmax reference model, fully offline);
//! the `pjrt` feature adds the PJRT path over AOT-lowered HLO artifacts.
//! Compilation is cached per artifact and **timed** — the compile-time
//! measurements are the data behind the paper's Figure A.2 (JAX naive
//! recompilation cost as a function of batch size).

pub mod backend;
pub mod client;
pub mod compile_cache;
pub mod hlo_analysis;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod tensor;

pub use backend::{AccumOut, AccumStats, Backend, Prepared};
pub use client::{ModelRuntime, Runtime};
pub use compile_cache::{CompileCache, CompileRecord};
pub use hlo_analysis::{analyze, analyze_file, HloStats};
pub use manifest::{ExecutableMeta, Manifest, ModelMeta};
pub use reference::{ReferenceBackend, REFERENCE_MODEL};
pub use tensor::Tensor;
