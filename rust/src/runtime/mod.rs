//! PJRT runtime: loads the AOT artifacts (HLO text + manifest + initial
//! parameters) and executes them on the CPU PJRT client.
//!
//! This is the only boundary between L3 (Rust) and the L2/L1 graphs.
//! Everything crossing it uses the flat-parameter ABI described in
//! DESIGN.md §3:
//!
//! ```text
//! accum(params[P], acc[P], x[B,H,W,C], y[B], mask[B])
//!       -> (acc'[P], loss_sum, sq_norms[B])
//! apply(params[P], acc[P], seed i32[1], denom[1], lr[1], noise_mult[1])
//!       -> params'[P]
//! eval (params[P], x[B,H,W,C], y[B]) -> (loss_sum, ncorrect)
//! ```
//!
//! Compilation is cached per artifact and **timed** — the compile-time
//! measurements are the data behind the paper's Figure A.2 (JAX naive
//! recompilation cost as a function of batch size).

pub mod client;
pub mod compile_cache;
pub mod hlo_analysis;
pub mod manifest;

pub use client::{ModelRuntime, Runtime};
pub use compile_cache::{CompileCache, CompileRecord};
pub use hlo_analysis::{analyze, analyze_file, HloStats};
pub use manifest::{ExecutableMeta, Manifest, ModelMeta};
