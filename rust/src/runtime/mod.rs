//! Execution runtime: manifest + pluggable backends behind one facade.
//!
//! This is the only boundary between L3 (Rust) and the L2/L1 graphs.
//! Everything crossing it uses the flat-parameter ABI described in
//! DESIGN.md §3, addressed through typed call structs:
//!
//! ```text
//! accum(params[P], acc[P], AccumArgs { x[B,H,W,C], y[B], mask[B] })
//!       -> (acc'[P], loss_sum, sq_norms[B])
//! apply(params[P], acc[P], ApplyArgs { seed, denom, lr, noise_mult })
//!       -> params'[P]
//! eval (params[P], x[B,H,W,C], y[B]) -> (loss_sum, ncorrect)
//! ```
//!
//! Hot loops run on a **session** ([`ExecSession`], opened via
//! [`Backend::open_session`]): the session owns the round-tripping
//! buffers (params + the gradient accumulator) for the life of a run —
//! the `donate_argnums` / XLA input-output-aliasing analogue, and the
//! hook a device-resident backend uses to keep those buffers on device
//! across calls (DESIGN.md §3). The legacy copying/donating entry
//! points (`run_accum*`, `run_apply*`) remain as migration shims,
//! bitwise-identical to the session path.
//!
//! The [`Backend`] trait (DESIGN.md §2) seams the executor out of the
//! coordinator: the default build ships the pure-Rust
//! [`ReferenceBackend`] (linear+softmax reference model, fully offline);
//! the `pjrt` feature adds the PJRT path over AOT-lowered HLO artifacts.
//! Backends are shared as `Arc<dyn Backend + Send + Sync>`. Compilation
//! is cached per artifact and **timed** — the compile-time measurements
//! are the data behind the paper's Figure A.2 (JAX naive recompilation
//! cost as a function of batch size).

pub mod backend;
pub mod client;
pub mod compile_cache;
pub mod hlo_analysis;
pub mod kernels;
pub mod layers;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod tensor;

pub use backend::{
    AccumArgs, AccumOut, AccumStats, ApplyArgs, Backend, ExecSession, Prepared,
};
pub use client::{ModelRuntime, Runtime};
pub use compile_cache::{CompileCache, CompileRecord};
pub use hlo_analysis::{analyze, analyze_file, HloStats};
pub use kernels::Kernel;
pub use layers::{executed_choices, LayerPlan, PlannedLayer};
pub use manifest::{ExecutableMeta, Manifest, ModelMeta};
pub use reference::{ReferenceBackend, REFERENCE_MODEL};
pub use tensor::Tensor;
