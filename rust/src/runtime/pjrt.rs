//! PJRT backend (feature `pjrt`): executes the AOT-lowered HLO
//! artifacts through the `xla` bindings — the deployment path of the
//! paper's system. Offline builds type-check this module against the
//! in-tree stub crate (vendor/xla-stub), which errors at runtime; link
//! real bindings to execute artifacts.
//!
//! Tensors cross the boundary by value: the crate-owned [`Tensor`] is
//! re-encoded into an `xla::Literal` per call.
//!
//! **Session / device-residency mapping** (DESIGN.md §3): the session
//! API ([`Backend::open_session`]) is this backend's hook for keeping
//! params and the gradient accumulator device-resident across calls —
//! the contract `jax.jit(donate_argnums=...)` lowers to, where the
//! round-tripping operand shares its device buffer with the
//! corresponding output. Real PJRT bindings express that via
//! `ExecuteOptions` non-donatable-argument sets at execute time plus
//! `input_output_alias` in the lowered HLO (the AOT pipeline already
//! marks those pairs); a device-resident `PjrtSession` would upload
//! params once in `open_session`, hold two `PjRtBuffer`s, alias them
//! through every execute, and only download at `read_params` (the
//! checkpoint seam). Against the offline stub the device side is
//! unavailable, so this backend keeps the trait defaults: the session
//! is host-buffered over the donating defaults, which mint one fresh
//! host `Tensor` per call and *move* it into the bound slot — no extra
//! copy, and the trainer already holds exactly one params and one acc
//! binding for the run.

use super::backend::{AccumArgs, AccumOut, ApplyArgs, Backend, Prepared};
use super::compile_cache::{CompileCache, CompileRecord};
use super::manifest::{ExecutableMeta, ModelMeta};
use super::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::{Arc, Mutex};

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Backend over the PJRT CPU client. `Send + Sync`: the compile cache
/// sits behind a `Mutex` (the stub client carries no state; real
/// bindings' clients are internally synchronized).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: Mutex<CompileCache<xla::PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { client, cache: Mutex::new(CompileCache::new()) })
    }

    fn lookup(&self, prep: &Prepared) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.cache
            .lock()
            .unwrap()
            .get_cached(&prep.key)
            .ok_or_else(|| anyhow!("executable {} was not prepared", prep.key))
    }

    /// Fold the 64-bit per-step seed into the ABI's i32 seed slot,
    /// xoring the halves so both contribute.
    fn fold_seed(seed: u64) -> i32 {
        ((seed >> 32) ^ (seed & 0xffff_ffff)) as u32 as i32
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, dir: &Path, _meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared> {
        let full = dir.join(&exe.path);
        let client = &self.client;
        // Append-only cache: recover a lock poisoned by a panicking
        // worker (same rationale as the reference backend).
        let (_, compile_seconds) = self
            .cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .get_or_compile(&exe.path, || {
            let proto = xla::HloModuleProto::from_text_file(&full)
                .map_err(xerr)
                .with_context(|| format!("parsing HLO text {}", full.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(xerr)
                .with_context(|| format!("PJRT compile of {}", full.display()))
        })?;
        Ok(Prepared { key: exe.path.clone(), compile_seconds })
    }

    fn is_compiled(&self, key: &str) -> bool {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).is_cached(key)
    }

    fn compile_records(&self) -> Vec<CompileRecord> {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).records().to_vec()
    }

    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumOut> {
        let exe = self.lookup(prep)?;
        let b = args.batch();
        let img = meta.image as i64;
        let xs = xla::Literal::vec1(args.x)
            .reshape(&[b as i64, img, img, meta.channels as i64])
            .map_err(xerr)?;
        let ys = xla::Literal::vec1(args.y);
        let ms = xla::Literal::vec1(args.mask);
        let ps = xla::Literal::vec1(params.as_slice());
        let ac = xla::Literal::vec1(acc.as_slice());
        let out = exe.execute(&[&ps, &ac, &xs, &ys, &ms]).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (acc_out, loss, sq) = out.to_tuple3().map_err(xerr)?;
        Ok(AccumOut {
            acc: Tensor::from_vec(acc_out.to_vec::<f32>().map_err(xerr)?),
            loss_sum: loss.get_first_element::<f32>().map_err(xerr)?,
            sq_norms: sq.to_vec::<f32>().map_err(xerr)?,
        })
    }

    fn run_apply(
        &self,
        prep: &Prepared,
        _meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<Tensor> {
        let exe = self.lookup(prep)?;
        let ps = xla::Literal::vec1(params.as_slice());
        let ac = xla::Literal::vec1(acc.as_slice());
        let out = exe
            .execute(&[
                &ps,
                &ac,
                &xla::Literal::vec1(&[Self::fold_seed(args.seed)]),
                &xla::Literal::vec1(&[args.denom]),
                &xla::Literal::vec1(&[args.lr]),
                &xla::Literal::vec1(&[args.noise_mult]),
            ])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let params_out = out.to_tuple1().map_err(xerr)?;
        Ok(Tensor::from_vec(params_out.to_vec::<f32>().map_err(xerr)?))
    }

    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let exe = self.lookup(prep)?;
        let img = meta.image as i64;
        let xs = xla::Literal::vec1(x)
            .reshape(&[y.len() as i64, img, img, meta.channels as i64])
            .map_err(xerr)?;
        let ys = xla::Literal::vec1(y);
        let ps = xla::Literal::vec1(params.as_slice());
        let out = exe.execute(&[&ps, &xs, &ys]).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (loss, ncorrect) = out.to_tuple2().map_err(xerr)?;
        Ok((
            loss.get_first_element::<f32>().map_err(xerr)?,
            ncorrect.get_first_element::<f32>().map_err(xerr)?,
        ))
    }
}
