//! PJRT backend (feature `pjrt`): executes the AOT-lowered HLO
//! artifacts through the `xla` bindings — the deployment path of the
//! paper's system. Offline builds type-check this module against the
//! in-tree stub crate (vendor/xla-stub), which errors at runtime; link
//! real bindings to execute artifacts.
//!
//! Tensors cross the boundary by value: the crate-owned [`Tensor`] is
//! re-encoded into an `xla::Literal` per call.
//!
//! **Donation mapping** (DESIGN.md §3): the `run_*_into` entry points
//! are this backend's hook for XLA input-output aliasing — the same
//! contract `jax.jit(donate_argnums=...)` lowers to, where the
//! round-tripping operand (`acc` for accum, `params` for apply) shares
//! its device buffer with the corresponding output. Real PJRT bindings
//! express that via `ExecuteOptions` non-donatable-argument sets at
//! execute time plus `input_output_alias` in the lowered HLO (the AOT
//! pipeline already marks those pairs); a device-resident backend would
//! override `run_accum_into`/`run_apply_into` to keep the buffer on
//! device across calls. Against the offline stub the device side is
//! unavailable, so this backend keeps the trait defaults: the copying
//! form mints one fresh host `Tensor` per call and the donating default
//! *moves* it into the donated slot — no extra copy, and the trainer's
//! hot loop still holds one params and one acc binding for the run.

// The ABI methods carry the full flat-param call (8-9 args by design).
#![allow(clippy::too_many_arguments)]

use super::backend::{AccumOut, Backend, Prepared};
use super::compile_cache::{CompileCache, CompileRecord};
use super::manifest::{ExecutableMeta, ModelMeta};
use super::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::path::Path;
use std::sync::Arc;

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Backend over the PJRT CPU client.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: RefCell<CompileCache<xla::PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { client, cache: RefCell::new(CompileCache::new()) })
    }

    fn lookup(&self, prep: &Prepared) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.cache
            .borrow()
            .get_cached(&prep.key)
            .ok_or_else(|| anyhow!("executable {} was not prepared", prep.key))
    }

    /// Fold the 64-bit per-step seed into the ABI's i32 seed slot,
    /// xoring the halves so both contribute.
    fn fold_seed(seed: u64) -> i32 {
        ((seed >> 32) ^ (seed & 0xffff_ffff)) as u32 as i32
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, dir: &Path, _meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared> {
        let full = dir.join(&exe.path);
        let client = &self.client;
        let (_, compile_seconds) = self.cache.borrow_mut().get_or_compile(&exe.path, || {
            let proto = xla::HloModuleProto::from_text_file(&full)
                .map_err(xerr)
                .with_context(|| format!("parsing HLO text {}", full.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(xerr)
                .with_context(|| format!("PJRT compile of {}", full.display()))
        })?;
        Ok(Prepared { key: exe.path.clone(), compile_seconds })
    }

    fn is_compiled(&self, key: &str) -> bool {
        self.cache.borrow().is_cached(key)
    }

    fn compile_records(&self) -> Vec<CompileRecord> {
        self.cache.borrow().records().to_vec()
    }

    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumOut> {
        let exe = self.lookup(prep)?;
        let b = y.len();
        let img = meta.image as i64;
        let xs = xla::Literal::vec1(x)
            .reshape(&[b as i64, img, img, meta.channels as i64])
            .map_err(xerr)?;
        let ys = xla::Literal::vec1(y);
        let ms = xla::Literal::vec1(mask);
        let ps = xla::Literal::vec1(params.as_slice());
        let ac = xla::Literal::vec1(acc.as_slice());
        let out = exe.execute(&[&ps, &ac, &xs, &ys, &ms]).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (acc_out, loss, sq) = out.to_tuple3().map_err(xerr)?;
        Ok(AccumOut {
            acc: Tensor::from_vec(acc_out.to_vec::<f32>().map_err(xerr)?),
            loss_sum: loss.get_first_element::<f32>().map_err(xerr)?,
            sq_norms: sq.to_vec::<f32>().map_err(xerr)?,
        })
    }

    fn run_apply(
        &self,
        prep: &Prepared,
        _meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<Tensor> {
        let exe = self.lookup(prep)?;
        let ps = xla::Literal::vec1(params.as_slice());
        let ac = xla::Literal::vec1(acc.as_slice());
        let out = exe
            .execute(&[
                &ps,
                &ac,
                &xla::Literal::vec1(&[Self::fold_seed(seed)]),
                &xla::Literal::vec1(&[denom]),
                &xla::Literal::vec1(&[lr]),
                &xla::Literal::vec1(&[noise_mult]),
            ])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let params_out = out.to_tuple1().map_err(xerr)?;
        Ok(Tensor::from_vec(params_out.to_vec::<f32>().map_err(xerr)?))
    }

    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let exe = self.lookup(prep)?;
        let img = meta.image as i64;
        let xs = xla::Literal::vec1(x)
            .reshape(&[y.len() as i64, img, img, meta.channels as i64])
            .map_err(xerr)?;
        let ys = xla::Literal::vec1(y);
        let ps = xla::Literal::vec1(params.as_slice());
        let out = exe.execute(&[&ps, &xs, &ys]).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (loss, ncorrect) = out.to_tuple2().map_err(xerr)?;
        Ok((
            loss.get_first_element::<f32>().map_err(xerr)?,
            ncorrect.get_first_element::<f32>().map_err(xerr)?,
        ))
    }
}
