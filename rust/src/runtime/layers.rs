//! The executable layer IR's flat-parameter layout: [`LayerPlan`].
//!
//! A model is a chain of dense layers ([`crate::models::LayerSpec`])
//! ending in a softmax-xent head. The plan resolves that chain against
//! a [`ModelMeta`] into everything the reference kernels need to
//! execute it over one flat f32 parameter vector:
//!
//! * **Parameter layout** — layer blocks in chain order, each
//!   `[W row-major | b]`:
//!
//!   ```text
//!   params = [ W0[d_out0, d_in0] | b0[d_out0] | W1[...] | b1[...] | ... ]
//!   ```
//!
//!   For a single-layer model this degenerates to `[W | b]` — exactly
//!   the seed `ref-linear` layout, which is what makes the one-layer IR
//!   model bitwise-compatible with the original hardcoded kernel
//!   (checkpoints included).
//!
//! * **Forward-tape layout** — per example, the backward pass needs
//!   each layer's *input* activations. The input image is borrowed from
//!   the batch; hidden activations (post-activation, one slot per
//!   hidden layer) are stored at `act_off` in a per-example tape window
//!   of [`LayerPlan::tape_stride`] floats. Storing post-activations is
//!   enough for ReLU backward: `a > 0 ⟺ z > 0`.
//!
//! * **dz layout** — per example, per layer, the gradient w.r.t. the
//!   layer's pre-activation output lives at `dz_off` in a window of
//!   [`LayerPlan::dz_stride`] floats. Layer slots are contiguous in
//!   chain order, so the backward pass can split one window into
//!   "already-final dz of layer l" and "da being built for layer l-1".
//!
//! * **Executed clipping branch** — [`executed_choices`] maps an accum
//!   variant onto a per-layer [`LayerChoice`]: ghost-style layers fold
//!   the clipped gradient with a fused reweighted `axpy` (never
//!   materializing a per-example weight gradient), per-example layers
//!   materialize each example's layer gradient first (the Opacus-style
//!   memory traffic the paper's Table 2 profiles). The `mix` variant
//!   applies the Bu et al. decision rule
//!   ([`crate::clipping::mix_ghost_choice`]) per layer — the executed
//!   counterpart of the analytic registry in `clipping.rs`.

use super::manifest::ModelMeta;
use crate::clipping::{mix_ghost_choice, LayerChoice};
use crate::models::{Activation, LayerSpec};
use anyhow::{anyhow, Result};

/// One layer of a [`LayerPlan`]: the spec plus every resolved offset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedLayer {
    /// The layer's dims + activation.
    pub spec: LayerSpec,
    /// Offset of `W` (row-major `[d_out, d_in]`) in the flat params.
    pub w_off: usize,
    /// Offset of `b` (`[d_out]`) in the flat params.
    pub b_off: usize,
    /// Offset of this layer's *output* activations in the per-example
    /// tape window. Only meaningful for hidden layers (the head's
    /// logits live in the dz window instead); for the last layer this
    /// equals [`LayerPlan::tape_stride`].
    pub act_off: usize,
    /// Offset of this layer's dz slot in the per-example dz window.
    pub dz_off: usize,
}

/// Flat-parameter + scratch layout of one executable layered model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPlan {
    /// Layers in chain order (input → head).
    pub layers: Vec<PlannedLayer>,
    /// Total flat parameters (must equal `ModelMeta::n_params`).
    pub n_params: usize,
    /// Flattened input dim `H*W*C` (== `d_in` of the first layer).
    pub input_dim: usize,
    /// Classes (== `d_out` of the last layer).
    pub num_classes: usize,
    /// Per-example tape floats (sum of hidden-layer widths).
    pub tape_stride: usize,
    /// Per-example dz floats (sum of all layer widths).
    pub dz_stride: usize,
    /// Largest layer width (eval ping-pong buffer bound).
    pub max_width: usize,
    /// Largest layer input dim (materialized-row scratch bound).
    pub max_d_in: usize,
}

impl LayerPlan {
    /// Resolve `meta`'s layer chain into a plan, validating the chain
    /// against the model geometry (input dim, class count, head
    /// activation, parameter count). A meta without an explicit layer
    /// list resolves to the legacy single dense layer
    /// (`ModelMeta::layer_specs`), so pre-IR manifests keep executing.
    pub fn build(meta: &ModelMeta) -> Result<Self> {
        let specs = meta.layer_specs();
        let input_dim = meta.image * meta.image * meta.channels;
        let first = specs.first().expect("layer_specs is never empty");
        if first.d_in != input_dim {
            return Err(anyhow!(
                "layer 0 d_in {} != image dim {input_dim} ({}x{}x{})",
                first.d_in,
                meta.image,
                meta.image,
                meta.channels
            ));
        }
        let mut layers = Vec::with_capacity(specs.len());
        let (mut off, mut tape, mut dz) = (0usize, 0usize, 0usize);
        let (mut max_width, mut max_d_in) = (0usize, 0usize);
        for (l, spec) in specs.iter().enumerate() {
            if spec.d_in == 0 || spec.d_out == 0 {
                return Err(anyhow!("layer {l}: zero-width dense layer"));
            }
            if l > 0 && specs[l - 1].d_out != spec.d_in {
                return Err(anyhow!(
                    "layer chain broken at {l}: d_out {} feeds d_in {}",
                    specs[l - 1].d_out,
                    spec.d_in
                ));
            }
            let last = l == specs.len() - 1;
            if last && spec.activation != Activation::None {
                return Err(anyhow!("head layer must not carry an activation"));
            }
            let w_off = off;
            let b_off = off + spec.d_in * spec.d_out;
            off = b_off + spec.d_out;
            let act_off = tape;
            if !last {
                tape += spec.d_out;
            }
            layers.push(PlannedLayer { spec: *spec, w_off, b_off, act_off, dz_off: dz });
            dz += spec.d_out;
            max_width = max_width.max(spec.d_out);
            max_d_in = max_d_in.max(spec.d_in);
        }
        let head = layers.last().expect("non-empty");
        if head.spec.d_out != meta.num_classes {
            return Err(anyhow!(
                "head d_out {} != num_classes {}",
                head.spec.d_out,
                meta.num_classes
            ));
        }
        if off != meta.n_params {
            return Err(anyhow!(
                "layer chain lays out {off} params but the manifest says {}",
                meta.n_params
            ));
        }
        Ok(Self {
            layers,
            n_params: off,
            input_dim,
            num_classes: meta.num_classes,
            tape_stride: tape,
            dz_stride: dz,
            max_width,
            max_d_in,
        })
    }

    /// Multiply-adds of one forward pass per example (the threading
    /// work gate's unit).
    pub fn macs_per_example(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.spec.d_in * l.spec.d_out)
            .sum()
    }

    /// Total accumulator row units (sum of layer widths) — the phase-2
    /// parallel partitioning domain.
    pub fn total_rows(&self) -> usize {
        self.dz_stride
    }
}

/// Per-layer executed clipping branch for one accum `variant`:
///
/// * `nonprivate` / `naive` / `masked` / `ghost` / `bk` — every layer
///   folds fused ([`LayerChoice::Ghost`]): the vmapped graphs fuse
///   clip+accumulate, and the ghost/BK graphs never materialize
///   per-example weight grads by construction.
/// * `perex` — every layer materializes ([`LayerChoice::PerExample`]):
///   the Opacus-style hook cost, observable as extra memory traffic.
/// * `mix` — the Bu et al. (2022) rule per layer, at the CPU ladder's
///   effective sequence length t = 1.
///
/// All branches produce **bitwise-identical** accumulators and norms
/// (the per-example norm is computed once, in the shared Gram form, and
/// the materialized fold adds exactly the same addends in the same
/// order) — property-tested in `rust/tests/layered_models.rs`. The
/// branch choice moves memory traffic and wall-clock only.
pub fn executed_choices(variant: &str, plan: &LayerPlan) -> Result<Vec<LayerChoice>> {
    match variant {
        "nonprivate" | "naive" | "masked" | "ghost" | "bk" => {
            Ok(vec![LayerChoice::Ghost; plan.layers.len()])
        }
        "perex" => Ok(vec![LayerChoice::PerExample; plan.layers.len()]),
        "mix" => Ok(plan
            .layers
            .iter()
            .map(|l| mix_ghost_choice(&l.spec.linear_dims()))
            .collect()),
        other => Err(anyhow!("unknown accum variant {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_of(layers: Vec<LayerSpec>, image: usize, channels: usize, ncls: usize) -> ModelMeta {
        ModelMeta {
            family: "test".into(),
            n_params: layers.iter().map(LayerSpec::params).sum(),
            image,
            channels,
            num_classes: ncls,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "test.bin".into(),
            executables: Vec::new(),
            layers,
        }
    }

    #[test]
    fn single_layer_plan_is_the_seed_layout() {
        let meta = meta_of(vec![LayerSpec::dense(16 * 16 * 3, 10)], 16, 3, 10);
        let plan = LayerPlan::build(&meta).unwrap();
        assert_eq!(plan.layers.len(), 1);
        assert_eq!(plan.layers[0].w_off, 0);
        assert_eq!(plan.layers[0].b_off, 10 * 768);
        assert_eq!(plan.n_params, 10 * 768 + 10);
        assert_eq!(plan.tape_stride, 0, "no hidden layers, no tape");
        assert_eq!(plan.dz_stride, 10);
        assert_eq!(plan.max_d_in, 768);
    }

    #[test]
    fn legacy_meta_without_layers_resolves_to_one_dense() {
        let mut meta = meta_of(vec![LayerSpec::dense(48, 4)], 4, 3, 4);
        meta.layers = Vec::new(); // pre-IR manifest
        let plan = LayerPlan::build(&meta).unwrap();
        assert_eq!(plan.layers.len(), 1);
        assert_eq!(plan.layers[0].spec, LayerSpec::dense(48, 4));
    }

    #[test]
    fn multi_layer_offsets_chain() {
        let meta = meta_of(
            vec![
                LayerSpec::dense_relu(12, 5),
                LayerSpec::dense_relu(5, 4),
                LayerSpec::dense(4, 3),
            ],
            2,
            3,
            3,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        assert_eq!(plan.layers[0].w_off, 0);
        assert_eq!(plan.layers[0].b_off, 60);
        assert_eq!(plan.layers[1].w_off, 65);
        assert_eq!(plan.layers[1].b_off, 65 + 20);
        assert_eq!(plan.layers[2].w_off, 89);
        assert_eq!(plan.n_params, meta.n_params);
        // Tape holds the two hidden outputs; dz every layer's output.
        assert_eq!(plan.tape_stride, 5 + 4);
        assert_eq!(plan.dz_stride, 5 + 4 + 3);
        assert_eq!(plan.layers[0].act_off, 0);
        assert_eq!(plan.layers[1].act_off, 5);
        assert_eq!(plan.layers[0].dz_off, 0);
        assert_eq!(plan.layers[1].dz_off, 5);
        assert_eq!(plan.layers[2].dz_off, 9);
        assert_eq!(plan.max_width, 5);
        assert_eq!(plan.max_d_in, 12);
        assert_eq!(plan.total_rows(), 12);
        assert_eq!(plan.macs_per_example(), 12 * 5 + 5 * 4 + 4 * 3);
    }

    #[test]
    fn malformed_chains_are_rejected() {
        // Broken chain.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 5), LayerSpec::dense(6, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Head activation.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Wrong head width.
        let meta = meta_of(vec![LayerSpec::dense(12, 4)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Wrong input dim.
        let meta = meta_of(vec![LayerSpec::dense(10, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // n_params mismatch.
        let mut meta = meta_of(vec![LayerSpec::dense(12, 3)], 2, 3, 3);
        meta.n_params += 1;
        assert!(LayerPlan::build(&meta).is_err());
        // Zero-width layer.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 0), LayerSpec::dense(0, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
    }

    #[test]
    fn executed_choices_map_variants_onto_branches() {
        let meta = meta_of(
            vec![LayerSpec::dense_relu(12, 5), LayerSpec::dense(5, 3)],
            2,
            3,
            3,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        for fused in ["nonprivate", "naive", "masked", "ghost", "bk"] {
            assert_eq!(
                executed_choices(fused, &plan).unwrap(),
                vec![LayerChoice::Ghost; 2],
                "{fused}"
            );
        }
        assert_eq!(
            executed_choices("perex", &plan).unwrap(),
            vec![LayerChoice::PerExample; 2]
        );
        assert!(executed_choices("mystery", &plan).is_err());
    }

    #[test]
    fn mix_choices_follow_the_decision_rule_per_layer() {
        // At t = 1 the rule is: ghost iff 2 <= d_in * d_out. A 1x1
        // hidden layer is the one executable shape where per-example
        // wins.
        let meta = meta_of(
            vec![
                LayerSpec::dense_relu(3, 1),
                LayerSpec::dense_relu(1, 1), // 2*1 > 1: per-example
                LayerSpec::dense(1, 2),      // 2 <= 2: ghost
            ],
            1,
            3,
            2,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        let choices = executed_choices("mix", &plan).unwrap();
        assert_eq!(
            choices,
            vec![LayerChoice::Ghost, LayerChoice::PerExample, LayerChoice::Ghost]
        );
        // And each choice equals the analytic registry's call.
        for (c, l) in choices.iter().zip(&plan.layers) {
            assert_eq!(*c, mix_ghost_choice(&l.spec.linear_dims()));
        }
    }
}
