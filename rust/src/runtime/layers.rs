//! The executable layer IR's flat-parameter layout: [`LayerPlan`].
//!
//! A model is a chain of layers ([`crate::models::LayerSpec`]: dense,
//! conv2d, layernorm, attention) ending in a dense softmax-xent head.
//! The plan resolves that chain against a [`ModelMeta`] into everything
//! the reference kernels need to execute it over one flat f32 parameter
//! vector:
//!
//! * **Parameter layout** — layer blocks in chain order, sub-layout per
//!   kind (DESIGN.md §13):
//!
//!   ```text
//!   dense:     [ W[d_out, d_in] | b[d_out] ]
//!   conv2d:    [ K[c_out, c_in*kh*kw] | b[c_out] ]
//!   layernorm: [ gamma[d] | beta[d] ]
//!   attention: [ Wq[dh,d] | bq | Wk[dh,d] | bk | Wv[dh,d] | bv
//!              | Wo[d,dh] | bo ]
//!   ```
//!
//!   For a single dense layer this degenerates to `[W | b]` — exactly
//!   the seed `ref-linear` layout, which is what makes the one-layer IR
//!   model bitwise-compatible with the original hardcoded kernel
//!   (checkpoints included).
//!
//! * **Forward-tape layout** — per example, the backward pass needs
//!   each layer's *input* activations. The input image is borrowed from
//!   the batch; hidden outputs (post-activation, one slot per hidden
//!   layer) are stored at `act_off` in a per-example tape window of
//!   [`LayerPlan::tape_stride`] floats (post-activations are enough for
//!   ReLU backward: `a > 0 ⟺ z > 0`). Non-dense kinds tape extra
//!   forward intermediates at `ext_off` ([`tape_extras`]): layernorm
//!   its `xhat` + `rstd`, attention its `q/k/v`, softmax probabilities,
//!   and attended context.
//!
//! * **dz layout** — per example, per layer, the gradient w.r.t. the
//!   layer's pre-activation output lives at `dz_off` in a window of
//!   [`LayerPlan::dz_stride`] floats. Layer slots are contiguous in
//!   chain order, so the backward pass can split one window into
//!   "already-final dz of layer l" and "da being built for layer l-1".
//!   Attention additionally stores its internal projection gradients
//!   (`dq/dk/dv/dctx`) at `dz_ext_off` ([`dz_extras`]) — phase 2 reads
//!   them to fold the q/k/v/o parameter gradients.
//!
//! * **Executed clipping branch** — [`executed_choices`] maps an accum
//!   variant onto a per-layer [`LayerChoice`]: ghost-style layers fold
//!   the clipped gradient with a fused reweighted `axpy` (never
//!   materializing a per-example weight gradient), per-example layers
//!   materialize each example's layer gradient first (the Opacus-style
//!   memory traffic the paper's Table 2 profiles). The `mix` variant
//!   applies the Bu et al. decision rule
//!   ([`crate::clipping::mix_ghost_choice`]) per layer — over each
//!   kind's ghost view (convs: im2col; attention: the fused qkv) — the
//!   executed counterpart of the analytic registry in `clipping.rs`.

use super::manifest::ModelMeta;
use crate::clipping::{mix_ghost_choice, LayerChoice};
use crate::models::{conv_out, Activation, LayerKind, LayerSpec};
use anyhow::{anyhow, Result};

/// Per-example tape floats a layer stores *beyond* its output slot
/// (forward intermediates its backward needs).
pub fn tape_extras(spec: &LayerSpec) -> usize {
    match spec.kind {
        LayerKind::Dense | LayerKind::Conv2d { .. } => 0,
        // xhat[d] + rstd.
        LayerKind::LayerNorm => spec.d_out + 1,
        // q, k, v, ctx ([t, d_head] each) + softmax probs [t, t].
        LayerKind::Attention { t, d_head, .. } => 4 * t * d_head + t * t,
    }
}

/// Per-example dz floats a layer stores beyond its output-grad slot
/// (backward intermediates phase 2 folds into parameter gradients).
pub fn dz_extras(spec: &LayerSpec) -> usize {
    match spec.kind {
        // dq, dk, dv, dctx ([t, d_head] each).
        LayerKind::Attention { t, d_head, .. } => 4 * t * d_head,
        _ => 0,
    }
}

/// Accumulator row units this layer contributes to phase 2: dense one
/// per output row, conv one per output channel, layernorm gamma + beta,
/// attention one per q/k/v/o projection row.
pub fn row_units(spec: &LayerSpec) -> usize {
    match spec.kind {
        LayerKind::Dense => spec.d_out,
        LayerKind::Conv2d { c_out, .. } => c_out,
        LayerKind::LayerNorm => 2,
        LayerKind::Attention { d_model, d_head, .. } => 3 * d_head + d_model,
    }
}

/// Widest phase-2 contribution any of this layer's row units computes
/// (scratch bound for the canonical position-summed contribution).
fn unit_width(spec: &LayerSpec) -> usize {
    match spec.kind {
        LayerKind::Dense => spec.d_in,
        LayerKind::Conv2d { c_in, kh, kw, .. } => c_in * kh * kw,
        LayerKind::LayerNorm => spec.d_out,
        LayerKind::Attention { d_model, d_head, .. } => d_model.max(d_head),
    }
}

/// Phase-1 backward scratch floats this layer needs per worker: convs
/// unfold the input (im2col patches `[T, c_in*kh*kw]`) and transpose dz
/// (`[T, c_out]`) for the Gram-norm dot products; attention needs one
/// `[t]` row for the softmax backward.
fn bwd_scratch(spec: &LayerSpec) -> usize {
    match spec.kind {
        LayerKind::Dense | LayerKind::LayerNorm => 0,
        LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad } => {
            let t = conv_out(h_in, kh, stride, pad) * conv_out(w_in, kw, stride, pad);
            t * (c_in * kh * kw) + t * c_out
        }
        LayerKind::Attention { t, .. } => t,
    }
}

/// One layer of a [`LayerPlan`]: the spec plus every resolved offset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedLayer {
    /// The layer's dims + activation + kind.
    pub spec: LayerSpec,
    /// Offset of the layer's parameter block in the flat params (dense:
    /// `W` row-major `[d_out, d_in]`; conv: `K` as `[c_out, c_in*kh*kw]`
    /// rows; layernorm: `gamma`; attention: `Wq`).
    pub w_off: usize,
    /// Offset of the first bias-like block (dense/conv: `b`; layernorm:
    /// `beta`; attention: `bq` — the remaining attention sub-blocks
    /// follow the layout in the module doc).
    pub b_off: usize,
    /// Offset of this layer's *output* activations in the per-example
    /// tape window. Only meaningful for hidden layers (the head's
    /// logits live in the dz window instead).
    pub act_off: usize,
    /// Offset of this layer's kind-specific forward extras
    /// ([`tape_extras`]) in the tape window.
    pub ext_off: usize,
    /// Offset of this layer's dz slot in the per-example dz window.
    pub dz_off: usize,
    /// Offset of this layer's kind-specific backward extras
    /// ([`dz_extras`]) in the dz window.
    pub dz_ext_off: usize,
}

/// Flat-parameter + scratch layout of one executable layered model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPlan {
    /// Layers in chain order (input → head).
    pub layers: Vec<PlannedLayer>,
    /// Total flat parameters (must equal `ModelMeta::n_params`).
    pub n_params: usize,
    /// Flattened input dim `H*W*C` (== `d_in` of the first layer).
    pub input_dim: usize,
    /// Classes (== `d_out` of the last layer).
    pub num_classes: usize,
    /// Per-example tape floats (hidden-layer widths + tape extras).
    pub tape_stride: usize,
    /// Per-example dz floats (all layer widths + dz extras).
    pub dz_stride: usize,
    /// Largest layer width (eval ping-pong buffer bound).
    pub max_width: usize,
    /// Largest layer input dim.
    pub max_d_in: usize,
    /// Widest phase-2 row-unit contribution (scratch bound).
    pub max_unit_width: usize,
    /// Phase-1 backward scratch floats per worker ([`bwd_scratch`]).
    pub bwd_scratch: usize,
    /// Eval forward scratch floats (largest [`tape_extras`] — eval has
    /// no tape, so non-dense forward intermediates live here).
    pub eval_scratch: usize,
}

impl LayerPlan {
    /// Resolve `meta`'s layer chain into a plan, validating the chain
    /// against the model geometry (input dim, class count, head
    /// activation, parameter count). A meta without an explicit layer
    /// list resolves to the legacy single dense layer
    /// (`ModelMeta::layer_specs`), so pre-IR manifests keep executing.
    pub fn build(meta: &ModelMeta) -> Result<Self> {
        let specs = meta.layer_specs();
        let input_dim = meta.image * meta.image * meta.channels;
        let first = specs.first().expect("layer_specs is never empty");
        if first.d_in != input_dim {
            return Err(anyhow!(
                "layer 0 d_in {} != image dim {input_dim} ({}x{}x{})",
                first.d_in,
                meta.image,
                meta.image,
                meta.channels
            ));
        }
        let mut layers = Vec::with_capacity(specs.len());
        let (mut off, mut tape, mut dz) = (0usize, 0usize, 0usize);
        let (mut max_width, mut max_d_in) = (0usize, 0usize);
        let (mut max_unit, mut scratch, mut eval_scratch) = (0usize, 0usize, 0usize);
        for (l, spec) in specs.iter().enumerate() {
            if spec.d_in == 0 || spec.d_out == 0 {
                return Err(anyhow!("layer {l}: zero-width layer"));
            }
            if l > 0 && specs[l - 1].d_out != spec.d_in {
                return Err(anyhow!(
                    "layer chain broken at {l}: d_out {} feeds d_in {}",
                    specs[l - 1].d_out,
                    spec.d_in
                ));
            }
            match spec.kind {
                LayerKind::Dense => {}
                LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad } => {
                    if c_in == 0 || c_out == 0 || kh == 0 || kw == 0 || stride == 0 {
                        return Err(anyhow!("layer {l}: degenerate conv2d geometry"));
                    }
                    if h_in + 2 * pad < kh || w_in + 2 * pad < kw {
                        return Err(anyhow!(
                            "layer {l}: conv2d kernel {kh}x{kw} exceeds padded input"
                        ));
                    }
                    if spec.d_in != c_in * h_in * w_in {
                        return Err(anyhow!(
                            "layer {l}: conv2d d_in {} != {c_in}x{h_in}x{w_in}",
                            spec.d_in
                        ));
                    }
                    let ho = conv_out(h_in, kh, stride, pad);
                    let wo = conv_out(w_in, kw, stride, pad);
                    if spec.d_out != c_out * ho * wo {
                        return Err(anyhow!(
                            "layer {l}: conv2d d_out {} != {c_out}x{ho}x{wo}",
                            spec.d_out
                        ));
                    }
                }
                LayerKind::LayerNorm => {
                    if spec.d_in != spec.d_out {
                        return Err(anyhow!("layer {l}: layernorm must preserve width"));
                    }
                }
                LayerKind::Attention { t, d_model, d_head } => {
                    if t == 0 || d_model == 0 || d_head == 0 {
                        return Err(anyhow!("layer {l}: degenerate attention geometry"));
                    }
                    if spec.d_in != t * d_model || spec.d_out != spec.d_in {
                        return Err(anyhow!(
                            "layer {l}: attention d_in {} / d_out {} != {t}x{d_model}",
                            spec.d_in,
                            spec.d_out
                        ));
                    }
                }
            }
            let last = l == specs.len() - 1;
            if last && spec.activation != Activation::None {
                return Err(anyhow!("head layer must not carry an activation"));
            }
            if last && spec.kind != LayerKind::Dense {
                return Err(anyhow!(
                    "head layer must be dense (softmax-xent consumes dense logits)"
                ));
            }
            let w_off = off;
            let b_off = match spec.kind {
                LayerKind::Dense => off + spec.d_in * spec.d_out,
                LayerKind::Conv2d { c_in, c_out, kh, kw, .. } => off + c_out * c_in * kh * kw,
                LayerKind::LayerNorm => off + spec.d_out,
                LayerKind::Attention { d_model, d_head, .. } => off + d_model * d_head,
            };
            off += spec.params();
            let act_off = tape;
            let mut ext_off = act_off;
            if !last {
                tape += spec.d_out;
                ext_off = tape;
                tape += tape_extras(spec);
            }
            let dz_off = dz;
            let dz_ext_off = dz_off + spec.d_out;
            dz = dz_ext_off + dz_extras(spec);
            layers.push(PlannedLayer {
                spec: *spec,
                w_off,
                b_off,
                act_off,
                ext_off,
                dz_off,
                dz_ext_off,
            });
            max_width = max_width.max(spec.d_out);
            max_d_in = max_d_in.max(spec.d_in);
            max_unit = max_unit.max(unit_width(spec));
            scratch = scratch.max(bwd_scratch(spec));
            eval_scratch = eval_scratch.max(tape_extras(spec));
        }
        let head = layers.last().expect("non-empty");
        if head.spec.d_out != meta.num_classes {
            return Err(anyhow!(
                "head d_out {} != num_classes {}",
                head.spec.d_out,
                meta.num_classes
            ));
        }
        if off != meta.n_params {
            return Err(anyhow!(
                "layer chain lays out {off} params but the manifest says {}",
                meta.n_params
            ));
        }
        Ok(Self {
            layers,
            n_params: off,
            input_dim,
            num_classes: meta.num_classes,
            tape_stride: tape,
            dz_stride: dz,
            max_width,
            max_d_in,
            max_unit_width: max_unit,
            bwd_scratch: scratch,
            eval_scratch,
        })
    }

    /// Multiply-adds of one forward pass per example (the threading
    /// work gate's unit).
    pub fn macs_per_example(&self) -> usize {
        self.layers.iter().map(|l| l.spec.macs()).sum()
    }

    /// Total accumulator row units (sum of [`row_units`] per layer) —
    /// the phase-2 parallel partitioning domain.
    pub fn total_rows(&self) -> usize {
        self.layers.iter().map(|l| row_units(&l.spec)).sum()
    }
}

/// Per-layer executed clipping branch for one accum `variant`:
///
/// * `nonprivate` / `naive` / `masked` / `ghost` / `bk` — every layer
///   folds fused ([`LayerChoice::Ghost`]): the vmapped graphs fuse
///   clip+accumulate, and the ghost/BK graphs never materialize
///   per-example weight grads by construction.
/// * `perex` — every layer materializes ([`LayerChoice::PerExample`]):
///   the Opacus-style hook cost, observable as extra memory traffic.
/// * `mix` — the Bu et al. (2022) rule per layer, over each kind's
///   ghost-view dims ([`LayerSpec::linear_dims`]): dense at t = 1,
///   conv2d at t = ho*wo over im2col patches, attention at its
///   sequence length.
///
/// All branches produce **bitwise-identical** accumulators and norms
/// (the per-example norm is computed once, in the shared Gram form, and
/// the materialized fold adds exactly the same addends in the same
/// order) — property-tested in `rust/tests/layered_models.rs`. The
/// branch choice moves memory traffic and wall-clock only.
pub fn executed_choices(variant: &str, plan: &LayerPlan) -> Result<Vec<LayerChoice>> {
    match variant {
        "nonprivate" | "naive" | "masked" | "ghost" | "bk" => {
            Ok(vec![LayerChoice::Ghost; plan.layers.len()])
        }
        "perex" => Ok(vec![LayerChoice::PerExample; plan.layers.len()]),
        "mix" => Ok(plan
            .layers
            .iter()
            .map(|l| mix_ghost_choice(&l.spec.linear_dims()))
            .collect()),
        other => Err(anyhow!("unknown accum variant {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_of(layers: Vec<LayerSpec>, image: usize, channels: usize, ncls: usize) -> ModelMeta {
        ModelMeta {
            family: "test".into(),
            n_params: layers.iter().map(LayerSpec::params).sum(),
            image,
            channels,
            num_classes: ncls,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "test.bin".into(),
            executables: Vec::new(),
            layers,
        }
    }

    #[test]
    fn single_layer_plan_is_the_seed_layout() {
        let meta = meta_of(vec![LayerSpec::dense(16 * 16 * 3, 10)], 16, 3, 10);
        let plan = LayerPlan::build(&meta).unwrap();
        assert_eq!(plan.layers.len(), 1);
        assert_eq!(plan.layers[0].w_off, 0);
        assert_eq!(plan.layers[0].b_off, 10 * 768);
        assert_eq!(plan.n_params, 10 * 768 + 10);
        assert_eq!(plan.tape_stride, 0, "no hidden layers, no tape");
        assert_eq!(plan.dz_stride, 10);
        assert_eq!(plan.max_d_in, 768);
    }

    #[test]
    fn legacy_meta_without_layers_resolves_to_one_dense() {
        let mut meta = meta_of(vec![LayerSpec::dense(48, 4)], 4, 3, 4);
        meta.layers = Vec::new(); // pre-IR manifest
        let plan = LayerPlan::build(&meta).unwrap();
        assert_eq!(plan.layers.len(), 1);
        assert_eq!(plan.layers[0].spec, LayerSpec::dense(48, 4));
    }

    #[test]
    fn multi_layer_offsets_chain() {
        let meta = meta_of(
            vec![
                LayerSpec::dense_relu(12, 5),
                LayerSpec::dense_relu(5, 4),
                LayerSpec::dense(4, 3),
            ],
            2,
            3,
            3,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        assert_eq!(plan.layers[0].w_off, 0);
        assert_eq!(plan.layers[0].b_off, 60);
        assert_eq!(plan.layers[1].w_off, 65);
        assert_eq!(plan.layers[1].b_off, 65 + 20);
        assert_eq!(plan.layers[2].w_off, 89);
        assert_eq!(plan.n_params, meta.n_params);
        // Tape holds the two hidden outputs; dz every layer's output.
        assert_eq!(plan.tape_stride, 5 + 4);
        assert_eq!(plan.dz_stride, 5 + 4 + 3);
        assert_eq!(plan.layers[0].act_off, 0);
        assert_eq!(plan.layers[1].act_off, 5);
        assert_eq!(plan.layers[0].dz_off, 0);
        assert_eq!(plan.layers[1].dz_off, 5);
        assert_eq!(plan.layers[2].dz_off, 9);
        assert_eq!(plan.max_width, 5);
        assert_eq!(plan.max_d_in, 12);
        assert_eq!(plan.total_rows(), 12);
        assert_eq!(plan.macs_per_example(), 12 * 5 + 5 * 4 + 4 * 3);
    }

    #[test]
    fn heterogeneous_offsets_cover_every_kind() {
        // conv [3,4,4] -k3,s2,p1-> [2,2,2] relu -> attention (t=2, d=4,
        // dh=3) -> layernorm 8 -> dense head.
        let meta = meta_of(
            vec![
                LayerSpec::conv2d(3, 4, 2, 3, 2, 1, Activation::Relu),
                LayerSpec::attention(2, 4, 3),
                LayerSpec::layernorm(8),
                LayerSpec::dense(8, 5),
            ],
            4,
            3,
            5,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        let &[conv, attn, ln, head] = &plan.layers[..] else { panic!() };
        // Params: conv K 2*27 + b 2 = 56; attention 3*(12+3)+12+4 = 61;
        // layernorm 16; head 8*5+5 = 45.
        assert_eq!(conv.w_off, 0);
        assert_eq!(conv.b_off, 54);
        assert_eq!(attn.w_off, 56);
        assert_eq!(attn.b_off, 56 + 12, "bq follows Wq");
        assert_eq!(ln.w_off, 117);
        assert_eq!(ln.b_off, 117 + 8, "beta follows gamma");
        assert_eq!(head.w_off, 133);
        assert_eq!(plan.n_params, 133 + 45);
        // Tape: conv out 8 (no extras) | attn out 8 + extras
        // (4*2*3 + 4 = 28) | ln out 8 + extras (8 + 1 = 9).
        assert_eq!(conv.act_off, 0);
        assert_eq!(conv.ext_off, 8);
        assert_eq!(attn.act_off, 8);
        assert_eq!(attn.ext_off, 16);
        assert_eq!(ln.act_off, 44);
        assert_eq!(ln.ext_off, 52);
        assert_eq!(plan.tape_stride, 61);
        // dz: conv 8 | attn 8 + dq/dk/dv/dctx 24 | ln 8 | head 5.
        assert_eq!(conv.dz_off, 0);
        assert_eq!(attn.dz_off, 8);
        assert_eq!(attn.dz_ext_off, 16);
        assert_eq!(ln.dz_off, 40);
        assert_eq!(head.dz_off, 48);
        assert_eq!(plan.dz_stride, 53);
        // Row units: conv 2 channels, attn 3*3+4, ln 2, head 5.
        assert_eq!(plan.total_rows(), 2 + 13 + 2 + 5);
        assert_eq!(plan.max_unit_width, 27, "conv im2col row");
        // Conv scratch: patches 4*27 + dzT 4*2 = 116 > attn row 2.
        assert_eq!(plan.bwd_scratch, 116);
        assert_eq!(plan.eval_scratch, 28, "attention fwd intermediates");
        assert_eq!(
            plan.macs_per_example(),
            4 * 27 * 2 + (4 * 2 * 4 * 3 + 2 * 2 * 2 * 3) + 2 * 8 + 8 * 5
        );
    }

    #[test]
    fn malformed_kind_geometry_is_rejected() {
        // conv d_out inconsistent with its geometry.
        let mut bad = LayerSpec::conv2d(3, 4, 2, 3, 2, 1, Activation::Relu);
        bad.d_out += 1;
        let meta = meta_of(vec![bad, LayerSpec::dense(9, 5)], 4, 3, 5);
        assert!(LayerPlan::build(&meta).is_err());
        // Kernel exceeds padded input.
        let meta = meta_of(vec![LayerSpec::conv2d(3, 2, 2, 5, 1, 1, Activation::None)], 2, 3, 8);
        assert!(LayerPlan::build(&meta).is_err());
        // Layernorm must preserve width.
        let mut ln = LayerSpec::layernorm(12);
        ln.d_out = 10;
        let meta = meta_of(vec![ln, LayerSpec::dense(10, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Attention t*d_model mismatch.
        let mut at = LayerSpec::attention(3, 4, 2);
        at.d_in = 14;
        at.d_out = 14;
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 14), at, LayerSpec::dense(14, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Head must be dense.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 8), LayerSpec::layernorm(8)], 2, 3, 8);
        assert!(LayerPlan::build(&meta).is_err());
    }

    #[test]
    fn shipped_non_dense_models_plan_cleanly() {
        for name in ["cnn-small", "attn-tiny"] {
            let model = crate::models::cpu_ladder()
                .into_iter()
                .find(|m| m.name == name)
                .unwrap();
            let meta = ModelMeta {
                family: model.family.into(),
                n_params: model.params(),
                image: model.image,
                channels: model.channels,
                num_classes: model.num_classes,
                clip_norm: model.clip_norm,
                flops_fwd_per_example: model.fwd_flops_per_example(),
                init_params: "x.bin".into(),
                executables: Vec::new(),
                layers: model.layers.clone(),
            };
            let plan = LayerPlan::build(&meta).unwrap();
            assert_eq!(plan.n_params, model.params(), "{name}");
            assert!(plan.bwd_scratch > 0, "{name} has a non-dense layer");
        }
    }

    #[test]
    fn malformed_chains_are_rejected() {
        // Broken chain.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 5), LayerSpec::dense(6, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Head activation.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Wrong head width.
        let meta = meta_of(vec![LayerSpec::dense(12, 4)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // Wrong input dim.
        let meta = meta_of(vec![LayerSpec::dense(10, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
        // n_params mismatch.
        let mut meta = meta_of(vec![LayerSpec::dense(12, 3)], 2, 3, 3);
        meta.n_params += 1;
        assert!(LayerPlan::build(&meta).is_err());
        // Zero-width layer.
        let meta = meta_of(vec![LayerSpec::dense_relu(12, 0), LayerSpec::dense(0, 3)], 2, 3, 3);
        assert!(LayerPlan::build(&meta).is_err());
    }

    #[test]
    fn executed_choices_map_variants_onto_branches() {
        let meta = meta_of(
            vec![LayerSpec::dense_relu(12, 5), LayerSpec::dense(5, 3)],
            2,
            3,
            3,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        for fused in ["nonprivate", "naive", "masked", "ghost", "bk"] {
            assert_eq!(
                executed_choices(fused, &plan).unwrap(),
                vec![LayerChoice::Ghost; 2],
                "{fused}"
            );
        }
        assert_eq!(
            executed_choices("perex", &plan).unwrap(),
            vec![LayerChoice::PerExample; 2]
        );
        assert!(executed_choices("mystery", &plan).is_err());
    }

    #[test]
    fn mix_choices_follow_the_decision_rule_per_layer() {
        // At t = 1 the rule is: ghost iff 2 <= d_in * d_out. A 1x1
        // hidden layer is the one executable shape where per-example
        // wins.
        let meta = meta_of(
            vec![
                LayerSpec::dense_relu(3, 1),
                LayerSpec::dense_relu(1, 1), // 2*1 > 1: per-example
                LayerSpec::dense(1, 2),      // 2 <= 2: ghost
            ],
            1,
            3,
            2,
        );
        let plan = LayerPlan::build(&meta).unwrap();
        let choices = executed_choices("mix", &plan).unwrap();
        assert_eq!(
            choices,
            vec![LayerChoice::Ghost, LayerChoice::PerExample, LayerChoice::Ghost]
        );
        // And each choice equals the analytic registry's call.
        for (c, l) in choices.iter().zip(&plan.layers) {
            assert_eq!(*c, mix_ghost_choice(&l.spec.linear_dims()));
        }
    }
}
