//! The execution-backend seam (the "multi-backend" refactor).
//!
//! A [`Backend`] owns compilation/caching of a model's executables and
//! the three calls of the flat-parameter ABI (DESIGN.md §3):
//!
//! ```text
//! accum(params[P], acc[P], x[B,H,W,C], y[B], mask[B])
//!       -> (acc'[P], loss_sum, sq_norms[B])
//! apply(params[P], acc[P], seed, denom, lr, noise_mult) -> params'[P]
//! eval (params[P], x[B,H,W,C], y[B]) -> (loss_sum, ncorrect)
//! ```
//!
//! The accum/apply calls exist in two forms:
//!
//! * **copying** (`run_accum`, `run_apply`) — the caller keeps its
//!   buffers; the backend returns fresh ones.
//! * **donating** (`run_accum_into`, `run_apply_into`) — the caller
//!   *donates* the round-tripping buffer (the gradient accumulator for
//!   accum, the parameters for apply) and the backend updates it in
//!   place. This is the Rust analogue of JAX's `donate_argnums` / XLA
//!   input-output aliasing: the hot loop never pays a P-length copy per
//!   call. Both forms must produce bitwise-identical results — the
//!   proptests in `rust/tests/proptest_invariants.rs` enforce it.
//!
//! The copying forms are required (so a backend can never accidentally
//! ship neither); the donating forms default to "run the copying form,
//! move the result into the donated buffer" — already zero-copy for a
//! backend that returns a fresh `Tensor` per call (a move, not a
//! memcpy). Backends with a genuinely in-place kernel (the reference
//! backend) override the donating forms and implement the copying forms
//! as clone + donate.
//!
//! Two implementations ship:
//!
//! * [`super::reference::ReferenceBackend`] — pure-Rust linear+softmax
//!   reference model (the Rust port of `python/compile/kernels/ref.py`);
//!   always available, default.
//! * `super::pjrt::PjrtBackend` (feature `pjrt`) — executes AOT-lowered
//!   HLO artifacts through the `xla` bindings.
//!
//! The trait is object-safe; the runtime facade holds `Rc<dyn Backend>`.

use super::compile_cache::CompileRecord;
use super::manifest::{ExecutableMeta, ModelMeta};
use super::tensor::{read_flat_f32, Tensor};
use anyhow::Result;
use std::path::Path;

/// Handle to a prepared (compiled-and-cached) executable.
#[derive(Debug, Clone)]
pub struct Prepared {
    /// Artifact file name — the backend's cache key.
    pub key: String,
    /// Wall-clock seconds this `prepare` spent compiling, or `None` on a
    /// cache hit. One lookup answers both "give me the executable" and
    /// "did this batch just pay a compile" (the Fig. A.2 attribution).
    pub compile_seconds: Option<f64>,
}

/// Decoded outputs of one accum call.
#[derive(Debug, Clone)]
pub struct AccumOut {
    /// New gradient accumulator; round-trips into the next accum call.
    pub acc: Tensor,
    /// Sum of masked per-example losses.
    pub loss_sum: f32,
    /// Per-example squared gradient norms (zeros for nonprivate).
    pub sq_norms: Vec<f32>,
}

/// Scalar outputs of one *donating* accum call — the accumulator itself
/// is updated in place in the donated buffer.
#[derive(Debug, Clone)]
pub struct AccumStats {
    /// Sum of masked per-example losses.
    pub loss_sum: f32,
    /// Per-example squared gradient norms (zeros for nonprivate).
    pub sq_norms: Vec<f32>,
}

/// An execution backend: compiles artifacts and runs the ABI calls.
pub trait Backend {
    /// Short backend name ("reference" | "pjrt").
    fn name(&self) -> &'static str;

    /// Compile (or fetch from cache) the executable for `exe`. The
    /// returned [`Prepared`] reports compile time iff this call compiled.
    fn prepare(&self, dir: &Path, meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared>;

    /// True if `key` (an artifact file name) is already compiled.
    fn is_compiled(&self, key: &str) -> bool;

    /// Every compilation this backend performed, with timings.
    fn compile_records(&self) -> Vec<CompileRecord>;

    /// Initial flat parameter vector for `meta`. The default reads the
    /// AOT-written little-endian f32 file; backends without artifact
    /// files (the reference backend) synthesize their own.
    fn init_params(&self, dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        read_flat_f32(&dir.join(&meta.init_params), meta.n_params)
    }

    /// One gradient-accumulation call (the Algorithm 1/2 inner loop),
    /// copying form: the input accumulator is untouched and a fresh one
    /// is returned. `x` is row-major `[B, H, W, C]`; `mask` the
    /// Algorithm-2 masks. An in-place backend implements this as
    /// clone + [`Self::run_accum_into`].
    #[allow(clippy::too_many_arguments)]
    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumOut>;

    /// Donating form of the accum call: `acc` is updated in place (the
    /// `donate_argnums` analogue, DESIGN.md §3). On error the donated
    /// buffer is left unmodified. Must be bitwise-identical to
    /// [`Self::run_accum`].
    ///
    /// Default: runs the copying form and *moves* the returned tensor
    /// into `acc` — zero-copy already for backends minting a fresh
    /// result; override only with a genuinely in-place kernel.
    #[allow(clippy::too_many_arguments)]
    fn run_accum_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &mut Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumStats> {
        let out = self.run_accum(prep, meta, params, acc, x, y, mask)?;
        *acc = out.acc;
        Ok(AccumStats { loss_sum: out.loss_sum, sq_norms: out.sq_norms })
    }

    /// The once-per-logical-batch noise + SGD step, copying form. `seed`
    /// is the full-width 64-bit per-step noise seed; `denom` the
    /// Algorithm-1 `|L|` divisor; `noise_mult` is `sigma * C` (0 for
    /// non-private). An in-place backend implements this as
    /// clone + [`Self::run_apply_into`].
    #[allow(clippy::too_many_arguments)]
    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<Tensor>;

    /// Donating form of the apply call: `params` is updated in place.
    /// On error the donated buffer is left unmodified. Must be
    /// bitwise-identical to [`Self::run_apply`].
    ///
    /// Default: runs the copying form and *moves* the returned tensor
    /// into `params`; override only with a genuinely in-place kernel.
    #[allow(clippy::too_many_arguments)]
    fn run_apply_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &mut Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<()> {
        *params = self.run_apply(prep, meta, params, acc, seed, denom, lr, noise_mult)?;
        Ok(())
    }

    /// Forward-only evaluation: `(loss_sum, ncorrect)` over the batch.
    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)>;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal copying-only backend: the donating forms must come from
    /// the trait defaults (this is the path a literal-marshalling
    /// backend like PJRT runs in production).
    struct CopyOnly;

    impl Backend for CopyOnly {
        fn name(&self) -> &'static str {
            "copy-only"
        }

        fn prepare(
            &self,
            _dir: &Path,
            _meta: &ModelMeta,
            exe: &ExecutableMeta,
        ) -> Result<Prepared> {
            Ok(Prepared { key: exe.path.clone(), compile_seconds: None })
        }

        fn is_compiled(&self, _key: &str) -> bool {
            true
        }

        fn compile_records(&self) -> Vec<CompileRecord> {
            Vec::new()
        }

        /// Toy kernel: acc' = acc + mask-weighted example count in slot
        /// 0, loss = batch size.
        fn run_accum(
            &self,
            _prep: &Prepared,
            _meta: &ModelMeta,
            _params: &Tensor,
            acc: &Tensor,
            _x: &[f32],
            y: &[i32],
            mask: &[f32],
        ) -> Result<AccumOut> {
            let mut out = acc.to_vec();
            out[0] += mask.iter().sum::<f32>();
            Ok(AccumOut {
                acc: Tensor::from_vec(out),
                loss_sum: y.len() as f32,
                sq_norms: vec![0.5; y.len()],
            })
        }

        /// Toy step: params' = params - lr * acc / denom.
        fn run_apply(
            &self,
            _prep: &Prepared,
            _meta: &ModelMeta,
            params: &Tensor,
            acc: &Tensor,
            _seed: u64,
            denom: f32,
            lr: f32,
            _noise_mult: f32,
        ) -> Result<Tensor> {
            let out: Vec<f32> = params
                .as_slice()
                .iter()
                .zip(acc.as_slice())
                .map(|(p, a)| p - lr * a / denom)
                .collect();
            Ok(Tensor::from_vec(out))
        }

        fn run_eval(
            &self,
            _prep: &Prepared,
            _meta: &ModelMeta,
            _params: &Tensor,
            _x: &[f32],
            y: &[i32],
        ) -> Result<(f32, f32)> {
            Ok((y.len() as f32, 0.0))
        }
    }

    fn toy_meta() -> ModelMeta {
        ModelMeta {
            family: "toy".into(),
            n_params: 3,
            image: 1,
            channels: 1,
            num_classes: 2,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "toy.bin".into(),
            executables: Vec::new(),
        }
    }

    #[test]
    fn default_donating_forms_match_copying_forms() {
        let b = CopyOnly;
        let meta = toy_meta();
        let prep = Prepared { key: "toy".into(), compile_seconds: None };
        let params = Tensor::vec1(&[1.0, 2.0, 3.0]);
        let acc = Tensor::vec1(&[4.0, 0.0, -1.0]);
        let (x, y, mask) = (vec![0.0f32; 2], vec![0, 1], vec![1.0f32, 0.0]);

        let copied = b.run_accum(&prep, &meta, &params, &acc, &x, &y, &mask).unwrap();
        let mut donated = acc.clone();
        let stats = b
            .run_accum_into(&prep, &meta, &params, &mut donated, &x, &y, &mask)
            .unwrap();
        assert_eq!(copied.acc, donated, "default donating accum must equal copying");
        assert_eq!(copied.loss_sum, stats.loss_sum);
        assert_eq!(copied.sq_norms, stats.sq_norms);
        // The donated buffer was genuinely updated in place.
        assert_eq!(donated.as_slice()[0], 5.0);

        let applied = b
            .run_apply(&prep, &meta, &params, &acc, 7, 2.0, 0.5, 0.0)
            .unwrap();
        let mut donated_p = params.clone();
        b.run_apply_into(&prep, &meta, &mut donated_p, &acc, 7, 2.0, 0.5, 0.0)
            .unwrap();
        assert_eq!(applied, donated_p, "default donating apply must equal copying");
        assert_eq!(donated_p.as_slice()[0], 1.0 - 0.5 * 4.0 / 2.0);
    }
}
