//! The execution-backend seam (the "multi-backend" refactor).
//!
//! A [`Backend`] owns compilation/caching of a model's executables and
//! the three calls of the flat-parameter ABI (DESIGN.md §3):
//!
//! ```text
//! accum(params[P], acc[P], x[B,H,W,C], y[B], mask[B])
//!       -> (acc'[P], loss_sum, sq_norms[B])
//! apply(params[P], acc[P], seed, denom, lr, noise_mult) -> params'[P]
//! eval (params[P], x[B,H,W,C], y[B]) -> (loss_sum, ncorrect)
//! ```
//!
//! Two implementations ship:
//!
//! * [`super::reference::ReferenceBackend`] — pure-Rust linear+softmax
//!   reference model (the Rust port of `python/compile/kernels/ref.py`);
//!   always available, default.
//! * `super::pjrt::PjrtBackend` (feature `pjrt`) — executes AOT-lowered
//!   HLO artifacts through the `xla` bindings.
//!
//! The trait is object-safe; the runtime facade holds `Rc<dyn Backend>`.

use super::compile_cache::CompileRecord;
use super::manifest::{ExecutableMeta, ModelMeta};
use super::tensor::{read_flat_f32, Tensor};
use anyhow::Result;
use std::path::Path;

/// Handle to a prepared (compiled-and-cached) executable.
#[derive(Debug, Clone)]
pub struct Prepared {
    /// Artifact file name — the backend's cache key.
    pub key: String,
    /// Wall-clock seconds this `prepare` spent compiling, or `None` on a
    /// cache hit. One lookup answers both "give me the executable" and
    /// "did this batch just pay a compile" (the Fig. A.2 attribution).
    pub compile_seconds: Option<f64>,
}

/// Decoded outputs of one accum call.
#[derive(Debug, Clone)]
pub struct AccumOut {
    /// New gradient accumulator; round-trips into the next accum call.
    pub acc: Tensor,
    /// Sum of masked per-example losses.
    pub loss_sum: f32,
    /// Per-example squared gradient norms (zeros for nonprivate).
    pub sq_norms: Vec<f32>,
}

/// An execution backend: compiles artifacts and runs the ABI calls.
pub trait Backend {
    /// Short backend name ("reference" | "pjrt").
    fn name(&self) -> &'static str;

    /// Compile (or fetch from cache) the executable for `exe`. The
    /// returned [`Prepared`] reports compile time iff this call compiled.
    fn prepare(&self, dir: &Path, meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared>;

    /// True if `key` (an artifact file name) is already compiled.
    fn is_compiled(&self, key: &str) -> bool;

    /// Every compilation this backend performed, with timings.
    fn compile_records(&self) -> Vec<CompileRecord>;

    /// Initial flat parameter vector for `meta`. The default reads the
    /// AOT-written little-endian f32 file; backends without artifact
    /// files (the reference backend) synthesize their own.
    fn init_params(&self, dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        read_flat_f32(&dir.join(&meta.init_params), meta.n_params)
    }

    /// One gradient-accumulation call (the Algorithm 1/2 inner loop).
    /// `x` is row-major `[B, H, W, C]`; `mask` the Algorithm-2 masks.
    #[allow(clippy::too_many_arguments)]
    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumOut>;

    /// The once-per-logical-batch noise + SGD step. `seed` is the
    /// full-width 64-bit per-step noise seed; `denom` the Algorithm-1
    /// `|L|` divisor; `noise_mult` is `sigma * C` (0 for non-private).
    #[allow(clippy::too_many_arguments)]
    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<Tensor>;

    /// Forward-only evaluation: `(loss_sum, ncorrect)` over the batch.
    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)>;
}
