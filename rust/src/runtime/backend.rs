//! The execution-backend seam: typed call structs, stateful sessions
//! with bound buffers, and the legacy free-function entry points.
//!
//! A [`Backend`] owns compilation/caching of a model's executables and
//! the three calls of the flat-parameter ABI (DESIGN.md §3):
//!
//! ```text
//! accum(params[P], acc[P], AccumArgs { x[B,H,W,C], y[B], mask[B] })
//!       -> (acc'[P], loss_sum, sq_norms[B])
//! apply(params[P], acc[P], ApplyArgs { seed, denom, lr, noise_mult })
//!       -> params'[P]
//! eval (params[P], x[B,H,W,C], y[B]) -> (loss_sum, ncorrect)
//! ```
//!
//! ## Sessions (the primary API)
//!
//! [`Backend::open_session`] binds the round-tripping state — the flat
//! parameter vector and the gradient accumulator — to an
//! [`ExecSession`] that *owns* those buffers for the life of a run.
//! This is the Rust analogue of how the paper's JAX implementation gets
//! its speed: compiled executables keep params and the accumulator
//! device-resident across calls (`donate_argnums` / XLA input-output
//! aliasing), so the hot loop never marshals a P-length vector. A
//! caller drives the session (`accum`, `apply`, `zero_acc`, `eval`)
//! and only crosses the host boundary at the checkpoint seam
//! (`read_params` / `write_params`).
//!
//! The default `open_session` returns a host-buffered session over the
//! backend's donating entry points — exactly right for the reference
//! backend (whose donating kernels are genuinely in-place) and the
//! correct host-side shape for PJRT until real bindings keep the
//! buffers on device (then `PjrtBackend` overrides `open_session` and
//! the same trainer code becomes zero-marshalling).
//!
//! ## Legacy entry points (migration shims)
//!
//! The free-function forms predate sessions and remain so every
//! existing caller and proptest keeps passing during the migration:
//!
//! * **copying** (`run_accum`, `run_apply`) — the caller keeps its
//!   buffers; the backend returns fresh ones. Required methods.
//! * **donating** (`run_accum_into`, `run_apply_into`) — the caller
//!   *donates* the round-tripping buffer and the backend updates it in
//!   place. Defaults run the copying form and *move* the result into
//!   the donated buffer.
//!
//! Sessions and the legacy forms execute the same kernels on the same
//! buffers, so all three (session, donating, copying) are
//! **bitwise-identical** — the proptests in
//! `rust/tests/proptest_invariants.rs` and
//! `rust/tests/session_api.rs` enforce it.
//!
//! The trait is object-safe; the runtime facade holds
//! `Arc<dyn Backend + Send + Sync>` and the data-parallel executor
//! ([`crate::cluster::parallel`]) drives one session per worker thread
//! — `ExecSession: Send` plus the `read_acc`/`write_acc` all-reduce
//! seam are what make that possible.

#![warn(missing_docs)]

use super::compile_cache::CompileRecord;
use super::manifest::{ExecutableMeta, ModelMeta};
use super::tensor::{read_flat_f32, Tensor};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Handle to a prepared (compiled-and-cached) executable.
#[derive(Debug, Clone)]
pub struct Prepared {
    /// Artifact file name — the backend's cache key.
    pub key: String,
    /// Wall-clock seconds this `prepare` spent compiling, or `None` on a
    /// cache hit. One lookup answers both "give me the executable" and
    /// "did this batch just pay a compile" (the Fig. A.2 attribution).
    pub compile_seconds: Option<f64>,
}

/// Batch operands of one accum call (the Algorithm 1/2 inner loop).
///
/// Borrowed views, grouped so every accum entry point — session or
/// legacy — takes one struct instead of three trailing slices.
#[derive(Debug, Clone, Copy)]
pub struct AccumArgs<'a> {
    /// Row-major `[B, H, W, C]` input images.
    pub x: &'a [f32],
    /// `[B]` class labels.
    pub y: &'a [i32],
    /// `[B]` Algorithm-2 masks (0 for padding slots).
    pub mask: &'a [f32],
}

impl AccumArgs<'_> {
    /// Batch size `B` (one label per example).
    pub fn batch(&self) -> usize {
        self.y.len()
    }
}

/// Scalar operands of the once-per-logical-batch noise + SGD step.
#[derive(Debug, Clone, Copy)]
pub struct ApplyArgs {
    /// Full-width 64-bit per-step noise seed.
    pub seed: u64,
    /// The Algorithm-1 `|L|` divisor (expected logical batch).
    pub denom: f32,
    /// Learning rate.
    pub lr: f32,
    /// `sigma * C` (0 for the non-private baseline).
    pub noise_mult: f32,
}

/// Decoded outputs of one copying accum call.
#[derive(Debug, Clone)]
pub struct AccumOut {
    /// New gradient accumulator; round-trips into the next accum call.
    pub acc: Tensor,
    /// Sum of masked per-example losses.
    pub loss_sum: f32,
    /// Per-example squared gradient norms (zeros for nonprivate).
    pub sq_norms: Vec<f32>,
}

/// Scalar outputs of one bound-buffer accum call — the accumulator
/// itself stays resident in the session (or the donated buffer).
#[derive(Debug, Clone)]
pub struct AccumStats {
    /// Sum of masked per-example losses.
    pub loss_sum: f32,
    /// Per-example squared gradient norms (zeros for nonprivate).
    pub sq_norms: Vec<f32>,
}

/// A stateful execution session: the bound-buffer view of one model.
///
/// The session owns the flat parameter vector and the gradient
/// accumulator for the life of a run (for a device backend: persistent
/// device buffers; for the host backends: two `Tensor`s updated in
/// place). All calls take a [`Prepared`] handle so compile attribution
/// stays a caller concern, exactly as with the legacy entry points.
///
/// Determinism contract: a session driven through any interleaving of
/// `accum`/`apply`/`zero_acc` is bitwise-identical to the same call
/// sequence through the legacy entry points with host-held buffers.
///
/// `Send` is a supertrait: a session is exactly the thing a worker
/// thread owns, so the `Arc<dyn Backend>` sharing story (see
/// [`Backend`]) would be moot if sessions could not cross threads.
pub trait ExecSession: Send {
    /// One gradient-accumulation call; the bound accumulator is updated
    /// in place. On error the bound buffers are left unmodified.
    fn accum(&mut self, prep: &Prepared, args: &AccumArgs<'_>) -> Result<AccumStats>;

    /// The noise + SGD step; the bound parameters are updated in place
    /// from the bound accumulator. On error the bound buffers are left
    /// unmodified.
    fn apply(&mut self, prep: &Prepared, args: &ApplyArgs) -> Result<()>;

    /// Re-zero the bound accumulator (the per-optimizer-step reset —
    /// `Tensor::fill` on the host; a device kernel launch on a
    /// device-resident backend, hence fallible).
    fn zero_acc(&mut self) -> Result<()>;

    /// Forward-only evaluation against the bound parameters:
    /// `(loss_sum, ncorrect)` over the batch.
    fn eval(&self, prep: &Prepared, x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// Copy the bound parameters out — the checkpoint seam (a
    /// device-to-host transfer for a device-resident backend).
    fn read_params(&self) -> Result<Tensor>;

    /// Replace the bound parameters — the resume seam (a host-to-device
    /// transfer for a device-resident backend). Fails if the length
    /// does not match the model.
    fn write_params(&mut self, params: Tensor) -> Result<()>;

    /// Copy the bound gradient accumulator out — the **all-reduce
    /// seam** (DESIGN.md §8): the data-parallel driver reads each
    /// rank's partial accumulator here before the deterministic tree
    /// reduction. A device-resident backend implements this as a
    /// device-to-host transfer (or, with real collectives, replaces
    /// the read/reduce/write round-trip with an in-fabric all-reduce
    /// that honors the same fixed pairing order).
    fn read_acc(&self) -> Result<Tensor>;

    /// Replace the bound gradient accumulator — the reduced sum is
    /// installed here on rank 0 before its `apply` call. Fails if the
    /// length does not match the model.
    fn write_acc(&mut self, acc: Tensor) -> Result<()>;
}

/// Host-buffered [`ExecSession`] over a backend's donating entry
/// points: the trait default. For backends with genuinely in-place
/// kernels (the reference backend) this *is* the bound-buffer hot path;
/// for literal-marshalling backends (offline PJRT) it is the correct
/// host-side shape until real bindings pin the buffers on device.
struct HostSession<'a, B: ?Sized> {
    backend: &'a B,
    meta: ModelMeta,
    params: Tensor,
    acc: Tensor,
}

impl<B: Backend + ?Sized> ExecSession for HostSession<'_, B> {
    fn accum(&mut self, prep: &Prepared, args: &AccumArgs<'_>) -> Result<AccumStats> {
        self.backend
            .run_accum_into(prep, &self.meta, &self.params, &mut self.acc, args)
    }

    fn apply(&mut self, prep: &Prepared, args: &ApplyArgs) -> Result<()> {
        self.backend
            .run_apply_into(prep, &self.meta, &mut self.params, &self.acc, args)
    }

    fn zero_acc(&mut self) -> Result<()> {
        self.acc.fill(0.0);
        Ok(())
    }

    fn eval(&self, prep: &Prepared, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        self.backend.run_eval(prep, &self.meta, &self.params, x, y)
    }

    fn read_params(&self) -> Result<Tensor> {
        Ok(self.params.clone())
    }

    fn write_params(&mut self, params: Tensor) -> Result<()> {
        if params.len() != self.meta.n_params {
            return Err(anyhow!(
                "write_params length {} != n_params {}",
                params.len(),
                self.meta.n_params
            ));
        }
        self.params = params;
        Ok(())
    }

    fn read_acc(&self) -> Result<Tensor> {
        Ok(self.acc.clone())
    }

    fn write_acc(&mut self, acc: Tensor) -> Result<()> {
        if acc.len() != self.meta.n_params {
            return Err(anyhow!(
                "write_acc length {} != n_params {}",
                acc.len(),
                self.meta.n_params
            ));
        }
        self.acc = acc;
        Ok(())
    }
}

/// An execution backend: compiles artifacts and runs the ABI calls.
///
/// `Send + Sync` are supertraits: backends are shared as
/// `Arc<dyn Backend + Send + Sync>` across (future) worker threads,
/// and the supertrait is what lets the default [`Backend::open_session`]
/// hand out `Send` sessions that borrow the backend.
pub trait Backend: Send + Sync {
    /// Short backend name ("reference" | "pjrt").
    fn name(&self) -> &'static str;

    /// Compile (or fetch from cache) the executable for `exe`. The
    /// returned [`Prepared`] reports compile time iff this call compiled.
    fn prepare(&self, dir: &Path, meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared>;

    /// True if `key` (an artifact file name) is already compiled.
    fn is_compiled(&self, key: &str) -> bool;

    /// Every compilation this backend performed, with timings.
    fn compile_records(&self) -> Vec<CompileRecord>;

    /// Initial flat parameter vector for `meta`. The default reads the
    /// AOT-written little-endian f32 file; backends without artifact
    /// files (the reference backend) synthesize their own.
    fn init_params(&self, dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        read_flat_f32(&dir.join(&meta.init_params), meta.n_params)
    }

    /// Open a stateful session that *owns* `params` (donated here) and
    /// a zeroed gradient accumulator for the life of a run. The default
    /// is the host-buffered session over the donating entry points; a
    /// device-resident backend overrides this to upload the buffers
    /// once and keep them on device across calls.
    fn open_session(
        &self,
        dir: &Path,
        meta: &ModelMeta,
        params: Tensor,
    ) -> Result<Box<dyn ExecSession + '_>> {
        let _ = dir; // host sessions need no artifact directory
        if params.len() != meta.n_params {
            return Err(anyhow!(
                "session params length {} != n_params {}",
                params.len(),
                meta.n_params
            ));
        }
        let acc = Tensor::zeros(meta.n_params);
        Ok(Box::new(HostSession { backend: self, meta: meta.clone(), params, acc }))
    }

    /// One gradient-accumulation call, copying form: the input
    /// accumulator is untouched and a fresh one is returned. An
    /// in-place backend implements this as clone +
    /// [`Self::run_accum_into`]. Legacy migration shim — new code
    /// drives [`Self::open_session`] instead.
    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumOut>;

    /// Donating form of the accum call: `acc` is updated in place (the
    /// `donate_argnums` analogue, DESIGN.md §3). On error the donated
    /// buffer is left unmodified. Must be bitwise-identical to
    /// [`Self::run_accum`].
    ///
    /// Default: runs the copying form and *moves* the returned tensor
    /// into `acc` — zero-copy already for backends minting a fresh
    /// result; override only with a genuinely in-place kernel.
    fn run_accum_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &mut Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumStats> {
        let out = self.run_accum(prep, meta, params, acc, args)?;
        *acc = out.acc;
        Ok(AccumStats { loss_sum: out.loss_sum, sq_norms: out.sq_norms })
    }

    /// The once-per-logical-batch noise + SGD step, copying form. An
    /// in-place backend implements this as clone +
    /// [`Self::run_apply_into`]. Legacy migration shim — new code
    /// drives [`Self::open_session`] instead.
    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<Tensor>;

    /// Donating form of the apply call: `params` is updated in place.
    /// On error the donated buffer is left unmodified. Must be
    /// bitwise-identical to [`Self::run_apply`].
    ///
    /// Default: runs the copying form and *moves* the returned tensor
    /// into `params`; override only with a genuinely in-place kernel.
    fn run_apply_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &mut Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<()> {
        *params = self.run_apply(prep, meta, params, acc, args)?;
        Ok(())
    }

    /// Forward-only evaluation: `(loss_sum, ncorrect)` over the batch.
    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)>;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal copying-only backend: the donating forms and the session
    /// must come from the trait defaults (this is the path a
    /// literal-marshalling backend like PJRT runs in production).
    struct CopyOnly;

    impl Backend for CopyOnly {
        fn name(&self) -> &'static str {
            "copy-only"
        }

        fn prepare(
            &self,
            _dir: &Path,
            _meta: &ModelMeta,
            exe: &ExecutableMeta,
        ) -> Result<Prepared> {
            Ok(Prepared { key: exe.path.clone(), compile_seconds: None })
        }

        fn is_compiled(&self, _key: &str) -> bool {
            true
        }

        fn compile_records(&self) -> Vec<CompileRecord> {
            Vec::new()
        }

        /// Toy kernel: acc' = acc + mask-weighted example count in slot
        /// 0, loss = batch size.
        fn run_accum(
            &self,
            _prep: &Prepared,
            _meta: &ModelMeta,
            _params: &Tensor,
            acc: &Tensor,
            args: &AccumArgs<'_>,
        ) -> Result<AccumOut> {
            let mut out = acc.to_vec();
            out[0] += args.mask.iter().sum::<f32>();
            Ok(AccumOut {
                acc: Tensor::from_vec(out),
                loss_sum: args.batch() as f32,
                sq_norms: vec![0.5; args.batch()],
            })
        }

        /// Toy step: params' = params - lr * acc / denom.
        fn run_apply(
            &self,
            _prep: &Prepared,
            _meta: &ModelMeta,
            params: &Tensor,
            acc: &Tensor,
            args: &ApplyArgs,
        ) -> Result<Tensor> {
            let out: Vec<f32> = params
                .as_slice()
                .iter()
                .zip(acc.as_slice())
                .map(|(p, a)| p - args.lr * a / args.denom)
                .collect();
            Ok(Tensor::from_vec(out))
        }

        fn run_eval(
            &self,
            _prep: &Prepared,
            _meta: &ModelMeta,
            params: &Tensor,
            _x: &[f32],
            y: &[i32],
        ) -> Result<(f32, f32)> {
            Ok((y.len() as f32 + params.as_slice()[0], 0.0))
        }
    }

    fn toy_meta() -> ModelMeta {
        ModelMeta {
            family: "toy".into(),
            n_params: 3,
            image: 1,
            channels: 1,
            num_classes: 2,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "toy.bin".into(),
            executables: Vec::new(),
            layers: Vec::new(),
        }
    }

    fn toy_prep() -> Prepared {
        Prepared { key: "toy".into(), compile_seconds: None }
    }

    #[test]
    fn default_donating_forms_match_copying_forms() {
        let b = CopyOnly;
        let meta = toy_meta();
        let prep = toy_prep();
        let params = Tensor::vec1(&[1.0, 2.0, 3.0]);
        let acc = Tensor::vec1(&[4.0, 0.0, -1.0]);
        let (x, y, mask) = (vec![0.0f32; 2], vec![0, 1], vec![1.0f32, 0.0]);
        let args = AccumArgs { x: &x, y: &y, mask: &mask };

        let copied = b.run_accum(&prep, &meta, &params, &acc, &args).unwrap();
        let mut donated = acc.clone();
        let stats = b
            .run_accum_into(&prep, &meta, &params, &mut donated, &args)
            .unwrap();
        assert_eq!(copied.acc, donated, "default donating accum must equal copying");
        assert_eq!(copied.loss_sum, stats.loss_sum);
        assert_eq!(copied.sq_norms, stats.sq_norms);
        // The donated buffer was genuinely updated in place.
        assert_eq!(donated.as_slice()[0], 5.0);

        let apply = ApplyArgs { seed: 7, denom: 2.0, lr: 0.5, noise_mult: 0.0 };
        let applied = b.run_apply(&prep, &meta, &params, &acc, &apply).unwrap();
        let mut donated_p = params.clone();
        b.run_apply_into(&prep, &meta, &mut donated_p, &acc, &apply).unwrap();
        assert_eq!(applied, donated_p, "default donating apply must equal copying");
        assert_eq!(donated_p.as_slice()[0], 1.0 - 0.5 * 4.0 / 2.0);
    }

    #[test]
    fn default_session_matches_legacy_call_sequence() {
        let b = CopyOnly;
        let meta = toy_meta();
        let prep = toy_prep();
        let params = Tensor::vec1(&[1.0, 2.0, 3.0]);
        let (x, y) = (vec![0.0f32; 2], vec![0, 1]);
        let masks = [vec![1.0f32, 1.0], vec![1.0f32, 0.0]];

        let mut sess = b.open_session(Path::new("."), &meta, params.clone()).unwrap();

        // Legacy side: host-held buffers through the copying forms.
        let mut acc_legacy = Tensor::zeros(meta.n_params);
        for mask in &masks {
            let args = AccumArgs { x: &x, y: &y, mask };
            let stats = sess.accum(&prep, &args).unwrap();
            let out = b.run_accum(&prep, &meta, &params, &acc_legacy, &args).unwrap();
            acc_legacy = out.acc;
            assert_eq!(stats.loss_sum, out.loss_sum);
            assert_eq!(stats.sq_norms, out.sq_norms);
        }

        let apply = ApplyArgs { seed: 3, denom: 2.0, lr: 0.25, noise_mult: 0.0 };
        sess.apply(&prep, &apply).unwrap();
        let p_legacy = b.run_apply(&prep, &meta, &params, &acc_legacy, &apply).unwrap();
        assert_eq!(sess.read_params().unwrap(), p_legacy);

        // eval sees the session's updated parameters.
        let (loss, _) = sess.eval(&prep, &x, &y).unwrap();
        assert_eq!(loss, y.len() as f32 + p_legacy.as_slice()[0]);

        // zero_acc resets the bound accumulator: the next apply from a
        // zeroed accumulator is a no-op at lr-weight zero gradient.
        sess.zero_acc().unwrap();
        let before = sess.read_params().unwrap();
        sess.apply(&prep, &apply).unwrap();
        assert_eq!(sess.read_params().unwrap(), before);
    }

    #[test]
    fn session_acc_seam_reads_and_writes_the_bound_accumulator() {
        // The all-reduce seam: read_acc exposes the bound accumulator,
        // write_acc installs a (reduced) replacement that the next
        // apply consumes.
        let b = CopyOnly;
        let meta = toy_meta();
        let prep = toy_prep();
        let mut sess = b
            .open_session(Path::new("."), &meta, Tensor::vec1(&[1.0, 2.0, 3.0]))
            .unwrap();
        assert_eq!(sess.read_acc().unwrap(), Tensor::zeros(3), "fresh session acc is zero");

        let (x, y, mask) = (vec![0.0f32; 2], vec![0, 1], vec![1.0f32, 1.0]);
        sess.accum(&prep, &AccumArgs { x: &x, y: &y, mask: &mask }).unwrap();
        assert_eq!(sess.read_acc().unwrap().as_slice()[0], 2.0);

        // Install a "reduced" accumulator and apply: the step must use it.
        sess.write_acc(Tensor::vec1(&[4.0, 0.0, 0.0])).unwrap();
        let apply = ApplyArgs { seed: 1, denom: 2.0, lr: 0.5, noise_mult: 0.0 };
        sess.apply(&prep, &apply).unwrap();
        assert_eq!(sess.read_params().unwrap().as_slice()[0], 1.0 - 0.5 * 4.0 / 2.0);

        // Length mismatch is rejected without touching the binding.
        assert!(sess.write_acc(Tensor::zeros(1)).is_err());
        assert_eq!(sess.read_acc().unwrap(), Tensor::vec1(&[4.0, 0.0, 0.0]));
    }

    #[test]
    fn session_write_params_validates_length() {
        let b = CopyOnly;
        let meta = toy_meta();
        let mut sess = b
            .open_session(Path::new("."), &meta, Tensor::zeros(meta.n_params))
            .unwrap();
        assert!(sess.write_params(Tensor::zeros(2)).is_err());
        sess.write_params(Tensor::vec1(&[9.0, 8.0, 7.0])).unwrap();
        assert_eq!(sess.read_params().unwrap().to_vec(), vec![9.0, 8.0, 7.0]);
    }

    #[test]
    fn open_session_validates_params_length() {
        let b = CopyOnly;
        let meta = toy_meta();
        assert!(b.open_session(Path::new("."), &meta, Tensor::zeros(1)).is_err());
    }
}
