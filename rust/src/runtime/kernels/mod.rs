//! SIMD + cache-blocked inner kernels under the bitwise determinism
//! contract (DESIGN.md §14).
//!
//! Every hot inner loop of the reference backend — `dot`, `axpy`, the
//! dense/attention matvecs, and the ghost Gram products — dispatches
//! through this module. The contract is absolute: **a kernel switch
//! never moves a single bit.** The scalar path *is* the specification
//! (the seed's 8-lane fixed-tree reduction), and the vector paths
//! reproduce it by construction:
//!
//! * **Lane-to-vector mapping.** The scalar `dot` keeps 8 independent
//!   partial sums (`lanes[j] += a[8i+j] * b[8i+j]`) and folds them
//!   through one fixed tree. An AVX2 256-bit register holds exactly
//!   those 8 lanes, so `acc = add(acc, mul(a, b))` per 8-element chunk
//!   performs the identical per-lane operation sequence — one rounding
//!   for the multiply, one for the add, never an FMA (a fused
//!   multiply-add skips the intermediate rounding and would change
//!   bits). NEON maps the same 8 lanes onto two 128-bit registers
//!   (lanes 0-3 and 4-7). Both extract the lanes and fold them through
//!   the *same* tree as the scalar path, then add the same
//!   sequentially-summed remainder tail ([`dot` handles `len % 8`
//!   through one shared helper, `dot_tail`]).
//! * **Cache blocking.** The blocked matvec ([`matvec`]) computes four
//!   output rows per pass so the shared input vector is streamed once
//!   per block instead of once per row; each row still owns its private
//!   8-lane accumulator and tree, so its bits are untouched. The
//!   blocked transpose-matvec ([`matvec_t`]) folds four `axpy` rows per
//!   pass; per destination element the operation chain
//!   `((d + g0*w0) + g1*w1) + ...` is exactly the chain four sequential
//!   `axpy` calls perform, just without re-loading the destination.
//!   Blocking stays strictly *within* one accumulation unit (one layer
//!   row, one example), so the per-(layer, row)-in-example-order
//!   addition chains of the two-phase accumulator — and with them
//!   thread/chunk/worker invariance — are untouched.
//! * **Runtime detection.** [`Kernel::auto`] picks the best verified
//!   instruction set at backend construction (AVX2 on x86-64, NEON on
//!   aarch64, scalar elsewhere); `--kernel scalar` and the
//!   `DPSHORT_FORCE_SCALAR` environment knob force the fallback (the
//!   cross-ISA CI job runs the whole bitwise-equality suite that way).
//!   The audit rule `kernel.unverified-isa` warns when a run would
//!   select an instruction set outside [`VERIFIED_ISAS`] — the set the
//!   scalar-vs-SIMD proptest matrix actually covers.
//!
//! This module is the **one sanctioned home for intrinsics and
//! bounds-unchecked code** in the crate: `dpshort lint --source`
//! denies the patterns everywhere else (`lint.unsafe-code`).

/// Instruction sets covered by the bitwise-equality test matrix
/// (`rust/tests/kernel_bitwise.rs` + the unit tests below). A run that
/// selects anything else trips the `kernel.unverified-isa` audit rule.
pub const VERIFIED_ISAS: &[&str] = &["scalar", "avx2", "neon"];

/// Resolved kernel selection for one backend instance. Constructed by
/// [`Kernel::auto`] / [`Kernel::parse`] only, so a SIMD variant exists
/// only when its instruction set was actually detected at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// The portable 8-lane fixed-tree scalar path (the specification).
    Scalar,
    /// AVX2: all 8 lanes in one 256-bit register, mul-then-add.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON: lanes 0-3 / 4-7 in two 128-bit registers, mul-then-add.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// `DPSHORT_FORCE_SCALAR` (any value but `0`) pins auto-detection to
/// the scalar fallback — the cross-ISA CI job uses it to run the
/// bitwise-equality suite with SIMD disabled.
fn force_scalar() -> bool {
    std::env::var_os("DPSHORT_FORCE_SCALAR").is_some_and(|v| v != "0")
}

#[cfg(target_arch = "x86_64")]
fn simd_kernel() -> Kernel {
    if is_x86_feature_detected!("avx2") {
        Kernel::Avx2
    } else {
        Kernel::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn simd_kernel() -> Kernel {
    // NEON is baseline on aarch64; no runtime probe needed.
    Kernel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn simd_kernel() -> Kernel {
    Kernel::Scalar
}

impl Kernel {
    /// Best verified kernel for this machine (scalar when nothing
    /// better is available or `DPSHORT_FORCE_SCALAR` is set).
    pub fn auto() -> Kernel {
        if force_scalar() {
            Kernel::Scalar
        } else {
            simd_kernel()
        }
    }

    /// Parse a `--kernel` value: `scalar` forces the fallback, `simd`
    /// requests the detected vector path (falling back to scalar when
    /// the machine has none), `auto` is the default policy.
    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "scalar" => Some(Kernel::Scalar),
            "simd" | "auto" => Some(Kernel::auto()),
            _ => None,
        }
    }

    /// The bench-axis label: `"scalar"` or `"simd"`.
    pub fn axis(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "simd",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "simd",
        }
    }

    /// The concrete instruction-set name (`"scalar"`, `"avx2"`,
    /// `"neon"`) — what the audit rule checks against
    /// [`VERIFIED_ISAS`].
    pub fn isa(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }
}

/// The instruction set [`Kernel::auto`] resolves to on this machine —
/// what `RunPlan::lower` records for the `kernel.unverified-isa` rule.
pub fn detected_isa(forced_scalar: bool) -> &'static str {
    if forced_scalar {
        Kernel::Scalar.isa()
    } else {
        Kernel::auto().isa()
    }
}

/// Shared remainder handling for every `dot` path: the trailing
/// `len % 8` products summed sequentially, in order — scalar, AVX2 and
/// NEON all call this exact helper so the tail bits cannot diverge.
#[inline]
fn dot_tail(at: &[f32], bt: &[f32]) -> f32 {
    let mut tail = 0.0f32;
    for (av, bv) in at.iter().zip(bt) {
        tail += av * bv;
    }
    tail
}

/// The fixed reduction tree over the 8 lanes plus the sequential tail —
/// the other half of the shared-semantics contract ([`dot_tail`]).
#[inline]
fn lane_tree(l: &[f32; 8], tail: f32) -> f32 {
    (((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))) + tail
}

/// The specification `dot`: 8 independent lanes over `chunks_exact(8)`,
/// the fixed tree, the sequential tail. Byte-for-byte the arithmetic of
/// the pre-SIMD reference kernel.
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n8 = a.len() - a.len() % 8;
    let (a8, at) = a.split_at(n8);
    let (b8, bt) = b.split_at(n8);
    let mut lanes = [0.0f32; 8];
    for (ac, bc) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for j in 0..8 {
            lanes[j] += ac[j] * bc[j];
        }
    }
    lane_tree(&lanes, dot_tail(at, bt))
}

/// The specification `axpy`: `row += g * xi`, elementwise (one multiply
/// rounding + one add rounding per element, no cross-element order).
#[inline]
fn axpy_scalar(row: &mut [f32], xi: &[f32], g: f32) {
    for (a, &xv) in row.iter_mut().zip(xi) {
        *a += g * xv;
    }
}

/// Fixed-tree dot product, dispatched on the selected kernel. All paths
/// are bitwise-equal by construction (module docs).
#[inline]
pub fn dot(k: Kernel, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match k {
        Kernel::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 variant is only constructed after runtime
        // detection confirmed AVX2 support.
        Kernel::Avx2 => unsafe { x86::dot_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Kernel::Neon => unsafe { arm::dot_neon(a, b) },
    }
}

/// `row += g * xi`, dispatched on the selected kernel.
#[inline]
pub fn axpy(k: Kernel, row: &mut [f32], xi: &[f32], g: f32) {
    match k {
        Kernel::Scalar => axpy_scalar(row, xi, g),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `dot`.
        Kernel::Avx2 => unsafe { x86::axpy_avx2(row, xi, g) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `dot`.
        Kernel::Neon => unsafe { arm::axpy_neon(row, xi, g) },
    }
}

/// One dense layer's forward matvec:
/// `out[r] = dot(W[r, :], a) + bias[r]`. The scalar path is the legacy
/// row-at-a-time loop; the SIMD paths cache-block four output rows per
/// pass over `a` (each row keeps its private lanes and tree, so the
/// per-row bits match the scalar path exactly).
pub fn matvec(k: Kernel, out: &mut [f32], w: &[f32], bias: &[f32], a: &[f32]) {
    let d_in = a.len();
    match k {
        Kernel::Scalar => {
            for (r, slot) in out.iter_mut().enumerate() {
                *slot = dot_scalar(&w[r * d_in..(r + 1) * d_in], a) + bias[r];
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `dot`.
        Kernel::Avx2 => unsafe {
            blocked_matvec(out, w, bias, a, x86::dot4_avx2, x86::dot_avx2);
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `dot`.
        Kernel::Neon => unsafe {
            blocked_matvec(out, w, bias, a, arm::dot4_neon, arm::dot_neon);
        },
    }
}

/// The shared 4-row blocking schedule of the SIMD [`matvec`] paths.
///
/// # Safety
///
/// `dot4` / `dot1` must be safe to call on this machine (the caller
/// dispatched on a detected [`Kernel`] variant).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
unsafe fn blocked_matvec(
    out: &mut [f32],
    w: &[f32],
    bias: &[f32],
    a: &[f32],
    dot4: unsafe fn(&[f32], &[f32], &[f32], &[f32], &[f32]) -> [f32; 4],
    dot1: unsafe fn(&[f32], &[f32]) -> f32,
) {
    let d_in = a.len();
    let mut r = 0usize;
    while r + 4 <= out.len() {
        let vals = dot4(
            &w[r * d_in..(r + 1) * d_in],
            &w[(r + 1) * d_in..(r + 2) * d_in],
            &w[(r + 2) * d_in..(r + 3) * d_in],
            &w[(r + 3) * d_in..(r + 4) * d_in],
            a,
        );
        for j in 0..4 {
            out[r + j] = vals[j] + bias[r + j];
        }
        r += 4;
    }
    while r < out.len() {
        out[r] = dot1(&w[r * d_in..(r + 1) * d_in], a) + bias[r];
        r += 1;
    }
}

/// Transpose matvec as a fold of `axpy` rows:
/// `da += Σ_r gs[r] * W[r, :]` — the dense backward / attention
/// input-gradient inner loop. The scalar path performs the legacy
/// sequential `axpy` chain; the SIMD paths fold four rows per pass
/// (per destination element the identical operation chain, one load
/// and store per block instead of per row).
pub fn matvec_t(k: Kernel, da: &mut [f32], w: &[f32], gs: &[f32]) {
    let d_in = da.len();
    match k {
        Kernel::Scalar => {
            for (r, &g) in gs.iter().enumerate() {
                axpy_scalar(da, &w[r * d_in..(r + 1) * d_in], g);
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `dot`.
        Kernel::Avx2 => unsafe {
            blocked_matvec_t(da, w, gs, x86::axpy4_avx2, x86::axpy_avx2);
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `dot`.
        Kernel::Neon => unsafe {
            blocked_matvec_t(da, w, gs, arm::axpy4_neon, arm::axpy_neon);
        },
    }
}

/// The shared 4-row blocking schedule of the SIMD [`matvec_t`] paths.
///
/// # Safety
///
/// As for [`blocked_matvec`].
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
unsafe fn blocked_matvec_t(
    da: &mut [f32],
    w: &[f32],
    gs: &[f32],
    axpy4: unsafe fn(&mut [f32], &[f32], &[f32], &[f32], &[f32], [f32; 4]),
    axpy1: unsafe fn(&mut [f32], &[f32], f32),
) {
    let d_in = da.len();
    let mut r = 0usize;
    while r + 4 <= gs.len() {
        axpy4(
            da,
            &w[r * d_in..(r + 1) * d_in],
            &w[(r + 1) * d_in..(r + 2) * d_in],
            &w[(r + 2) * d_in..(r + 3) * d_in],
            &w[(r + 3) * d_in..(r + 4) * d_in],
            [gs[r], gs[r + 1], gs[r + 2], gs[r + 3]],
        );
        r += 4;
    }
    while r < gs.len() {
        axpy1(da, &w[r * d_in..(r + 1) * d_in], gs[r]);
        r += 1;
    }
}

/// The ghost Gram-norm product over token matrices `a: [t, aw]`,
/// `g: [t, gw]`: `Σ_{s,u} (a_s·a_u + 1)(g_s·g_u)` — the outer
/// accumulation stays strictly s-major/u-inner sequential (it is part
/// of the determinism contract); only the inner dots dispatch.
pub fn gram_sq(k: Kernel, a: &[f32], aw: usize, g: &[f32], gw: usize, t: usize) -> f32 {
    let mut sq = 0.0f32;
    for s in 0..t {
        let (a_s, g_s) = (&a[s * aw..(s + 1) * aw], &g[s * gw..(s + 1) * gw]);
        for u in 0..t {
            let ga = dot(k, a_s, &a[u * aw..(u + 1) * aw]) + 1.0;
            let gg = dot(k, g_s, &g[u * gw..(u + 1) * gw]);
            sq += ga * gg;
        }
    }
    sq
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 lowering of the fixed-tree kernels. Every function keeps
    //! the multiply and the add as separate (separately rounded)
    //! instructions — `vmulps` + `vaddps`, never `vfmadd` — so each
    //! lane performs the scalar path's exact operation sequence.

    use super::{dot_tail, lane_tree};
    use core::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps,
        _mm256_setzero_ps, _mm256_storeu_ps,
    };

    #[inline]
    unsafe fn fold_chunk(acc: __m256, a: *const f32, b: *const f32) -> __m256 {
        _mm256_add_ps(acc, _mm256_mul_ps(_mm256_loadu_ps(a), _mm256_loadu_ps(b)))
    }

    /// AVX2 `dot`: the 8 scalar lanes live in one `__m256`.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n8 = a.len() - a.len() % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i < n8 {
            acc = fold_chunk(acc, a.as_ptr().add(i), b.as_ptr().add(i));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        lane_tree(&lanes, dot_tail(&a[n8..], &b[n8..]))
    }

    /// Four dots sharing one streamed pass over `a` (the cache-blocked
    /// matvec inner step); each row keeps a private accumulator.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4_avx2(r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], a: &[f32]) -> [f32; 4] {
        let n8 = a.len() - a.len() % 8;
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i < n8 {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(r0.as_ptr().add(i)), av));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(r1.as_ptr().add(i)), av));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_loadu_ps(r2.as_ptr().add(i)), av));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_loadu_ps(r3.as_ptr().add(i)), av));
            i += 8;
        }
        let mut out = [0.0f32; 4];
        for (slot, (acc, row)) in
            out.iter_mut().zip([(acc0, r0), (acc1, r1), (acc2, r2), (acc3, r3)])
        {
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
            *slot = lane_tree(&lanes, dot_tail(&row[n8..], &a[n8..]));
        }
        out
    }

    /// AVX2 `axpy`: per element one multiply rounding + one add
    /// rounding, exactly the scalar chain.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(row: &mut [f32], xi: &[f32], g: f32) {
        let n8 = row.len() - row.len() % 8;
        let gv = _mm256_set1_ps(g);
        let mut i = 0usize;
        while i < n8 {
            let p = row.as_mut_ptr().add(i);
            let v = _mm256_add_ps(
                _mm256_loadu_ps(p),
                _mm256_mul_ps(gv, _mm256_loadu_ps(xi.as_ptr().add(i))),
            );
            _mm256_storeu_ps(p, v);
            i += 8;
        }
        while i < row.len() {
            row[i] += g * xi[i];
            i += 1;
        }
    }

    /// Four `axpy` rows folded in one pass: per destination element the
    /// chain `((d + g0*w0) + g1*w1) + ...` — identical bits to four
    /// sequential `axpy` calls, one destination load/store per block.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy4_avx2(
        da: &mut [f32],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
        g: [f32; 4],
    ) {
        let n8 = da.len() - da.len() % 8;
        let g0 = _mm256_set1_ps(g[0]);
        let g1 = _mm256_set1_ps(g[1]);
        let g2 = _mm256_set1_ps(g[2]);
        let g3 = _mm256_set1_ps(g[3]);
        let mut i = 0usize;
        while i < n8 {
            let p = da.as_mut_ptr().add(i);
            let mut v = _mm256_loadu_ps(p);
            v = _mm256_add_ps(v, _mm256_mul_ps(g0, _mm256_loadu_ps(r0.as_ptr().add(i))));
            v = _mm256_add_ps(v, _mm256_mul_ps(g1, _mm256_loadu_ps(r1.as_ptr().add(i))));
            v = _mm256_add_ps(v, _mm256_mul_ps(g2, _mm256_loadu_ps(r2.as_ptr().add(i))));
            v = _mm256_add_ps(v, _mm256_mul_ps(g3, _mm256_loadu_ps(r3.as_ptr().add(i))));
            _mm256_storeu_ps(p, v);
            i += 8;
        }
        while i < da.len() {
            let mut v = da[i];
            v += g[0] * r0[i];
            v += g[1] * r1[i];
            v += g[2] * r2[i];
            v += g[3] * r3[i];
            da[i] = v;
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    //! NEON lowering: scalar lanes 0-3 and 4-7 live in two 128-bit
    //! registers, multiply and add separately rounded (`fmul` + `fadd`,
    //! never `fmla`), the same tree and tail as every other path.

    use super::{dot_tail, lane_tree};
    use core::arch::aarch64::{
        float32x4_t, vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32,
    };

    #[inline]
    unsafe fn fold_pair(
        lo: float32x4_t,
        hi: float32x4_t,
        a: *const f32,
        b: *const f32,
    ) -> (float32x4_t, float32x4_t) {
        let lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(a), vld1q_f32(b)));
        let hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(a.add(4)), vld1q_f32(b.add(4))));
        (lo, hi)
    }

    #[inline]
    unsafe fn reduce(lo: float32x4_t, hi: float32x4_t, tail: f32) -> f32 {
        let mut lanes = [0.0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        lane_tree(&lanes, tail)
    }

    /// NEON `dot` (see module docs).
    ///
    /// # Safety
    ///
    /// aarch64 only (NEON is baseline there).
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n8 = a.len() - a.len() % 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i < n8 {
            (lo, hi) = fold_pair(lo, hi, a.as_ptr().add(i), b.as_ptr().add(i));
            i += 8;
        }
        reduce(lo, hi, dot_tail(&a[n8..], &b[n8..]))
    }

    /// Four dots sharing one streamed pass over `a`.
    ///
    /// # Safety
    ///
    /// aarch64 only.
    pub unsafe fn dot4_neon(r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], a: &[f32]) -> [f32; 4] {
        [dot_neon(r0, a), dot_neon(r1, a), dot_neon(r2, a), dot_neon(r3, a)]
    }

    /// NEON `axpy`.
    ///
    /// # Safety
    ///
    /// aarch64 only.
    pub unsafe fn axpy_neon(row: &mut [f32], xi: &[f32], g: f32) {
        let n4 = row.len() - row.len() % 4;
        let gv = vdupq_n_f32(g);
        let mut i = 0usize;
        while i < n4 {
            let p = row.as_mut_ptr().add(i);
            let v = vaddq_f32(vld1q_f32(p), vmulq_f32(gv, vld1q_f32(xi.as_ptr().add(i))));
            vst1q_f32(p, v);
            i += 4;
        }
        while i < row.len() {
            row[i] += g * xi[i];
            i += 1;
        }
    }

    /// Four `axpy` rows folded per pass (see the AVX2 twin for the
    /// bitwise argument).
    ///
    /// # Safety
    ///
    /// aarch64 only.
    pub unsafe fn axpy4_neon(
        da: &mut [f32],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
        g: [f32; 4],
    ) {
        let n4 = da.len() - da.len() % 4;
        let g0 = vdupq_n_f32(g[0]);
        let g1 = vdupq_n_f32(g[1]);
        let g2 = vdupq_n_f32(g[2]);
        let g3 = vdupq_n_f32(g[3]);
        let mut i = 0usize;
        while i < n4 {
            let p = da.as_mut_ptr().add(i);
            let mut v = vld1q_f32(p);
            v = vaddq_f32(v, vmulq_f32(g0, vld1q_f32(r0.as_ptr().add(i))));
            v = vaddq_f32(v, vmulq_f32(g1, vld1q_f32(r1.as_ptr().add(i))));
            v = vaddq_f32(v, vmulq_f32(g2, vld1q_f32(r2.as_ptr().add(i))));
            v = vaddq_f32(v, vmulq_f32(g3, vld1q_f32(r3.as_ptr().add(i))));
            vst1q_f32(p, v);
            i += 4;
        }
        while i < da.len() {
            let mut v = da[i];
            v += g[0] * r0[i];
            v += g[1] * r1[i];
            v += g[2] * r2[i];
            v += g[3] * r3[i];
            da[i] = v;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaChaRng;

    /// The pre-SIMD reference `dot`, copied verbatim from
    /// `runtime/reference.rs` as it stood before this module existed —
    /// the bitwise oracle the shared-tail satellite pins against.
    fn legacy_dot(a: &[f32], b: &[f32]) -> f32 {
        let n8 = a.len() - a.len() % 8;
        let (a8, at) = a.split_at(n8);
        let (b8, bt) = b.split_at(n8);
        let mut lanes = [0.0f32; 8];
        for (ac, bc) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
            for j in 0..8 {
                lanes[j] += ac[j] * bc[j];
            }
        }
        let mut tail = 0.0f32;
        for (av, bv) in at.iter().zip(bt) {
            tail += av * bv;
        }
        (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
            + tail
    }

    fn synth(n: usize, stream: u64) -> Vec<f32> {
        let mut rng = ChaChaRng::from_seed_stream(7, stream, b"kernels\0");
        (0..n).map(|_| rng.next_normal() as f32).collect()
    }

    fn all_kernels() -> Vec<Kernel> {
        let mut ks = vec![Kernel::Scalar];
        let auto = Kernel::auto();
        if auto != Kernel::Scalar {
            ks.push(auto);
        }
        ks
    }

    #[test]
    fn dot_is_bitwise_pinned_across_lengths_0_to_33() {
        // The satellite contract: every kernel's dot — including the
        // shared remainder-tail handling — reproduces the legacy
        // implementation bit for bit at every length around the 8-lane
        // boundary (0, partial tail, exact multiples, full + tail).
        for len in 0..=33usize {
            let a = synth(len, 0);
            let b = synth(len, 1);
            let want = legacy_dot(&a, &b).to_bits();
            for k in all_kernels() {
                let got = dot(k, &a, &b).to_bits();
                assert_eq!(got, want, "len {len}, kernel {k:?}");
            }
        }
    }

    #[test]
    fn axpy_matches_the_scalar_chain_bitwise() {
        for len in [0usize, 1, 3, 4, 7, 8, 9, 16, 31, 33, 100] {
            let xi = synth(len, 2);
            let base = synth(len, 3);
            let mut want = base.clone();
            axpy_scalar(&mut want, &xi, 0.37);
            for k in all_kernels() {
                let mut got = base.clone();
                axpy(k, &mut got, &xi, 0.37);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "len {len}, kernel {k:?}");
            }
        }
    }

    #[test]
    fn blocked_matvec_is_bitwise_equal_to_row_at_a_time() {
        // Row counts around the 4-row block boundary x widths around
        // the 8-lane boundary.
        for d_out in [1usize, 3, 4, 5, 8, 11] {
            for d_in in [1usize, 7, 8, 9, 24, 33] {
                let w = synth(d_out * d_in, 4);
                let bias = synth(d_out, 5);
                let a = synth(d_in, 6);
                let mut want = vec![0.0f32; d_out];
                matvec(Kernel::Scalar, &mut want, &w, &bias, &a);
                for (r, slot) in want.iter().enumerate() {
                    let exp = legacy_dot(&w[r * d_in..(r + 1) * d_in], &a) + bias[r];
                    assert_eq!(slot.to_bits(), exp.to_bits());
                }
                for k in all_kernels() {
                    let mut got = vec![0.0f32; d_out];
                    matvec(k, &mut got, &w, &bias, &a);
                    let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, wb, "{d_out}x{d_in}, kernel {k:?}");
                }
            }
        }
    }

    #[test]
    fn blocked_matvec_t_is_bitwise_equal_to_sequential_axpy() {
        for rows in [1usize, 3, 4, 5, 8, 11] {
            for d_in in [1usize, 7, 8, 9, 24, 33] {
                let w = synth(rows * d_in, 7);
                let gs = synth(rows, 8);
                let base = synth(d_in, 9);
                let mut want = base.clone();
                for (r, &g) in gs.iter().enumerate() {
                    axpy_scalar(&mut want, &w[r * d_in..(r + 1) * d_in], g);
                }
                for k in all_kernels() {
                    let mut got = base.clone();
                    matvec_t(k, &mut got, &w, &gs);
                    let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, wb, "{rows}x{d_in}, kernel {k:?}");
                }
            }
        }
    }

    #[test]
    fn gram_sq_is_bitwise_equal_across_kernels() {
        let (t, aw, gw) = (5usize, 13usize, 9usize);
        let a = synth(t * aw, 10);
        let g = synth(t * gw, 11);
        let want = gram_sq(Kernel::Scalar, &a, aw, &g, gw, t).to_bits();
        for k in all_kernels() {
            assert_eq!(gram_sq(k, &a, aw, &g, gw, t).to_bits(), want, "kernel {k:?}");
        }
    }

    #[test]
    fn kernel_parse_axis_and_isa_are_consistent() {
        assert_eq!(Kernel::parse("scalar"), Some(Kernel::Scalar));
        assert_eq!(Kernel::parse("nonsense"), None);
        let simd = Kernel::parse("simd").unwrap();
        assert_eq!(Kernel::parse("auto"), Some(Kernel::auto()));
        assert_eq!(Kernel::Scalar.axis(), "scalar");
        assert_eq!(Kernel::Scalar.isa(), "scalar");
        assert!(VERIFIED_ISAS.contains(&simd.isa()), "{}", simd.isa());
        assert!(VERIFIED_ISAS.contains(&Kernel::auto().isa()));
        assert_eq!(detected_isa(true), "scalar");
        assert!(VERIFIED_ISAS.contains(&detected_isa(false)));
    }
}
