//! Pure-Rust reference backend: the default, dependency-free executor.
//!
//! Ports the linear+softmax reference model and the kernel oracles of
//! `python/compile/kernels/ref.py` to Rust so the entire sampler →
//! batcher → trainer → accountant → report pipeline runs end-to-end
//! offline, with the exact Algorithm 1/2 semantics:
//!
//! * per-example gradients of softmax cross-entropy over one linear
//!   layer (`logits = W x + b`, flat params `[W row-major | b]`),
//! * per-example squared grad norms via the closed form
//!   `||g_i||^2 = ||dlogits_i||^2 * (||x_i||^2 + 1)` (weight ⊗ input
//!   outer product plus the bias row — for a single linear layer this
//!   equals the ghost-norm trick, which is why the `ghost`/`bk`
//!   variants share the per-example path here),
//! * masked clip-and-accumulate `acc += mask_i * min(1, C/||g_i||) g_i`,
//! * the noisy step `params - lr * (acc + sigma*C*z) / denom` with
//!   ChaCha20-seeded Gaussian noise from the 64-bit per-step seed.
//!
//! ## Hot-path implementation (DESIGN.md §3)
//!
//! The kernels are written for steady-state speed without giving up
//! bitwise determinism:
//!
//! * **Bound buffers / donation** — the backend implements the
//!   `run_*_into` forms natively: the gradient accumulator and the
//!   parameter vector are updated in place, never cloned per call, so
//!   the default session ([`Backend::open_session`]) drives these
//!   in-place kernels directly — the session's bound `Tensor`s are the
//!   working buffers. The copying forms are clone + donate, so all
//!   entry points are identical by construction.
//! * **Scratch arenas** — per-call working sets (dlogits, clip scales,
//!   losses, the apply noise vector) live in reusable arenas instead
//!   of per-example `Vec` allocations. Arenas are pooled behind a
//!   `Mutex<Vec<_>>`: a call pops one (or creates a fresh one on first
//!   concurrent use) and returns it afterwards, so the lock is held
//!   only for the pop/push — concurrent sessions driven by the
//!   data-parallel executor (`cluster::parallel`) run their kernels
//!   genuinely in parallel instead of serializing on a shared arena,
//!   and the steady state still allocates nothing (one arena per
//!   concurrently active session).
//! * **Blocked matvec** — logits come from an 8-lane unrolled dot
//!   product with a fixed reduction tree; each weight row stays hot
//!   across the lane loop.
//! * **Deterministic threading** — `std::thread::scope` with fixed
//!   index partitions. Phase 1 (per-example dlogits/norms/scales) is
//!   parallel over *example ranges*; phase 2 (the `acc +=` update) is
//!   parallel over *class-row ranges* with every worker scanning
//!   examples in order, so bits never depend on thread count or
//!   physical chunking — padding-neutrality stays exact.
//!   `ReferenceBackend::with_threads` exposes the knob (wired to
//!   `dpshort --threads`).
//!
//! "Compilation" is a spec decode, timed through the same
//! [`CompileCache`] as PJRT so the masked-vs-naive compile-count
//! invariants (Fig. A.2) are observable on this backend too.

use super::backend::{AccumArgs, AccumOut, AccumStats, ApplyArgs, Backend, Prepared};
use super::compile_cache::{CompileCache, CompileRecord};
use super::manifest::{ExecutableMeta, Manifest, ModelMeta};
use super::tensor::Tensor;
use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Name of the synthetic reference model in [`ReferenceBackend::manifest`].
pub const REFERENCE_MODEL: &str = "ref-linear";

/// Minimum inner-loop multiply-adds a worker thread must amortize
/// before auto-threading spawns it: scoped-thread spawn costs tens of
/// microseconds, so each worker needs at least that much kernel work to
/// pay for itself. The gate only affects wall-clock, never results
/// (see the determinism notes above).
const MIN_WORK_PER_WORKER: usize = 200_000;

/// Cap for auto-detected worker threads (diminishing returns beyond the
/// row count of the reference model).
const MAX_AUTO_THREADS: usize = 8;

/// Decoded executable spec (the reference backend's "compiled" form).
#[derive(Debug, Clone)]
enum RefExec {
    Accum { variant: String, batch: usize },
    Apply,
    Eval { batch: usize },
}

/// Reusable per-call working buffers — the scratch arena. Sized on
/// first use, reused (and regrown, never shrunk below need) afterwards,
/// so the steady-state hot loop performs no heap allocation beyond the
/// per-call `sq_norms` output.
#[derive(Debug, Default)]
struct Scratch {
    /// `[B, ncls]`: logits, transformed in place into dlogits.
    dlogits: Vec<f32>,
    /// `[B]`: accumulate scale `mask_i * min(1, C/||g_i||)`.
    scale: Vec<f32>,
    /// `[B]`: unmasked per-example losses.
    losses: Vec<f32>,
    /// `[P]`: Gaussian noise vector for the apply step.
    noise: Vec<f32>,
}

impl Scratch {
    /// Hand out the accum buffers `(dlogits[B*ncls], scale[B], losses[B])`.
    fn accum(&mut self, b: usize, ncls: usize) -> (&mut [f32], &mut [f32], &mut [f32]) {
        self.dlogits.resize(b * ncls, 0.0);
        self.scale.resize(b, 0.0);
        self.losses.resize(b, 0.0);
        (
            &mut self.dlogits[..b * ncls],
            &mut self.scale[..b],
            &mut self.losses[..b],
        )
    }

    /// Hand out the `[P]` noise buffer for the apply step.
    fn noise(&mut self, n: usize) -> &mut [f32] {
        self.noise.resize(n, 0.0);
        &mut self.noise[..n]
    }
}

/// The pure-Rust reference CPU backend. `Send + Sync`: the compile
/// cache and the scratch-arena pool sit behind `Mutex`es so the backend
/// can be shared as `Arc<dyn Backend + Send + Sync>` across sessions —
/// including sessions driven concurrently from worker threads.
pub struct ReferenceBackend {
    cache: Mutex<CompileCache<RefExec>>,
    /// Seed for the synthesized initial parameters.
    init_seed: u64,
    /// Worker-thread budget for the accum kernels (resolved at
    /// construction; results are bitwise-identical for every value).
    threads: usize,
    /// `with_threads(_, n > 0)`: use exactly `threads` workers instead
    /// of the work-size heuristic (tests and explicit operator control).
    forced_threads: bool,
    /// Scratch-arena pool: popped per call, pushed back afterwards, so
    /// concurrent sessions never serialize on a shared arena.
    scratch: Mutex<Vec<Scratch>>,
}

/// RAII checkout of one scratch arena from the backend's pool.
struct PooledScratch<'a> {
    pool: &'a Mutex<Vec<Scratch>>,
    scratch: Option<Scratch>,
}

impl<'a> PooledScratch<'a> {
    fn take(pool: &'a Mutex<Vec<Scratch>>) -> Self {
        let scratch = pool.lock().unwrap().pop().unwrap_or_default();
        Self { pool, scratch: Some(scratch) }
    }

    fn get(&mut self) -> &mut Scratch {
        self.scratch.as_mut().expect("scratch present until drop")
    }
}

impl Drop for PooledScratch<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.lock().unwrap().push(s);
        }
    }
}

impl ReferenceBackend {
    pub fn new(init_seed: u64) -> Self {
        Self::with_threads(init_seed, 0)
    }

    /// Backend with an explicit worker-thread count (`0` = auto-detect,
    /// where each kernel call sizes its worker set to the work
    /// available; `n > 0` = exactly `n` workers, spawn cost be damned).
    /// The thread count is a wall-clock knob only: outputs are
    /// bitwise-identical for every value, which the proptests assert.
    pub fn with_threads(init_seed: u64, threads: usize) -> Self {
        let forced = threads > 0;
        let threads = if forced {
            threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(MAX_AUTO_THREADS)
        };
        Self {
            cache: Mutex::new(CompileCache::new()),
            init_seed,
            threads,
            forced_threads: forced,
            scratch: Mutex::new(vec![Scratch::default()]),
        }
    }

    /// Worker count for a parallel section with `work` inner-loop
    /// multiply-adds and at most `cap` partitions. Auto mode spawns a
    /// worker only once it has [`MIN_WORK_PER_WORKER`] to amortize the
    /// spawn; forced mode honors the constructor's count. Either way
    /// the result only moves wall-clock, never bits.
    fn workers(&self, work: usize, cap: usize) -> usize {
        let cap = cap.max(1);
        if self.forced_threads {
            self.threads.min(cap).max(1)
        } else {
            (work / MIN_WORK_PER_WORKER).min(self.threads).min(cap).max(1)
        }
    }

    /// In-memory manifest for the reference model: every clipping
    /// variant at a ladder of physical batch sizes, plus apply/eval —
    /// the same catalog shape `python/compile/aot.py` writes for real
    /// artifacts, so the trainer cannot tell the backends apart.
    pub fn manifest(seed: u64) -> Manifest {
        let image = 16;
        let channels = 3;
        let num_classes = 10;
        let d = image * image * channels;
        let mut executables = Vec::new();
        for variant in ["nonprivate", "naive", "masked", "ghost", "bk"] {
            for batch in [1usize, 2, 4, 8, 16, 32, 64] {
                executables.push(ExecutableMeta {
                    path: format!("{REFERENCE_MODEL}_accum_{variant}_b{batch}_f32.ref"),
                    kind: "accum".into(),
                    variant: Some(variant.into()),
                    batch: Some(batch),
                    dtype: Some("f32".into()),
                });
            }
        }
        executables.push(ExecutableMeta {
            path: format!("{REFERENCE_MODEL}_apply.ref"),
            kind: "apply".into(),
            variant: None,
            batch: None,
            dtype: None,
        });
        executables.push(ExecutableMeta {
            path: format!("{REFERENCE_MODEL}_eval_b32.ref"),
            kind: "eval".into(),
            variant: None,
            batch: Some(32),
            dtype: None,
        });
        let meta = ModelMeta {
            family: "linear".into(),
            n_params: num_classes * d + num_classes,
            image,
            channels,
            num_classes,
            clip_norm: 1.0,
            flops_fwd_per_example: (2 * num_classes * d) as f64,
            init_params: format!("{REFERENCE_MODEL}_init.synthetic"),
            executables,
        };
        let mut models = BTreeMap::new();
        models.insert(REFERENCE_MODEL.to_string(), meta);
        Manifest { version: 1, seed, models }
    }

    fn spec(&self, prep: &Prepared) -> Result<Arc<RefExec>> {
        self.cache
            .lock()
            .unwrap()
            .get_cached(&prep.key)
            .ok_or_else(|| anyhow!("executable {} was not prepared", prep.key))
    }

    fn check_model_vectors(meta: &ModelMeta, params: &Tensor, acc: Option<&Tensor>) -> Result<()> {
        if params.len() != meta.n_params {
            return Err(anyhow!(
                "params length {} != n_params {}",
                params.len(),
                meta.n_params
            ));
        }
        if let Some(acc) = acc {
            if acc.len() != meta.n_params {
                return Err(anyhow!(
                    "acc length {} != n_params {}",
                    acc.len(),
                    meta.n_params
                ));
            }
        }
        Ok(())
    }

    fn check_batch(meta: &ModelMeta, x: &[f32], y: &[i32]) -> Result<()> {
        let d = image_dim(meta);
        if x.len() != y.len() * d {
            return Err(anyhow!(
                "x length {} != batch {} * image dim {}",
                x.len(),
                y.len(),
                d
            ));
        }
        for &yi in y {
            if yi < 0 || yi as usize >= meta.num_classes {
                return Err(anyhow!(
                    "label {yi} out of range for {} classes",
                    meta.num_classes
                ));
            }
        }
        Ok(())
    }
}

fn image_dim(meta: &ModelMeta) -> usize {
    meta.image * meta.image * meta.channels
}

/// 8-lane unrolled dot product with a fixed reduction tree — the inner
/// kernel of the blocked matvec. Lane association is part of the
/// determinism contract: the same inputs produce the same bits on every
/// run and thread count (the lanes and their final tree never change).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() - a.len() % 8;
    let (a8, at) = a.split_at(n8);
    let (b8, bt) = b.split_at(n8);
    let mut lanes = [0.0f32; 8];
    for (ac, bc) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for j in 0..8 {
            lanes[j] += ac[j] * bc[j];
        }
    }
    let mut tail = 0.0f32;
    for (av, bv) in at.iter().zip(bt) {
        tail += av * bv;
    }
    (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
        + tail
}

/// `row += g * xi` — no cross-iteration dependency, auto-vectorizes.
#[inline]
fn axpy(row: &mut [f32], xi: &[f32], g: f32) {
    for (a, &xv) in row.iter_mut().zip(xi) {
        *a += g * xv;
    }
}

/// Stable log-sum-exp of the logits.
fn logsumexp(lg: &[f32]) -> f32 {
    let max = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = lg.iter().map(|&l| (l - max).exp()).sum();
    max + z.ln()
}

/// Read-only inputs shared by every accum kernel worker.
#[derive(Clone, Copy)]
struct AccumCtx<'a> {
    meta: &'a ModelMeta,
    nonprivate: bool,
    params: &'a [f32],
    x: &'a [f32],
    y: &'a [i32],
    mask: &'a [f32],
}

/// Accum phase 1: for the examples of one partition (`start` onward,
/// one slot per element of `scale`), compute dlogits (softmax − onehot,
/// in place over the logits), the unmasked loss, the squared grad norm,
/// and the accumulate scale. Examples are independent — this is the
/// parallel-over-examples section. Output slices are the partition's
/// disjoint windows (local index 0 = example `start`).
fn accum_examples(
    ctx: AccumCtx<'_>,
    start: usize,
    dlogits: &mut [f32],
    scale: &mut [f32],
    losses: &mut [f32],
    sq_norms: &mut [f32],
) {
    let AccumCtx { meta, nonprivate, params, x, y, mask } = ctx;
    let d = image_dim(meta);
    let ncls = meta.num_classes;
    let (w, rest) = params.split_at(ncls * d);
    let bias = &rest[..ncls];
    for k in 0..scale.len() {
        let i = start + k;
        let xi = &x[i * d..(i + 1) * d];
        let dl = &mut dlogits[k * ncls..(k + 1) * ncls];
        // Blocked matvec: logits land in the dlogits slot and are
        // transformed in place below.
        for (cls, slot) in dl.iter_mut().enumerate() {
            *slot = dot(&w[cls * d..(cls + 1) * d], xi) + bias[cls];
        }
        let yi = y[i] as usize;
        let ly = dl[yi];
        let max = dl.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in dl.iter_mut() {
            *v = (*v - max).exp();
            z += *v;
        }
        losses[k] = max + z.ln() - ly;
        for v in dl.iter_mut() {
            *v /= z;
        }
        dl[yi] -= 1.0;
        if nonprivate {
            // Batched-gradient baseline: no clipping, norms reported
            // as zeros (matching `_accum_nonprivate` in model.py).
            sq_norms[k] = 0.0;
            scale[k] = mask[i];
        } else {
            let xsq = dot(xi, xi);
            let dlsq = dot(dl, dl);
            let sq = dlsq * (xsq + 1.0);
            sq_norms[k] = sq;
            let norm = sq.max(0.0).sqrt().max(1e-12);
            scale[k] = ((meta.clip_norm as f32) / norm).min(1.0) * mask[i];
        }
    }
}

/// Accum phase 2: `acc += scale_i * (dlogits_i ⊗ x_i, dlogits_i)` for
/// the class rows `[c0, c0 + b_rows.len())`, scanning examples in batch
/// order. Parallelism partitions *rows* (coordinates), never examples,
/// so every accumulator coordinate sees the exact addition chain of a
/// sequential per-example run — for any thread count and any physical
/// chunking of the same example stream (Algorithm-2 padding neutrality
/// stays bitwise-exact).
fn accum_update(
    ctx: AccumCtx<'_>,
    c0: usize,
    w_rows: &mut [f32],
    b_rows: &mut [f32],
    dlogits: &[f32],
    scale: &[f32],
) {
    let d = image_dim(ctx.meta);
    let ncls = ctx.meta.num_classes;
    let x = ctx.x;
    let rows = b_rows.len();
    for (i, &sc) in scale.iter().enumerate() {
        if sc == 0.0 {
            continue;
        }
        let xi = &x[i * d..(i + 1) * d];
        let dl = &dlogits[i * ncls..(i + 1) * ncls];
        for r in 0..rows {
            let g = sc * dl[c0 + r];
            axpy(&mut w_rows[r * d..(r + 1) * d], xi, g);
            b_rows[r] += g;
        }
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, _dir: &Path, _meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared> {
        let spec = match exe.kind.as_str() {
            "accum" => RefExec::Accum {
                variant: exe
                    .variant
                    .clone()
                    .ok_or_else(|| anyhow!("accum artifact {} missing variant", exe.path))?,
                batch: exe
                    .batch
                    .ok_or_else(|| anyhow!("accum artifact {} missing batch", exe.path))?,
            },
            "apply" => RefExec::Apply,
            "eval" => RefExec::Eval {
                batch: exe
                    .batch
                    .ok_or_else(|| anyhow!("eval artifact {} missing batch", exe.path))?,
            },
            other => return Err(anyhow!("unknown executable kind {other:?} for {}", exe.path)),
        };
        let (_, compile_seconds) =
            self.cache.lock().unwrap().get_or_compile(&exe.path, || Ok(spec))?;
        Ok(Prepared { key: exe.path.clone(), compile_seconds })
    }

    fn is_compiled(&self, key: &str) -> bool {
        self.cache.lock().unwrap().is_cached(key)
    }

    fn compile_records(&self) -> Vec<CompileRecord> {
        self.cache.lock().unwrap().records().to_vec()
    }

    /// Synthesized deterministic init: small Gaussian weights, zero
    /// biases (no artifact file to read).
    fn init_params(&self, _dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        let d = image_dim(meta);
        let ncls = meta.num_classes;
        let mut rng = ChaChaRng::from_seed_stream(self.init_seed, 0, b"refinit\0");
        let mut v = Vec::with_capacity(meta.n_params);
        for _ in 0..ncls * d {
            v.push((0.05 * rng.next_normal()) as f32);
        }
        v.resize(meta.n_params, 0.0);
        Ok(Tensor::from_vec(v))
    }

    /// Copying accum: clone + donate, so the two forms agree bitwise by
    /// construction (the donating kernel below is the implementation).
    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumOut> {
        let mut donated = acc.clone();
        let stats = self.run_accum_into(prep, meta, params, &mut donated, args)?;
        Ok(AccumOut { acc: donated, loss_sum: stats.loss_sum, sq_norms: stats.sq_norms })
    }

    /// Copying apply: clone + donate (see `run_accum`).
    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<Tensor> {
        let mut donated = params.clone();
        self.run_apply_into(prep, meta, &mut donated, acc, args)?;
        Ok(donated)
    }

    /// Native donating accum: `acc` is updated in place through the
    /// scratch arena + deterministic-threading kernel described in the
    /// module docs. This is also the session hot path (the default
    /// session binds its buffers to this kernel).
    fn run_accum_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &mut Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumStats> {
        let spec = self.spec(prep)?;
        let (variant, batch) = match spec.as_ref() {
            RefExec::Accum { variant, batch } => (variant.as_str(), *batch),
            _ => return Err(anyhow!("{} is not an accum executable", prep.key)),
        };
        let (x, y, mask) = (args.x, args.y, args.mask);
        let b = y.len();
        if b != batch {
            return Err(anyhow!("accum batch mismatch: executable {batch}, got {b}"));
        }
        if mask.len() != b {
            return Err(anyhow!("mask length {} != batch {b}", mask.len()));
        }
        Self::check_model_vectors(meta, params, Some(acc))?;
        Self::check_batch(meta, x, y)?;

        let d = image_dim(meta);
        let ncls = meta.num_classes;
        let ctx = AccumCtx {
            meta,
            nonprivate: variant == "nonprivate",
            params: params.as_slice(),
            x,
            y,
            mask,
        };
        let mut sq_norms = vec![0.0f32; b];

        let mut pooled = PooledScratch::take(&self.scratch);
        let (dlogits, scale, losses) = pooled.get().accum(b, ncls);

        // Phase 1: per-example dlogits / losses / norms / scales,
        // parallel over fixed contiguous example partitions.
        let nthreads = self.workers(b * ncls * d, b);
        if nthreads > 1 {
            let per = b.div_ceil(nthreads);
            std::thread::scope(|sc| {
                for (ti, (((dl, sl), ls), sq)) in dlogits
                    .chunks_mut(per * ncls)
                    .zip(scale.chunks_mut(per))
                    .zip(losses.chunks_mut(per))
                    .zip(sq_norms.chunks_mut(per))
                    .enumerate()
                {
                    sc.spawn(move || accum_examples(ctx, ti * per, dl, sl, ls, sq));
                }
            });
        } else {
            accum_examples(ctx, 0, dlogits, scale, losses, &mut sq_norms);
        }

        // Masked loss sum in example order (the sequential association).
        let mut loss_sum = 0.0f32;
        for (&ls, &m) in losses.iter().zip(mask) {
            loss_sum += m * ls;
        }

        // Phase 2: the in-place accumulator update, parallel over fixed
        // class-row partitions (examples always scanned in order).
        let dlogits: &[f32] = dlogits;
        let scale: &[f32] = scale;
        let acc_s = acc.as_mut_slice();
        let (w_acc, rest) = acc_s.split_at_mut(ncls * d);
        let bias_acc = &mut rest[..ncls];
        let t2 = self.workers(b * ncls * d, ncls);
        if t2 > 1 {
            let rows_per = ncls.div_ceil(t2);
            std::thread::scope(|sc| {
                for (ti, (wc, bc)) in w_acc
                    .chunks_mut(rows_per * d)
                    .zip(bias_acc.chunks_mut(rows_per))
                    .enumerate()
                {
                    sc.spawn(move || accum_update(ctx, ti * rows_per, wc, bc, dlogits, scale));
                }
            });
        } else {
            accum_update(ctx, 0, w_acc, bias_acc, dlogits, scale);
        }
        Ok(AccumStats { loss_sum, sq_norms })
    }

    /// Native donating apply: in-place SGD step with bulk ChaCha20
    /// Gaussian noise (`fill_normals` over the arena's noise buffer).
    /// The copying `run_apply` is clone + this.
    fn run_apply_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &mut Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<()> {
        let spec = self.spec(prep)?;
        if !matches!(spec.as_ref(), RefExec::Apply) {
            return Err(anyhow!("{} is not an apply executable", prep.key));
        }
        Self::check_model_vectors(meta, params, Some(acc))?;
        let ApplyArgs { seed, denom, lr, noise_mult } = *args;
        if !denom.is_finite() || denom <= 0.0 {
            return Err(anyhow!("apply denom must be positive, got {denom}"));
        }
        let out = params.as_mut_slice();
        if noise_mult != 0.0 {
            let mut pooled = PooledScratch::take(&self.scratch);
            let noise = pooled.get().noise(out.len());
            let mut rng = ChaChaRng::from_seed_stream(seed, 0, b"applynse");
            rng.fill_normals(noise);
            for ((pj, &aj), &z) in out.iter_mut().zip(acc.as_slice()).zip(noise.iter()) {
                *pj -= lr * (aj + noise_mult * z) / denom;
            }
        } else {
            for (pj, &aj) in out.iter_mut().zip(acc.as_slice()) {
                *pj -= lr * aj / denom;
            }
        }
        Ok(())
    }

    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let spec = self.spec(prep)?;
        let batch = match spec.as_ref() {
            RefExec::Eval { batch } => *batch,
            _ => return Err(anyhow!("{} is not an eval executable", prep.key)),
        };
        if y.len() != batch {
            return Err(anyhow!("eval batch must be exactly {batch}, got {}", y.len()));
        }
        Self::check_model_vectors(meta, params, None)?;
        Self::check_batch(meta, x, y)?;
        let d = image_dim(meta);
        let ncls = meta.num_classes;
        let p = params.as_slice();
        let (w, rest) = p.split_at(ncls * d);
        let bias = &rest[..ncls];
        let mut lg = vec![0.0f32; ncls];
        let mut loss_sum = 0.0f32;
        let mut ncorrect = 0.0f32;
        for (i, &yi) in y.iter().enumerate() {
            let xi = &x[i * d..(i + 1) * d];
            for (cls, slot) in lg.iter_mut().enumerate() {
                *slot = dot(&w[cls * d..(cls + 1) * d], xi) + bias[cls];
            }
            loss_sum += logsumexp(&lg) - lg[yi as usize];
            let mut best = 0usize;
            for (j, &v) in lg.iter().enumerate() {
                if v > lg[best] {
                    best = j;
                }
            }
            if best == yi as usize {
                ncorrect += 1.0;
            }
        }
        Ok((loss_sum, ncorrect))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ReferenceBackend, ModelMeta) {
        let backend = ReferenceBackend::new(0);
        let manifest = ReferenceBackend::manifest(0);
        let meta = manifest.models[REFERENCE_MODEL].clone();
        (backend, meta)
    }

    fn prepare_accum(
        b: &ReferenceBackend,
        meta: &ModelMeta,
        variant: &str,
        batch: usize,
    ) -> Prepared {
        let exe = meta.find_accum(variant, batch, "f32").expect("lowered").clone();
        b.prepare(Path::new("."), meta, &exe).unwrap()
    }

    fn batch_of(meta: &ModelMeta, n: usize) -> (Vec<f32>, Vec<i32>) {
        let d = image_dim(meta);
        let mut rng = ChaChaRng::from_seed_stream(7, 1, b"testdata");
        let x: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let y: Vec<i32> = (0..n).map(|i| (i % meta.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifest_is_complete() {
        let m = ReferenceBackend::manifest(0);
        let meta = m.model(REFERENCE_MODEL).unwrap();
        assert!(meta.find_apply().is_some());
        assert_eq!(meta.find_eval().and_then(|e| e.batch), Some(32));
        assert_eq!(meta.accum_batches("masked", "f32"), vec![1, 2, 4, 8, 16, 32, 64]);
        assert_eq!(meta.n_params, 10 * 16 * 16 * 3 + 10);
        assert!(meta.variants().contains(&"nonprivate".to_string()));
    }

    #[test]
    fn init_params_deterministic_and_nondegenerate() {
        let (b, meta) = setup();
        let p1 = b.init_params(Path::new("."), &meta).unwrap();
        let p2 = b.init_params(Path::new("."), &meta).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), meta.n_params);
        let nonzero = p1.as_slice().iter().filter(|v| **v != 0.0).count();
        assert!(nonzero > meta.n_params / 2);
        let other = ReferenceBackend::new(1).init_params(Path::new("."), &meta).unwrap();
        assert_ne!(p1, other);
    }

    #[test]
    fn masked_examples_contribute_nothing() {
        let (b, meta) = setup();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let d = image_dim(&meta);
        let (x, y) = batch_of(&meta, 4);
        // Batch of 4 with the last two slots masked out (Alg. 2 padding)
        // must equal the same two live examples run at batch 2.
        let prep4 = prepare_accum(&b, &meta, "masked", 4);
        let padded = b
            .run_accum(
                &prep4,
                &meta,
                &params,
                &acc,
                &AccumArgs { x: &x, y: &y, mask: &[1.0, 1.0, 0.0, 0.0] },
            )
            .unwrap();
        let prep2 = prepare_accum(&b, &meta, "masked", 2);
        let live = b
            .run_accum(
                &prep2,
                &meta,
                &params,
                &acc,
                &AccumArgs { x: &x[..2 * d], y: &y[..2], mask: &[1.0, 1.0] },
            )
            .unwrap();
        assert_eq!(padded.acc, live.acc);
        assert_eq!(padded.loss_sum, live.loss_sum);
        // All-masked batch: accumulator unchanged, loss zero.
        let none = b
            .run_accum(&prep4, &meta, &params, &acc, &AccumArgs { x: &x, y: &y, mask: &[0.0; 4] })
            .unwrap();
        assert_eq!(none.acc, acc);
        assert_eq!(none.loss_sum, 0.0);
        // Norms are still reported for every slot (B of them).
        assert_eq!(none.sq_norms.len(), 4);
    }

    #[test]
    fn clipped_accumulator_norm_bounded_by_batch_times_clip() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "masked", 8);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 8);
        let out = b
            .run_accum(&prep, &meta, &params, &acc, &AccumArgs { x: &x, y: &y, mask: &[1.0; 8] })
            .unwrap();
        let norm: f32 = out
            .acc
            .as_slice()
            .iter()
            .map(|v| v * v)
            .sum::<f32>()
            .sqrt();
        // Triangle inequality: ||sum of clipped grads|| <= B * C.
        assert!(norm <= 8.0 * meta.clip_norm as f32 + 1e-4, "norm {norm}");
        assert!(out.loss_sum > 0.0);
        assert!(out.sq_norms.iter().all(|s| *s >= 0.0 && s.is_finite()));
    }

    #[test]
    fn nonprivate_reports_zero_norms_and_skips_clipping() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "nonprivate", 2);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 2);
        let out = b
            .run_accum(&prep, &meta, &params, &acc, &AccumArgs { x: &x, y: &y, mask: &[1.0, 1.0] })
            .unwrap();
        assert_eq!(out.sq_norms, vec![0.0, 0.0]);
        let norm: f32 = out.acc.as_slice().iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm > 0.0);
    }

    #[test]
    fn ghost_variant_matches_per_example_path() {
        // Single linear layer: the ghost-norm trick is exact, so ghost
        // and masked produce identical accumulators.
        let (b, meta) = setup();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 4);
        let args = AccumArgs { x: &x, y: &y, mask: &[1.0; 4] };
        let masked = prepare_accum(&b, &meta, "masked", 4);
        let ghost = prepare_accum(&b, &meta, "ghost", 4);
        let a = b.run_accum(&masked, &meta, &params, &acc, &args).unwrap();
        let g = b.run_accum(&ghost, &meta, &params, &acc, &args).unwrap();
        assert_eq!(a.acc, g.acc);
        assert_eq!(a.sq_norms, g.sq_norms);
    }

    #[test]
    fn donated_accum_matches_copying_accum_bitwise() {
        let (b, meta) = setup();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = batch_of(&meta, 8);
        let mut acc_init = Tensor::zeros(meta.n_params);
        acc_init.as_mut_slice()[3] = 0.25;
        for variant in ["masked", "nonprivate", "ghost"] {
            let prep = prepare_accum(&b, &meta, variant, 8);
            let mask = [1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0, 1.0];
            let args = AccumArgs { x: &x, y: &y, mask: &mask };
            let copied = b.run_accum(&prep, &meta, &params, &acc_init, &args).unwrap();
            let mut donated = acc_init.clone();
            let stats = b
                .run_accum_into(&prep, &meta, &params, &mut donated, &args)
                .unwrap();
            assert_eq!(copied.acc, donated, "{variant}: acc diverged");
            assert_eq!(copied.loss_sum.to_bits(), stats.loss_sum.to_bits());
            assert_eq!(copied.sq_norms, stats.sq_norms);
        }
    }

    #[test]
    fn thread_count_never_changes_the_bits() {
        // The determinism contract: outputs are a pure function of the
        // inputs, not of the parallelism. Exercise a batch above the
        // threading gate with every thread count 1..=4.
        let meta = ReferenceBackend::manifest(0).models[REFERENCE_MODEL].clone();
        let (x, y) = batch_of(&meta, 32);
        let mut mask = vec![1.0f32; 32];
        mask[7] = 0.0;
        mask[31] = 0.0;
        let mut reference_out: Option<AccumOut> = None;
        for threads in 1..=4 {
            let b = ReferenceBackend::with_threads(0, threads);
            let prep = prepare_accum(&b, &meta, "masked", 32);
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let acc = Tensor::zeros(meta.n_params);
            let out = b
                .run_accum(&prep, &meta, &params, &acc, &AccumArgs { x: &x, y: &y, mask: &mask })
                .unwrap();
            if let Some(want) = &reference_out {
                assert_eq!(want.acc, out.acc, "threads={threads}: acc diverged");
                assert_eq!(want.loss_sum.to_bits(), out.loss_sum.to_bits());
                assert_eq!(want.sq_norms, out.sq_norms);
            } else {
                reference_out = Some(out);
            }
        }
    }

    #[test]
    fn apply_without_noise_is_plain_sgd_and_with_noise_is_seeded() {
        let (b, meta) = setup();
        let apply_meta = meta.find_apply().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        acc.as_mut_slice()[0] = 2.0;
        let plain = ApplyArgs { seed: 42, denom: 4.0, lr: 0.1, noise_mult: 0.0 };
        let out = b.run_apply(&prep, &meta, &params, &acc, &plain).unwrap();
        let want = params.as_slice()[0] - 0.1 * 2.0 / 4.0;
        assert!((out.as_slice()[0] - want).abs() < 1e-7);
        assert_eq!(out.as_slice()[1], params.as_slice()[1]);
        // Noise: deterministic per seed, different across seeds.
        let noisy = |seed| ApplyArgs { seed, denom: 4.0, lr: 0.1, noise_mult: 1.0 };
        let n1 = b.run_apply(&prep, &meta, &params, &acc, &noisy(7)).unwrap();
        let n2 = b.run_apply(&prep, &meta, &params, &acc, &noisy(7)).unwrap();
        let n3 = b.run_apply(&prep, &meta, &params, &acc, &noisy(8)).unwrap();
        assert_eq!(n1, n2);
        assert_ne!(n1, n3);
        assert_ne!(n1, out);
    }

    #[test]
    fn donated_apply_matches_copying_apply_bitwise() {
        let (b, meta) = setup();
        let apply_meta = meta.find_apply().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        acc.as_mut_slice()[5] = -1.5;
        for noise_mult in [0.0f32, 1.3] {
            let args = ApplyArgs { seed: 99, denom: 8.0, lr: 0.2, noise_mult };
            let copied = b.run_apply(&prep, &meta, &params, &acc, &args).unwrap();
            let mut donated = params.clone();
            b.run_apply_into(&prep, &meta, &mut donated, &acc, &args).unwrap();
            assert_eq!(copied, donated, "noise_mult={noise_mult}");
        }
    }

    #[test]
    fn session_binds_buffers_to_the_in_place_kernels() {
        // The default session over the reference backend must follow the
        // exact legacy call sequence bitwise: two accums, an apply, a
        // zero_acc, another accum.
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "masked", 8);
        let apply_meta = meta.find_apply().unwrap().clone();
        let apply_prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = batch_of(&meta, 8);
        let mask = [1.0, 0.0, 1.0, 1.0, 1.0, 1.0, 0.0, 1.0];
        let args = AccumArgs { x: &x, y: &y, mask: &mask };
        let apply = ApplyArgs { seed: 11, denom: 6.0, lr: 0.1, noise_mult: 1.0 };

        let mut sess = b.open_session(Path::new("."), &meta, params.clone()).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        let mut p = params.clone();
        for _ in 0..2 {
            let s = sess.accum(&prep, &args).unwrap();
            let l = b.run_accum_into(&prep, &meta, &p, &mut acc, &args).unwrap();
            assert_eq!(s.loss_sum.to_bits(), l.loss_sum.to_bits());
        }
        sess.apply(&apply_prep, &apply).unwrap();
        b.run_apply_into(&apply_prep, &meta, &mut p, &acc, &apply).unwrap();
        assert_eq!(sess.read_params().unwrap(), p);

        sess.zero_acc().unwrap();
        acc.fill(0.0);
        let s = sess.accum(&prep, &args).unwrap();
        let l = b.run_accum_into(&prep, &meta, &p, &mut acc, &args).unwrap();
        assert_eq!(s.loss_sum.to_bits(), l.loss_sum.to_bits());
        assert_eq!(s.sq_norms, l.sq_norms);
    }

    #[test]
    fn eval_counts_and_losses_are_sane() {
        let (b, meta) = setup();
        let eval_meta = meta.find_eval().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &eval_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = batch_of(&meta, 32);
        let (loss, ncorrect) = b.run_eval(&prep, &meta, &params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=32.0).contains(&ncorrect));
        // Wrong batch size is a clean error.
        let (x2, y2) = batch_of(&meta, 8);
        assert!(b.run_eval(&prep, &meta, &params, &x2, &y2).is_err());
    }

    #[test]
    fn prepare_caches_and_reports_compiles_once() {
        let (b, meta) = setup();
        let exe = meta.find_accum("masked", 8, "f32").unwrap().clone();
        let p1 = b.prepare(Path::new("."), &meta, &exe).unwrap();
        assert!(p1.compile_seconds.is_some());
        assert!(b.is_compiled(&p1.key));
        let p2 = b.prepare(Path::new("."), &meta, &exe).unwrap();
        assert!(p2.compile_seconds.is_none(), "second prepare must be a cache hit");
        assert_eq!(b.compile_records().len(), 1);
    }

    #[test]
    fn out_of_range_label_is_an_error() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "masked", 1);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let d = image_dim(&meta);
        let x = vec![0.0f32; d];
        let too_big = AccumArgs { x: &x, y: &[99], mask: &[1.0] };
        assert!(b.run_accum(&prep, &meta, &params, &acc, &too_big).is_err());
        let negative = AccumArgs { x: &x, y: &[-1], mask: &[1.0] };
        assert!(b.run_accum(&prep, &meta, &params, &acc, &negative).is_err());
    }

    #[test]
    fn backend_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ReferenceBackend>();
    }
}
