//! Pure-Rust reference backend: the default, dependency-free executor.
//!
//! Executes the **layered model IR** ([`super::layers::LayerPlan`]):
//! any chain of dense / conv2d / layernorm / attention layers ending in
//! a dense softmax-xent head, with the exact Algorithm 1/2 semantics,
//! so the entire sampler → batcher → trainer → accountant → report
//! pipeline runs end-to-end offline on every model of
//! [`crate::models::cpu_ladder`] (`ref-linear`, `mlp-small`,
//! `cnn-small`, `attn-tiny`, ...):
//!
//! * **forward tape** — per example, hidden activations are recorded
//!   (post-activation) so the backward pass can revisit every layer's
//!   input; non-dense kinds also tape the forward intermediates their
//!   backward needs (layernorm `xhat`/`rstd`; attention `q/k/v`,
//!   softmax probabilities, context — DESIGN.md §13);
//! * **per-example backward across all layers** — `dz` per layer via
//!   each kind's input-gradient rule + the ReLU mask, per-example
//!   squared norms per layer via the ghost Gram products
//!   `Σ_{s,u} (a_s·a_u + 1)(g_s·g_u)` over the layer's token view
//!   (dense: t = 1, where the identity degenerates to
//!   `‖dz‖²·(‖a‖² + 1)`; conv2d: t = spatial positions over im2col
//!   patches; attention: one Gram per q/k/v/o projection; layernorm:
//!   the O(d) elementwise norm);
//! * **global-norm clipping** — the per-example norm is the sum of the
//!   per-layer squared norms over the *whole* network (never clipped
//!   per layer), then the masked clip-and-accumulate
//!   `acc += mask_i * min(1, C/‖g_i‖) g_i`;
//! * **executed clipping branches** — ghost-style layers fold the
//!   clipped gradient with a fused reweighted `axpy` (per-example
//!   weight grads never materialize); `perex` layers materialize each
//!   example's layer gradient first (the Opacus hook cost, observable
//!   as memory traffic); the `mix` variant picks per layer via the
//!   Bu et al. decision rule ([`super::layers::executed_choices`]).
//!   The norm is computed once, in the shared Gram form, and the
//!   materialized fold adds bit-identical addends in the same order —
//!   so **every variant is bitwise-identical** in accumulator, loss,
//!   and norms; the branch moves memory traffic and wall-clock only
//!   (property-tested in `rust/tests/layered_models.rs`);
//! * the noisy step `params - lr * (acc + sigma*C*z) / denom` with
//!   ChaCha20-seeded Gaussian noise from the 64-bit per-step seed.
//!
//! For a single dense layer all of this degenerates to the seed's
//! hardcoded linear+softmax kernel — same `[W | b]` layout, same dot
//! products, same clip — and the `ref-linear` trajectory is pinned
//! bitwise against a port of that original kernel by the oracle
//! proptest in `rust/tests/layered_models.rs`.
//!
//! ## Hot-path implementation (DESIGN.md §3, §9)
//!
//! The kernels are written for steady-state speed without giving up
//! bitwise determinism:
//!
//! * **Bound buffers / donation** — the backend implements the
//!   `run_*_into` forms natively: the gradient accumulator and the
//!   parameter vector are updated in place, never cloned per call, so
//!   the default session ([`Backend::open_session`]) drives these
//!   in-place kernels directly.
//! * **Scratch arenas** — per-call working sets (the dz tape, the
//!   activation tape, clip scales, losses, the apply noise vector)
//!   live in pooled reusable arenas (popped per call, returned after),
//!   so concurrent sessions never serialize and the steady state
//!   allocates only the per-call `sq_norms` output and the phase-2 row
//!   units.
//! * **Dispatched kernels** — every hot inner loop (dot / axpy / the
//!   dense and attention matvecs / the ghost Gram products) goes
//!   through [`super::kernels`]: the 8-lane fixed-tree scalar path or
//!   its bitwise-identical AVX2/NEON + cache-blocked lowering, selected
//!   once at backend construction (`--kernel`, DESIGN.md §14). Kernel
//!   choice moves wall-clock only, never bits.
//! * **Deterministic threading** — `std::thread::scope` with fixed
//!   index partitions. Phase 1 (per-example forward/backward) is
//!   parallel over *example ranges*; phase 2 (the `acc +=` update) is
//!   parallel over *accumulator row units* — one unit per (layer,
//!   output row) — with every worker scanning examples in order, so
//!   bits never depend on thread count or physical chunking and
//!   Algorithm-2 padding neutrality stays exact.
//!   `ReferenceBackend::with_threads` exposes the knob (wired to
//!   `dpshort --threads`).
//!
//! "Compilation" is a spec decode — the accum specs embed the resolved
//! [`LayerPlan`] and per-layer branch choices — timed through the same
//! [`CompileCache`] as PJRT so the masked-vs-naive compile-count
//! invariants (Fig. A.2) are observable on this backend too.

use super::backend::{AccumArgs, AccumOut, AccumStats, ApplyArgs, Backend, Prepared};
use super::compile_cache::{CompileCache, CompileRecord};
use super::kernels::{self, Kernel};
use super::layers::{dz_extras, executed_choices, tape_extras, LayerPlan, PlannedLayer};
use super::manifest::{ExecutableMeta, Manifest, ModelMeta};
use super::tensor::{quantize_bf16, Tensor};
use crate::clipping::LayerChoice;
use crate::models::{conv_out, cpu_ladder, Activation, LayerKind, LayerSpec};
use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Name of the canonical (seed) reference model: the single dense
/// layer. The in-memory manifest carries the whole CPU-executable
/// ladder ([`cpu_ladder`]); this one stays the default rung.
pub const REFERENCE_MODEL: &str = "ref-linear";

/// Accum variants the in-memory manifest lowers for every CPU model.
/// `perex` is the materializing per-example graph, `mix` the per-layer
/// decision-rule graph; the rest keep their PR-1 meanings (and all of
/// them agree bitwise — see the module docs).
pub const ACCUM_VARIANTS: &[&str] =
    &["nonprivate", "naive", "masked", "ghost", "bk", "perex", "mix"];

/// Physical batch ladder lowered per (model, variant).
const ACCUM_BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32, 64];

/// Eval executable batch size (fixed at "AOT" time, like real artifacts).
const EVAL_BATCH: usize = 32;

/// Minimum inner-loop multiply-adds a worker thread must amortize
/// before auto-threading spawns it: scoped-thread spawn costs tens of
/// microseconds, so each worker needs at least that much kernel work to
/// pay for itself. The gate only affects wall-clock, never results
/// (see the determinism notes above).
const MIN_WORK_PER_WORKER: usize = 200_000;

/// Cap for auto-detected worker threads (diminishing returns beyond the
/// row count of the reference models).
const MAX_AUTO_THREADS: usize = 8;

/// Decoded executable spec (the reference backend's "compiled" form).
/// Accum/eval specs embed the resolved [`LayerPlan`] (and, for accum,
/// the per-layer fused/materialized branch), so the hot loop never
/// re-derives the layout.
#[derive(Debug, Clone)]
enum RefExec {
    Accum {
        variant: String,
        batch: usize,
        plan: LayerPlan,
        /// Per layer: `true` = fused ghost-style accumulate,
        /// `false` = materialized per-example accumulate.
        fused: Vec<bool>,
    },
    Apply {
        /// `--param-dtype bf16`: quantize the parameter storage back to
        /// bf16 (round-to-nearest-even) after the f32 update.
        bf16: bool,
    },
    Eval {
        batch: usize,
        plan: LayerPlan,
    },
}

/// Reusable per-call working buffers — the scratch arena. Sized on
/// first use, reused afterwards, so the steady-state hot loop performs
/// no heap allocation beyond the per-call `sq_norms` output and the
/// phase-2 row-unit table.
#[derive(Debug, Default)]
struct Scratch {
    /// `[B, dz_stride]`: per-example, per-layer pre-activation grads
    /// (the head slot holds logits, transformed in place into dz).
    dz: Vec<f32>,
    /// `[B, tape_stride]`: per-example hidden activations (forward tape).
    tape: Vec<f32>,
    /// `[B]`: accumulate scale `mask_i * min(1, C/||g_i||)`.
    scale: Vec<f32>,
    /// `[B]`: unmasked per-example losses.
    losses: Vec<f32>,
    /// `[workers * bwd_scratch]`: per-worker phase-1 backward scratch
    /// (conv im2col patches + dz transpose, attention softmax row).
    bwd: Vec<f32>,
    /// `[max_unit_width]`: phase-2 materialization row (the
    /// `perex`-style scaled-copy buffer), pool-owned so the blocked
    /// update never allocates in the hot loop.
    m_row: Vec<f32>,
    /// `[max_unit_width]`: phase-2 canonical contribution block (the
    /// position-summed conv/attention row), pool-owned like `m_row`.
    contrib: Vec<f32>,
    /// `[P]`: Gaussian noise vector for the apply step.
    noise: Vec<f32>,
}

/// The accum working set one arena hands out: phase-1 tapes plus the
/// phase-2 block buffers, borrowed together so a single pooled checkout
/// serves both phases of a single-threaded call.
struct AccumBuffers<'a> {
    dz: &'a mut [f32],
    tape: &'a mut [f32],
    scale: &'a mut [f32],
    losses: &'a mut [f32],
    bwd: &'a mut [f32],
    m_row: &'a mut [f32],
    contrib: &'a mut [f32],
}

impl Scratch {
    /// Hand out the accum buffers ([`AccumBuffers`]), each resized from
    /// the [`LayerPlan`]: `dz[B*dz_stride]`, `tape[B*tape_stride]`,
    /// `scale[B]`, `losses[B]`, `bwd[workers*bwd_scratch]`, and the two
    /// `[max_unit_width]` phase-2 block buffers.
    fn accum(&mut self, b: usize, workers: usize, plan: &LayerPlan) -> AccumBuffers<'_> {
        self.dz.resize(b * plan.dz_stride, 0.0);
        self.tape.resize(b * plan.tape_stride, 0.0);
        self.scale.resize(b, 0.0);
        self.losses.resize(b, 0.0);
        self.bwd.resize(workers * plan.bwd_scratch, 0.0);
        self.m_row.resize(plan.max_unit_width, 0.0);
        self.contrib.resize(plan.max_unit_width, 0.0);
        AccumBuffers {
            dz: &mut self.dz[..b * plan.dz_stride],
            tape: &mut self.tape[..b * plan.tape_stride],
            scale: &mut self.scale[..b],
            losses: &mut self.losses[..b],
            bwd: &mut self.bwd[..workers * plan.bwd_scratch],
            m_row: &mut self.m_row[..plan.max_unit_width],
            contrib: &mut self.contrib[..plan.max_unit_width],
        }
    }

    /// Hand out just the two `[max_unit_width]` phase-2 block buffers —
    /// each spawned phase-2 worker checks out its own arena and takes
    /// these, so the threaded update allocates nothing per step either.
    fn blocks(&mut self, plan: &LayerPlan) -> (&mut [f32], &mut [f32]) {
        self.m_row.resize(plan.max_unit_width, 0.0);
        self.contrib.resize(plan.max_unit_width, 0.0);
        (
            &mut self.m_row[..plan.max_unit_width],
            &mut self.contrib[..plan.max_unit_width],
        )
    }

    /// Hand out the `[P]` noise buffer for the apply step.
    fn noise(&mut self, n: usize) -> &mut [f32] {
        self.noise.resize(n, 0.0);
        &mut self.noise[..n]
    }
}

/// The pure-Rust reference CPU backend. `Send + Sync`: the compile
/// cache and the scratch-arena pool sit behind `Mutex`es so the backend
/// can be shared as `Arc<dyn Backend + Send + Sync>` across sessions —
/// including sessions driven concurrently from worker threads.
pub struct ReferenceBackend {
    cache: Mutex<CompileCache<RefExec>>,
    /// Seed for the synthesized initial parameters.
    init_seed: u64,
    /// Worker-thread budget for the accum kernels (resolved at
    /// construction; results are bitwise-identical for every value).
    threads: usize,
    /// `with_threads(_, n > 0)`: use exactly `threads` workers instead
    /// of the work-size heuristic (tests and explicit operator control).
    forced_threads: bool,
    /// Inner-loop kernel (resolved at construction; bitwise-identical
    /// for every value — `--kernel` is a wall-clock knob only).
    kernel: Kernel,
    /// Scratch-arena pool: popped per call, pushed back afterwards, so
    /// concurrent sessions never serialize on a shared arena.
    scratch: Mutex<Vec<Scratch>>,
}

/// RAII checkout of one scratch arena from the backend's pool.
struct PooledScratch<'a> {
    pool: &'a Mutex<Vec<Scratch>>,
    scratch: Option<Scratch>,
}

impl<'a> PooledScratch<'a> {
    fn take(pool: &'a Mutex<Vec<Scratch>>) -> Self {
        // Recover from poisoning: a panicking worker (e.g. an injected
        // fault, DESIGN.md §11) may die holding this lock, but scratch
        // buffers are resized before every use, so a half-written one
        // is still safe to reuse.
        let scratch = pool
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .pop()
            .unwrap_or_default();
        Self { pool, scratch: Some(scratch) }
    }

    fn get(&mut self) -> &mut Scratch {
        self.scratch.as_mut().expect("scratch present until drop")
    }
}

impl Drop for PooledScratch<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.lock().unwrap_or_else(std::sync::PoisonError::into_inner).push(s);
        }
    }
}

impl ReferenceBackend {
    pub fn new(init_seed: u64) -> Self {
        Self::with_threads(init_seed, 0)
    }

    /// Backend with an explicit worker-thread count (`0` = auto-detect,
    /// where each kernel call sizes its worker set to the work
    /// available; `n > 0` = exactly `n` workers, spawn cost be damned).
    /// The thread count is a wall-clock knob only: outputs are
    /// bitwise-identical for every value, which the proptests assert.
    pub fn with_threads(init_seed: u64, threads: usize) -> Self {
        Self::with_options(init_seed, threads, Kernel::auto())
    }

    /// Backend with both wall-clock knobs pinned: worker threads (as in
    /// [`Self::with_threads`]) and the inner-loop [`Kernel`]. Like the
    /// thread count, the kernel never moves bits (DESIGN.md §14) — the
    /// scalar-vs-SIMD proptests in `rust/tests/kernel_bitwise.rs`
    /// assert it end to end.
    pub fn with_options(init_seed: u64, threads: usize, kernel: Kernel) -> Self {
        let forced = threads > 0;
        let threads = if forced {
            threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(MAX_AUTO_THREADS)
        };
        Self {
            cache: Mutex::new(CompileCache::new()),
            init_seed,
            threads,
            forced_threads: forced,
            kernel,
            scratch: Mutex::new(vec![Scratch::default()]),
        }
    }

    /// The inner-loop kernel this backend was constructed with.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Worker count for a parallel section with `work` inner-loop
    /// multiply-adds and at most `cap` partitions. Auto mode spawns a
    /// worker only once it has [`MIN_WORK_PER_WORKER`] to amortize the
    /// spawn; forced mode honors the constructor's count. Either way
    /// the result only moves wall-clock, never bits.
    fn workers(&self, work: usize, cap: usize) -> usize {
        let cap = cap.max(1);
        if self.forced_threads {
            self.threads.min(cap).max(1)
        } else {
            (work / MIN_WORK_PER_WORKER).min(self.threads).min(cap).max(1)
        }
    }

    /// In-memory manifest for the CPU-executable ladder
    /// ([`cpu_ladder`]): every model's layer IR, every clipping variant
    /// at a ladder of physical batch sizes, plus apply/eval — the same
    /// catalog shape `python/compile/aot.py` writes for real artifacts,
    /// so the trainer cannot tell the backends apart.
    pub fn manifest(seed: u64) -> Manifest {
        let mut models = BTreeMap::new();
        for m in cpu_ladder() {
            let mut executables = Vec::new();
            // Both parameter dtypes are lowered for every accum rung:
            // `bf16` executables run the same f32 compute over
            // bf16-quantized parameter storage (DESIGN.md §14), and
            // their presence is what turns the precision figures from
            // analytic into measured rows.
            for variant in ACCUM_VARIANTS {
                for &batch in ACCUM_BATCHES {
                    for dtype in ["f32", "bf16"] {
                        executables.push(ExecutableMeta {
                            path: format!("{}_accum_{variant}_b{batch}_{dtype}.ref", m.name),
                            kind: "accum".into(),
                            variant: Some((*variant).into()),
                            batch: Some(batch),
                            dtype: Some(dtype.into()),
                        });
                    }
                }
            }
            // The dtype-less apply stays first so `find_apply()` keeps
            // returning the f32 step; the bf16 apply re-quantizes the
            // stored parameters after the f32 update.
            executables.push(ExecutableMeta {
                path: format!("{}_apply.ref", m.name),
                kind: "apply".into(),
                variant: None,
                batch: None,
                dtype: None,
            });
            executables.push(ExecutableMeta {
                path: format!("{}_apply_bf16.ref", m.name),
                kind: "apply".into(),
                variant: None,
                batch: None,
                dtype: Some("bf16".into()),
            });
            executables.push(ExecutableMeta {
                path: format!("{}_eval_b{EVAL_BATCH}.ref", m.name),
                kind: "eval".into(),
                variant: None,
                batch: Some(EVAL_BATCH),
                dtype: None,
            });
            let meta = ModelMeta {
                family: m.family.into(),
                n_params: m.params(),
                image: m.image,
                channels: m.channels,
                num_classes: m.num_classes,
                clip_norm: m.clip_norm,
                flops_fwd_per_example: m.fwd_flops_per_example(),
                init_params: format!("{}_init.synthetic", m.name),
                executables,
                layers: m.layers.clone(),
            };
            models.insert(m.name.to_string(), meta);
        }
        Manifest { version: 2, seed, models }
    }

    fn spec(&self, prep: &Prepared) -> Result<Arc<RefExec>> {
        // The compile cache is append-only, so a lock poisoned by a
        // panicking worker still holds a consistent map — recover it.
        self.cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .get_cached(&prep.key)
            .ok_or_else(|| anyhow!("executable {} was not prepared", prep.key))
    }

    fn check_model_vectors(meta: &ModelMeta, params: &Tensor, acc: Option<&Tensor>) -> Result<()> {
        if params.len() != meta.n_params {
            return Err(anyhow!(
                "params length {} != n_params {}",
                params.len(),
                meta.n_params
            ));
        }
        if let Some(acc) = acc {
            if acc.len() != meta.n_params {
                return Err(anyhow!(
                    "acc length {} != n_params {}",
                    acc.len(),
                    meta.n_params
                ));
            }
        }
        Ok(())
    }

    fn check_batch(meta: &ModelMeta, x: &[f32], y: &[i32]) -> Result<()> {
        let d = image_dim(meta);
        if x.len() != y.len() * d {
            return Err(anyhow!(
                "x length {} != batch {} * image dim {}",
                x.len(),
                y.len(),
                d
            ));
        }
        for &yi in y {
            if yi < 0 || yi as usize >= meta.num_classes {
                return Err(anyhow!(
                    "label {yi} out of range for {} classes",
                    meta.num_classes
                ));
            }
        }
        Ok(())
    }
}

fn image_dim(meta: &ModelMeta) -> usize {
    meta.image * meta.image * meta.channels
}

// The former local `dot` / `axpy` / `dense_forward` / `gram_sq` inner
// kernels now live in [`super::kernels`] (`dot` / `axpy` / `matvec` /
// `matvec_t` / `gram_sq`), dispatched on the backend's [`Kernel`] —
// the scalar path is byte-for-byte the old arithmetic, and the SIMD
// paths are pinned bitwise against it (DESIGN.md §14).

/// Stable log-sum-exp of the logits.
fn logsumexp(lg: &[f32]) -> f32 {
    let max = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = lg.iter().map(|&l| (l - max).exp()).sum();
    max + z.ln()
}

/// Layernorm epsilon (matches `python/compile/vit.py`).
const EPS_LN: f32 = 1e-6;

/// Resolved conv2d geometry (channels-first, floor output size).
#[derive(Clone, Copy)]
struct ConvGeo {
    c_in: usize,
    h_in: usize,
    w_in: usize,
    c_out: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
}

impl ConvGeo {
    fn of(kind: LayerKind) -> Self {
        let LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad } = kind else {
            unreachable!("ConvGeo::of on a non-conv layer")
        };
        let ho = conv_out(h_in, kh, stride, pad);
        let wo = conv_out(w_in, kw, stride, pad);
        Self { c_in, h_in, w_in, c_out, kh, kw, stride, pad, ho, wo }
    }

    /// im2col patch width `c_in * kh * kw`.
    fn patch(&self) -> usize {
        self.c_in * self.kh * self.kw
    }

    /// Spatial output positions `ho * wo` (the ghost token count).
    fn t(&self) -> usize {
        self.ho * self.wo
    }
}

/// conv2d forward: `out[c, oy, ox] = b[c] + Σ K[c, ·] * patch(oy, ox)`,
/// channels-first, zero padding, fixed `(c_in, ky, kx)` addition order.
fn conv_forward(out: &mut [f32], k: &[f32], bias: &[f32], a_in: &[f32], g: ConvGeo) {
    let (kp, hw) = (g.kh * g.kw, g.h_in * g.w_in);
    for c in 0..g.c_out {
        let krow = &k[c * g.patch()..(c + 1) * g.patch()];
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                let mut acc = bias[c];
                for cc in 0..g.c_in {
                    for ky in 0..g.kh {
                        let iy = oy * g.stride + ky;
                        if iy < g.pad || iy - g.pad >= g.h_in {
                            continue;
                        }
                        let iy = iy - g.pad;
                        for kx in 0..g.kw {
                            let ix = ox * g.stride + kx;
                            if ix < g.pad || ix - g.pad >= g.w_in {
                                continue;
                            }
                            let ix = ix - g.pad;
                            acc += krow[cc * kp + ky * g.kw + kx]
                                * a_in[cc * hw + iy * g.w_in + ix];
                        }
                    }
                }
                out[c * g.t() + oy * g.wo + ox] = acc;
            }
        }
    }
}

/// conv2d input gradient: scatter `dz[c, s] * K[c, ·]` back onto the
/// (pre-zeroed) input window — the transpose of [`conv_forward`].
fn conv_input_grad(da: &mut [f32], k: &[f32], dz_l: &[f32], g: ConvGeo) {
    let (kp, hw) = (g.kh * g.kw, g.h_in * g.w_in);
    da.fill(0.0);
    for c in 0..g.c_out {
        let krow = &k[c * g.patch()..(c + 1) * g.patch()];
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                let gv = dz_l[c * g.t() + oy * g.wo + ox];
                for cc in 0..g.c_in {
                    for ky in 0..g.kh {
                        let iy = oy * g.stride + ky;
                        if iy < g.pad || iy - g.pad >= g.h_in {
                            continue;
                        }
                        let iy = iy - g.pad;
                        for kx in 0..g.kw {
                            let ix = ox * g.stride + kx;
                            if ix < g.pad || ix - g.pad >= g.w_in {
                                continue;
                            }
                            let ix = ix - g.pad;
                            da[cc * hw + iy * g.w_in + ix] += gv * krow[cc * kp + ky * g.kw + kx];
                        }
                    }
                }
            }
        }
    }
}

/// conv2d ghost norm: unfold the input into im2col patches `[t, patch]`
/// and transpose dz to `[t, c_out]` (both in `scratch`), then the Gram
/// product ([`kernels::gram_sq`]) — `‖dK‖² + ‖db‖²` exactly
/// (DESIGN.md §13).
fn conv_norm_sq(kn: Kernel, a_in: &[f32], dz_l: &[f32], g: ConvGeo, scratch: &mut [f32]) -> f32 {
    let (kp, hw, pw) = (g.kh * g.kw, g.h_in * g.w_in, g.patch());
    let (patches, rest) = scratch.split_at_mut(g.t() * pw);
    let dzt = &mut rest[..g.t() * g.c_out];
    patches.fill(0.0);
    for oy in 0..g.ho {
        for ox in 0..g.wo {
            let row = &mut patches[(oy * g.wo + ox) * pw..(oy * g.wo + ox + 1) * pw];
            for cc in 0..g.c_in {
                for ky in 0..g.kh {
                    let iy = oy * g.stride + ky;
                    if iy < g.pad || iy - g.pad >= g.h_in {
                        continue;
                    }
                    let iy = iy - g.pad;
                    for kx in 0..g.kw {
                        let ix = ox * g.stride + kx;
                        if ix < g.pad || ix - g.pad >= g.w_in {
                            continue;
                        }
                        let ix = ix - g.pad;
                        row[cc * kp + ky * g.kw + kx] = a_in[cc * hw + iy * g.w_in + ix];
                    }
                }
            }
        }
    }
    for c in 0..g.c_out {
        for s in 0..g.t() {
            dzt[s * g.c_out + c] = dz_l[c * g.t() + s];
        }
    }
    kernels::gram_sq(kn, patches, pw, dzt, g.c_out, g.t())
}

/// layernorm forward: whole-vector mean/variance, `xhat` and `rstd`
/// onto the tape extras, `out = gamma ∘ xhat + beta`.
fn ln_forward(out: &mut [f32], gamma: &[f32], beta: &[f32], a_in: &[f32], ext: &mut [f32]) {
    let d = a_in.len();
    let mut mu = 0.0f32;
    for &v in a_in {
        mu += v;
    }
    let mu = mu / d as f32;
    let mut var = 0.0f32;
    for &v in a_in {
        let c = v - mu;
        var += c * c;
    }
    let var = var / d as f32;
    let rstd = 1.0 / (var + EPS_LN).sqrt();
    let (xhat, rest) = ext.split_at_mut(d);
    rest[0] = rstd;
    for (xh, &v) in xhat.iter_mut().zip(a_in) {
        *xh = (v - mu) * rstd;
    }
    for (j, o) in out.iter_mut().enumerate() {
        *o = xhat[j] * gamma[j] + beta[j];
    }
}

/// layernorm input gradient:
/// `dx = rstd * (dxhat − mean(dxhat) − xhat * mean(dxhat ∘ xhat))`
/// with `dxhat = dout ∘ gamma`.
fn ln_input_grad(da: &mut [f32], gamma: &[f32], xhat: &[f32], rstd: f32, dout: &[f32]) {
    let d = dout.len();
    let (mut m1, mut m2) = (0.0f32, 0.0f32);
    for j in 0..d {
        let dxh = dout[j] * gamma[j];
        m1 += dxh;
        m2 += dxh * xhat[j];
    }
    let m1 = m1 / d as f32;
    let m2 = m2 / d as f32;
    for (j, dv) in da.iter_mut().enumerate() {
        *dv = rstd * (dout[j] * gamma[j] - m1 - xhat[j] * m2);
    }
}

/// Attention parameter block views
/// `[Wq | bq | Wk | bk | Wv | bv | Wo | bo]` (shapes in the
/// `runtime/layers.rs` module docs).
struct AttnParams<'a> {
    wq: &'a [f32],
    bq: &'a [f32],
    wk: &'a [f32],
    bk: &'a [f32],
    wv: &'a [f32],
    bv: &'a [f32],
    wo: &'a [f32],
    bo: &'a [f32],
}

fn attn_params(p: &[f32], d: usize, dh: usize) -> AttnParams<'_> {
    let (wq, p) = p.split_at(dh * d);
    let (bq, p) = p.split_at(dh);
    let (wk, p) = p.split_at(dh * d);
    let (bk, p) = p.split_at(dh);
    let (wv, p) = p.split_at(dh * d);
    let (bv, p) = p.split_at(dh);
    let (wo, p) = p.split_at(d * dh);
    let (bo, _) = p.split_at(d);
    AttnParams { wq, bq, wk, bk, wv, bv, wo, bo }
}

/// Single-head scaled-dot-product attention forward over `[t, d]`
/// tokens: `q/k/v = X W^T + b`, row-max-subtracted softmax of
/// `q k^T / √dh`, `ctx = A v`, `out = ctx Wo^T + bo`. The intermediates
/// (`q, k, v, A, ctx`) land in `ext` — the tape extras in accum, a
/// scratch buffer in eval.
fn attn_forward(
    kn: Kernel,
    out: &mut [f32],
    p: &[f32],
    a_in: &[f32],
    ext: &mut [f32],
    t: usize,
    dh: usize,
) {
    let d = a_in.len() / t;
    let AttnParams { wq, bq, wk, bk, wv, bv, wo, bo } = attn_params(p, d, dh);
    let (q, ext) = ext.split_at_mut(t * dh);
    let (k, ext) = ext.split_at_mut(t * dh);
    let (v, ext) = ext.split_at_mut(t * dh);
    let (probs, ext) = ext.split_at_mut(t * t);
    let ctx = &mut ext[..t * dh];
    for s in 0..t {
        let xs = &a_in[s * d..(s + 1) * d];
        kernels::matvec(kn, &mut q[s * dh..(s + 1) * dh], wq, bq, xs);
        kernels::matvec(kn, &mut k[s * dh..(s + 1) * dh], wk, bk, xs);
        kernels::matvec(kn, &mut v[s * dh..(s + 1) * dh], wv, bv, xs);
    }
    let inv = 1.0 / (dh as f32).sqrt();
    for s in 0..t {
        let qs = &q[s * dh..(s + 1) * dh];
        let row = &mut probs[s * t..(s + 1) * t];
        for (u, slot) in row.iter_mut().enumerate() {
            *slot = kernels::dot(kn, qs, &k[u * dh..(u + 1) * dh]) * inv;
        }
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for val in row.iter_mut() {
            *val = (*val - max).exp();
            z += *val;
        }
        for val in row.iter_mut() {
            *val /= z;
        }
    }
    // `ctx_s = Σ_u A[s, u] v_u` is the matvec-transpose fold over the
    // value rows (bit-identical to the former sequential axpy chain).
    for s in 0..t {
        let cs = &mut ctx[s * dh..(s + 1) * dh];
        cs.fill(0.0);
        kernels::matvec_t(kn, cs, v, &probs[s * t..(s + 1) * t]);
    }
    for s in 0..t {
        kernels::matvec(kn, &mut out[s * d..(s + 1) * d], wo, bo, &ctx[s * dh..(s + 1) * dh]);
    }
}

/// Attention backward through the softmax: fills the dz extras
/// `dq/dk/dv/dctx` from `dout` and the taped `q/k/v/A/ctx` (phase 2
/// folds them into the q/k/v/o parameter gradients; the norm and the
/// input gradient read them too). `scratch` holds one `[t]` row.
fn attn_backward(
    kn: Kernel,
    p: &[f32],
    spec: LayerSpec,
    tape_ext: &[f32],
    dout: &[f32],
    dz_ext: &mut [f32],
    scratch: &mut [f32],
) {
    let LayerKind::Attention { t, d_model: d, d_head: dh } = spec.kind else {
        unreachable!("attn_backward on a non-attention layer")
    };
    let wo = attn_params(p, d, dh).wo;
    let (q, rest) = tape_ext.split_at(t * dh);
    let (k, rest) = rest.split_at(t * dh);
    let (v, rest) = rest.split_at(t * dh);
    let (probs, _) = rest.split_at(t * t);
    let (dq, rest) = dz_ext.split_at_mut(t * dh);
    let (dk, rest) = rest.split_at_mut(t * dh);
    let (dv, dctx) = rest.split_at_mut(t * dh);
    // dctx_s = Wo^T dout_s — the matvec-transpose fold over Wo rows.
    for s in 0..t {
        let dcs = &mut dctx[s * dh..(s + 1) * dh];
        dcs.fill(0.0);
        kernels::matvec_t(kn, dcs, wo, &dout[s * d..(s + 1) * d]);
    }
    // dv_u = Σ_s A[s, u] dctx_s (fixed s-major order; destinations are
    // scattered across u, so this stays a per-row axpy).
    dv.fill(0.0);
    for s in 0..t {
        let dcs = &dctx[s * dh..(s + 1) * dh];
        for u in 0..t {
            kernels::axpy(kn, &mut dv[u * dh..(u + 1) * dh], dcs, probs[s * t + u]);
        }
    }
    // Softmax backward per row: dA = dctx v^T, ds = A ∘ (dA − Σ A∘dA),
    // dq_s = (1/√dh) ds k, dk_u += (1/√dh) ds^T q.
    let inv = 1.0 / (dh as f32).sqrt();
    dk.fill(0.0);
    let da_row = &mut scratch[..t];
    for s in 0..t {
        let dcs = &dctx[s * dh..(s + 1) * dh];
        let arow = &probs[s * t..(s + 1) * t];
        for (u, slot) in da_row.iter_mut().enumerate() {
            *slot = kernels::dot(kn, dcs, &v[u * dh..(u + 1) * dh]);
        }
        let mut rowsum = 0.0f32;
        for u in 0..t {
            rowsum += arow[u] * da_row[u];
        }
        let dqs = &mut dq[s * dh..(s + 1) * dh];
        dqs.fill(0.0);
        let qs = &q[s * dh..(s + 1) * dh];
        for u in 0..t {
            let dsu = arow[u] * (da_row[u] - rowsum);
            kernels::axpy(kn, dqs, &k[u * dh..(u + 1) * dh], dsu);
            kernels::axpy(kn, &mut dk[u * dh..(u + 1) * dh], qs, dsu);
        }
        for x in dqs.iter_mut() {
            *x *= inv;
        }
    }
    for x in dk.iter_mut() {
        *x *= inv;
    }
}

/// Attention input gradient `dX = dq Wq + dk Wk + dv Wv` (from the
/// already-filled dz extras).
fn attn_input_grad(kn: Kernel, da: &mut [f32], p: &[f32], spec: LayerSpec, dz_ext: &[f32]) {
    let LayerKind::Attention { t, d_model: d, d_head: dh } = spec.kind else {
        unreachable!("attn_input_grad on a non-attention layer")
    };
    let AttnParams { wq, wk, wv, .. } = attn_params(p, d, dh);
    let (dq, rest) = dz_ext.split_at(t * dh);
    let (dk, rest) = rest.split_at(t * dh);
    let (dv, _) = rest.split_at(t * dh);
    da.fill(0.0);
    for s in 0..t {
        let das = &mut da[s * d..(s + 1) * d];
        kernels::matvec_t(kn, das, wq, &dq[s * dh..(s + 1) * dh]);
        kernels::matvec_t(kn, das, wk, &dk[s * dh..(s + 1) * dh]);
        kernels::matvec_t(kn, das, wv, &dv[s * dh..(s + 1) * dh]);
    }
}

/// One layer's forward, dispatched by kind, with the ReLU applied to
/// `out` in place — the arithmetic shared bit-for-bit by the accum tape
/// and the eval pass. `ext` receives the kind's forward intermediates
/// ([`tape_extras`] floats: the tape in accum, scratch in eval).
fn layer_forward(
    kn: Kernel,
    pl: &PlannedLayer,
    params: &[f32],
    a_in: &[f32],
    out: &mut [f32],
    ext: &mut [f32],
) {
    let spec = pl.spec;
    match spec.kind {
        LayerKind::Dense => {
            let w = &params[pl.w_off..pl.w_off + spec.d_in * spec.d_out];
            let bias = &params[pl.b_off..pl.b_off + spec.d_out];
            kernels::matvec(kn, out, w, bias, a_in);
        }
        LayerKind::Conv2d { .. } => {
            let g = ConvGeo::of(spec.kind);
            let k = &params[pl.w_off..pl.w_off + g.c_out * g.patch()];
            let bias = &params[pl.b_off..pl.b_off + g.c_out];
            conv_forward(out, k, bias, a_in, g);
        }
        LayerKind::LayerNorm => {
            let gamma = &params[pl.w_off..pl.w_off + spec.d_out];
            let beta = &params[pl.b_off..pl.b_off + spec.d_out];
            ln_forward(out, gamma, beta, a_in, ext);
        }
        LayerKind::Attention { t, d_head, .. } => {
            let p = &params[pl.w_off..pl.w_off + spec.params()];
            attn_forward(kn, out, p, a_in, ext, t, d_head);
        }
    }
    if spec.activation == Activation::Relu {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// Read-only inputs shared by every accum kernel worker.
#[derive(Clone, Copy)]
struct AccumCtx<'a> {
    plan: &'a LayerPlan,
    /// Inner-loop kernel (the backend's, resolved at construction).
    kernel: Kernel,
    nonprivate: bool,
    clip_norm: f32,
    params: &'a [f32],
    x: &'a [f32],
    y: &'a [i32],
    mask: &'a [f32],
}

/// One phase-1 worker's disjoint output windows (local index 0 =
/// example `start`) plus its private backward scratch
/// ([`LayerPlan::bwd_scratch`] floats).
struct AccumPart<'p> {
    start: usize,
    dz: &'p mut [f32],
    tape: &'p mut [f32],
    scale: &'p mut [f32],
    losses: &'p mut [f32],
    sq_norms: &'p mut [f32],
    scratch: &'p mut [f32],
}

/// Accum phase 1: for the examples of one partition (`part.start`
/// onward, one slot per element of `part.scale`), run the layered
/// forward (hidden activations + kind extras onto the tape, head logits
/// into the dz slot), transform the logits into dz (softmax − onehot)
/// with the unmasked loss, then backpropagate dz through every layer
/// (each kind's input-gradient rule + the ReLU mask, attention filling
/// its dz extras first) while accumulating the per-layer Gram-form
/// squared norms into the **global** per-example norm, and finally the
/// accumulate scale. Examples are independent — this is the
/// parallel-over-examples section.
fn accum_examples(ctx: AccumCtx<'_>, part: AccumPart<'_>) {
    let AccumPart { start, dz, tape, scale, losses, sq_norms, scratch } = part;
    let plan = ctx.plan;
    let kn = ctx.kernel;
    let d = plan.input_dim;
    let ts = plan.tape_stride;
    let dzs = plan.dz_stride;
    let nlayers = plan.layers.len();
    for k in 0..scale.len() {
        let i = start + k;
        let xi = &ctx.x[i * d..(i + 1) * d];
        let tape_w = &mut tape[k * ts..(k + 1) * ts];
        let dz_w = &mut dz[k * dzs..(k + 1) * dzs];

        // Forward: hidden layers write (post-activation output +
        // extras) onto the tape; the head writes its logits into its
        // dz slot, where the softmax transform below turns them into
        // dz in place.
        for l in 0..nlayers {
            let pl = plan.layers[l];
            let (d_in, d_out) = (pl.spec.d_in, pl.spec.d_out);
            if l + 1 == nlayers {
                let a_in: &[f32] = if l == 0 {
                    xi
                } else {
                    &tape_w[plan.layers[l - 1].act_off..][..d_in]
                };
                let out = &mut dz_w[pl.dz_off..pl.dz_off + d_out];
                layer_forward(kn, &pl, ctx.params, a_in, out, &mut []);
            } else {
                let (lo, hi) = tape_w.split_at_mut(pl.act_off);
                let a_in: &[f32] = if l == 0 {
                    xi
                } else {
                    &lo[plan.layers[l - 1].act_off..][..d_in]
                };
                let (out, rest) = hi.split_at_mut(d_out);
                let ext = &mut rest[..tape_extras(&pl.spec)];
                layer_forward(kn, &pl, ctx.params, a_in, out, ext);
            }
        }

        // Head: softmax − onehot in place over the logits, plus the
        // unmasked loss (identical arithmetic to the eval path's
        // logsumexp).
        let head = plan.layers[nlayers - 1];
        let dl = &mut dz_w[head.dz_off..head.dz_off + head.spec.d_out];
        let yi = ctx.y[i] as usize;
        let ly = dl[yi];
        let max = dl.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in dl.iter_mut() {
            *v = (*v - max).exp();
            z += *v;
        }
        losses[k] = max + z.ln() - ly;
        for v in dl.iter_mut() {
            *v /= z;
        }
        dl[yi] -= 1.0;

        // Backward: per-layer Gram norms into the global per-example
        // norm, and dz for the next layer down via each kind's
        // input-gradient rule (ReLU-masked). Attention fills its dz
        // extras (dq/dk/dv/dctx) first — unconditionally, because
        // phase 2 folds them into parameter gradients even when the
        // nonprivate variant skips the norm.
        let mut sq_total = 0.0f32;
        for l in (0..nlayers).rev() {
            let pl = plan.layers[l];
            let (d_in, d_out) = (pl.spec.d_in, pl.spec.d_out);
            if let LayerKind::Attention { .. } = pl.spec.kind {
                let p = &ctx.params[pl.w_off..pl.w_off + pl.spec.params()];
                let tape_ext = &tape_w[pl.ext_off..pl.ext_off + tape_extras(&pl.spec)];
                let (lo, hi) = dz_w.split_at_mut(pl.dz_ext_off);
                let dout = &lo[pl.dz_off..pl.dz_off + d_out];
                let dz_ext = &mut hi[..dz_extras(&pl.spec)];
                attn_backward(kn, p, pl.spec, tape_ext, dout, dz_ext, scratch);
            }
            if !ctx.nonprivate {
                let a_in: &[f32] = if l == 0 {
                    xi
                } else {
                    &tape_w[plan.layers[l - 1].act_off..][..d_in]
                };
                let dz_l = &dz_w[pl.dz_off..pl.dz_off + d_out];
                match pl.spec.kind {
                    LayerKind::Dense => {
                        let dlsq = kernels::dot(kn, dz_l, dz_l);
                        let asq = kernels::dot(kn, a_in, a_in);
                        sq_total += dlsq * (asq + 1.0);
                    }
                    LayerKind::Conv2d { .. } => {
                        let g = ConvGeo::of(pl.spec.kind);
                        sq_total += conv_norm_sq(kn, a_in, dz_l, g, scratch);
                    }
                    LayerKind::LayerNorm => {
                        // ‖dγ‖² + ‖dβ‖² = Σ (dout·xhat)² + dout².
                        let xhat = &tape_w[pl.ext_off..pl.ext_off + d_out];
                        let mut s = 0.0f32;
                        for (&dv, &xv) in dz_l.iter().zip(xhat) {
                            let gj = dv * xv;
                            s += gj * gj + dv * dv;
                        }
                        sq_total += s;
                    }
                    LayerKind::Attention { t, d_model, d_head } => {
                        // One Gram per projection: q/k/v against the
                        // input tokens, o against the context rows.
                        let tdh = t * d_head;
                        let ext = &dz_w[pl.dz_ext_off..pl.dz_ext_off + 4 * tdh];
                        let ctx_rows =
                            &tape_w[pl.ext_off + 3 * tdh + t * t..pl.ext_off + 4 * tdh + t * t];
                        sq_total += kernels::gram_sq(kn, a_in, d_model, &ext[..tdh], d_head, t);
                        sq_total +=
                            kernels::gram_sq(kn, a_in, d_model, &ext[tdh..2 * tdh], d_head, t);
                        sq_total +=
                            kernels::gram_sq(kn, a_in, d_model, &ext[2 * tdh..3 * tdh], d_head, t);
                        sq_total += kernels::gram_sq(kn, ctx_rows, d_head, dz_l, d_model, t);
                    }
                }
            }
            if l > 0 {
                let prev = plan.layers[l - 1];
                let (lo, hi) = dz_w.split_at_mut(pl.dz_off);
                let dz_l = &hi[..d_out];
                let da = &mut lo[prev.dz_off..prev.dz_off + prev.spec.d_out];
                match pl.spec.kind {
                    LayerKind::Dense => {
                        da.fill(0.0);
                        let w = &ctx.params[pl.w_off..pl.w_off + d_in * d_out];
                        kernels::matvec_t(kn, da, w, dz_l);
                    }
                    LayerKind::Conv2d { .. } => {
                        let g = ConvGeo::of(pl.spec.kind);
                        let kern = &ctx.params[pl.w_off..pl.w_off + g.c_out * g.patch()];
                        conv_input_grad(da, kern, dz_l, g);
                    }
                    LayerKind::LayerNorm => {
                        let gamma = &ctx.params[pl.w_off..pl.w_off + d_out];
                        let xhat = &tape_w[pl.ext_off..pl.ext_off + d_out];
                        let rstd = tape_w[pl.ext_off + d_out];
                        ln_input_grad(da, gamma, xhat, rstd, dz_l);
                    }
                    LayerKind::Attention { .. } => {
                        let p = &ctx.params[pl.w_off..pl.w_off + pl.spec.params()];
                        let dz_ext = &hi[d_out..d_out + dz_extras(&pl.spec)];
                        attn_input_grad(kn, da, p, pl.spec, dz_ext);
                    }
                }
                if prev.spec.activation == Activation::Relu {
                    let a_prev = &tape_w[prev.act_off..prev.act_off + prev.spec.d_out];
                    for (dv, &av) in da.iter_mut().zip(a_prev) {
                        if av <= 0.0 {
                            *dv = 0.0;
                        }
                    }
                }
            }
        }

        if ctx.nonprivate {
            // Batched-gradient baseline: no clipping, norms reported
            // as zeros (matching `_accum_nonprivate` in model.py).
            sq_norms[k] = 0.0;
            scale[k] = ctx.mask[i];
        } else {
            sq_norms[k] = sq_total;
            let norm = sq_total.max(0.0).sqrt().max(1e-12);
            scale[k] = (ctx.clip_norm / norm).min(1.0) * ctx.mask[i];
        }
    }
}

/// Where a phase-2 unit reads its `a` tokens: the batch input or a
/// per-example tape offset.
#[derive(Clone, Copy)]
enum ASrc {
    /// The batch input `x` (layer 0).
    Batch,
    /// A per-example tape window offset (a hidden output, or attention
    /// context rows).
    Tape(usize),
}

/// The per-kind shape of a phase-2 work unit.
#[derive(Clone, Copy)]
enum UnitKind {
    /// One dense output row: `contrib = dz[row] * a` at t = 1 — the
    /// seed-exact arithmetic (`g = sc·dz`, then the fused `axpy` /
    /// materialized copy-then-add fold).
    Dense { d_in: usize, a: ASrc, dz_idx: usize },
    /// One conv2d output channel: its K row + bias, the contribution
    /// summed over spatial positions in row-major order (the position
    /// sum is computed once, in `contrib`, so fused and materialized
    /// add bit-identical addends).
    ConvChannel { geo: ConvGeo, a: ASrc, dz_off: usize, channel: usize },
    /// One token-matrix projection row (attention q/k/v/o):
    /// `contrib[c] = Σ_s g[s]·a[s, c]` over `[t, width]` token rows,
    /// `g[s]` strided out of the dz window.
    TokenRow { t: usize, width: usize, a: ASrc, g_off: usize, g_stride: usize },
    /// The layernorm gamma block: `contrib_j = dout_j · xhat_j`.
    LnGamma { d: usize, dz_off: usize, xhat_off: usize },
    /// The layernorm beta block: `contrib_j = dout_j`.
    LnBeta { d: usize, dz_off: usize },
}

/// One phase-2 work unit: a weight-like slice of the accumulator (plus
/// its bias slot, when the kind has one) and everything needed to
/// locate its inputs per example. Units partition the accumulator
/// disjointly, so threads own non-overlapping `&mut` slices.
struct RowUnit<'a> {
    kind: UnitKind,
    /// Inner-loop cost (partitioning weight).
    cost: usize,
    /// Fused ghost-style accumulate (vs materialize-then-add).
    fused: bool,
    /// This unit's weight slice of the accumulator.
    w: &'a mut [f32],
    /// This unit's bias slot of the accumulator (layernorm has none —
    /// gamma and beta are both weight-like blocks).
    b: Option<&'a mut f32>,
}

/// Decompose the flat accumulator into [`RowUnit`]s in layout order
/// (layer-major, then per-kind: dense/conv output rows, attention
/// q/k/v/o projection rows, layernorm gamma + beta).
fn build_row_units<'a>(
    plan: &LayerPlan,
    fused: &[bool],
    acc: &'a mut [f32],
) -> Vec<RowUnit<'a>> {
    let mut units = Vec::with_capacity(plan.total_rows());
    let mut rest: &'a mut [f32] = acc;
    for (l, pl) in plan.layers.iter().enumerate() {
        let (d_in, d_out) = (pl.spec.d_in, pl.spec.d_out);
        let a = if l == 0 { ASrc::Batch } else { ASrc::Tape(plan.layers[l - 1].act_off) };
        match pl.spec.kind {
            LayerKind::Dense => {
                let (w_region, tail) = rest.split_at_mut(d_in * d_out);
                let (b_region, tail) = tail.split_at_mut(d_out);
                rest = tail;
                for ((r, w), b) in
                    w_region.chunks_mut(d_in).enumerate().zip(b_region.iter_mut())
                {
                    units.push(RowUnit {
                        kind: UnitKind::Dense { d_in, a, dz_idx: pl.dz_off + r },
                        cost: d_in + 1,
                        fused: fused[l],
                        w,
                        b: Some(b),
                    });
                }
            }
            LayerKind::Conv2d { .. } => {
                let geo = ConvGeo::of(pl.spec.kind);
                let (w_region, tail) = rest.split_at_mut(geo.c_out * geo.patch());
                let (b_region, tail) = tail.split_at_mut(geo.c_out);
                rest = tail;
                for ((channel, w), b) in
                    w_region.chunks_mut(geo.patch()).enumerate().zip(b_region.iter_mut())
                {
                    units.push(RowUnit {
                        kind: UnitKind::ConvChannel { geo, a, dz_off: pl.dz_off, channel },
                        cost: geo.t() * geo.patch() + 1,
                        fused: fused[l],
                        w,
                        b: Some(b),
                    });
                }
            }
            LayerKind::LayerNorm => {
                let (gamma, tail) = rest.split_at_mut(d_out);
                let (beta, tail) = tail.split_at_mut(d_out);
                rest = tail;
                units.push(RowUnit {
                    kind: UnitKind::LnGamma {
                        d: d_out,
                        dz_off: pl.dz_off,
                        xhat_off: pl.ext_off,
                    },
                    cost: d_out + 1,
                    fused: fused[l],
                    w: gamma,
                    b: None,
                });
                units.push(RowUnit {
                    kind: UnitKind::LnBeta { d: d_out, dz_off: pl.dz_off },
                    cost: d_out + 1,
                    fused: fused[l],
                    w: beta,
                    b: None,
                });
            }
            LayerKind::Attention { t, d_model, d_head } => {
                let tdh = t * d_head;
                // q/k/v projections: rows read the input tokens and the
                // matching dz-extras column.
                for grp in 0..3 {
                    let (w_region, tail) = rest.split_at_mut(d_head * d_model);
                    let (b_region, tail) = tail.split_at_mut(d_head);
                    rest = tail;
                    let g_base = pl.dz_ext_off + grp * tdh;
                    for ((j, w), b) in
                        w_region.chunks_mut(d_model).enumerate().zip(b_region.iter_mut())
                    {
                        units.push(RowUnit {
                            kind: UnitKind::TokenRow {
                                t,
                                width: d_model,
                                a,
                                g_off: g_base + j,
                                g_stride: d_head,
                            },
                            cost: t * d_model + 1,
                            fused: fused[l],
                            w,
                            b: Some(b),
                        });
                    }
                }
                // Wo: rows read the taped context and the dout column.
                let ctx_off = pl.ext_off + 3 * tdh + t * t;
                let (w_region, tail) = rest.split_at_mut(d_model * d_head);
                let (b_region, tail) = tail.split_at_mut(d_model);
                rest = tail;
                for ((j, w), b) in
                    w_region.chunks_mut(d_head).enumerate().zip(b_region.iter_mut())
                {
                    units.push(RowUnit {
                        kind: UnitKind::TokenRow {
                            t,
                            width: d_head,
                            a: ASrc::Tape(ctx_off),
                            g_off: pl.dz_off + j,
                            g_stride: d_model,
                        },
                        cost: t * d_head + 1,
                        fused: fused[l],
                        w,
                        b: Some(b),
                    });
                }
            }
        }
    }
    units
}

/// Fold one example's (unscaled) contribution row into the accumulator:
/// fused adds `sc * contrib` in place; materialized writes the scaled
/// row first (the Opacus-style memory traffic) and then adds the
/// bit-identical addends — same bits either way, by construction.
#[inline]
fn fold_row(kn: Kernel, w: &mut [f32], contrib: &[f32], sc: f32, fused: bool, m_row: &mut [f32]) {
    if fused {
        kernels::axpy(kn, w, contrib, sc);
    } else {
        let m = &mut m_row[..contrib.len()];
        for (mv, &cv) in m.iter_mut().zip(contrib) {
            *mv = sc * cv;
        }
        for (wv, &mv) in w.iter_mut().zip(m.iter()) {
            *wv += mv;
        }
    }
}

/// Accum phase 2: fold every live example's contribution into each row
/// unit of one partition, scanning examples in batch order. Parallelism
/// partitions *units* (accumulator coordinates), never examples, so
/// every coordinate sees the exact addition chain of a sequential
/// per-example run — for any thread count and any physical chunking of
/// the same example stream (Algorithm-2 padding neutrality stays
/// bitwise-exact). Units with a position sum (conv channels, attention
/// projection rows) compute the canonical contribution once, into
/// `contrib`, so the fused and materialized branches add the same bits.
fn accum_update(
    ctx: AccumCtx<'_>,
    units: &mut [RowUnit<'_>],
    dz: &[f32],
    tape: &[f32],
    scale: &[f32],
    m_row: &mut [f32],
    contrib: &mut [f32],
) {
    let kn = ctx.kernel;
    let d = ctx.plan.input_dim;
    let ts = ctx.plan.tape_stride;
    let dzs = ctx.plan.dz_stride;
    debug_assert!(m_row.len() >= ctx.plan.max_unit_width);
    debug_assert!(contrib.len() >= ctx.plan.max_unit_width);
    for (i, &sc) in scale.iter().enumerate() {
        if sc == 0.0 {
            continue;
        }
        let xi = &ctx.x[i * d..(i + 1) * d];
        let tape_w = &tape[i * ts..(i + 1) * ts];
        let dz_w = &dz[i * dzs..(i + 1) * dzs];
        let resolve = |a: ASrc, len: usize| -> &[f32] {
            match a {
                ASrc::Batch => xi,
                ASrc::Tape(off) => &tape_w[off..off + len],
            }
        };
        for u in units.iter_mut() {
            match u.kind {
                UnitKind::Dense { d_in, a, dz_idx } => {
                    let a_in = resolve(a, d_in);
                    let g = sc * dz_w[dz_idx];
                    fold_row(kn, u.w, a_in, g, u.fused, m_row);
                    if let Some(b) = u.b.as_deref_mut() {
                        *b += g;
                    }
                }
                UnitKind::ConvChannel { geo, a, dz_off, channel } => {
                    let (kp, hw) = (geo.kh * geo.kw, geo.h_in * geo.w_in);
                    let a_in = resolve(a, geo.c_in * hw);
                    let c = &mut contrib[..geo.patch()];
                    c.fill(0.0);
                    let mut gb = 0.0f32;
                    for oy in 0..geo.ho {
                        for ox in 0..geo.wo {
                            let g = dz_w[dz_off + channel * geo.t() + oy * geo.wo + ox];
                            gb += g;
                            for cc in 0..geo.c_in {
                                for ky in 0..geo.kh {
                                    let iy = oy * geo.stride + ky;
                                    if iy < geo.pad || iy - geo.pad >= geo.h_in {
                                        continue;
                                    }
                                    let iy = iy - geo.pad;
                                    for kx in 0..geo.kw {
                                        let ix = ox * geo.stride + kx;
                                        if ix < geo.pad || ix - geo.pad >= geo.w_in {
                                            continue;
                                        }
                                        let ix = ix - geo.pad;
                                        c[cc * kp + ky * geo.kw + kx] +=
                                            g * a_in[cc * hw + iy * geo.w_in + ix];
                                    }
                                }
                            }
                        }
                    }
                    fold_row(kn, u.w, c, sc, u.fused, m_row);
                    if let Some(b) = u.b.as_deref_mut() {
                        *b += sc * gb;
                    }
                }
                UnitKind::TokenRow { t, width, a, g_off, g_stride } => {
                    let a_rows = resolve(a, t * width);
                    let c = &mut contrib[..width];
                    c.fill(0.0);
                    let mut gb = 0.0f32;
                    for s in 0..t {
                        let g = dz_w[g_off + s * g_stride];
                        gb += g;
                        kernels::axpy(kn, c, &a_rows[s * width..(s + 1) * width], g);
                    }
                    fold_row(kn, u.w, c, sc, u.fused, m_row);
                    if let Some(b) = u.b.as_deref_mut() {
                        *b += sc * gb;
                    }
                }
                UnitKind::LnGamma { d, dz_off, xhat_off } => {
                    let dout = &dz_w[dz_off..dz_off + d];
                    let xhat = &tape_w[xhat_off..xhat_off + d];
                    let c = &mut contrib[..d];
                    for (cv, (&dv, &xv)) in c.iter_mut().zip(dout.iter().zip(xhat)) {
                        *cv = dv * xv;
                    }
                    fold_row(kn, u.w, c, sc, u.fused, m_row);
                }
                UnitKind::LnBeta { d, dz_off } => {
                    let dout = &dz_w[dz_off..dz_off + d];
                    fold_row(kn, u.w, dout, sc, u.fused, m_row);
                }
            }
        }
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, _dir: &Path, meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared> {
        let spec = match exe.kind.as_str() {
            "accum" => {
                let variant = exe
                    .variant
                    .clone()
                    .ok_or_else(|| anyhow!("accum artifact {} missing variant", exe.path))?;
                let batch = exe
                    .batch
                    .ok_or_else(|| anyhow!("accum artifact {} missing batch", exe.path))?;
                let plan = LayerPlan::build(meta)?;
                let fused = executed_choices(&variant, &plan)?
                    .iter()
                    .map(|c| *c == LayerChoice::Ghost)
                    .collect();
                RefExec::Accum { variant, batch, plan, fused }
            }
            "apply" => RefExec::Apply { bf16: exe.dtype.as_deref() == Some("bf16") },
            "eval" => RefExec::Eval {
                batch: exe
                    .batch
                    .ok_or_else(|| anyhow!("eval artifact {} missing batch", exe.path))?,
                plan: LayerPlan::build(meta)?,
            },
            other => return Err(anyhow!("unknown executable kind {other:?} for {}", exe.path)),
        };
        let (_, compile_seconds) = self
            .cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .get_or_compile(&exe.path, || Ok(spec))?;
        Ok(Prepared { key: exe.path.clone(), compile_seconds })
    }

    fn is_compiled(&self, key: &str) -> bool {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).is_cached(key)
    }

    fn compile_records(&self) -> Vec<CompileRecord> {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).records().to_vec()
    }

    /// Synthesized deterministic init, laid out by the layer plan:
    /// small Gaussian weights, zero biases, drawn layer by layer from
    /// one ChaCha stream (for the single-layer model this is exactly
    /// the seed's `[W Gaussians | b zeros]` sequence).
    fn init_params(&self, _dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        let specs = meta.layer_specs();
        let n: usize = specs.iter().map(LayerSpec::params).sum();
        if n != meta.n_params {
            return Err(anyhow!(
                "layer chain lays out {n} params but the manifest says {}",
                meta.n_params
            ));
        }
        let mut rng = ChaChaRng::from_seed_stream(self.init_seed, 0, b"refinit\0");
        let mut v = Vec::with_capacity(meta.n_params);
        // One weight block = `rows * cols` scaled normals followed by
        // `rows` zero biases, drawn in flat-layout order from the single
        // b"refinit\0" stream (the dense draw order is the seed's).
        let mut block = |v: &mut Vec<f32>, rng: &mut ChaChaRng, rows: usize, cols: usize| {
            for _ in 0..rows * cols {
                v.push((0.05 * rng.next_normal()) as f32);
            }
            v.resize(v.len() + rows, 0.0);
        };
        for spec in &specs {
            match spec.kind {
                LayerKind::Dense => block(&mut v, &mut rng, spec.d_out, spec.d_in),
                LayerKind::Conv2d { c_in, c_out, kh, kw, .. } => {
                    block(&mut v, &mut rng, c_out, c_in * kh * kw);
                }
                LayerKind::LayerNorm => {
                    // gamma = 1, beta = 0: the identity normalizer.
                    v.resize(v.len() + spec.d_out, 1.0);
                    v.resize(v.len() + spec.d_out, 0.0);
                }
                LayerKind::Attention { d_model, d_head, .. } => {
                    block(&mut v, &mut rng, d_head, d_model); // Wq | bq
                    block(&mut v, &mut rng, d_head, d_model); // Wk | bk
                    block(&mut v, &mut rng, d_head, d_model); // Wv | bv
                    block(&mut v, &mut rng, d_model, d_head); // Wo | bo
                }
            }
        }
        Ok(Tensor::from_vec(v))
    }

    /// Copying accum: clone + donate, so the two forms agree bitwise by
    /// construction (the donating kernel below is the implementation).
    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumOut> {
        let mut donated = acc.clone();
        let stats = self.run_accum_into(prep, meta, params, &mut donated, args)?;
        Ok(AccumOut { acc: donated, loss_sum: stats.loss_sum, sq_norms: stats.sq_norms })
    }

    /// Copying apply: clone + donate (see `run_accum`).
    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<Tensor> {
        let mut donated = params.clone();
        self.run_apply_into(prep, meta, &mut donated, acc, args)?;
        Ok(donated)
    }

    /// Native donating accum: `acc` is updated in place through the
    /// scratch arena + deterministic-threading layered kernel described
    /// in the module docs. This is also the session hot path (the
    /// default session binds its buffers to this kernel).
    fn run_accum_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &mut Tensor,
        args: &AccumArgs<'_>,
    ) -> Result<AccumStats> {
        let spec = self.spec(prep)?;
        let (variant, batch, plan, fused) = match spec.as_ref() {
            RefExec::Accum { variant, batch, plan, fused } => {
                (variant.as_str(), *batch, plan, fused.as_slice())
            }
            _ => return Err(anyhow!("{} is not an accum executable", prep.key)),
        };
        let (x, y, mask) = (args.x, args.y, args.mask);
        let b = y.len();
        if b != batch {
            return Err(anyhow!("accum batch mismatch: executable {batch}, got {b}"));
        }
        if mask.len() != b {
            return Err(anyhow!("mask length {} != batch {b}", mask.len()));
        }
        if plan.n_params != meta.n_params {
            return Err(anyhow!(
                "executable {} was prepared for a {}-param model, got {}",
                prep.key,
                plan.n_params,
                meta.n_params
            ));
        }
        Self::check_model_vectors(meta, params, Some(acc))?;
        Self::check_batch(meta, x, y)?;

        let ctx = AccumCtx {
            plan,
            kernel: self.kernel,
            nonprivate: variant == "nonprivate",
            clip_norm: meta.clip_norm as f32,
            params: params.as_slice(),
            x,
            y,
            mask,
        };
        let (ts, dzs) = (plan.tape_stride, plan.dz_stride);
        let mut sq_norms = vec![0.0f32; b];

        // Worker count is resolved before the arena checkout so the
        // phase-1 backward scratch (`bwd`) can be sized per worker.
        let work = b * plan.macs_per_example();
        let nthreads = self.workers(work, b);
        let mut pooled = PooledScratch::take(&self.scratch);
        let AccumBuffers { dz, tape, scale, losses, bwd, m_row, contrib } =
            pooled.get().accum(b, nthreads, plan);

        // Phase 1: per-example forward tape + backward dz / losses /
        // norms / scales, parallel over fixed contiguous example
        // partitions. Partitions are cut first (handles the
        // tape_stride = 0 single-layer case cleanly), then each runs on
        // its own scoped thread with a private backward-scratch slice
        // (scratch holds transient per-example intermediates only, so
        // it moves no bits across partitions).
        if nthreads > 1 {
            let per = b.div_ceil(nthreads);
            let mut parts: Vec<AccumPart<'_>> = Vec::with_capacity(nthreads);
            {
                // Explicit reborrows: the partition cursors consume the
                // reborrow, not the bindings (which the single-thread
                // branch and the loss fold still use).
                let mut dz_rest: &mut [f32] = &mut dz[..];
                let mut tape_rest: &mut [f32] = &mut tape[..];
                let mut scale_rest: &mut [f32] = &mut scale[..];
                let mut losses_rest: &mut [f32] = &mut losses[..];
                let mut sq_rest: &mut [f32] = &mut sq_norms[..];
                let mut bwd_rest: &mut [f32] = &mut bwd[..];
                let mut start = 0usize;
                while start < b {
                    let count = per.min(b - start);
                    let (dz_c, r) = dz_rest.split_at_mut(count * dzs);
                    dz_rest = r;
                    let (tp_c, r) = tape_rest.split_at_mut(count * ts);
                    tape_rest = r;
                    let (sc_c, r) = scale_rest.split_at_mut(count);
                    scale_rest = r;
                    let (ls_c, r) = losses_rest.split_at_mut(count);
                    losses_rest = r;
                    let (sq_c, r) = sq_rest.split_at_mut(count);
                    sq_rest = r;
                    let (bw_c, r) = bwd_rest.split_at_mut(plan.bwd_scratch);
                    bwd_rest = r;
                    parts.push(AccumPart {
                        start,
                        dz: dz_c,
                        tape: tp_c,
                        scale: sc_c,
                        losses: ls_c,
                        sq_norms: sq_c,
                        scratch: bw_c,
                    });
                    start += count;
                }
            }
            std::thread::scope(|sc| {
                for part in parts {
                    sc.spawn(move || accum_examples(ctx, part));
                }
            });
        } else {
            // Explicit reborrows again: the struct field moves would
            // otherwise consume the bindings the fold and phase 2 use.
            accum_examples(
                ctx,
                AccumPart {
                    start: 0,
                    dz: &mut dz[..],
                    tape: &mut tape[..],
                    scale: &mut scale[..],
                    losses: &mut losses[..],
                    sq_norms: &mut sq_norms,
                    scratch: &mut bwd[..],
                },
            );
        }

        // Masked loss sum in example order (the sequential association).
        let mut loss_sum = 0.0f32;
        for (&ls, &m) in losses.iter().zip(mask) {
            loss_sum += m * ls;
        }

        // Phase 2: the in-place accumulator update, parallel over fixed
        // row-unit partitions (examples always scanned in order). A
        // unit's cost is ~its per-example inner-loop work, and costs
        // differ by an order of magnitude across layers (768 vs 32 on
        // mlp-small; conv channels and attention rows carry a position
        // sum on top), so partitions are cut by *cumulative cost*, not
        // unit count — equal-count chunks would hand one thread nearly
        // all the work. Cuts stay contiguous and every unit still scans
        // examples in order, so the partitioning moves wall-clock only,
        // never bits.
        let dz: &[f32] = dz;
        let tape: &[f32] = tape;
        let scale: &[f32] = scale;
        let mut units = build_row_units(plan, fused, acc.as_mut_slice());
        let t2 = self.workers(work, units.len());
        if t2 > 1 {
            let total: usize = units.iter().map(|u| u.cost).sum();
            let target = total.div_ceil(t2);
            std::thread::scope(|sc| {
                let mut rest: &mut [RowUnit<'_>] = &mut units[..];
                while !rest.is_empty() {
                    let mut cut = 0usize;
                    let mut cost = 0usize;
                    while cut < rest.len() && (cut == 0 || cost < target) {
                        cost += rest[cut].cost;
                        cut += 1;
                    }
                    let (chunk, tail) = rest.split_at_mut(cut);
                    rest = tail;
                    // Each worker checks out its own arena for the
                    // phase-2 block buffers (the pool grows to the
                    // steady-state worker count once and stays there —
                    // `memory.rs` prices exactly this).
                    sc.spawn(move || {
                        let mut pooled = PooledScratch::take(&self.scratch);
                        let (m_row, contrib) = pooled.get().blocks(ctx.plan);
                        accum_update(ctx, chunk, dz, tape, scale, m_row, contrib);
                    });
                }
            });
        } else {
            accum_update(ctx, &mut units, dz, tape, scale, m_row, contrib);
        }
        Ok(AccumStats { loss_sum, sq_norms })
    }

    /// Native donating apply: in-place SGD step with bulk ChaCha20
    /// Gaussian noise (`fill_normals` over the arena's noise buffer).
    /// The copying `run_apply` is clone + this.
    fn run_apply_into(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &mut Tensor,
        acc: &Tensor,
        args: &ApplyArgs,
    ) -> Result<()> {
        let spec = self.spec(prep)?;
        let bf16 = match spec.as_ref() {
            RefExec::Apply { bf16 } => *bf16,
            _ => return Err(anyhow!("{} is not an apply executable", prep.key)),
        };
        Self::check_model_vectors(meta, params, Some(acc))?;
        let ApplyArgs { seed, denom, lr, noise_mult } = *args;
        if !denom.is_finite() || denom <= 0.0 {
            return Err(anyhow!("apply denom must be positive, got {denom}"));
        }
        let out = params.as_mut_slice();
        if noise_mult != 0.0 {
            let mut pooled = PooledScratch::take(&self.scratch);
            let noise = pooled.get().noise(out.len());
            let mut rng = ChaChaRng::from_seed_stream(seed, 0, b"applynse");
            rng.fill_normals(noise);
            for ((pj, &aj), &z) in out.iter_mut().zip(acc.as_slice()).zip(noise.iter()) {
                *pj -= lr * (aj + noise_mult * z) / denom;
            }
        } else {
            for (pj, &aj) in out.iter_mut().zip(acc.as_slice()) {
                *pj -= lr * aj / denom;
            }
        }
        if bf16 {
            // bf16 storage, f32 compute: the update above ran in f32;
            // round-to-nearest-even back onto the bf16 grid on store
            // (DESIGN.md §14). Quantizing after the full loop is
            // elementwise, so it commutes with any update order.
            quantize_bf16(out);
        }
        Ok(())
    }

    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let spec = self.spec(prep)?;
        let (batch, plan) = match spec.as_ref() {
            RefExec::Eval { batch, plan } => (*batch, plan),
            _ => return Err(anyhow!("{} is not an eval executable", prep.key)),
        };
        if y.len() != batch {
            return Err(anyhow!("eval batch must be exactly {batch}, got {}", y.len()));
        }
        if plan.n_params != meta.n_params {
            return Err(anyhow!(
                "executable {} was prepared for a {}-param model, got {}",
                prep.key,
                plan.n_params,
                meta.n_params
            ));
        }
        Self::check_model_vectors(meta, params, None)?;
        Self::check_batch(meta, x, y)?;
        let d = plan.input_dim;
        let ncls = plan.num_classes;
        let p = params.as_slice();
        // Ping-pong activation buffers over the layered forward. `ext`
        // is throwaway room for forward-only intermediates (layernorm
        // xhat/rstd, attention q/k/v/probs/ctx): eval reuses the exact
        // accum forward kernel so accum loss == eval loss bitwise.
        let mut cur = vec![0.0f32; plan.max_width];
        let mut nxt = vec![0.0f32; plan.max_width];
        let mut ext = vec![0.0f32; plan.eval_scratch];
        let mut loss_sum = 0.0f32;
        let mut ncorrect = 0.0f32;
        for (i, &yi) in y.iter().enumerate() {
            let xi = &x[i * d..(i + 1) * d];
            for (l, pl) in plan.layers.iter().enumerate() {
                let (d_in, d_out) = (pl.spec.d_in, pl.spec.d_out);
                let a_in: &[f32] = if l == 0 { xi } else { &cur[..d_in] };
                let out = &mut nxt[..d_out];
                layer_forward(self.kernel, pl, p, a_in, out, &mut ext[..tape_extras(&pl.spec)]);
                std::mem::swap(&mut cur, &mut nxt);
            }
            let lg = &cur[..ncls];
            loss_sum += logsumexp(lg) - lg[yi as usize];
            let mut best = 0usize;
            for (j, &v) in lg.iter().enumerate() {
                if v > lg[best] {
                    best = j;
                }
            }
            if best == yi as usize {
                ncorrect += 1.0;
            }
        }
        Ok((loss_sum, ncorrect))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ReferenceBackend, ModelMeta) {
        let backend = ReferenceBackend::new(0);
        let manifest = ReferenceBackend::manifest(0);
        let meta = manifest.models[REFERENCE_MODEL].clone();
        (backend, meta)
    }

    fn mlp_meta() -> ModelMeta {
        ReferenceBackend::manifest(0).models["mlp-small"].clone()
    }

    fn model_meta(name: &str) -> ModelMeta {
        ReferenceBackend::manifest(0).models[name].clone()
    }

    /// One model per layer-kind shape: the seed single-dense, the MLP,
    /// the conv stack, and the attention+layernorm stack.
    fn kind_ladder() -> Vec<ModelMeta> {
        ["ref-linear", "mlp-small", "cnn-small", "attn-tiny"].into_iter().map(model_meta).collect()
    }

    fn prepare_accum(
        b: &ReferenceBackend,
        meta: &ModelMeta,
        variant: &str,
        batch: usize,
    ) -> Prepared {
        let exe = meta.find_accum(variant, batch, "f32").expect("lowered").clone();
        b.prepare(Path::new("."), meta, &exe).unwrap()
    }

    fn batch_of(meta: &ModelMeta, n: usize) -> (Vec<f32>, Vec<i32>) {
        let d = image_dim(meta);
        let mut rng = ChaChaRng::from_seed_stream(7, 1, b"testdata");
        let x: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let y: Vec<i32> = (0..n).map(|i| (i % meta.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifest_is_complete() {
        let m = ReferenceBackend::manifest(0);
        // The whole CPU ladder is lowered, not just the seed model —
        // including the non-dense rungs.
        for name in ["ref-linear", "mlp-small", "mlp-wide", "cnn-small", "attn-tiny"] {
            let meta = m.model(name).unwrap();
            assert!(meta.find_apply().is_some(), "{name}");
            assert_eq!(meta.find_eval().and_then(|e| e.batch), Some(32), "{name}");
            assert_eq!(
                meta.accum_batches("masked", "f32"),
                vec![1, 2, 4, 8, 16, 32, 64],
                "{name}"
            );
            // Both parameter dtypes are lowered (the bf16 rows are what
            // the measured precision figures consume), and the default
            // dtype-less apply lookup still lands on the f32 step.
            assert_eq!(
                meta.accum_batches("ghost", "bf16"),
                vec![1, 2, 4, 8, 16, 32, 64],
                "{name}"
            );
            assert_eq!(meta.find_apply().and_then(|e| e.dtype.clone()), None, "{name}");
            assert!(
                meta.executables
                    .iter()
                    .any(|e| e.kind == "apply" && e.dtype.as_deref() == Some("bf16")),
                "{name}: bf16 apply lowered"
            );
            let variants = meta.variants();
            for v in ACCUM_VARIANTS {
                assert!(variants.contains(&v.to_string()), "{name} missing {v}");
            }
            assert!(!meta.layers.is_empty(), "{name}: manifest carries the layer IR");
            LayerPlan::build(meta).unwrap();
        }
        let lin = m.model(REFERENCE_MODEL).unwrap();
        assert_eq!(lin.n_params, 10 * 16 * 16 * 3 + 10);
        let mlp = m.model("mlp-small").unwrap();
        assert_eq!(mlp.layers.len(), 3);
    }

    #[test]
    fn init_params_deterministic_and_nondegenerate() {
        for meta in kind_ladder() {
            let b = ReferenceBackend::new(0);
            let p1 = b.init_params(Path::new("."), &meta).unwrap();
            let p2 = b.init_params(Path::new("."), &meta).unwrap();
            assert_eq!(p1, p2);
            assert_eq!(p1.len(), meta.n_params);
            let nonzero = p1.as_slice().iter().filter(|v| **v != 0.0).count();
            assert!(nonzero > meta.n_params / 2);
            let other = ReferenceBackend::new(1).init_params(Path::new("."), &meta).unwrap();
            assert_ne!(p1, other);
            // The bias block at each layer's b_off lands zeroed — its
            // length is kind-shaped (dense d_out, conv c_out, layernorm
            // beta, attention bq) — and layernorm gamma lands all-ones.
            let plan = LayerPlan::build(&meta).unwrap();
            for pl in &plan.layers {
                let b_len = match pl.spec.kind {
                    LayerKind::Dense | LayerKind::LayerNorm => pl.spec.d_out,
                    LayerKind::Conv2d { c_out, .. } => c_out,
                    LayerKind::Attention { d_head, .. } => d_head,
                };
                assert!(p1.as_slice()[pl.b_off..pl.b_off + b_len].iter().all(|v| *v == 0.0));
                if pl.spec.kind == LayerKind::LayerNorm {
                    assert!(p1.as_slice()[pl.w_off..pl.w_off + pl.spec.d_out]
                        .iter()
                        .all(|v| *v == 1.0));
                }
            }
        }
    }

    #[test]
    fn masked_examples_contribute_nothing() {
        for meta in kind_ladder() {
            let b = ReferenceBackend::new(0);
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let acc = Tensor::zeros(meta.n_params);
            let d = image_dim(&meta);
            let (x, y) = batch_of(&meta, 4);
            // Batch of 4 with the last two slots masked out (Alg. 2
            // padding) must equal the same two live examples at batch 2.
            let prep4 = prepare_accum(&b, &meta, "masked", 4);
            let padded = b
                .run_accum(
                    &prep4,
                    &meta,
                    &params,
                    &acc,
                    &AccumArgs { x: &x, y: &y, mask: &[1.0, 1.0, 0.0, 0.0] },
                )
                .unwrap();
            let prep2 = prepare_accum(&b, &meta, "masked", 2);
            let live = b
                .run_accum(
                    &prep2,
                    &meta,
                    &params,
                    &acc,
                    &AccumArgs { x: &x[..2 * d], y: &y[..2], mask: &[1.0, 1.0] },
                )
                .unwrap();
            assert_eq!(padded.acc, live.acc);
            assert_eq!(padded.loss_sum, live.loss_sum);
            // All-masked batch: accumulator unchanged, loss zero.
            let none = b
                .run_accum(
                    &prep4,
                    &meta,
                    &params,
                    &acc,
                    &AccumArgs { x: &x, y: &y, mask: &[0.0; 4] },
                )
                .unwrap();
            assert_eq!(none.acc, acc);
            assert_eq!(none.loss_sum, 0.0);
            // Norms are still reported for every slot (B of them).
            assert_eq!(none.sq_norms.len(), 4);
        }
    }

    #[test]
    fn clipped_accumulator_norm_bounded_by_batch_times_clip() {
        for meta in kind_ladder() {
            let b = ReferenceBackend::new(0);
            let prep = prepare_accum(&b, &meta, "masked", 8);
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let acc = Tensor::zeros(meta.n_params);
            let (x, y) = batch_of(&meta, 8);
            let out = b
                .run_accum(
                    &prep,
                    &meta,
                    &params,
                    &acc,
                    &AccumArgs { x: &x, y: &y, mask: &[1.0; 8] },
                )
                .unwrap();
            let norm: f32 = out.acc.as_slice().iter().map(|v| v * v).sum::<f32>().sqrt();
            // Triangle inequality: ||sum of clipped grads|| <= B * C.
            assert!(norm <= 8.0 * meta.clip_norm as f32 + 1e-4, "norm {norm}");
            assert!(out.loss_sum > 0.0);
            assert!(out.sq_norms.iter().all(|s| *s >= 0.0 && s.is_finite()));
        }
    }

    #[test]
    fn nonprivate_reports_zero_norms_and_skips_clipping() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "nonprivate", 2);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 2);
        let out = b
            .run_accum(&prep, &meta, &params, &acc, &AccumArgs { x: &x, y: &y, mask: &[1.0, 1.0] })
            .unwrap();
        assert_eq!(out.sq_norms, vec![0.0, 0.0]);
        let norm: f32 = out.acc.as_slice().iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm > 0.0);
    }

    #[test]
    fn ghost_and_materializing_per_example_paths_agree_bitwise() {
        // The ghost (fused) and perex (materialized) branches execute
        // different accumulate code but must land on identical bits —
        // norms *and* accumulator — on every model. The generated-stack
        // proptest lives in rust/tests/layered_models.rs; this is the
        // fast in-module spot check.
        for meta in kind_ladder() {
            let b = ReferenceBackend::new(0);
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let acc = Tensor::zeros(meta.n_params);
            let (x, y) = batch_of(&meta, 4);
            let args = AccumArgs { x: &x, y: &y, mask: &[1.0, 0.0, 1.0, 1.0] };
            let mut outs = Vec::new();
            for variant in ["masked", "ghost", "perex", "mix", "bk"] {
                let prep = prepare_accum(&b, &meta, variant, 4);
                outs.push((variant, b.run_accum(&prep, &meta, &params, &acc, &args).unwrap()));
            }
            let (_, first) = &outs[0];
            for (variant, o) in &outs[1..] {
                assert_eq!(first.acc, o.acc, "{variant}: acc diverged");
                assert_eq!(first.sq_norms, o.sq_norms, "{variant}: norms diverged");
                assert_eq!(first.loss_sum.to_bits(), o.loss_sum.to_bits(), "{variant}");
            }
        }
    }

    #[test]
    fn multi_layer_gradient_reaches_every_layer() {
        // The backward pass must put gradient mass in every parameter
        // block of every layer — per kind: dense/conv weight + bias,
        // layernorm gamma + beta, and all eight attention sub-blocks
        // (Wq/bq/Wk/bk/Wv/bv/Wo/bo). Catches a dropped phase-2 unit or
        // a dz-extras slot phase 2 never folds.
        for meta in [mlp_meta(), model_meta("cnn-small"), model_meta("attn-tiny")] {
            let b = ReferenceBackend::new(0);
            let plan = LayerPlan::build(&meta).unwrap();
            let prep = prepare_accum(&b, &meta, "masked", 8);
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let acc = Tensor::zeros(meta.n_params);
            let (x, y) = batch_of(&meta, 8);
            let out = b
                .run_accum(
                    &prep,
                    &meta,
                    &params,
                    &acc,
                    &AccumArgs { x: &x, y: &y, mask: &[1.0; 8] },
                )
                .unwrap();
            let g = out.acc.as_slice();
            for (l, pl) in plan.layers.iter().enumerate() {
                // (label, offset, len) per parameter sub-block.
                let blocks: Vec<(&str, usize, usize)> = match pl.spec.kind {
                    LayerKind::Dense => vec![
                        ("W", pl.w_off, pl.spec.d_in * pl.spec.d_out),
                        ("b", pl.b_off, pl.spec.d_out),
                    ],
                    LayerKind::Conv2d { c_in, c_out, kh, kw, .. } => vec![
                        ("K", pl.w_off, c_out * c_in * kh * kw),
                        ("b", pl.b_off, c_out),
                    ],
                    LayerKind::LayerNorm => vec![
                        ("gamma", pl.w_off, pl.spec.d_out),
                        ("beta", pl.b_off, pl.spec.d_out),
                    ],
                    LayerKind::Attention { d_model, d_head, .. } => {
                        let (wlen, step) = (d_head * d_model, d_head * d_model + d_head);
                        vec![
                            ("Wq", pl.w_off, wlen),
                            ("bq", pl.w_off + wlen, d_head),
                            ("Wk", pl.w_off + step, wlen),
                            ("bk", pl.w_off + step + wlen, d_head),
                            ("Wv", pl.w_off + 2 * step, wlen),
                            ("bv", pl.w_off + 2 * step + wlen, d_head),
                            ("Wo", pl.w_off + 3 * step, d_model * d_head),
                            ("bo", pl.w_off + 3 * step + d_model * d_head, d_model),
                        ]
                    }
                };
                for (label, off, len) in blocks {
                    assert!(
                        g[off..off + len].iter().any(|v| *v != 0.0),
                        "{}: layer {l} block {label} got no gradient",
                        meta.init_params
                    );
                }
            }
        }
    }

    #[test]
    fn accum_loss_equals_eval_loss_bitwise() {
        // The accum head and the eval forward share their arithmetic:
        // with an all-ones mask the masked loss sum must equal the eval
        // loss sum bit for bit, on every model.
        for meta in kind_ladder() {
            let b = ReferenceBackend::new(0);
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let acc = Tensor::zeros(meta.n_params);
            let (x, y) = batch_of(&meta, EVAL_BATCH);
            let prep = prepare_accum(&b, &meta, "masked", EVAL_BATCH);
            let out = b
                .run_accum(
                    &prep,
                    &meta,
                    &params,
                    &acc,
                    &AccumArgs { x: &x, y: &y, mask: &[1.0; EVAL_BATCH] },
                )
                .unwrap();
            let eval_exe = meta.find_eval().unwrap().clone();
            let eval_prep = b.prepare(Path::new("."), &meta, &eval_exe).unwrap();
            let (loss, _) = b.run_eval(&eval_prep, &meta, &params, &x, &y).unwrap();
            assert_eq!(out.loss_sum.to_bits(), loss.to_bits());
        }
    }

    #[test]
    fn donated_accum_matches_copying_accum_bitwise() {
        let (b, meta) = setup();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = batch_of(&meta, 8);
        let mut acc_init = Tensor::zeros(meta.n_params);
        acc_init.as_mut_slice()[3] = 0.25;
        for variant in ["masked", "nonprivate", "ghost", "perex", "mix"] {
            let prep = prepare_accum(&b, &meta, variant, 8);
            let mask = [1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0, 1.0];
            let args = AccumArgs { x: &x, y: &y, mask: &mask };
            let copied = b.run_accum(&prep, &meta, &params, &acc_init, &args).unwrap();
            let mut donated = acc_init.clone();
            let stats = b
                .run_accum_into(&prep, &meta, &params, &mut donated, &args)
                .unwrap();
            assert_eq!(copied.acc, donated, "{variant}: acc diverged");
            assert_eq!(copied.loss_sum.to_bits(), stats.loss_sum.to_bits());
            assert_eq!(copied.sq_norms, stats.sq_norms);
        }
    }

    #[test]
    fn thread_count_never_changes_the_bits() {
        // The determinism contract: outputs are a pure function of the
        // inputs, not of the parallelism. Exercise a batch above the
        // threading gate with every thread count 1..=4, on both the
        // single-layer model and every multi-layer kind.
        for meta in kind_ladder() {
            let (x, y) = batch_of(&meta, 32);
            let mut mask = vec![1.0f32; 32];
            mask[7] = 0.0;
            mask[31] = 0.0;
            let mut reference_out: Option<AccumOut> = None;
            for threads in 1..=4 {
                let b = ReferenceBackend::with_threads(0, threads);
                let prep = prepare_accum(&b, &meta, "mix", 32);
                let params = b.init_params(Path::new("."), &meta).unwrap();
                let acc = Tensor::zeros(meta.n_params);
                let out = b
                    .run_accum(
                        &prep,
                        &meta,
                        &params,
                        &acc,
                        &AccumArgs { x: &x, y: &y, mask: &mask },
                    )
                    .unwrap();
                if let Some(want) = &reference_out {
                    assert_eq!(want.acc, out.acc, "threads={threads}: acc diverged");
                    assert_eq!(want.loss_sum.to_bits(), out.loss_sum.to_bits());
                    assert_eq!(want.sq_norms, out.sq_norms);
                } else {
                    reference_out = Some(out);
                }
            }
        }
    }

    #[test]
    fn kernel_choice_never_changes_the_bits() {
        // The DESIGN.md §14 contract, spot-checked in-module on every
        // layer kind: the scalar path and the auto-detected SIMD path
        // produce identical accumulators, losses, and norms. (The full
        // trajectory-level proptests live in
        // rust/tests/kernel_bitwise.rs.)
        for meta in kind_ladder() {
            let (x, y) = batch_of(&meta, 8);
            let mask = [1.0, 0.0, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0];
            let mut want: Option<AccumOut> = None;
            for kernel in [Kernel::Scalar, Kernel::auto()] {
                let b = ReferenceBackend::with_options(0, 0, kernel);
                let prep = prepare_accum(&b, &meta, "mix", 8);
                let params = b.init_params(Path::new("."), &meta).unwrap();
                let acc = Tensor::zeros(meta.n_params);
                let out = b
                    .run_accum(
                        &prep,
                        &meta,
                        &params,
                        &acc,
                        &AccumArgs { x: &x, y: &y, mask: &mask },
                    )
                    .unwrap();
                if let Some(w) = &want {
                    assert_eq!(w.acc, out.acc, "{kernel:?}: acc diverged");
                    assert_eq!(w.loss_sum.to_bits(), out.loss_sum.to_bits(), "{kernel:?}");
                    assert_eq!(w.sq_norms, out.sq_norms, "{kernel:?}");
                } else {
                    want = Some(out);
                }
            }
        }
    }

    #[test]
    fn bf16_apply_quantizes_parameter_storage() {
        let (b, meta) = setup();
        let bf16_exe = meta
            .executables
            .iter()
            .find(|e| e.kind == "apply" && e.dtype.as_deref() == Some("bf16"))
            .unwrap()
            .clone();
        let prep = b.prepare(Path::new("."), &meta, &bf16_exe).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        acc.as_mut_slice()[0] = 2.0;
        let args = ApplyArgs { seed: 42, denom: 4.0, lr: 0.1, noise_mult: 1.0 };
        let out = b.run_apply(&prep, &meta, &params, &acc, &args).unwrap();
        // Every stored value sits on the bf16 grid...
        assert!(out.as_slice().iter().all(|v| v.to_bits() & 0xffff == 0));
        // ...and equals the f32 step rounded onto it (bf16 storage,
        // f32 compute — never bf16 arithmetic).
        let f32_exe = meta.find_apply().unwrap().clone();
        let f32_prep = b.prepare(Path::new("."), &meta, &f32_exe).unwrap();
        let f32_out = b.run_apply(&f32_prep, &meta, &params, &acc, &args).unwrap();
        let mut rounded = f32_out.clone();
        rounded.quantize_bf16();
        assert_eq!(out, rounded);
    }

    #[test]
    fn apply_without_noise_is_plain_sgd_and_with_noise_is_seeded() {
        let (b, meta) = setup();
        let apply_meta = meta.find_apply().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        acc.as_mut_slice()[0] = 2.0;
        let plain = ApplyArgs { seed: 42, denom: 4.0, lr: 0.1, noise_mult: 0.0 };
        let out = b.run_apply(&prep, &meta, &params, &acc, &plain).unwrap();
        let want = params.as_slice()[0] - 0.1 * 2.0 / 4.0;
        assert!((out.as_slice()[0] - want).abs() < 1e-7);
        assert_eq!(out.as_slice()[1], params.as_slice()[1]);
        // Noise: deterministic per seed, different across seeds.
        let noisy = |seed| ApplyArgs { seed, denom: 4.0, lr: 0.1, noise_mult: 1.0 };
        let n1 = b.run_apply(&prep, &meta, &params, &acc, &noisy(7)).unwrap();
        let n2 = b.run_apply(&prep, &meta, &params, &acc, &noisy(7)).unwrap();
        let n3 = b.run_apply(&prep, &meta, &params, &acc, &noisy(8)).unwrap();
        assert_eq!(n1, n2);
        assert_ne!(n1, n3);
        assert_ne!(n1, out);
    }

    #[test]
    fn donated_apply_matches_copying_apply_bitwise() {
        let (b, meta) = setup();
        let apply_meta = meta.find_apply().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        acc.as_mut_slice()[5] = -1.5;
        for noise_mult in [0.0f32, 1.3] {
            let args = ApplyArgs { seed: 99, denom: 8.0, lr: 0.2, noise_mult };
            let copied = b.run_apply(&prep, &meta, &params, &acc, &args).unwrap();
            let mut donated = params.clone();
            b.run_apply_into(&prep, &meta, &mut donated, &acc, &args).unwrap();
            assert_eq!(copied, donated, "noise_mult={noise_mult}");
        }
    }

    #[test]
    fn session_binds_buffers_to_the_in_place_kernels() {
        // The default session over the reference backend must follow the
        // exact legacy call sequence bitwise: two accums, an apply, a
        // zero_acc, another accum — on the multi-layer model.
        let b = ReferenceBackend::new(0);
        let meta = mlp_meta();
        let prep = prepare_accum(&b, &meta, "ghost", 8);
        let apply_meta = meta.find_apply().unwrap().clone();
        let apply_prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = batch_of(&meta, 8);
        let mask = [1.0, 0.0, 1.0, 1.0, 1.0, 1.0, 0.0, 1.0];
        let args = AccumArgs { x: &x, y: &y, mask: &mask };
        let apply = ApplyArgs { seed: 11, denom: 6.0, lr: 0.1, noise_mult: 1.0 };

        let mut sess = b.open_session(Path::new("."), &meta, params.clone()).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        let mut p = params.clone();
        for _ in 0..2 {
            let s = sess.accum(&prep, &args).unwrap();
            let l = b.run_accum_into(&prep, &meta, &p, &mut acc, &args).unwrap();
            assert_eq!(s.loss_sum.to_bits(), l.loss_sum.to_bits());
        }
        sess.apply(&apply_prep, &apply).unwrap();
        b.run_apply_into(&apply_prep, &meta, &mut p, &acc, &apply).unwrap();
        assert_eq!(sess.read_params().unwrap(), p);

        sess.zero_acc().unwrap();
        acc.fill(0.0);
        let s = sess.accum(&prep, &args).unwrap();
        let l = b.run_accum_into(&prep, &meta, &p, &mut acc, &args).unwrap();
        assert_eq!(s.loss_sum.to_bits(), l.loss_sum.to_bits());
        assert_eq!(s.sq_norms, l.sq_norms);
    }

    #[test]
    fn eval_counts_and_losses_are_sane() {
        for meta in kind_ladder() {
            let b = ReferenceBackend::new(0);
            let eval_meta = meta.find_eval().unwrap().clone();
            let prep = b.prepare(Path::new("."), &meta, &eval_meta).unwrap();
            let params = b.init_params(Path::new("."), &meta).unwrap();
            let (x, y) = batch_of(&meta, 32);
            let (loss, ncorrect) = b.run_eval(&prep, &meta, &params, &x, &y).unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            assert!((0.0..=32.0).contains(&ncorrect));
            // Wrong batch size is a clean error.
            let (x2, y2) = batch_of(&meta, 8);
            assert!(b.run_eval(&prep, &meta, &params, &x2, &y2).is_err());
        }
    }

    #[test]
    fn prepare_caches_and_reports_compiles_once() {
        let (b, meta) = setup();
        let exe = meta.find_accum("masked", 8, "f32").unwrap().clone();
        let p1 = b.prepare(Path::new("."), &meta, &exe).unwrap();
        assert!(p1.compile_seconds.is_some());
        assert!(b.is_compiled(&p1.key));
        let p2 = b.prepare(Path::new("."), &meta, &exe).unwrap();
        assert!(p2.compile_seconds.is_none(), "second prepare must be a cache hit");
        assert_eq!(b.compile_records().len(), 1);
    }

    #[test]
    fn out_of_range_label_is_an_error() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "masked", 1);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let d = image_dim(&meta);
        let x = vec![0.0f32; d];
        let too_big = AccumArgs { x: &x, y: &[99], mask: &[1.0] };
        assert!(b.run_accum(&prep, &meta, &params, &acc, &too_big).is_err());
        let negative = AccumArgs { x: &x, y: &[-1], mask: &[1.0] };
        assert!(b.run_accum(&prep, &meta, &params, &acc, &negative).is_err());
    }

    #[test]
    fn backend_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ReferenceBackend>();
    }
}
